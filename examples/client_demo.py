"""Futures client demo: ONE front door for all three schedulers, dynamic
DAGs, failure poisoning, cancel, a crash drill, and the serving layer.

    PYTHONPATH=src python examples/client_demo.py
"""
from repro.client import Client, DependencyFailed, as_completed
from repro.core.engine import FaultPlan

N = 200


def main():
    # ---- the one snippet, unmodified, for every scheduler --------------
    for s in ("dwork", "pmake", "mpi_list"):
        with Client(scheduler=s, workers=4) as c:
            fs = [c.submit(lambda x=x: x * x) for x in range(N)]
            vals = c.gather(fs)
            assert vals == [x * x for x in range(N)]
            ov = c.report()
            print(f"{s:8s}: {ov.n_tasks} futures, "
                  f"{ov.per_task_overhead_s * 1e6:.1f}us/future overhead")

    # ---- dynamic DAG: futures as dependencies, built on the fly --------
    with Client(workers=4) as c:
        shards = [c.submit(lambda i=i: list(range(i * 10, (i + 1) * 10)))
                  for i in range(8)]
        counts = [c.submit(len, s) for s in shards]        # future-as-arg
        total = c.submit(lambda *cs: sum(cs), *counts)     # fan-in
        assert total.result(30) == 80
        done_order = [f.result() for f in as_completed(counts, timeout=30)]
        print(f"dag     : fan-out 8 -> fan-in, total={total.result()}, "
              f"as_completed saw {len(done_order)} futures")

    # ---- failure poisoning + cancel ------------------------------------
    c = Client(workers=2)
    bad = c.submit(lambda: 1 / 0)
    doomed = c.submit(lambda v: v + 1, bad)       # poisoned downstream
    never = c.submit(lambda: "nope")
    assert never.cancel()                         # not yet stolen: cancelled
    with c:
        try:
            doomed.result(10)
        except DependencyFailed as e:
            print(f"poison  : downstream future observed: {e}")

    # ---- crash drill: seeded worker kill, exactly-once resolution ------
    faults = FaultPlan(seed=7).kill_worker("w2", after_steals=20)
    with Client(workers=4, steal_n=8, faults=faults) as c:
        fs = [c.submit(lambda x=x: x + 1) for x in range(N)]
        assert c.gather(fs) == [x + 1 for x in range(N)]
        ov = c.report()
        print(f"faults  : {len(fs)}/{len(fs)} resolved exactly once, "
              f"requeued={ov.n_requeued} (w2 killed mid-run)")

    # ---- serving: the same client front door ---------------------------
    with Client(workers=2, lease_timeout=30.0) as c:
        fe = c.serve(lambda payloads: [p * 2 for p in payloads],
                     max_wait_s=0.002)
        reqs = [fe.submit(i) for i in range(50)]
        assert all(r.wait(30.0) and r.value == i * 2
                   for i, r in enumerate(reqs))
        report = c.close()
        lat = report.trace.latency_report()
        print(f"serving : {lat.n_requests} requests, "
              f"p95={lat.p95_s * 1e3:.2f}ms over {lat.n_batches} batches")


if __name__ == "__main__":
    main()
