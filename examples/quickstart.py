"""Quickstart: build an assigned architecture (reduced for CPU), run a
forward pass, one training step, and a prefill+decode round.

    PYTHONPATH=src python examples/quickstart.py [arch]
"""
import sys

import jax
import jax.numpy as jnp

from repro.configs import RunConfig, get_config
from repro.models.common import Options, param_count
from repro.models.model import build_model
from repro.optim.adamw import init_opt
from repro.runtime.serve_step import greedy_generate
from repro.runtime.train_step import make_train_step

arch = sys.argv[1] if len(sys.argv) > 1 else "gemma2-2b"
cfg = get_config(arch).reduced()
model = build_model(cfg, Options(q_block=64, kv_block=64, moe_group=64))
params = model.init(jax.random.PRNGKey(0))
print(f"{cfg.name} ({cfg.family}), reduced: {param_count(params):,} params")

B, S = 2, 64
batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (B, S), 2,
                                      cfg.vocab_size)}
if cfg.mrope:
    batch["mrope_positions"] = jnp.broadcast_to(
        jnp.arange(S)[None, None], (3, B, S))
if cfg.family == "audio":
    batch["encoder_frames"] = jnp.zeros(
        (B, cfg.encoder.n_frames, cfg.d_model), jnp.bfloat16)

logits, aux = jax.jit(lambda p, b: model.forward(p, b))(params, batch)
print("forward:", logits.shape, "finite:", bool(jnp.isfinite(logits).all()))

rc = RunConfig(total_steps=10, warmup_steps=1)
batch["labels"] = jnp.roll(batch["tokens"], -1, 1)
step = jax.jit(make_train_step(model, rc))
_, _, metrics = step(params, init_opt(params, rc), batch)
print(f"train step: loss={float(metrics['loss']):.4f} "
      f"grad_norm={float(metrics['grad_norm']):.3f}")

del batch["labels"]
out = greedy_generate(model, params, batch, max_new=8, cache_len=S + 16)
print("generated:", out[0].tolist())
