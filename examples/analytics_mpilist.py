"""mpi-list analytics example — the paper's Fig. 3 workload shape: read a
sharded dataset in parallel, compute summary stats, then a 2D histogram
via map + reduce.  (Paper: 2592 parquet files -> 320 ranks; here: synthetic
shard files -> 8 in-proc ranks.)

    PYTHONPATH=src python examples/analytics_mpilist.py
"""
import tempfile
import time
from pathlib import Path

import numpy as np

from repro.core.mpi_list import Context

# --- make a sharded "docking scores" dataset (score, r3 columns)
workdir = Path(tempfile.mkdtemp(prefix="mpilist_"))
rng = np.random.default_rng(0)
n_files = 24
for i in range(n_files):
    np.savez(workdir / f"part_{i:03d}.npz",
             score=rng.normal(-7.0, 2.0, 5000),
             r3=rng.gamma(2.0, 1.5, 5000))

C = Context(8)
t0 = time.perf_counter()
dfm = (C.iterates(n_files)
       .map(lambda i: dict(np.load(workdir / f"part_{i:03d}.npz"))))
n = dfm.len()
t1 = time.perf_counter()
print(f"Read {n_files} npz files to {C.procs} ranks in {t1-t0:.2f}s")

# summary stats (paper: collected stats to rank 0)
stats = dfm.map(lambda d: {"lo": (d["score"].min(), d["r3"].min()),
                           "hi": (d["score"].max(), d["r3"].max())})
lo = stats.reduce(lambda a, d: (min(a[0], d["lo"][0]), min(a[1], d["lo"][1])),
                  (np.inf, np.inf))
hi = stats.reduce(lambda a, d: (max(a[0], d["hi"][0]), max(a[1], d["hi"][1])),
                  (-np.inf, -np.inf))
t2 = time.perf_counter()
print(f"Collected stats to rank 0 in {t2-t1:.2f}s: lo={lo}, hi={hi}")

# 2D histogram: map each shard to its partial histogram, reduce by sum
edges_s = np.linspace(lo[0], hi[0], 301)
edges_r = np.linspace(lo[1], hi[1], 201)
H = (dfm.map(lambda d: np.histogram2d(d["score"], d["r3"],
                                      bins=(edges_s, edges_r))[0])
     .reduce(np.add, np.zeros((300, 200))))
t3 = time.perf_counter()
print(f"Collected histogram in {t3-t2:.2f}s; total={int(H.sum())} "
      f"(expected {n_files*5000}), straggler gap so far: {C.sync_time*1e3:.2f} ms")
assert int(H.sum()) == n_files * 5000
