"""pmake campaign example (paper Fig. 1 pattern): shard-train -> summarize.

Runs real popen'd training jobs under pmake's EFT scheduler with file-based
restart — re-running this script rebuilds nothing.

    PYTHONPATH=src python examples/train_campaign.py [workdir]
"""
import sys

from repro.launch.campaign import main

workdir = sys.argv[1] if len(sys.argv) > 1 else "/tmp/repro_campaign_example"
main(["--workdir", workdir, "--shards", "2", "--steps", "4",
      "--batch", "2", "--seq", "64", "--nodes", "2"])
print(f"campaign artifacts in {workdir} (rules.yaml, shard_*.jsonl, "
      f"report.json, *.sh, *.log)")
