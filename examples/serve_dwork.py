"""dwork-scheduled batched inference example: generation requests are dwork
tasks; the worker steals METG-sized batches, prefills + decodes, completes.

    PYTHONPATH=src python examples/serve_dwork.py
"""
from repro.launch.serve import main

main(["--arch", "deepseek-7b", "--requests", "6", "--prompt-len", "16",
      "--max-new", "4"])
