"""Continuous-serving inference example: generation requests flow through
the resident engine + METG-batching frontend (`repro.core.serving`) —
bounded admission, dynamic batch sizing, per-request latency percentiles.

    PYTHONPATH=src python examples/serve_dwork.py
"""
from repro.launch.serve import main

main(["--arch", "deepseek-7b", "--requests", "6", "--prompt-len", "16",
      "--max-new", "4"])
