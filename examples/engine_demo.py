"""Unified engine demo: one workload, three schedulers, one substrate.

Runs the same 200-task workload through the dwork pool, pmake, and the
engine-backed mpi-list context; prints measured per-task overhead and the
empirical-vs-analytic METG crosscheck for each, then demonstrates
deterministic fault injection (a worker killed mid-run with zero lost
tasks).

    PYTHONPATH=src python examples/engine_demo.py
"""
import tempfile

from repro.core.dwork import Client, InProcTransport, TaskServer, run_pool
from repro.core.engine import Engine, FaultPlan, crosscheck
from repro.core.metg import METGModel
from repro.core.mpi_list import Context
from repro.core.pmake import PMake

N = 200


def main():
    # ---- dwork: bag of tasks on a TaskServer, engine worker pool -------
    srv = TaskServer()
    boss = Client(InProcTransport(srv), "boss")
    for i in range(N):
        boss.create(f"sq{i}", meta={"x": i})
    rep = run_pool(srv, lambda name, meta: (True, meta["x"] ** 2),
                   workers=4, steal_n=4)
    ov = rep.overhead()
    model = METGModel.from_measured(rtt_s=ov.rpc_per_task_s)
    print("dwork   :", ov.summary())
    print("          crosscheck:",
          crosscheck("dwork", ov.per_task_overhead_s, model.dwork_metg(4)))

    # ---- pmake: file-based rules, engine pool with EFT priority --------
    rules = ('sq:\n  resources: {time: 1, nrs: 1}\n'
             '  out: {o: "sq_{n}.out"}\n  script: "echo {n}"\n')
    targets = (f'all:\n  dirname: .\n  loop:\n    n: "range({N})"\n'
               '  tgt: {o: "sq_{n}.out"}\n')
    pm = PMake(rules, targets, root=tempfile.mkdtemp(), total_nodes=4,
               transport="inproc", runner=lambda t: True)
    stats = pm.run()
    print("pmake   :", stats, pm.report.overhead().summary())

    # ---- mpi-list: engine-backed supersteps + seeded stragglers --------
    C = Context(16, engine_workers=4, straggler_sigma=1e-3, seed=0)
    out = C.scatter(list(range(N))).map(lambda x: x ** 2).collect()
    assert out == [i ** 2 for i in range(N)]
    print("mpi-list: mean sync gap %.3f ms," % (1e3 * C.gaps[0]),
          "crosscheck:", C.straggler_crosscheck())

    # ---- fault injection: kill a worker mid-run, zero lost tasks -------
    eng = Engine(workers=4, transport="inproc", steal_n=8,
                 faults=FaultPlan(seed=7).kill_worker("w2", after_steals=20))
    for i in range(N):
        eng.submit(f"t{i}", fn=lambda: None)
    rep = eng.run()
    print("faults  : completed=%d/%d requeued=%d (w2 killed mid-run)"
          % (len(rep.completed), N, rep.overhead().n_requeued))


if __name__ == "__main__":
    main()
