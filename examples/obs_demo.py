"""Observability demo: a resident client under mixed load with the live
stats endpoint up, scraped while the engine runs, and the whole session
exported as a Perfetto-loadable Chrome trace + a critical-path explain
report at exit.

    PYTHONPATH=src python examples/obs_demo.py
    PYTHONPATH=src python examples/obs_demo.py --port 8787   # then, elsewhere:
    PYTHONPATH=src python -m repro.core.obs.top --url http://127.0.0.1:8787

CI runs this with --stats-out/--trace-out/--explain-out and uploads the
files as workflow artifacts, so every run leaves an inspectable timeline
AND its explanation (which chain of tasks gated the makespan, scheduler
vs compute split).
"""
import argparse
import json
import time
import urllib.request

from repro.client import Client


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=200,
                    help="serving requests to push through the frontend")
    ap.add_argument("--futures", type=int, default=300,
                    help="plain futures to submit alongside")
    ap.add_argument("--workers", type=int, default=4)
    ap.add_argument("--port", type=int, default=0,
                    help="stats port (0 = ephemeral)")
    ap.add_argument("--stats-out", default=None,
                    help="write the final /stats JSON here")
    ap.add_argument("--trace-out", default=None,
                    help="write the Chrome trace (.trace.json) here")
    ap.add_argument("--explain-out", default=None,
                    help="write the critical-path explain report here")
    args = ap.parse_args(argv)

    with Client(scheduler="dwork", workers=args.workers, shards=2) as c:
        srv = c.stats_server(port=args.port)
        print(f"live stats at {srv.url}/stats  (/health, /metrics; "
              f"dashboard: python -m repro.core.obs.top --url {srv.url})")

        # mixed load: plain futures + a serving frontend, concurrently;
        # requests alternate tenant labels so the per-tenant slices show
        # up in /stats and the tenant-labelled latency histograms
        fe = c.serve(lambda ps: [p * 2 for p in ps], max_wait_s=0.002)
        fe.snapshot()                    # arm windowed tenant monitoring
        fs = [c.submit(lambda x=x: x * x) for x in range(args.futures)]
        reqs = [fe.submit(i, tenant=("blue" if i % 2 else "green"))
                for i in range(args.requests)]

        # scrape mid-flight: the engine keeps running under the GET
        time.sleep(0.05)
        mid = json.loads(urllib.request.urlopen(
            srv.url + "/stats", timeout=5).read())
        print(f"mid-run : {mid['rates']['tasks_per_s']:.0f} tasks/s over a "
              f"{mid['rates']['window_s'] * 1e3:.0f}ms window, "
              f"{len(mid['workers'])} workers, "
              f"ready depth {mid['engine']['ready_depth']}")

        assert c.gather(fs) == [x * x for x in range(args.futures)]
        assert all(r.wait(30.0) and r.value == i * 2
                   for i, r in enumerate(reqs))

        # final scrape + the Prometheus view of the same registry
        stats = json.loads(urllib.request.urlopen(
            srv.url + "/stats", timeout=5).read())
        prom = urllib.request.urlopen(
            srv.url + "/metrics", timeout=5).read().decode()
        done = stats["engine"]["tasks_done"]
        print(f"final   : {done} tasks done, "
              f"{stats['engine']['trace']['n_emitted']} trace events, "
              f"{sum(1 for ln in prom.splitlines() if ln and not ln.startswith('#'))} "
              f"prometheus samples")
        if args.stats_out:
            with open(args.stats_out, "w") as f:
                json.dump(stats, f, indent=1, default=str)
            print(f"wrote {args.stats_out}")

        # per-tenant accounting from the trace (the windowed /stats
        # slices cover scrape-to-scrape; this is the whole session)
        by_t = c.engine.tracer.latency_report().by_tenant or {}
        print("tenants : " + ", ".join(
            f"{t}: {r.n_requests} req p95 {r.p95_s * 1e3:.2f}ms"
            for t, r in sorted(by_t.items())))

        # the critical-path explanation of the session so far: which
        # chain gated the makespan, scheduler vs compute split
        cp = c.report().explain()
        print(f"explain : {len(cp.path)} tasks gate the "
              f"{cp.makespan_s * 1e3:.1f}ms makespan "
              f"(scheduler {cp.sched_frac:.0%}, "
              f"concurrency mean {cp.concurrency_mean:.2f} "
              f"peak {cp.concurrency_peak})")
        if args.explain_out:
            from repro.core.obs.explain import render
            with open(args.explain_out, "w") as f:
                f.write(render(cp) + "\n")
            print(f"wrote {args.explain_out}")

        report = c.close()
    if args.trace_out:
        report.trace.to_chrome_trace(
            args.trace_out, critical_path=cp.path)
        print(f"wrote {args.trace_out} (open in https://ui.perfetto.dev — "
              f"the 'critical path' lane is the makespan chain)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
