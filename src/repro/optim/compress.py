"""Gradient compression for cross-pod all-reduce: int8 block quantization
with error feedback.

On a 2-pod mesh the gradient reduction crosses the (slow) pod interconnect;
compressing the cross-pod leg 4x (fp32 -> int8 + per-block scales) is the
classic distributed-optimization trick.  Error feedback accumulates the
quantization residual locally and re-injects it next step, which restores
convergence to the uncompressed trajectory (Seide et al.; Karimireddy et
al.).  `compressed_grads` is dry-run friendly: the quantize/dequantize pair
materializes the int8 tensors in HLO, so the collective analysis sees the
4x-smaller reduce operands when applied under shard_map.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

BLOCK = 256


def quantize_int8(x: jnp.ndarray):
    """Per-block symmetric int8 quantization. Returns (q, scales)."""
    flat = x.reshape(-1)
    pad = (-flat.size) % BLOCK
    if pad:
        flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, BLOCK).astype(jnp.float32)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q, scale, shape):
    flat = (q.astype(jnp.float32) * scale).reshape(-1)
    n = 1
    for d in shape:
        n *= d
    return flat[:n].reshape(shape)


def compress_roundtrip(x):
    q, s = quantize_int8(x)
    return dequantize_int8(q, s, x.shape)


def compressed_grads(grads, error_state):
    """Apply int8 compression with error feedback to a gradient pytree.

    Returns (decompressed_grads, new_error_state).  The all-reduce itself is
    implicit in the surrounding pjit; under shard_map the q/scale tensors
    are what crosses the network.
    """
    if error_state is None:
        error_state = jax.tree_util.tree_map(
            lambda g: jnp.zeros_like(g, jnp.float32), grads)

    def one(g, e):
        corrected = g.astype(jnp.float32) + e
        out = compress_roundtrip(corrected)
        new_e = corrected - out
        return out.astype(g.dtype), new_e

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_e = treedef.flatten_up_to(error_state)
    outs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (jax.tree_util.tree_unflatten(treedef, [o[0] for o in outs]),
            jax.tree_util.tree_unflatten(treedef, [o[1] for o in outs]))
