"""AdamW from scratch (no optax): global-norm clipping, decoupled weight
decay, warmup+cosine schedule, optional reduced-precision moments
(quantized-optimizer memory trick for the 480B-on-one-pod case)."""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class OptState(NamedTuple):
    count: jnp.ndarray
    m: dict
    v: dict


def init_opt(params, rc) -> OptState:
    dt = jnp.dtype(rc.adam_state_dtype)
    zeros = lambda x: jnp.zeros(x.shape, dt)
    return OptState(count=jnp.zeros((), jnp.int32),
                    m=jax.tree_util.tree_map(zeros, params),
                    v=jax.tree_util.tree_map(zeros, params))


def abstract_opt(abstract_params, rc) -> OptState:
    dt = jnp.dtype(rc.adam_state_dtype)
    z = lambda x: jax.ShapeDtypeStruct(x.shape, dt)
    return OptState(count=jax.ShapeDtypeStruct((), jnp.int32),
                    m=jax.tree_util.tree_map(z, abstract_params),
                    v=jax.tree_util.tree_map(z, abstract_params))


def lr_schedule(step, rc):
    step = step.astype(jnp.float32)
    warm = rc.lr * (step + 1.0) / max(rc.warmup_steps, 1)
    t = jnp.clip((step - rc.warmup_steps)
                 / max(rc.total_steps - rc.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * rc.lr * (1.0 + jnp.cos(jnp.pi * t))
    return jnp.where(step < rc.warmup_steps, warm, cos)


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree_util.tree_leaves(tree)))


def clip_by_global_norm(grads, max_norm: float):
    g = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(g, 1e-9))
    return jax.tree_util.tree_map(
        lambda x: (x.astype(jnp.float32) * scale).astype(x.dtype), grads), g


def adamw_update(grads, state: OptState, params, rc):
    """Returns (new_params, new_state, metrics)."""
    if rc.grad_clip:
        grads, gnorm = clip_by_global_norm(grads, rc.grad_clip)
    else:
        gnorm = global_norm(grads)
    count = state.count + 1
    lr = lr_schedule(state.count, rc)
    b1, b2, eps, wd = rc.beta1, rc.beta2, rc.eps, rc.weight_decay
    bc1 = 1.0 - b1 ** count.astype(jnp.float32)
    bc2 = 1.0 - b2 ** count.astype(jnp.float32)

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32)
        mf = b1 * m.astype(jnp.float32) + (1 - b1) * gf
        vf = b2 * v.astype(jnp.float32) + (1 - b2) * jnp.square(gf)
        step = (mf / bc1) / (jnp.sqrt(vf / bc2) + eps)
        pf = p.astype(jnp.float32)
        pf = pf - lr * (step + wd * pf)
        return pf.astype(p.dtype), mf.astype(m.dtype), vf.astype(v.dtype)

    p_l, treedef = jax.tree_util.tree_flatten(params)
    g_l = treedef.flatten_up_to(grads)
    m_l = treedef.flatten_up_to(state.m)
    v_l = treedef.flatten_up_to(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in zip(p_l, g_l, m_l, v_l)]
    new_params = jax.tree_util.tree_unflatten(treedef, [t[0] for t in out])
    new_m = jax.tree_util.tree_unflatten(treedef, [t[1] for t in out])
    new_v = jax.tree_util.tree_unflatten(treedef, [t[2] for t in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, OptState(count, new_m, new_v), metrics
