"""RWKV6 (Finch): attention-free LM with data-dependent per-channel decay.

Time-mix uses the chunked WKV formulation: within a chunk the decayed
products exp(cum_excl[t,d] - cumw[j,d]) are <= 1 for j < t (numerically
safe), across chunks a (hd_k x hd_v) state is carried per head.  This is
the oracle for the Pallas `rwkv6_scan` kernel.  Decode is the O(1)
recurrence.  Norms are LayerNorm (true to RWKV), channel-mix uses squared
ReLU.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import (dense_init, embed_init, layer_norm,
                                 shard_hint, softcap, zeros_init)


def dims(cfg):
    hd = cfg.rwkv.head_dim
    H = cfg.d_model // hd
    return H, hd


def _ln_pair(n_layers, D):
    L = (n_layers,) if n_layers else ()
    return {"s": jnp.ones(L + (D,)), "b": jnp.zeros(L + (D,))}


def init_time_mix(key, cfg, n_layers: int):
    D = cfg.d_model
    H, hd = dims(cfg)
    tsl, dl = cfg.rwkv.tokenshift_lora, cfg.rwkv.decay_lora
    ks = jax.random.split(key, 8)
    L = (n_layers,) if n_layers else ()
    return {
        "maa_x": zeros_init(None, L + (D,)),
        "maa": zeros_init(None, L + (5, D)),                 # w,k,v,r,g bases
        "maa_w1": dense_init(ks[0], L + (D, 5 * tsl), in_axis_size=D),
        "maa_w2": dense_init(ks[1], L + (5, tsl, D), in_axis_size=tsl),
        "w0": (jnp.zeros(L + (D,)) - 6.0),                   # decay base
        "w1": dense_init(ks[2], L + (D, dl), in_axis_size=D),
        "w2": dense_init(ks[3], L + (dl, D), in_axis_size=dl),
        "u": zeros_init(None, L + (H, hd)),                  # bonus
        "wr": dense_init(ks[4], L + (D, D), in_axis_size=D),
        "wk": dense_init(ks[5], L + (D, D), in_axis_size=D),
        "wv": dense_init(ks[6], L + (D, D), in_axis_size=D),
        "wg": dense_init(ks[7], L + (D, D), in_axis_size=D),
        "out": dense_init(ks[0], L + (D, D), in_axis_size=D),
        "ln_x": _ln_pair(n_layers, D),
    }


def init_channel_mix(key, cfg, n_layers: int):
    D, F = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    L = (n_layers,) if n_layers else ()
    return {
        "maa_k": zeros_init(None, L + (D,)),
        "maa_r": zeros_init(None, L + (D,)),
        "ck": dense_init(ks[0], L + (D, F), in_axis_size=D),
        "cv": dense_init(ks[1], L + (F, D), in_axis_size=F),
        "cr": dense_init(ks[2], L + (D, D), in_axis_size=D),
    }


def _shift(x, last=None):
    """xx[t] = x[t-1]; x (B,S,D); last (B,D) carries across calls."""
    first = jnp.zeros_like(x[:, :1]) if last is None else last[:, None].astype(x.dtype)
    return jnp.concatenate([first, x[:, :-1]], axis=1)


def _ddlerp(p, x, xx):
    """Data-dependent token-shift interpolation -> (x_w, x_k, x_v, x_r, x_g)."""
    B, S, D = x.shape
    dxx = xx - x
    xxx = x + dxx * p["maa_x"].astype(x.dtype)
    k = jnp.tanh(xxx @ p["maa_w1"].astype(x.dtype))          # (B,S,5*tsl)
    tsl = k.shape[-1] // 5
    k = k.reshape(B, S, 5, tsl)
    off = jnp.einsum("bstl,tld->bstd", k, p["maa_w2"].astype(x.dtype))
    mix = p["maa"].astype(x.dtype)[None, None] + off         # (B,S,5,D)
    return tuple(x + dxx * mix[:, :, i] for i in range(5))


def _wkv_chunk(S0, blk, *, H, hd):
    """One chunk. S0: (B,H,hd,hd) fp32 (k-dim x v-dim).
    blk: cumw (B,Q,H,hd) inclusive log-decay cumsum; r,k,v (B,Q,H,hd); u (H,hd)."""
    cumw, r, k, v, u = blk
    B, Q = r.shape[0], r.shape[1]
    # cum_excl[t] = cumw[t-1] (cumw of previous step; 0 at t=0)
    cum_excl = jnp.pad(cumw[:, :-1], ((0, 0), (1, 0), (0, 0), (0, 0)))
    # intra-chunk: A[t,j] = sum_d r[t,d] k[j,d] exp(cum_excl[t,d]-cumw[j,d]), j<t
    # (mask inside the exponent: j>=t deltas are positive => exp overflow
    # => NaN gradients through inf*0)
    diff = cum_excl[:, :, None] - cumw[:, None, :]           # (B,Q,Q,H,hd)
    mask = jnp.tril(jnp.ones((Q, Q), bool), k=-1)
    E = jnp.exp(jnp.where(mask[None, :, :, None, None], diff, -1e9))
    A = jnp.einsum("bthd,bjhd,btjhd->bthj", r, k, E)         # (B,Q,H,Q)
    Y = jnp.einsum("bthj,bjhd->bthd", A, v)
    # bonus diagonal
    Y = Y + jnp.einsum("bthd,bthd->bth", r, u[None, None] * k)[..., None] * v
    # inter-chunk from carried state
    rd = r * jnp.exp(cum_excl)
    Y = Y + jnp.einsum("bthk,bhkv->bthv", rd, S0)
    # state update
    dec_end = jnp.exp(cumw[:, -1:] - cumw)                   # (B,Q,H,hd)
    S1 = (S0 * jnp.exp(cumw[:, -1])[..., None]
          + jnp.einsum("bjhk,bjhv->bhkv", k * dec_end, v))
    return S1, Y


def time_mix(p, x, cfg, *, state=None, chunk=None):
    """x (B,S,D) -> (out, (last_x (B,D), S (B,H,hd,hd)))."""
    H, hd = dims(cfg)
    B, S, D = x.shape
    xx = _shift(x, None if state is None else state[0])
    x_w, x_k, x_v, x_r, x_g = _ddlerp(p, x, xx)
    w_log = (p["w0"].astype(jnp.float32)
             + jnp.tanh(x_w @ p["w1"].astype(x.dtype)).astype(jnp.float32)
             @ p["w2"].astype(jnp.float32))                  # (B,S,D)
    logw = -jnp.exp(w_log)                                   # <= 0
    r = (x_r @ p["wr"].astype(x.dtype)).reshape(B, S, H, hd).astype(jnp.float32)
    k = (x_k @ p["wk"].astype(x.dtype)).reshape(B, S, H, hd).astype(jnp.float32)
    v = (x_v @ p["wv"].astype(x.dtype)).reshape(B, S, H, hd).astype(jnp.float32)
    g = jax.nn.silu(x_g @ p["wg"].astype(x.dtype))
    u = p["u"].astype(jnp.float32)

    Q = min(chunk or cfg.rwkv.chunk, S)
    pad = (-S) % Q
    logw_h = logw.reshape(B, S, H, hd)
    if pad:
        r = jnp.pad(r, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        logw_h = jnp.pad(logw_h, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nC = (S + pad) // Q
    resh = lambda a: a.reshape(B, nC, Q, H, hd).transpose(1, 0, 2, 3, 4)
    cumw = jnp.cumsum(logw_h.reshape(B, nC, Q, H, hd), axis=2).transpose(1, 0, 2, 3, 4)
    S0 = (jnp.zeros((B, H, hd, hd), jnp.float32) if state is None
          else state[1])
    step = lambda c, b: _wkv_chunk(c, b, H=H, hd=hd)
    us = jnp.broadcast_to(u, (nC,) + u.shape)
    S_fin, Ys = jax.lax.scan(step, S0, (cumw, resh(r), resh(k), resh(v), us))
    Y = Ys.transpose(1, 0, 2, 3, 4).reshape(B, S + pad, H, hd)[:, :S]

    # per-head group norm, then gate and project
    y = Y.reshape(B, S, H, hd)
    mu = jnp.mean(y, axis=-1, keepdims=True)
    var = jnp.var(y, axis=-1, keepdims=True)
    y = (y - mu) * jax.lax.rsqrt(var + 64e-5)
    y = y.reshape(B, S, D) * p["ln_x"]["s"] + p["ln_x"]["b"]
    y = y.astype(x.dtype) * g
    out = y @ p["out"].astype(x.dtype)
    return shard_hint(out, "batch", None, None), (x[:, -1], S_fin)


def time_mix_decode(p, x, cfg, state):
    """x (B,1,D); state (last_x (B,D), S (B,H,hd,hd))."""
    H, hd = dims(cfg)
    B, _, D = x.shape
    last_x, S0 = state
    xx = last_x[:, None].astype(x.dtype)
    x_w, x_k, x_v, x_r, x_g = _ddlerp(p, x, xx)
    w_log = (p["w0"].astype(jnp.float32)
             + jnp.tanh(x_w @ p["w1"].astype(x.dtype)).astype(jnp.float32)
             @ p["w2"].astype(jnp.float32))
    w = jnp.exp(-jnp.exp(w_log))[:, 0].reshape(B, H, hd)     # (B,H,hd)
    r = (x_r @ p["wr"].astype(x.dtype)).reshape(B, H, hd).astype(jnp.float32)
    k = (x_k @ p["wk"].astype(x.dtype)).reshape(B, H, hd).astype(jnp.float32)
    v = (x_v @ p["wv"].astype(x.dtype)).reshape(B, H, hd).astype(jnp.float32)
    g = jax.nn.silu(x_g @ p["wg"].astype(x.dtype))[:, 0]
    u = p["u"].astype(jnp.float32)
    # y = r · (S0 + u ⊙ k v^T); S1 = diag(w) S0 + k v^T
    kv = jnp.einsum("bhk,bhv->bhkv", k, v)
    y = jnp.einsum("bhk,bhkv->bhv", r, S0 + u[None, ..., None] * kv)
    S1 = S0 * w[..., None] + kv
    mu = jnp.mean(y, axis=-1, keepdims=True)
    var = jnp.var(y, axis=-1, keepdims=True)
    y = (y - mu) * jax.lax.rsqrt(var + 64e-5)
    y = y.reshape(B, D) * p["ln_x"]["s"] + p["ln_x"]["b"]
    y = (y.astype(x.dtype) * g) @ p["out"].astype(x.dtype)
    return y[:, None], (x[:, -1], S1)


def channel_mix(p, x, cfg, *, state=None):
    xx = _shift(x, None if state is None else state)
    dxx = xx - x
    xk = x + dxx * p["maa_k"].astype(x.dtype)
    xr = x + dxx * p["maa_r"].astype(x.dtype)
    h = jnp.square(jax.nn.relu(xk @ p["ck"].astype(x.dtype)))
    h = shard_hint(h, "batch", None, "model_ff")
    out = jax.nn.sigmoid(xr @ p["cr"].astype(x.dtype)) * (h @ p["cv"].astype(x.dtype))
    return shard_hint(out, "batch", None, None), x[:, -1]


# ---------------------------------------------------------------------------
# Full RWKV LM
# ---------------------------------------------------------------------------


def init_lm(key, cfg):
    ks = jax.random.split(key, 5)
    L = cfg.n_layers
    return {
        "embed": embed_init(ks[0], (cfg.padded_vocab, cfg.d_model)),
        "ln0": _ln_pair(0, cfg.d_model),
        "ln1": _ln_pair(L, cfg.d_model),
        "ln2": _ln_pair(L, cfg.d_model),
        "tm": init_time_mix(ks[1], cfg, L),
        "cm": init_channel_mix(ks[2], cfg, L),
        "ln_out": _ln_pair(0, cfg.d_model),
        "head": dense_init(ks[3], (cfg.d_model, cfg.padded_vocab),
                           in_axis_size=cfg.d_model),
    }


def forward(params, cfg, tokens, *, opts=None, mode: str = "train",
            dtype=jnp.bfloat16, **_):
    B, S = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0).astype(dtype)
    x = shard_hint(x, "batch", None, None)
    x = layer_norm(x, params["ln0"]["s"], params["ln0"]["b"])

    def body(x, lp):
        h = layer_norm(x, lp["ln1"]["s"], lp["ln1"]["b"])
        a, (tm_x, S_fin) = time_mix(lp["tm"], h, cfg)
        x = x + a
        h = layer_norm(x, lp["ln2"]["s"], lp["ln2"]["b"])
        c, cm_x = channel_mix(lp["cm"], h, cfg)
        x = x + c
        return x, {"tm_x": tm_x, "S": S_fin, "cm_x": cm_x} if mode == "prefill" else None

    lp = {"ln1": params["ln1"], "ln2": params["ln2"], "tm": params["tm"],
          "cm": params["cm"]}
    x, states = jax.lax.scan(body, x, lp)
    x = layer_norm(x, params["ln_out"]["s"], params["ln_out"]["b"])
    if mode == "prefill":
        logits = x[:, -1] @ params["head"].astype(x.dtype)
        return logits, states, jnp.zeros((), jnp.float32)
    logits = x @ params["head"].astype(x.dtype)
    return shard_hint(logits, "batch", None, "vocab"), jnp.zeros((), jnp.float32)


def init_state(cfg, batch: int, abstract=False):
    H, hd = dims(cfg)
    L = cfg.n_layers
    mk = jax.ShapeDtypeStruct if abstract else (lambda s, d: jnp.zeros(s, d))
    return {"tm_x": mk((L, batch, cfg.d_model), jnp.float32),
            "S": mk((L, batch, H, hd, hd), jnp.float32),
            "cm_x": mk((L, batch, cfg.d_model), jnp.float32)}


def decode_step(params, cfg, tokens, positions, state, *, opts=None,
                dtype=jnp.bfloat16):
    """tokens (B,). RWKV needs no positions (kept for API uniformity)."""
    B = tokens.shape[0]
    x = jnp.take(params["embed"], tokens, axis=0)[:, None].astype(dtype)
    x = layer_norm(x, params["ln0"]["s"], params["ln0"]["b"])

    def body(x, xs):
        lp, st = xs
        h = layer_norm(x, lp["ln1"]["s"], lp["ln1"]["b"])
        a, (tm_x, S1) = time_mix_decode(lp["tm"], h, cfg, (st["tm_x"], st["S"]))
        x = x + a
        h = layer_norm(x, lp["ln2"]["s"], lp["ln2"]["b"])
        c, cm_x = channel_mix(lp["cm"], h, cfg, state=st["cm_x"])
        x = x + c
        return x, {"tm_x": tm_x, "S": S1, "cm_x": cm_x}

    lp = {"ln1": params["ln1"], "ln2": params["ln2"], "tm": params["tm"],
          "cm": params["cm"]}
    x, new_state = jax.lax.scan(body, x, (lp, state))
    x = layer_norm(x, params["ln_out"]["s"], params["ln_out"]["b"])
    logits = (x @ params["head"].astype(x.dtype))[:, 0]
    return logits, new_state
