"""Uniform model API over all families.

    model = build_model(cfg)
    params = model.init(key)
    logits, aux = model.forward(params, batch)                  # train
    logits, cache, aux = model.forward(params, batch, mode="prefill")
    logits, cache = model.decode_step(params, tokens, pos, cache)
    cache = model.init_cache(batch, max_len, abstract=True)
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.models import rwkv, transformer, whisper, zamba
from repro.models.common import Options


@dataclass
class Model:
    cfg: Any
    opts: Options
    _mod: Any

    def init(self, key):
        return self._mod.init_lm(key, self.cfg)

    def init_abstract(self, key=None):
        key = key if key is not None else jax.random.PRNGKey(0)
        return jax.eval_shape(lambda k: self._mod.init_lm(k, self.cfg), key)

    def forward(self, params, batch: dict, mode: str = "train"):
        kw = {}
        if self.cfg.mrope and "mrope_positions" in batch:
            kw["mrope_positions"] = batch["mrope_positions"]
        if self.cfg.family == "audio":
            kw["encoder_frames"] = batch["encoder_frames"]
        return self._mod.forward(params, self.cfg, batch["tokens"],
                                 opts=self.opts, mode=mode, **kw)

    def decode_step(self, params, tokens, positions, cache):
        return self._mod.decode_step(params, self.cfg, tokens, positions,
                                     cache, opts=self.opts)

    def init_cache(self, batch: int, max_len: int, dtype=jnp.bfloat16,
                   abstract: bool = False):
        if self.cfg.family == "ssm":
            return self._mod.init_state(self.cfg, batch, abstract=abstract)
        return self._mod.init_cache(self.cfg, batch, max_len, dtype=dtype,
                                    abstract=abstract)

    def with_opts(self, **kw) -> "Model":
        return Model(self.cfg, self.opts.replace(**kw), self._mod)


_FAMILY_MODULES = {
    "dense": transformer,
    "moe": transformer,
    "vlm": transformer,
    "hybrid": zamba,
    "ssm": rwkv,
    "audio": whisper,
}


def build_model(cfg, opts: Options = None) -> Model:
    return Model(cfg, opts or Options(), _FAMILY_MODULES[cfg.family])
