"""Mamba2 (SSD) block — chunked state-space-dual formulation.

Training/prefill uses the chunked algorithm (matmul-rich: intra-chunk
"attention-like" term + sequential inter-chunk state carry), which is also
the oracle for the Pallas `mamba2_ssd` kernel.  Decode is the O(1)-state
recurrence.

State per layer: ssm (B, H, hd, N) fp32 + conv ring buffer (B, W-1, convch).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import dense_init, ones_init, rms_norm, shard_hint, zeros_init


def dims(cfg):
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    H = d_inner // s.head_dim
    G, N, W = s.n_groups, s.state_dim, s.conv_dim
    convch = d_inner + 2 * G * N
    d_in_proj = 2 * d_inner + 2 * G * N + H
    return d_inner, H, G, N, W, convch, d_in_proj


def init_mamba(key, cfg, n_layers: int):
    d_inner, H, G, N, W, convch, d_in_proj = dims(cfg)
    D = cfg.d_model
    ks = jax.random.split(key, 4)
    L = (n_layers,) if n_layers else ()
    # A in [1, ~16): A_log uniform-ish init (mamba2 default)
    a0 = jnp.log(jnp.linspace(1.0, 16.0, H, dtype=jnp.float32))
    return {
        "in_proj": dense_init(ks[0], L + (D, d_in_proj), in_axis_size=D),
        "conv_w": dense_init(ks[1], L + (W, convch), in_axis_size=W),
        "conv_b": zeros_init(None, L + (convch,)),
        "A_log": jnp.broadcast_to(a0, L + (H,)).copy(),
        "dt_bias": zeros_init(None, L + (H,)),
        "D_skip": ones_init(None, L + (H,)),
        "norm": ones_init(None, L + (d_inner,)),
        "out_proj": dense_init(ks[2], L + (d_inner, D), in_axis_size=d_inner),
    }


def _split_proj(zxbcdt, cfg):
    d_inner, H, G, N, *_ = dims(cfg)
    z = zxbcdt[..., :d_inner]
    xin = zxbcdt[..., d_inner:2 * d_inner]
    Bc = zxbcdt[..., 2 * d_inner:2 * d_inner + G * N]
    Cc = zxbcdt[..., 2 * d_inner + G * N:2 * d_inner + 2 * G * N]
    dt = zxbcdt[..., 2 * d_inner + 2 * G * N:]
    return z, xin, Bc, Cc, dt


def _conv(xBC, w, b, conv_state=None):
    """Causal depthwise conv, window W. xBC: (B,S,C); w: (W,C).
    conv_state: (B, W-1, C) ring of trailing inputs (decode) or None."""
    W = w.shape[0]
    if conv_state is not None:
        full = jnp.concatenate([conv_state.astype(xBC.dtype), xBC], axis=1)
    else:
        full = jnp.pad(xBC, ((0, 0), (W - 1, 0), (0, 0)))
    S = xBC.shape[1]
    out = sum(full[:, i:i + S] * w[i].astype(xBC.dtype) for i in range(W))
    out = out + b.astype(xBC.dtype)
    new_state = full[:, -(W - 1):] if W > 1 else None
    return jax.nn.silu(out), new_state


def _ssd_chunk(carry, blk, *, H, G, N, hd):
    """One chunk of the SSD recurrence. carry: S0 (B,H,hd,N) fp32."""
    S0 = carry
    cum, Bh, Ch, xdt = blk          # cum (B,Q,H); Bh/Ch (B,Q,G,N); xdt (B,Q,H,hd)
    Hg = H // G
    B_, Q = cum.shape[0], cum.shape[1]
    # group heads: (B,Q,G,Hg)
    cum_g = cum.reshape(B_, Q, G, Hg)
    xdt_g = xdt.reshape(B_, Q, G, Hg, hd)
    # intra-chunk: Y[i] += sum_{j<=i} exp(cum_i-cum_j) (C_i·B_j) xdt_j
    # (mask INSIDE the exponent: upper-triangle deltas are positive and
    # would overflow exp, poisoning gradients via inf*0)
    scores = jnp.einsum("bign,bjgn->bijg", Ch, Bh)                  # (B,Q,Q,G)
    mask = jnp.tril(jnp.ones((Q, Q), bool))
    delta = cum_g[:, :, None] - cum_g[:, None, :, :]                # (B,Q,Q,G,Hg)
    Ldec = jnp.exp(jnp.where(mask[None, :, :, None, None], delta, -1e9))
    M = Ldec * scores[..., None]
    Y = jnp.einsum("bijgh,bjghd->bighd", M, xdt_g)                  # (B,Q,G,Hg,hd)
    # inter-chunk: Y[i] += exp(cum_i) C_i · S0
    S0_g = S0.reshape(B_, G, Hg, hd, N)
    Yin = jnp.einsum("bign,bghdn->bighd", Ch, S0_g) * jnp.exp(cum_g)[..., None]
    Y = Y + Yin
    # state update: S1 = exp(cum_Q) S0 + sum_j exp(cum_Q - cum_j) xdt_j B_j
    dec_end = jnp.exp(cum_g[:, -1:, :, :] - cum_g)                  # (B,Q,G,Hg)
    Supd = jnp.einsum("bjgh,bjghd,bjgn->bghdn", dec_end, xdt_g, Bh)
    S1 = S0_g * jnp.exp(cum_g[:, -1])[..., None, None] + Supd
    return S1.reshape(B_, H, hd, N), Y.reshape(B_, Q, H, hd)


def mamba_forward(p, x, cfg, *, initial_state=None, return_state=False):
    """x: (B,S,D) -> (B,S,D). Chunked SSD over the full sequence."""
    s = cfg.ssm
    d_inner, H, G, N, W, convch, _ = dims(cfg)
    hd = s.head_dim
    B_, S, D = x.shape
    Q = min(s.chunk, S)
    pad = (-S) % Q
    zxbcdt = x @ p["in_proj"].astype(x.dtype)
    z, xin, Bc, Cc, dt = _split_proj(zxbcdt, cfg)
    xBC, _ = _conv(jnp.concatenate([xin, Bc, Cc], -1), p["conv_w"], p["conv_b"])
    xin, Bc, Cc = (xBC[..., :d_inner], xBC[..., d_inner:d_inner + G * N],
                   xBC[..., d_inner + G * N:])
    xin = shard_hint(xin, "batch", None, "model_ff")
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))                    # (H,)
    dA = dt * A                                                     # (B,S,H)
    xdt = (xin.reshape(B_, S, H, hd).astype(jnp.float32)
           * dt[..., None])                                         # (B,S,H,hd)
    Bh = Bc.reshape(B_, S, G, N).astype(jnp.float32)
    Ch = Cc.reshape(B_, S, G, N).astype(jnp.float32)
    if pad:
        dA = jnp.pad(dA, ((0, 0), (0, pad), (0, 0)))
        xdt = jnp.pad(xdt, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Bh = jnp.pad(Bh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Ch = jnp.pad(Ch, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nC = (S + pad) // Q
    cum = jnp.cumsum(dA.reshape(B_, nC, Q, H), axis=2)
    blks = (cum.transpose(1, 0, 2, 3),
            Bh.reshape(B_, nC, Q, G, N).transpose(1, 0, 2, 3, 4),
            Ch.reshape(B_, nC, Q, G, N).transpose(1, 0, 2, 3, 4),
            xdt.reshape(B_, nC, Q, H, hd).transpose(1, 0, 2, 3, 4))
    S0 = (initial_state if initial_state is not None
          else jnp.zeros((B_, H, hd, N), jnp.float32))
    step = lambda c, b: _ssd_chunk(c, b, H=H, G=G, N=N, hd=hd)
    S_fin, Ys = jax.lax.scan(step, S0, blks)
    Y = Ys.transpose(1, 0, 2, 3, 4).reshape(B_, S + pad, H, hd)[:, :S]
    Y = Y + p["D_skip"].astype(jnp.float32)[:, None] * xin.reshape(
        B_, S, H, hd).astype(jnp.float32)
    y = Y.reshape(B_, S, d_inner).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    out = y @ p["out_proj"].astype(x.dtype)
    out = shard_hint(out, "batch", None, None)
    if return_state:
        return out, S_fin
    return out


def mamba_decode(p, x, cfg, state):
    """One step. x: (B,1,D); state {"ssm": (B,H,hd,N) f32, "conv": (B,W-1,convch)}."""
    s = cfg.ssm
    d_inner, H, G, N, W, convch, _ = dims(cfg)
    hd = s.head_dim
    B_ = x.shape[0]
    zxbcdt = x @ p["in_proj"].astype(x.dtype)
    z, xin, Bc, Cc, dt = _split_proj(zxbcdt, cfg)
    xBC, conv_new = _conv(jnp.concatenate([xin, Bc, Cc], -1), p["conv_w"],
                          p["conv_b"], conv_state=state["conv"])
    xin, Bc, Cc = (xBC[..., :d_inner], xBC[..., d_inner:d_inner + G * N],
                   xBC[..., d_inner + G * N:])
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    dA = jnp.exp(dt[:, 0] * A)                                      # (B,H)
    xh = xin.reshape(B_, H, hd).astype(jnp.float32) * dt[:, 0, :, None]
    Bh = Bc.reshape(B_, G, N).astype(jnp.float32)
    Ch = Cc.reshape(B_, G, N).astype(jnp.float32)
    Hg = H // G
    S_g = state["ssm"].reshape(B_, G, Hg, hd, N)
    xh_g = xh.reshape(B_, G, Hg, hd)
    S_new = (S_g * dA.reshape(B_, G, Hg)[..., None, None]
             + jnp.einsum("bghd,bgn->bghdn", xh_g, Bh))
    Y = jnp.einsum("bgn,bghdn->bghd", Ch, S_new)
    Y = Y + p["D_skip"].astype(jnp.float32).reshape(G, Hg)[None, :, :, None] \
        * xin.reshape(B_, G, Hg, hd).astype(jnp.float32)
    y = Y.reshape(B_, 1, d_inner).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    out = y @ p["out_proj"].astype(x.dtype)
    return out, {"ssm": S_new.reshape(B_, H, hd, N), "conv": conv_new}


def init_mamba_state(cfg, batch: int, abstract=False, n_layers=None):
    d_inner, H, G, N, W, convch, _ = dims(cfg)
    L = (n_layers,) if n_layers else ()
    mk = jax.ShapeDtypeStruct if abstract else (lambda s, d: jnp.zeros(s, d))
    return {"ssm": mk(L + (batch, H, cfg.ssm.head_dim, N), jnp.float32),
            "conv": mk(L + (batch, W - 1, convch), jnp.float32)}
