"""Attention: GQA projections + blockwise (flash-style) softmax attention.

TPU-mesh-aware layout (model axis = 16):
  * Q heads are physically PADDED (group-major flat layout, head h = g*M_pad+m)
    so Hq_pad % 16 == 0 — flat projections reshape to heads with shard
    boundaries exactly on head boundaries => zero attention resharding.
    Padded heads' context is masked before W_o, so their params receive no
    gradient and the math is exact.
  * KV projections shard on heads when G % 16 == 0, else stay replicated
    (duplicate small compute beats score-matrix collectives; see DESIGN.md).
  * KV is expanded to flat Q-heads locally (broadcast+reshape — slice-local,
    no communication).
  * Decode caches are sequence-sharded (flash-decoding): softmax stats and
    the context contraction reduce over the model axis with tiny psums.

The blockwise path is the memory-feasible pure-JAX formulation (online
softmax over KV blocks, scanned over Q blocks) — also the oracle for the
Pallas flash kernel.  `skip_masked_blocks=True` wraps fully-masked KV blocks
in `lax.cond` so XLA skips their compute (§Perf knob).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.common import (dense_init, grad_cast, shard_hint, softcap,
                                 zeros_init)

NEG_INF = -2.0e38
MODEL_AXIS_SIZE = 16          # production mesh model-axis width


def head_padding(cfg, model_size: int = MODEL_AXIS_SIZE):
    """(Hq_pad, M_pad): pad per-group head count so G*M_pad % model == 0."""
    G = cfg.n_kv_heads
    M = cfg.n_heads // G
    m_pad = M
    while (G * m_pad) % model_size:
        m_pad += 1
    return G * m_pad, m_pad


def kv_shardable(cfg, model_size: int = MODEL_AXIS_SIZE) -> bool:
    return cfg.n_kv_heads % model_size == 0


def head_mask(cfg):
    """(Hq_pad,) 1.0 for real heads, 0.0 for padding."""
    hq_pad, m_pad = head_padding(cfg)
    M = cfg.n_heads // cfg.n_kv_heads
    return ((jnp.arange(hq_pad) % m_pad) < M).astype(jnp.float32)


def expand_kv(k, hq_pad: int):
    """(B,T,G,hd) -> (B,T,Hq_pad,hd) by repeating each group M_pad times.
    Pure broadcast+reshape: local slice under head sharding."""
    B, T, G, hd = k.shape
    m_pad = hq_pad // G
    return jnp.broadcast_to(k[:, :, :, None, :], (B, T, G, m_pad, hd)) \
        .reshape(B, T, hq_pad, hd)


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------


def init_attention(key, cfg, n_layers: int, *, d_in: Optional[int] = None,
                   d_out: Optional[int] = None):
    """Stacked GQA projection params: (L, ...) leading dim; flat head dims
    (padded for Q/O)."""
    d = d_in or cfg.d_model
    do = d_out or cfg.d_model
    hd = cfg.resolved_head_dim
    hq_pad, _ = head_padding(cfg)
    hkv = cfg.n_kv_heads
    ks = jax.random.split(key, 4)
    L = (n_layers,) if n_layers else ()
    p = {
        "wq": dense_init(ks[0], L + (d, hq_pad * hd), in_axis_size=d),
        "wk": dense_init(ks[1], L + (d, hkv * hd), in_axis_size=d),
        "wv": dense_init(ks[2], L + (d, hkv * hd), in_axis_size=d),
        "wo": dense_init(ks[3], L + (hq_pad * hd, do), in_axis_size=hq_pad * hd),
    }
    if cfg.qkv_bias:
        p["bq"] = zeros_init(None, L + (hq_pad * hd,))
        p["bk"] = zeros_init(None, L + (hkv * hd,))
        p["bv"] = zeros_init(None, L + (hkv * hd,))
    return p


def project_qkv(p, x, cfg):
    """x (B,S,D) -> q (B,S,Hq_pad,hd), k/v (B,S,G,hd)."""
    hd = cfg.resolved_head_dim
    hq_pad, _ = head_padding(cfg)
    B, S, _ = x.shape
    q = x @ p["wq"].astype(x.dtype)
    k = x @ p["wk"].astype(x.dtype)
    v = x @ p["wv"].astype(x.dtype)
    if "bq" in p:
        q = q + p["bq"].astype(x.dtype)
        k = k + p["bk"].astype(x.dtype)
        v = v + p["bv"].astype(x.dtype)
    q = shard_hint(q, "batch", None, "model_ff")
    if kv_shardable(cfg):
        k = shard_hint(k, "batch", None, "model_ff")
        v = shard_hint(v, "batch", None, "model_ff")
    else:
        k = shard_hint(k, "batch", None, None)
        v = shard_hint(v, "batch", None, None)
    q = grad_cast(q.reshape(B, S, hq_pad, hd))
    k = grad_cast(k.reshape(B, S, cfg.n_kv_heads, hd))
    v = grad_cast(v.reshape(B, S, cfg.n_kv_heads, hd))
    return q, k, v


def project_out(p, ctx, cfg):
    """ctx (B,S,Hq_pad,hd) -> (B,S,d_out); masks padded heads first."""
    B, S = ctx.shape[:2]
    ctx = grad_cast(ctx) * head_mask(cfg)[None, None, :, None].astype(ctx.dtype)
    out = ctx.reshape(B, S, -1) @ p["wo"].astype(ctx.dtype)
    return shard_hint(out, "batch", None, None)


# ---------------------------------------------------------------------------
# Blockwise attention (training / prefill) — flat heads
# ---------------------------------------------------------------------------


def _block_mask(qpos, kpos, *, causal: bool, window=None):
    m = jnp.ones((qpos.shape[0], kpos.shape[0]), bool)
    if causal:
        m &= qpos[:, None] >= kpos[None, :]
    if window is not None:
        m &= (qpos[:, None] - kpos[None, :]) < window
    return m


def flash_attention(q, k, v, *, causal: bool = True, window=None,
                    logit_softcap: float = 0.0, scale: float,
                    q_block: int = 1024, kv_block: int = 1024,
                    q_offset: int = 0, skip_masked_blocks: bool = False,
                    probs_bf16: bool = False):
    """Blockwise attention with online softmax.

    q: (B, S, H, hd);  k, v: (B, T, H, hd) — caller pre-expands GQA KV.
    Returns (B, S, H, hdv).
    """
    B, S, H, hd = q.shape
    T = k.shape[1]
    hdv = v.shape[-1]
    qb = min(q_block, S)
    kb = min(kv_block, T)
    S0, T0 = S, T
    qpad, kpad = (-S) % qb, (-T) % kb
    if qpad:
        q = jnp.pad(q, ((0, 0), (0, qpad), (0, 0), (0, 0)))
        S += qpad
    if kpad:
        k = jnp.pad(k, ((0, 0), (0, kpad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, kpad), (0, 0), (0, 0)))
        T += kpad
    nq, nk = S // qb, T // kb

    qr = q.reshape(B, nq, qb, H, hd).transpose(1, 0, 3, 2, 4)   # (nq,B,H,qb,hd)
    kr = k.reshape(B, nk, kb, H, hd).transpose(1, 0, 3, 2, 4)
    vr = v.reshape(B, nk, kb, H, hdv).transpose(1, 0, 3, 2, 4)

    # STATIC causal skip: unroll q-blocks in Python, inner scan only over
    # the j <= i KV blocks — ~2x fewer score blocks, visible to both the
    # compiler and the roofline analysis (a lax.cond would execute-or-not
    # dynamically but always count statically).
    static_skip = (skip_masked_blocks and causal and window is None
                   and q_offset == 0 and nq <= 64 and S == T)

    def q_step(_, qi_and_i):
        qi, i = qi_and_i
        qpos = q_offset + i * qb + jnp.arange(qb)

        def kv_step(carry, kj_vj_j):
            m_run, l_run, acc = carry
            kj, vj, j = kj_vj_j
            kpos = j * kb + jnp.arange(kb)

            def compute(args):
                m_run, l_run, acc = args
                # bf16 operands + fp32 accumulation = MXU semantics; explicit
                # f32 upcasts would materialize f32 copies of Q/K AND make
                # the backward all-reduces fp32 (2x collective bytes)
                s = jnp.einsum("bhqd,bhkd->bhqk", qi, kj,
                               preferred_element_type=jnp.float32) * scale
                if logit_softcap:
                    s = softcap(s, logit_softcap)
                allow = _block_mask(qpos, kpos, causal=causal, window=window)
                allow &= (kpos < T0)[None, :]
                s = jnp.where(allow[None, None], s, NEG_INF)
                m_new = jnp.maximum(m_run, jnp.max(s, axis=-1))
                alpha = jnp.exp(m_run - m_new)
                prob = jnp.exp(s - m_new[..., None])
                l_new = l_run * alpha + jnp.sum(prob, axis=-1)
                if probs_bf16:  # halve prob-buffer traffic; PV on the MXU
                    pv = jnp.einsum("bhqk,bhkd->bhqd", prob.astype(vj.dtype),
                                    vj, preferred_element_type=jnp.float32)
                else:
                    pv = jnp.einsum("bhqk,bhkd->bhqd", prob,
                                    vj.astype(jnp.float32))
                acc_new = acc * alpha[..., None] + pv.astype(jnp.float32)
                return m_new, l_new, acc_new

            if skip_masked_blocks and not static_skip:
                needed = jnp.array(True)
                if causal:
                    needed &= j * kb <= q_offset + (i + 1) * qb - 1
                if window is not None:
                    needed &= (j + 1) * kb - 1 >= q_offset + i * qb - window + 1
                carry = jax.lax.cond(needed, compute, lambda a: a,
                                     (m_run, l_run, acc))
            else:
                carry = compute((m_run, l_run, acc))
            return carry, None

        m0 = jnp.full((B, H, qb), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, H, qb), jnp.float32)
        a0 = jnp.zeros((B, H, qb, hdv), jnp.float32)
        n_vis = (int(qi_and_i[1]) * qb // kb + 1) if static_skip else nk
        (m_f, l_f, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0),
            (kr[:n_vis], vr[:n_vis], jnp.arange(n_vis)))
        out = acc / jnp.maximum(l_f, 1e-37)[..., None]
        return None, out.astype(q.dtype)

    if static_skip:
        outs = [q_step(None, (qr[i], i))[1] for i in range(nq)]
        out = jnp.stack(outs).transpose(1, 0, 3, 2, 4).reshape(B, S, H, hdv)
    else:
        _, outs = jax.lax.scan(q_step, None, (qr, jnp.arange(nq)))
        out = outs.transpose(1, 0, 3, 2, 4).reshape(B, S, H, hdv)
    return out[:, :S0] if qpad else out


# ---------------------------------------------------------------------------
# One-shot attention (decode / small cross-attention) — flat heads
# ---------------------------------------------------------------------------


def attend_once(q, k, v, *, mask=None, logit_softcap: float = 0.0, scale: float):
    """q: (B,S,H,hd); k,v: (B,T,H,hd); mask broadcastable to (B,1,S,T)."""
    s = jnp.einsum("bshd,bthd->bhst", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if logit_softcap:
        s = softcap(s, logit_softcap)
    if mask is not None:
        s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhst,bthd->bshd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


def decode_attention(q, k_cache, v_cache, positions, *, window=None,
                     logit_softcap: float = 0.0, scale: float):
    """Single-token decode: q (B,1,Hq_pad,hd) against a sequence-sharded
    cache (B,T,G,hd).  positions: (B,) absolute index of the new token
    (its KV already written).

    GQA via grouped einsum — only the (tiny) q is reshaped; the cache is
    never expanded/gathered, so its T-on-model sharding flows through:
    softmax stats and the context contraction psum over the model axis
    (flash-decoding)."""
    B, T, G, hd = k_cache.shape
    hq_pad = q.shape[2]
    mp = hq_pad // G
    qg = q.reshape(B, 1, G, mp, hd)
    kpos = jnp.arange(T)
    allow = kpos[None, :] <= positions[:, None]                # (B,T)
    if window is not None:
        allow &= (positions[:, None] - kpos[None, :]) < window
    kc = shard_hint(k_cache, "batch", "kv_seq", None, None)
    vc = shard_hint(v_cache, "batch", "kv_seq", None, None)
    # keep operands in cache dtype (no fp32 copy of the cache); the MXU
    # accumulates in fp32 via preferred_element_type
    s = jnp.einsum("bqgmh,btgh->bgmqt", qg.astype(kc.dtype), kc,
                   preferred_element_type=jnp.float32) * scale
    if logit_softcap:
        s = softcap(s, logit_softcap)
    s = jnp.where(allow[:, None, None, None, :], s, NEG_INF)
    s = shard_hint(s, "batch", None, None, None, "kv_seq")
    p = jax.nn.softmax(s, axis=-1)
    ctx = jnp.einsum("bgmqt,btgh->bqgmh", p.astype(vc.dtype), vc,
                     preferred_element_type=jnp.float32)
    return ctx.reshape(B, 1, hq_pad, hd).astype(q.dtype)
