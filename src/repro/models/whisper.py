"""Whisper-style encoder-decoder backbone (audio family).

The conv frontend is a STUB: `input_specs()` supplies precomputed frame
embeddings (B, n_frames, d_model).  Positions are sinusoidal for both sides
(see configs/whisper_base.py note).  Norms are LayerNorm; MLP is non-gated
GELU; decoder blocks = causal self-attn + cross-attn + MLP.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models.common import (Options, dense_init, embed_init, layer_norm,
                                 shard_hint)
from repro.models.transformer import apply_ffn, init_ffn


def sinusoid(positions, D: int):
    """(S,) or (B,S) int -> (..., D) sinusoidal embedding (whisper layout)."""
    half = D // 2
    freq = jnp.exp(-jnp.log(10000.0) * jnp.arange(half) / (half - 1))
    ang = positions.astype(jnp.float32)[..., None] * freq
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def _ln(n_layers, D):
    L = (n_layers,) if n_layers else ()
    return {"s": jnp.ones(L + (D,)), "b": jnp.zeros(L + (D,))}


def init_lm(key, cfg):
    enc = cfg.encoder
    ks = jax.random.split(key, 8)
    Le, Ld = enc.n_layers, cfg.n_layers
    return {
        "embed": embed_init(ks[0], (cfg.padded_vocab, cfg.d_model)),
        "enc": {
            "ln1": _ln(Le, cfg.d_model),
            "attn": attn.init_attention(ks[1], cfg, Le),
            "ln2": _ln(Le, cfg.d_model),
            "mlp": init_ffn(ks[2], cfg, Le),
            "ln_post": _ln(0, cfg.d_model),
        },
        "dec": {
            "ln1": _ln(Ld, cfg.d_model),
            "self_attn": attn.init_attention(ks[3], cfg, Ld),
            "ln_x": _ln(Ld, cfg.d_model),
            "cross_attn": attn.init_attention(ks[4], cfg, Ld),
            "ln2": _ln(Ld, cfg.d_model),
            "mlp": init_ffn(ks[5], cfg, Ld),
            "ln_post": _ln(0, cfg.d_model),
        },
    }


def encode(params, cfg, frames, *, opts: Options):
    """frames (B,F,D) -> memory (B,F,D)."""
    ep = params["enc"]
    x = frames + sinusoid(jnp.arange(frames.shape[1]), cfg.d_model).astype(frames.dtype)
    x = shard_hint(x, "batch", None, None)
    scale = cfg.resolved_head_dim ** -0.5

    def body(x, lp):
        h = layer_norm(x, lp["ln1"]["s"], lp["ln1"]["b"])
        q, k, v = attn.project_qkv(lp["attn"], h, cfg)
        hq_pad = q.shape[2]
        ctx = attn.flash_attention(q, attn.expand_kv(k, hq_pad),
                                   attn.expand_kv(v, hq_pad), causal=False,
                                   scale=scale, q_block=opts.q_block,
                                   kv_block=opts.kv_block)
        x = x + attn.project_out(lp["attn"], ctx, cfg)
        h = layer_norm(x, lp["ln2"]["s"], lp["ln2"]["b"])
        x = x + apply_ffn(lp["mlp"], h, cfg)
        return x, None

    x, _ = jax.lax.scan(body, x, {k: ep[k] for k in ("ln1", "attn", "ln2", "mlp")})
    return layer_norm(x, ep["ln_post"]["s"], ep["ln_post"]["b"])


def _cross_kv(lp, memory, cfg):
    hd = cfg.resolved_head_dim
    B, F, _ = memory.shape
    k = (memory @ lp["cross_attn"]["wk"].astype(memory.dtype)).reshape(
        B, F, cfg.n_kv_heads, hd)
    v = (memory @ lp["cross_attn"]["wv"].astype(memory.dtype)).reshape(
        B, F, cfg.n_kv_heads, hd)
    return k, v


def forward(params, cfg, tokens, *, encoder_frames, opts: Options = None,
            mode: str = "train", dtype=jnp.bfloat16, **_):
    """tokens (B,S) + encoder_frames (B,F,D) -> logits."""
    opts = opts or Options()
    B, S = tokens.shape
    memory = encode(params, cfg, encoder_frames.astype(dtype), opts=opts)
    dp = params["dec"]
    x = jnp.take(params["embed"], tokens, axis=0).astype(dtype)
    x = x + sinusoid(jnp.arange(S), cfg.d_model).astype(dtype)
    x = shard_hint(x, "batch", None, None)
    scale = cfg.resolved_head_dim ** -0.5

    def body(x, lp):
        h = layer_norm(x, lp["ln1"]["s"], lp["ln1"]["b"])
        q, k, v = attn.project_qkv(lp["self_attn"], h, cfg)
        hq_pad = q.shape[2]
        ctx = attn.flash_attention(q, attn.expand_kv(k, hq_pad),
                                   attn.expand_kv(v, hq_pad), causal=True,
                                   scale=scale, q_block=opts.q_block,
                                   kv_block=opts.kv_block,
                                   skip_masked_blocks=opts.skip_masked_blocks)
        x = x + attn.project_out(lp["self_attn"], ctx, cfg)
        h = layer_norm(x, lp["ln_x"]["s"], lp["ln_x"]["b"])
        hd = cfg.resolved_head_dim
        hq_pad, _ = attn.head_padding(cfg)
        qc = (h @ lp["cross_attn"]["wq"].astype(h.dtype)).reshape(
            B, S, hq_pad, hd)
        kc, vc = _cross_kv(lp, memory, cfg)
        ctx = attn.flash_attention(qc, attn.expand_kv(kc, hq_pad),
                                   attn.expand_kv(vc, hq_pad), causal=False,
                                   scale=scale, q_block=opts.q_block,
                                   kv_block=opts.kv_block)
        x = x + attn.project_out(lp["cross_attn"], ctx, cfg)
        h = layer_norm(x, lp["ln2"]["s"], lp["ln2"]["b"])
        x = x + apply_ffn(lp["mlp"], h, cfg)
        cache_out = (k, v) if mode == "prefill" else None
        return x, cache_out

    lkeys = ("ln1", "self_attn", "ln_x", "cross_attn", "ln2", "mlp")
    x, caches = jax.lax.scan(body, x, {k: dp[k] for k in lkeys})
    x = layer_norm(x, dp["ln_post"]["s"], dp["ln_post"]["b"])
    if mode == "prefill":
        logits = (x[:, -1:] @ params["embed"].T.astype(x.dtype))[:, 0]
        return logits, {"kv": caches, "memory": memory}, jnp.zeros((), jnp.float32)
    logits = x @ params["embed"].T.astype(x.dtype)
    return shard_hint(logits, "batch", None, "vocab"), jnp.zeros((), jnp.float32)


def init_cache(cfg, batch: int, max_len: int, dtype=jnp.bfloat16, abstract=False):
    hd = cfg.resolved_head_dim
    L = cfg.n_layers
    F = cfg.encoder.n_frames
    mk = jax.ShapeDtypeStruct if abstract else (lambda s, d: jnp.zeros(s, d))
    return {
        "kv": (mk((L, batch, max_len, cfg.n_kv_heads, hd), dtype),
               mk((L, batch, max_len, cfg.n_kv_heads, hd), dtype)),
        "memory": mk((batch, F, cfg.d_model), dtype),
    }


def decode_step(params, cfg, tokens, positions, cache, *, opts: Options = None,
                dtype=jnp.bfloat16):
    """One decoder token against self-attn cache + encoder memory."""
    opts = opts or Options()
    B = tokens.shape[0]
    dp = params["dec"]
    memory = cache["memory"].astype(dtype)
    x = jnp.take(params["embed"], tokens, axis=0)[:, None].astype(dtype)
    x = x + sinusoid(positions[:, None], cfg.d_model).astype(dtype)
    scale = cfg.resolved_head_dim ** -0.5

    def body(x, xs):
        lp, kv = xs
        h = layer_norm(x, lp["ln1"]["s"], lp["ln1"]["b"])
        q, k_new, v_new = attn.project_qkv(lp["self_attn"], h, cfg)
        k_c, v_c = kv
        upd = jax.vmap(
            lambda c, n, i: jax.lax.dynamic_update_slice_in_dim(c, n, i, 0))
        k_c = upd(k_c, k_new.astype(k_c.dtype), positions)
        v_c = upd(v_c, v_new.astype(v_c.dtype), positions)
        ctx = attn.decode_attention(q, k_c.astype(q.dtype), v_c.astype(q.dtype),
                                    positions, scale=scale)
        x = x + attn.project_out(lp["self_attn"], ctx, cfg)
        h = layer_norm(x, lp["ln_x"]["s"], lp["ln_x"]["b"])
        hd = cfg.resolved_head_dim
        hq_pad, _ = attn.head_padding(cfg)
        qc = (h @ lp["cross_attn"]["wq"].astype(h.dtype)).reshape(
            B, 1, hq_pad, hd)
        kc, vc = _cross_kv(lp, memory, cfg)
        ctx = attn.attend_once(qc, attn.expand_kv(kc, hq_pad),
                               attn.expand_kv(vc, hq_pad), scale=scale)
        x = x + attn.project_out(lp["cross_attn"], ctx, cfg)
        h = layer_norm(x, lp["ln2"]["s"], lp["ln2"]["b"])
        x = x + apply_ffn(lp["mlp"], h, cfg)
        return x, (k_c, v_c)

    lkeys = ("ln1", "self_attn", "ln_x", "cross_attn", "ln2", "mlp")
    x, kv_new = jax.lax.scan(body, x, ({k: dp[k] for k in lkeys}, cache["kv"]))
    x = layer_norm(x, dp["ln_post"]["s"], dp["ln_post"]["b"])
    logits = (x @ params["embed"].T.astype(x.dtype))[:, 0]
    return logits, {"kv": kv_new, "memory": cache["memory"]}
