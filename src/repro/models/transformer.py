"""Decoder-only transformer LM covering the dense / moe / mla / vlm families:
qwen2.5-32b, deepseek-67b, deepseek-7b, gemma2-2b (local+global, softcaps),
qwen2-vl-2b (M-RoPE), deepseek-v2-lite (MLA+MoE), arctic-480b (MoE+dense
residual).

Layers are stacked on a leading (L, ...) dim and executed with lax.scan.
MoE configs with `first_dense_layers` keep those leading layers unstacked.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import mla as mla_mod
from repro.models import moe as moe_mod
from repro.models.common import (Options, activation, dense_init, embed_init,
                                 maybe_remat, ones_init, rms_norm, shard_hint,
                                 softcap)
from repro.models.rope import apply_rope, mrope_angles, rope_angles

# ---------------------------------------------------------------------------
# FFN
# ---------------------------------------------------------------------------


def init_ffn(key, cfg, n_layers: int, d_ff: Optional[int] = None):
    D, F = cfg.d_model, d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    L = (n_layers,) if n_layers else ()
    p = {"w1": dense_init(ks[0], L + (D, F), in_axis_size=D),
         "w2": dense_init(ks[2], L + (F, D), in_axis_size=F)}
    if cfg.gated_mlp:
        p["w3"] = dense_init(ks[1], L + (D, F), in_axis_size=D)
    return p


def apply_ffn(p, x, cfg):
    act = activation(cfg.act)
    h = x @ p["w1"].astype(x.dtype)
    if "w3" in p:
        h = act(h) * (x @ p["w3"].astype(x.dtype))
    else:
        h = act(h)
    h = shard_hint(h, "batch", None, "model_ff")
    return shard_hint(h @ p["w2"].astype(x.dtype), "batch", None, None)


# ---------------------------------------------------------------------------
# Block
# ---------------------------------------------------------------------------


def init_block(key, cfg, n_layers: int, *, use_moe: bool, d_ff: Optional[int] = None):
    ks = jax.random.split(key, 3)
    L = (n_layers,) if n_layers else ()
    p = {"ln1": ones_init(None, L + (cfg.d_model,)),
         "ln2": ones_init(None, L + (cfg.d_model,))}
    if cfg.rms_plus_one:          # gemma zero-centered scales
        p["ln1"] = p["ln1"] * 0.0
        p["ln2"] = p["ln2"] * 0.0
    if cfg.post_norms:            # independent buffers (donation-safe)
        p["pn1"] = jnp.array(p["ln1"])
        p["pn2"] = jnp.array(p["ln2"])
    if cfg.mla is not None:
        p["attn"] = mla_mod.init_mla(ks[0], cfg, n_layers)
    else:
        p["attn"] = attn.init_attention(ks[0], cfg, n_layers)
    if use_moe:
        p["mlp"] = moe_mod.init_moe(ks[1], cfg, n_layers)
    else:
        p["mlp"] = init_ffn(ks[1], cfg, n_layers, d_ff)
    return p


def _norm(x, scale, cfg):
    return rms_norm(x, scale, cfg.norm_eps, plus_one=cfg.rms_plus_one)


def _attn_scale(cfg) -> float:
    if cfg.query_pre_attn_scalar:
        return cfg.query_pre_attn_scalar ** -0.5
    return cfg.resolved_head_dim ** -0.5


def apply_block(bp, x, cfg, sin, cos, *, opts: Options, window=None,
                mode: str = "train", cache=None, positions=None):
    """One transformer block.

    mode: train | prefill | decode.
    cache: (k, v) (B,T,Hkv,hd) or MLA (ckv, krope) — required for decode.
    Returns (x, cache_out, aux) where cache_out is the new/filled cache
    entry (prefill/decode) or None (train).
    """
    aux = jnp.zeros((), jnp.float32)
    h = _norm(x, bp["ln1"], cfg)
    cache_out = None

    if cfg.mla is not None:
        if mode == "decode":
            a_out, cache_out = mla_mod.mla_decode(
                bp["attn"], h, cfg, sin, cos, cache, positions,
                absorb=opts.mla_absorb)
        else:
            a_out, kv = mla_mod.mla_forward(
                bp["attn"], h, cfg, sin, cos, q_block=opts.q_block,
                kv_block=opts.kv_block,
                skip_masked_blocks=opts.skip_masked_blocks, return_cache=True,
                probs_bf16=opts.probs_bf16)
            if mode == "prefill":
                cache_out = kv
    else:
        if mode == "decode":
            q, k_new, v_new = attn.project_qkv(bp["attn"], h, cfg)
            q = apply_rope(q, sin, cos)
            k_new = apply_rope(k_new, sin, cos)
            k_c, v_c = cache
            upd = jax.vmap(
                lambda c, n, i: jax.lax.dynamic_update_slice_in_dim(c, n, i, 0))
            k_c = upd(k_c, k_new.astype(k_c.dtype), positions)
            v_c = upd(v_c, v_new.astype(v_c.dtype), positions)
            ctx = attn.decode_attention(
                q, k_c.astype(q.dtype), v_c.astype(q.dtype), positions,
                window=window, logit_softcap=cfg.attn_logit_softcap,
                scale=_attn_scale(cfg))
            a_out = attn.project_out(bp["attn"], ctx, cfg)
            cache_out = (k_c, v_c)
        else:
            q, k, v = attn.project_qkv(bp["attn"], h, cfg)
            q = apply_rope(q, sin, cos)
            k = apply_rope(k, sin, cos)
            hq_pad = q.shape[2]
            ctx = attn.flash_attention(
                q, attn.expand_kv(k, hq_pad), attn.expand_kv(v, hq_pad),
                causal=True, window=window,
                logit_softcap=cfg.attn_logit_softcap, scale=_attn_scale(cfg),
                q_block=opts.q_block, kv_block=opts.kv_block,
                skip_masked_blocks=opts.skip_masked_blocks,
                probs_bf16=opts.probs_bf16)
            a_out = attn.project_out(bp["attn"], ctx, cfg)
            if mode == "prefill":
                cache_out = (k, v)

    if cfg.post_norms:
        a_out = _norm(a_out, bp["pn1"], cfg)
    x = x + a_out

    h = _norm(x, bp["ln2"], cfg)
    if "router" in bp["mlp"]:
        f_out, aux = moe_mod.apply_moe(bp["mlp"], h, cfg,
                                       group_size=opts.moe_group)
    else:
        f_out = apply_ffn(bp["mlp"], h, cfg)
    if cfg.post_norms:
        f_out = _norm(f_out, bp["pn2"], cfg)
    x = x + f_out
    return x, cache_out, aux


# ---------------------------------------------------------------------------
# Full LM
# ---------------------------------------------------------------------------


def _n_first(cfg) -> int:
    return cfg.moe.first_dense_layers if cfg.moe is not None else 0


def init_lm(key, cfg):
    ks = jax.random.split(key, 4 + _n_first(cfg))
    p = {"embed": embed_init(ks[0], (cfg.padded_vocab, cfg.d_model))}
    n_first = _n_first(cfg)
    if n_first:
        dff = cfg.moe.dense_d_ff or cfg.d_ff
        p["first"] = tuple(
            init_block(ks[3 + i], cfg, 0, use_moe=False, d_ff=dff)
            for i in range(n_first))
    p["blocks"] = init_block(ks[1], cfg, cfg.n_layers - n_first,
                             use_moe=cfg.moe is not None)
    p["final_norm"] = (ones_init(None, (cfg.d_model,)) * 0.0
                       if cfg.rms_plus_one else ones_init(None, (cfg.d_model,)))
    if not cfg.tie_embeddings:
        p["head"] = dense_init(ks[2], (cfg.d_model, cfg.padded_vocab),
                               in_axis_size=cfg.d_model)
    return p


def _layer_windows(cfg, n_layers: int, seq_len: int):
    """Per-layer window values (traced through scan), or None if all-global."""
    if not cfg.sliding_window:
        return None
    if not cfg.local_global_every:
        return jnp.full((n_layers,), cfg.sliding_window, jnp.int32)
    li = jnp.arange(n_layers)
    is_global = (li % cfg.local_global_every) == (cfg.local_global_every - 1)
    return jnp.where(is_global, jnp.int32(seq_len + 1),
                     jnp.int32(cfg.sliding_window))


def _embed(params, cfg, tokens, dtype):
    x = jnp.take(params["embed"], tokens, axis=0).astype(dtype)
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.d_model ** 0.5, dtype)
    return shard_hint(x, "batch", None, None)


def _head(params, cfg, x):
    if cfg.tie_embeddings:
        logits = x @ params["embed"].T.astype(x.dtype)
    else:
        logits = x @ params["head"].astype(x.dtype)
    if cfg.final_logit_softcap:
        logits = softcap(logits, cfg.final_logit_softcap)
    return shard_hint(logits, "batch", None, "vocab")


def _angles(cfg, positions, mrope_positions):
    hd = cfg.mla.qk_rope_head_dim if cfg.mla is not None else cfg.resolved_head_dim
    if cfg.mrope and mrope_positions is not None:
        return mrope_angles(mrope_positions, cfg.mrope_sections, hd, cfg.rope_theta)
    return rope_angles(positions, hd, cfg.rope_theta)


def forward(params, cfg, tokens, *, opts: Options = None, mode: str = "train",
            mrope_positions=None, dtype=jnp.bfloat16):
    """tokens (B,S) -> logits (B,S,Vp) [, cache] ; plus moe aux loss."""
    opts = opts or Options()
    B, S = tokens.shape
    x = _embed(params, cfg, tokens, dtype)
    positions = jnp.arange(S)
    sin, cos = _angles(cfg, positions, mrope_positions)
    windows = _layer_windows(cfg, cfg.n_layers - _n_first(cfg), S)
    aux_total = jnp.zeros((), jnp.float32)

    first_caches = []
    for fb in params.get("first", ()):
        x, c_out, aux_l = apply_block(fb, x, cfg, sin, cos, opts=opts,
                                      window=None, mode=mode)
        first_caches.append(c_out)
        aux_total = aux_total + aux_l

    def body(carry, xs):
        x, aux = carry
        bp = xs["bp"]
        w = xs.get("w")
        x, cache_out, aux_l = apply_block(bp, x, cfg, sin, cos, opts=opts,
                                          window=w, mode=mode)
        return (x, aux + aux_l), cache_out

    xs = {"bp": params["blocks"]}
    if windows is not None:
        xs["w"] = windows
    (x, aux_total), caches = jax.lax.scan(
        maybe_remat(body, opts.remat), (x, aux_total), xs)

    if mode == "prefill":
        # serving only needs next-token logits after prefill
        x_last = _norm(x[:, -1:], params["final_norm"], cfg)
        logits = _head(params, cfg, x_last)[:, 0]
        return logits, {"layers": caches, "first": tuple(first_caches)}, aux_total
    x = _norm(x, params["final_norm"], cfg)
    logits = _head(params, cfg, x)
    return logits, aux_total


def init_cache(cfg, batch: int, max_len: int, dtype=jnp.bfloat16, abstract=False):
    """Decode cache pytree. Leading L dim over scanned layers; `first` layers
    keep their own unstacked entries."""
    n_first = _n_first(cfg)
    L = cfg.n_layers - n_first
    mk = jax.ShapeDtypeStruct if abstract else (lambda s, d: jnp.zeros(s, d))
    if cfg.mla is not None:
        m = cfg.mla
        entry = (mk((L, batch, max_len, m.kv_lora_rank), dtype),
                 mk((L, batch, max_len, m.qk_rope_head_dim), dtype))
        first = tuple((mk((batch, max_len, m.kv_lora_rank), dtype),
                       mk((batch, max_len, m.qk_rope_head_dim), dtype))
                      for _ in range(n_first))
    else:
        hd = cfg.resolved_head_dim
        entry = (mk((L, batch, max_len, cfg.n_kv_heads, hd), dtype),
                 mk((L, batch, max_len, cfg.n_kv_heads, hd), dtype))
        first = tuple((mk((batch, max_len, cfg.n_kv_heads, hd), dtype),
                       mk((batch, max_len, cfg.n_kv_heads, hd), dtype))
                      for _ in range(n_first))
    return {"layers": entry, "first": first}


def decode_step(params, cfg, tokens, positions, cache, *, opts: Options = None,
                dtype=jnp.bfloat16):
    """One token per sequence. tokens/positions (B,). Returns (logits (B,Vp),
    new_cache, aux)."""
    opts = opts or Options()
    B = tokens.shape[0]
    x = _embed(params, cfg, tokens[:, None], dtype)
    pos2d = positions[:, None]                       # (B,1)
    if cfg.mrope:
        mpos = jnp.broadcast_to(pos2d[None], (3, B, 1))
        sin, cos = _angles(cfg, pos2d, mpos)
    else:
        sin, cos = _angles(cfg, pos2d, None)
    S_max = jax.tree_util.tree_leaves(cache["layers"])[0].shape[2]
    windows = _layer_windows(cfg, cfg.n_layers - _n_first(cfg), S_max)

    new_first = []
    for fb, fc in zip(params.get("first", ()), cache["first"]):
        x, c_out, _ = apply_block(fb, x, cfg, sin, cos, opts=opts, window=None,
                                  mode="decode", cache=fc, positions=positions)
        new_first.append(c_out)

    def body(x, xs):
        bp = xs["bp"]
        w = xs.get("w")
        cache_l = xs["cache"]
        x, c_out, _ = apply_block(bp, x, cfg, sin, cos, opts=opts, window=w,
                                  mode="decode", cache=cache_l,
                                  positions=positions)
        return x, c_out

    xs = {"bp": params["blocks"], "cache": cache["layers"]}
    if windows is not None:
        xs["w"] = windows
    x, new_layers = jax.lax.scan(body, x, xs)

    x = _norm(x, params["final_norm"], cfg)
    logits = _head(params, cfg, x)[:, 0]
    return logits, {"layers": new_layers, "first": tuple(new_first)}
