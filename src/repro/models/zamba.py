"""Zamba2-style hybrid: Mamba2 backbone + weight-shared attention blocks.

`cfg.n_layers` Mamba2 layers are grouped; after every `cfg.attn_every`
Mamba layers, a single weight-SHARED transformer block (attention + FFN,
operating on concat(hidden, embedding) — 2*d_model in) is applied, followed
by a per-application (unshared) linear adapter back to d_model, following
the Zamba2 design.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import mamba2
from repro.models.common import (Options, dense_init, embed_init, ones_init,
                                 rms_norm, shard_hint)
from repro.models.rope import apply_rope, rope_angles
from repro.models.transformer import apply_ffn, init_ffn


def n_groups(cfg) -> int:
    assert cfg.n_layers % cfg.attn_every == 0
    return cfg.n_layers // cfg.attn_every


def init_lm(key, cfg):
    ks = jax.random.split(key, 8)
    G = n_groups(cfg)
    shared = {
        "ln1": ones_init(None, (2 * cfg.d_model,)),
        "attn": attn.init_attention(ks[1], cfg, 0, d_in=2 * cfg.d_model),
        "ln2": ones_init(None, (cfg.d_model,)),
        "mlp": init_ffn(ks[2], cfg, 0),
    }
    return {
        "embed": embed_init(ks[0], (cfg.padded_vocab, cfg.d_model)),
        "mamba_ln": ones_init(None, (cfg.n_layers, cfg.d_model)),
        "mamba": mamba2.init_mamba(ks[3], cfg, cfg.n_layers),
        "shared": shared,
        "adapters": dense_init(ks[4], (G, cfg.d_model, cfg.d_model),
                               in_axis_size=cfg.d_model),
        "final_norm": ones_init(None, (cfg.d_model,)),
        "head": dense_init(ks[5], (cfg.d_model, cfg.padded_vocab),
                           in_axis_size=cfg.d_model),
    }


def _shared_block(params, cfg, x, x0, sin, cos, adapter, *, opts,
                  mode: str = "train", cache=None, positions=None):
    """Shared attention block on concat(x, x0); adapter projects back."""
    sp = params["shared"]
    h = jnp.concatenate([x, x0], axis=-1)
    h = rms_norm(h, sp["ln1"], cfg.norm_eps)
    cache_out = None
    if mode == "decode":
        q, k_new, v_new = attn.project_qkv(sp["attn"], h, cfg)
        q = apply_rope(q, sin, cos)
        k_new = apply_rope(k_new, sin, cos)
        k_c, v_c = cache
        upd = jax.vmap(
            lambda c, n, i: jax.lax.dynamic_update_slice_in_dim(c, n, i, 0))
        k_c = upd(k_c, k_new.astype(k_c.dtype), positions)
        v_c = upd(v_c, v_new.astype(v_c.dtype), positions)
        ctx = attn.decode_attention(q, k_c.astype(q.dtype), v_c.astype(q.dtype),
                                    positions, scale=cfg.resolved_head_dim ** -0.5)
        cache_out = (k_c, v_c)
    else:
        q, k, v = attn.project_qkv(sp["attn"], h, cfg)
        q = apply_rope(q, sin, cos)
        k = apply_rope(k, sin, cos)
        hq_pad = q.shape[2]
        ctx = attn.flash_attention(q, attn.expand_kv(k, hq_pad),
                                   attn.expand_kv(v, hq_pad), causal=True,
                                   scale=cfg.resolved_head_dim ** -0.5,
                                   q_block=opts.q_block, kv_block=opts.kv_block,
                                   skip_masked_blocks=opts.skip_masked_blocks,
                                   probs_bf16=opts.probs_bf16)
        if mode == "prefill":
            cache_out = (k, v)
    a = attn.project_out(sp["attn"], ctx, cfg)
    a = a + apply_ffn(sp["mlp"], rms_norm(a, sp["ln2"], cfg.norm_eps), cfg)
    return x + a @ adapter.astype(x.dtype), cache_out


def forward(params, cfg, tokens, *, opts: Options = None, mode: str = "train",
            dtype=jnp.bfloat16, **_):
    opts = opts or Options()
    B, S = tokens.shape
    G, E = n_groups(cfg), cfg.attn_every
    x = jnp.take(params["embed"], tokens, axis=0).astype(dtype)
    x = shard_hint(x, "batch", None, None)
    x0 = x
    sin, cos = rope_angles(jnp.arange(S), cfg.resolved_head_dim, cfg.rope_theta)

    # reshape stacked mamba params to (G, E, ...)
    mam = jax.tree_util.tree_map(
        lambda a: a.reshape((G, E) + a.shape[1:]), params["mamba"])
    mam_ln = params["mamba_ln"].reshape(G, E, -1)

    def group(x, xs):
        mam_g, ln_g, adapter = xs

        def mamba_layer(x, lxs):
            mp, ln = lxs
            h = rms_norm(x, ln, cfg.norm_eps)
            return x + mamba2.mamba_forward(mp, h, cfg), None

        x, _ = jax.lax.scan(mamba_layer, x, (mam_g, ln_g))
        x, cache_out = _shared_block(params, cfg, x, x0, sin, cos, adapter,
                                     opts=opts, mode=mode)
        return x, cache_out

    x, caches = jax.lax.scan(group, x, (mam, mam_ln, params["adapters"]))
    if mode == "prefill":
        x_last = rms_norm(x[:, -1:], params["final_norm"], cfg.norm_eps)
        logits = (x_last @ params["head"].astype(x.dtype))[:, 0]
        # NOTE: prefill here returns only attention caches; mamba states are
        # returned by serve-level prefill via forward_with_states.
        return logits, caches, jnp.zeros((), jnp.float32)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = x @ params["head"].astype(x.dtype)
    return shard_hint(logits, "batch", None, "vocab"), jnp.zeros((), jnp.float32)


def init_cache(cfg, batch: int, max_len: int, dtype=jnp.bfloat16, abstract=False):
    """Attention KV caches (per shared-block application) + mamba states."""
    G = n_groups(cfg)
    hd = cfg.resolved_head_dim
    mk = jax.ShapeDtypeStruct if abstract else (lambda s, d: jnp.zeros(s, d))
    return {
        "kv": (mk((G, batch, max_len, cfg.n_kv_heads, hd), dtype),
               mk((G, batch, max_len, cfg.n_kv_heads, hd), dtype)),
        "mamba": mamba2.init_mamba_state(cfg, batch, abstract=abstract,
                                         n_layers=cfg.n_layers),
    }


def decode_step(params, cfg, tokens, positions, cache, *, opts: Options = None,
                dtype=jnp.bfloat16):
    opts = opts or Options()
    B = tokens.shape[0]
    G, E = n_groups(cfg), cfg.attn_every
    x = jnp.take(params["embed"], tokens, axis=0)[:, None].astype(dtype)
    x0 = x
    sin, cos = rope_angles(positions[:, None], cfg.resolved_head_dim,
                           cfg.rope_theta)
    mam = jax.tree_util.tree_map(
        lambda a: a.reshape((G, E) + a.shape[1:]), params["mamba"])
    mam_ln = params["mamba_ln"].reshape(G, E, -1)
    mstate = jax.tree_util.tree_map(
        lambda a: a.reshape((G, E) + a.shape[1:]), cache["mamba"])

    def group(x, xs):
        mam_g, ln_g, adapter, kv_g, mst_g = xs

        def mamba_layer(x, lxs):
            mp, ln, st = lxs
            h = rms_norm(x, ln, cfg.norm_eps)
            o, st1 = mamba2.mamba_decode(mp, h, cfg, st)
            return x + o, st1

        x, mst1 = jax.lax.scan(mamba_layer, x, (mam_g, ln_g, mst_g))
        x, kv1 = _shared_block(params, cfg, x, x0, sin, cos, adapter,
                               opts=opts, mode="decode", cache=kv_g,
                               positions=positions)
        return x, (kv1, mst1)

    x, (kv_new, mst_new) = jax.lax.scan(
        group, x, (mam, mam_ln, params["adapters"], cache["kv"], mstate))
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = (x @ params["head"].astype(x.dtype))[:, 0]
    new_cache = {
        "kv": kv_new,
        "mamba": jax.tree_util.tree_map(
            lambda a: a.reshape((G * E,) + a.shape[2:]), mst_new),
    }
    return logits, new_cache
