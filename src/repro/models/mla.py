"""Multi-head Latent Attention (DeepSeek-V2): low-rank compressed KV cache.

Cache per token is (kv_lora_rank + qk_rope_head_dim) floats — ~9x smaller
than full GQA KV.  Decode supports two paths:
  * naive   — decompress the whole cache to K/V each step (baseline)
  * absorb  — fold W_uk into the query and W_uv into the output so attention
              runs directly against the compressed cache (§Perf hillclimb)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.attention import flash_attention
from repro.models.common import dense_init, ones_init, rms_norm, shard_hint
from repro.models.rope import apply_rope, rope_angles


def init_mla(key, cfg, n_layers: int):
    m = cfg.mla
    D, H = cfg.d_model, cfg.n_heads
    dn, dr, dv, r = m.qk_nope_head_dim, m.qk_rope_head_dim, m.v_head_dim, m.kv_lora_rank
    ks = jax.random.split(key, 5)
    L = (n_layers,) if n_layers else ()
    return {
        "wq": dense_init(ks[0], L + (D, H * (dn + dr)), in_axis_size=D),
        "wdkv": dense_init(ks[1], L + (D, r + dr), in_axis_size=D),
        "kv_norm": ones_init(None, L + (r,)),
        "wuk": dense_init(ks[2], L + (r, H * dn), in_axis_size=r),
        "wuv": dense_init(ks[3], L + (r, H * dv), in_axis_size=r),
        "wo": dense_init(ks[4], L + (H * dv, D), in_axis_size=H * dv),
    }


def _project_q(p, x, cfg, sin, cos):
    m = cfg.mla
    H, dn, dr = cfg.n_heads, m.qk_nope_head_dim, m.qk_rope_head_dim
    B, S, _ = x.shape
    q = shard_hint(x @ p["wq"].astype(x.dtype), "batch", None, "model_ff")
    q = q.reshape(B, S, H, dn + dr)
    qn, qr = q[..., :dn], q[..., dn:]
    qr = apply_rope(qr, sin, cos)
    return qn, qr


def _compress_kv(p, x, cfg, sin, cos):
    m = cfg.mla
    r, dr = m.kv_lora_rank, m.qk_rope_head_dim
    ckv_full = x @ p["wdkv"].astype(x.dtype)          # (B,S,r+dr)
    ckv = rms_norm(ckv_full[..., :r], p["kv_norm"], cfg.norm_eps)
    krope = apply_rope(ckv_full[..., None, r:], sin, cos)[:, :, 0]  # (B,S,dr)
    return ckv, krope


def mla_forward(p, x, cfg, sin, cos, *, q_block=1024, kv_block=1024,
                skip_masked_blocks=False, return_cache=False,
                probs_bf16=False):
    """Training / prefill: full-sequence causal MLA."""
    m = cfg.mla
    H, dn, dr, dv = cfg.n_heads, m.qk_nope_head_dim, m.qk_rope_head_dim, m.v_head_dim
    B, S, _ = x.shape
    qn, qr = _project_q(p, x, cfg, sin, cos)
    ckv, krope = _compress_kv(p, x, cfg, sin, cos)
    kn = shard_hint(ckv @ p["wuk"].astype(x.dtype), "batch", None, "model_ff")
    v = shard_hint(ckv @ p["wuv"].astype(x.dtype), "batch", None, "model_ff")
    kn = kn.reshape(B, S, H, dn)
    v = v.reshape(B, S, H, dv)
    q = jnp.concatenate([qn, qr], axis=-1)
    k = jnp.concatenate([kn, jnp.broadcast_to(krope[:, :, None, :],
                                              (B, S, H, dr))], axis=-1)
    scale = (dn + dr) ** -0.5
    ctx = flash_attention(q, k, v, causal=True, scale=scale, q_block=q_block,
                          kv_block=kv_block, skip_masked_blocks=skip_masked_blocks,
                          probs_bf16=probs_bf16)
    out = ctx.reshape(B, S, H * dv) @ p["wo"].astype(x.dtype)
    out = shard_hint(out, "batch", None, None)
    if return_cache:
        return out, (ckv, krope)
    return out


def mla_decode(p, x, cfg, sin, cos, cache, positions, *, absorb: bool = False):
    """One decode step. x: (B,1,D). cache: (ckv (B,T,r), krope (B,T,dr)).

    Returns (out (B,1,D), new_cache).
    """
    m = cfg.mla
    H, dn, dr, dv, r = (cfg.n_heads, m.qk_nope_head_dim, m.qk_rope_head_dim,
                        m.v_head_dim, m.kv_lora_rank)
    B = x.shape[0]
    ckv_c, krope_c = cache
    T = ckv_c.shape[1]
    qn, qr = _project_q(p, x, cfg, sin, cos)              # (B,1,H,dn/dr)
    ckv_new, krope_new = _compress_kv(p, x, cfg, sin, cos)
    # write into cache at `positions`
    upd = jax.vmap(lambda c, n, i: jax.lax.dynamic_update_slice_in_dim(c, n, i, 0))
    ckv_c = upd(ckv_c, ckv_new.astype(ckv_c.dtype), positions)
    krope_c = upd(krope_c, krope_new.astype(krope_c.dtype), positions)

    kpos = jnp.arange(T)
    allow = kpos[None, :] <= positions[:, None]           # (B,T)
    scale = (dn + dr) ** -0.5

    if absorb:
        wuk = p["wuk"].astype(x.dtype).reshape(r, H, dn)
        # fold W_uk into q: scores_nope = (q_abs · ckv)
        q_abs = jnp.einsum("bshd,rhd->bshr", qn, wuk)     # (B,1,H,r)
        s = (jnp.einsum("bshr,btr->bhst", q_abs.astype(jnp.float32),
                        ckv_c.astype(jnp.float32))
             + jnp.einsum("bshd,btd->bhst", qr.astype(jnp.float32),
                          krope_c.astype(jnp.float32))) * scale
        s = jnp.where(allow[:, None, None, :], s, -2.0e38)
        prob = jax.nn.softmax(s, axis=-1)
        ctx_r = jnp.einsum("bhst,btr->bshr", prob, ckv_c.astype(jnp.float32))
        wuv = p["wuv"].astype(x.dtype).reshape(r, H, dv)
        ctx = jnp.einsum("bshr,rhd->bshd", ctx_r.astype(x.dtype), wuv)
    else:
        kn = (ckv_c.astype(x.dtype) @ p["wuk"].astype(x.dtype)).reshape(B, T, H, dn)
        vv = (ckv_c.astype(x.dtype) @ p["wuv"].astype(x.dtype)).reshape(B, T, H, dv)
        q = jnp.concatenate([qn, qr], axis=-1)
        k = jnp.concatenate([kn, jnp.broadcast_to(krope_c.astype(x.dtype)[:, :, None, :],
                                                  (B, T, H, dr))], axis=-1)
        s = jnp.einsum("bshe,bthe->bhst", q.astype(jnp.float32),
                       k.astype(jnp.float32)) * scale
        s = jnp.where(allow[:, None, None, :], s, -2.0e38)
        prob = jax.nn.softmax(s, axis=-1)
        ctx = jnp.einsum("bhst,bthd->bshd", prob, vv.astype(jnp.float32)).astype(x.dtype)

    out = ctx.reshape(B, 1, H * dv) @ p["wo"].astype(x.dtype)
    return out, (ckv_c, krope_c)


def init_mla_cache(cfg, batch: int, max_len: int, dtype=jnp.bfloat16, n_layers=None):
    m = cfg.mla
    L = (n_layers,) if n_layers else ()
    return (jnp.zeros(L + (batch, max_len, m.kv_lora_rank), dtype),
            jnp.zeros(L + (batch, max_len, m.qk_rope_head_dim), dtype))


def mla_cache_specs(cfg, batch: int, max_len: int, dtype=jnp.bfloat16, n_layers=None):
    m = cfg.mla
    L = (n_layers,) if n_layers else ()
    sds = jax.ShapeDtypeStruct
    return (sds(L + (batch, max_len, m.kv_lora_rank), dtype),
            sds(L + (batch, max_len, m.qk_rope_head_dim), dtype))
