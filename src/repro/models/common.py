"""Shared building blocks: init helpers, norms, activations, sharding hints.

Parameters are plain nested dicts of jnp arrays.  Layer-stacked parameters
carry a leading ``(L, ...)`` dim and are consumed by ``jax.lax.scan`` — this
keeps compile time O(1) in depth (required for 95-layer models lowered on a
512-device mesh).
"""
from __future__ import annotations

import contextlib
import math
import threading
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

# ---------------------------------------------------------------------------
# Ambient mesh for sharding hints (no-op when absent => CPU smoke tests)
# ---------------------------------------------------------------------------

_STATE = threading.local()


def current_mesh():
    return getattr(_STATE, "mesh", None)


def current_rules():
    return getattr(_STATE, "rules", None)


@contextlib.contextmanager
def mesh_context(mesh, rules=None):
    """Install an ambient mesh (+ logical sharding rules) for `shard_hint`."""
    prev = (getattr(_STATE, "mesh", None), getattr(_STATE, "rules", None))
    _STATE.mesh, _STATE.rules = mesh, rules
    try:
        yield mesh
    finally:
        _STATE.mesh, _STATE.rules = prev


def shard_hint(x, *logical_axes):
    """with_sharding_constraint against the ambient mesh via logical axis
    names ("batch", "seq", "model_d", "vocab", "expert", ...). No-op when no
    mesh is installed."""
    mesh = current_mesh()
    if mesh is None:
        return x
    rules = current_rules() or {}
    spec = P(*[rules.get(a) if isinstance(a, str) else a for a in logical_axes])
    return jax.lax.with_sharding_constraint(
        x, jax.sharding.NamedSharding(mesh, spec))


# ---------------------------------------------------------------------------
# Initializers
# ---------------------------------------------------------------------------


def dense_init(key, shape, in_axis_size: Optional[int] = None, dtype=jnp.float32):
    """Truncated-normal fan-in init. `shape` may include a leading stack dim —
    pass `in_axis_size` explicitly for stacked weights."""
    fan_in = in_axis_size if in_axis_size is not None else shape[-2]
    std = 1.0 / math.sqrt(fan_in)
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            * std).astype(dtype)


def embed_init(key, shape, dtype=jnp.float32):
    return (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(dtype)


def zeros_init(_key, shape, dtype=jnp.float32):
    return jnp.zeros(shape, dtype)


def ones_init(_key, shape, dtype=jnp.float32):
    return jnp.ones(shape, dtype)


def split_keys(key, n):
    return list(jax.random.split(key, n))


# ---------------------------------------------------------------------------
# Norms & activations (computed in fp32, cast back)
# ---------------------------------------------------------------------------


def rms_norm(x, scale, eps: float = 1e-6, *, plus_one: bool = False):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    s = scale.astype(jnp.float32)
    if plus_one:            # gemma-style (1 + scale)
        s = 1.0 + s
    return (y * s).astype(dtype)


def layer_norm(x, scale, bias, eps: float = 1e-5):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dtype)


def activation(name: str):
    return {
        "silu": jax.nn.silu,
        "gelu": lambda x: jax.nn.gelu(x, approximate=True),
        "relu": jax.nn.relu,
        "relu2": lambda x: jnp.square(jax.nn.relu(x)),
    }[name]


def softcap(x, cap: float):
    """Logit soft-capping: cap * tanh(x / cap) (Gemma2)."""
    if not cap:
        return x
    return cap * jnp.tanh(x / cap)


# ---------------------------------------------------------------------------
# Losses
# ---------------------------------------------------------------------------


def softmax_xent(logits, labels, vocab_size: int, z_loss: float = 1e-4):
    """Cross-entropy with optional z-loss; logits in fp32. labels == -1 are
    masked out. `vocab_size` masks padded vocab rows."""
    logits = logits.astype(jnp.float32)
    if vocab_size < logits.shape[-1]:
        # elementwise mask (a scatter here would force XLA to all-gather the
        # full sharded logits — 13.6 GB/device on gemma-sized vocabs)
        vmask = jax.lax.broadcasted_iota(
            jnp.int32, (logits.shape[-1],), 0) < vocab_size
        logits = jnp.where(vmask, logits, -1e9)
    valid = labels >= 0
    labels = jnp.where(valid, labels, 0)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - gold
    if z_loss:
        nll = nll + z_loss * jnp.square(lse)
    nll = jnp.where(valid, nll, 0.0)
    denom = jnp.maximum(jnp.sum(valid), 1)
    return jnp.sum(nll) / denom


class Options:
    """Runtime knobs threaded through model apply (perf hillclimb levers)."""

    def __init__(self, *, q_block: int = 1024, kv_block: int = 1024,
                 skip_masked_blocks: bool = False, mla_absorb: bool = False,
                 remat: str = "none", moe_group: int = 1024,
                 fused_xent: bool = False, probs_bf16: bool = False):
        self.q_block = q_block
        self.kv_block = kv_block
        self.skip_masked_blocks = skip_masked_blocks
        self.mla_absorb = mla_absorb
        self.remat = remat
        self.moe_group = moe_group
        self.fused_xent = fused_xent
        self.probs_bf16 = probs_bf16      # bf16 attention probs for the PV matmul

    def replace(self, **kw):
        cur = dict(q_block=self.q_block, kv_block=self.kv_block,
                   skip_masked_blocks=self.skip_masked_blocks,
                   mla_absorb=self.mla_absorb, remat=self.remat,
                   moe_group=self.moe_group, fused_xent=self.fused_xent,
                   probs_bf16=self.probs_bf16)
        cur.update(kw)
        return Options(**cur)


@jax.custom_vjp
def grad_cast(x):
    """Identity whose COTANGENT is cast to the primal dtype — mixed-precision
    boundary guard: fp32 attention internals otherwise push fp32 cotangents
    into the tensor-parallel matmul VJPs, doubling the backward all-reduce
    bytes."""
    return x


def _gc_fwd(x):
    return x, jnp.zeros((0,), x.dtype)      # dtype carrier (a raw dtype is
                                            # not a valid JAX residual)


def _gc_bwd(carrier, g):
    return (g.astype(carrier.dtype),)


grad_cast.defvjp(_gc_fwd, _gc_bwd)


def maybe_remat(fn, remat: str):
    if remat == "none":
        return fn
    if remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots)
    return jax.checkpoint(fn)          # "full": save nothing


def param_count(params) -> int:
    return sum(int(x.size) for x in jax.tree_util.tree_leaves(params))


def param_bytes(params) -> int:
    return sum(int(x.size) * x.dtype.itemsize for x in jax.tree_util.tree_leaves(params))
