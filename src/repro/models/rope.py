"""Rotary position embeddings: standard RoPE and Qwen2-VL M-RoPE."""
from __future__ import annotations

import jax.numpy as jnp


def rope_angles(positions, head_dim: int, theta: float):
    """positions (..., S) int -> (sin, cos) of shape (..., S, head_dim//2)."""
    half = head_dim // 2
    inv_freq = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * inv_freq
    return jnp.sin(ang), jnp.cos(ang)


def mrope_angles(mpositions, sections, head_dim: int, theta: float):
    """Multimodal RoPE (Qwen2-VL).

    mpositions: (3, B, S) — temporal / height / width position streams.
    sections:   per-stream rotary half-dims, summing to head_dim//2.
    Returns (sin, cos) of shape (B, S, head_dim//2).
    """
    half = head_dim // 2
    assert sum(sections) == half, (sections, half)
    inv_freq = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    stream = jnp.repeat(jnp.arange(len(sections)), jnp.array(sections),
                        total_repeat_length=half)          # (half,)
    pos = jnp.take(mpositions, stream, axis=0)             # (half, B, S)
    pos = jnp.moveaxis(pos, 0, -1).astype(jnp.float32)     # (B, S, half)
    ang = pos * inv_freq
    return jnp.sin(ang), jnp.cos(ang)


def apply_rope(x, sin, cos):
    """x: (..., S, H, hd); sin/cos: (..., S, hd//2) broadcast over heads.
    Half-split (llama) convention."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    s, c = sin[..., None, :], cos[..., None, :]
    x1f, x2f = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate([x1f * c - x2f * s, x2f * c + x1f * s], axis=-1)
    return out.astype(x.dtype)
