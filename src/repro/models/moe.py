"""Mixture-of-Experts FFN: grouped, capacity-dropped, expert-parallel.

Dispatch uses per-group scatter/gather (no (tokens, E, C) one-hot
materialization); experts are sharded on the `model` mesh axis (EP), tokens
on `data` — GSPMD inserts the dispatch/combine collectives.

Shared experts (DeepSeek-V2) and the Arctic dense residual are merged into a
single wide "shared" gated FFN applied to every token.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import activation, dense_init, shard_hint


def shared_width(cfg) -> int:
    m = cfg.moe
    w = m.n_shared_experts * m.d_expert
    if m.dense_residual:
        w += m.dense_d_ff or cfg.d_ff
    return w


def init_moe(key, cfg, n_layers: int):
    m = cfg.moe
    D, E, F = cfg.d_model, m.n_experts, m.d_expert
    ks = jax.random.split(key, 7)
    L = (n_layers,) if n_layers else ()
    p = {
        "router": dense_init(ks[0], L + (D, E), in_axis_size=D),
        "w1": dense_init(ks[1], L + (E, D, F), in_axis_size=D),
        "w3": dense_init(ks[2], L + (E, D, F), in_axis_size=D),
        "w2": dense_init(ks[3], L + (E, F, D), in_axis_size=F),
    }
    sw = shared_width(cfg)
    if sw:
        p["ws1"] = dense_init(ks[4], L + (D, sw), in_axis_size=D)
        p["ws3"] = dense_init(ks[5], L + (D, sw), in_axis_size=D)
        p["ws2"] = dense_init(ks[6], L + (sw, D), in_axis_size=sw)
    return p


def _capacity(g: int, k: int, cf: float, E: int) -> int:
    c = int(g * k * cf / E)
    c = max(8, ((c + 7) // 8) * 8)
    return min(c, g * k)


def apply_moe(p, x, cfg, *, group_size: int = 1024):
    """x: (B, S, D) -> (out (B,S,D), aux_loss scalar)."""
    m = cfg.moe
    E, k = m.n_experts, m.top_k
    B, S, D = x.shape
    T = B * S
    xf = x.reshape(T, D)
    g = min(group_size, T)
    while T % g:
        g //= 2
    G = T // g
    xg = shard_hint(xf.reshape(G, g, D), "moe_groups", None, None)

    logits = (xg.astype(jnp.float32) @ p["router"].astype(jnp.float32))  # (G,g,E)
    probs = jax.nn.softmax(logits, axis=-1)
    vals, idx = jax.lax.top_k(probs, k)                                   # (G,g,k)
    vals = vals / jnp.maximum(jnp.sum(vals, axis=-1, keepdims=True), 1e-9)

    # Switch-style load-balance aux loss
    me = jnp.mean(probs, axis=(0, 1))                                     # (E,)
    ce = jnp.mean(jnp.sum(jax.nn.one_hot(idx, E, dtype=jnp.float32), axis=2),
                  axis=(0, 1))                                            # (E,)
    aux = E * jnp.sum(me * ce) * m.router_aux_loss

    C = _capacity(g, k, m.capacity_factor, E)

    # GShard choice-major slot assignment: all 1st choices, then 2nd, ...
    idx_km = idx.transpose(0, 2, 1).reshape(G, k * g)                     # (G,k*g)
    oh = jax.nn.one_hot(idx_km, E, dtype=jnp.int32)                       # (G,k*g,E)
    slot = jnp.cumsum(oh, axis=1) - oh                                    # pos within expert
    slot = jnp.sum(slot * oh, axis=-1)                                    # (G,k*g)
    keep = slot < C

    gate_km = vals.transpose(0, 2, 1).reshape(G, k * g)
    tok_km = jnp.tile(jnp.arange(g), (k,))                                # (k*g,)

    def dispatch_one(xg1, e1, s1, keep1):
        upd = xg1[tok_km] * keep1[:, None].astype(xg1.dtype)              # (k*g, D)
        buf = jnp.zeros((E, C, D), xg1.dtype)
        return buf.at[e1, jnp.where(keep1, s1, 0)].add(
            jnp.where(keep1[:, None], upd, 0))

    ein = jax.vmap(dispatch_one)(xg, idx_km, slot, keep)                  # (G,E,C,D)
    # 2D-weight mode: slice the dispatch on the contraction dim ("moe_ff" ->
    # data) so the expert matmul is a partial-dot + tiny psum — weights never
    # move (GSPMD would otherwise all-to-all the expert weights each layer)
    ein = shard_hint(ein, "moe_groups", "expert", None, "moe_ff")

    act = activation(cfg.act)
    h = jnp.einsum("gecd,edf->gecf", ein, p["w1"].astype(ein.dtype))
    h = act(h) * jnp.einsum("gecd,edf->gecf", ein, p["w3"].astype(ein.dtype))
    h = shard_hint(h, "moe_groups", "expert", None, "moe_ff")
    eout = jnp.einsum("gecf,efd->gecd", h, p["w2"].astype(ein.dtype))
    eout = shard_hint(eout, "moe_groups", "expert", None, None)

    def combine_one(eo1, e1, s1, keep1, gate1):
        y = eo1[e1, s1] * (gate1 * keep1)[:, None].astype(eo1.dtype)      # (k*g,D)
        return jnp.sum(y.reshape(k, g, D), axis=0)

    y = jax.vmap(combine_one)(eout, idx_km, slot, keep,
                              gate_km.astype(eout.dtype))                 # (G,g,D)
    y = y.reshape(B, S, D)

    if "ws1" in p:
        hs = xf.reshape(B, S, D) @ p["ws1"].astype(x.dtype)
        hs = act(hs) * (xf.reshape(B, S, D) @ p["ws3"].astype(x.dtype))
        hs = shard_hint(hs, "batch", None, "model_ff")
        y = y + hs @ p["ws2"].astype(x.dtype)

    return shard_hint(y, "batch", None, None), aux
