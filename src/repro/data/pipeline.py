"""Data pipeline: synthetic corpus -> tokenize -> pack -> global batches.

Built on mpi-list (`repro.core.mpi_list`): documents are a DFM, tokenize is
`flatMap`, packing is `repartition` into fixed-length sequences — the
paper's §2.3 tool as the framework's input pipeline.  Deterministic per
(seed, epoch); each call of `batches()` yields {tokens, labels} with
labels = next-token (shifted), -1 padding masked.
"""
from __future__ import annotations

import numpy as np

from repro.core.mpi_list import Context


class SyntheticCorpus:
    """Zipf-ish token documents (no external data needed offline)."""

    def __init__(self, vocab_size: int, *, seed: int = 0,
                 mean_len: int = 512):
        self.vocab = vocab_size
        self.seed = seed
        self.mean_len = mean_len

    def docs(self, n: int, epoch: int = 0) -> list:
        rng = np.random.default_rng(self.seed + 1000 * epoch)
        out = []
        for _ in range(n):
            ln = int(rng.integers(self.mean_len // 2, self.mean_len * 2))
            # zipf-flavored ids clipped to vocab
            ids = rng.zipf(1.3, size=ln) % (self.vocab - 3)
            out.append(ids.astype(np.int32) + 2)      # 0=pad,1=bos reserved
        return out


def pack_documents(ctx: Context, docs: list, seq_len: int) -> np.ndarray:
    """mpi-list pipeline: scatter docs -> flatMap(tokens + EOS) ->
    repartition into (n_seq, seq_len) rows."""
    dfm = ctx.scatter(docs)
    tokens = dfm.flatMap(lambda d: list(d) + [1])       # EOS/BOS separator
    flat = np.asarray(tokens.collect(), dtype=np.int32)
    n_seq = len(flat) // seq_len
    return flat[: n_seq * seq_len].reshape(n_seq, seq_len)


class Pipeline:
    def __init__(self, vocab_size: int, seq_len: int, global_batch: int, *,
                 seed: int = 0, n_ranks: int = 4):
        self.corpus = SyntheticCorpus(vocab_size, seed=seed)
        self.ctx = Context(n_ranks)
        self.seq_len = seq_len
        self.global_batch = global_batch
        self._buf = np.zeros((0, seq_len + 1), np.int32)
        self._epoch = 0

    def _refill(self):
        need_tokens = self.global_batch * (self.seq_len + 1) * 2
        n_docs = max(8, need_tokens // self.corpus.mean_len)
        packed = pack_documents(self.ctx, self.corpus.docs(n_docs, self._epoch),
                                self.seq_len + 1)
        self._epoch += 1
        self._buf = np.concatenate([self._buf, packed], axis=0)

    def batches(self, n_steps: int):
        for _ in range(n_steps):
            while len(self._buf) < self.global_batch:
                self._refill()
            chunk, self._buf = (self._buf[: self.global_batch],
                                self._buf[self.global_batch:])
            yield {"tokens": chunk[:, :-1],
                   "labels": chunk[:, 1:].astype(np.int32)}
