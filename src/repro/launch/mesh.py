"""Production mesh definitions.

Single pod:  (data=16, model=16)            = 256 chips (TPU v5e pod)
Multi-pod:   (pod=2, data=16, model=16)     = 512 chips

Defined as functions so importing this module never touches jax device
state (the dry-run sets XLA_FLAGS before any jax initialization)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Degenerate 1-device mesh for CPU smoke tests."""
    return jax.make_mesh((1, 1), ("data", "model"))


# TPU v5e hardware constants (roofline denominators)
PEAK_FLOPS_BF16 = 197e12          # per chip
HBM_BW = 819e9                    # bytes/s per chip
ICI_BW = 50e9                     # bytes/s per link
