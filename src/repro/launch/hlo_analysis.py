"""Post-compile HLO analysis: flop/byte/collective accounting + roofline.

XLA's `cost_analysis()` counts `while` bodies ONCE (verified empirically:
a scan of 8 matmuls reports the flops of 1), so for scan-over-layers models
it undercounts by ~n_layers.  We therefore parse the optimized (SPMD-
partitioned, per-device) HLO text ourselves:

  * build the computation call graph (while body/condition, fusion `calls`,
    reduce `to_apply`, conditional branches),
  * extract while trip counts from the canonical compare-against-constant
    in loop conditions,
  * walk from ENTRY with execution multipliers,
  * count: dot flops (2 * out_elems * contraction) wherever they appear
    (incl. inside fused computations), HBM bytes for top-level ops of
    non-fused computations (operands + outputs — a fusion-aware traffic
    model), and collective bytes by kind.

Everything is per-device (the SPMD module is the per-device program).
"""
from __future__ import annotations

import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "s32": 4, "u32": 4,
    "s64": 8, "u64": 8, "f16": 2, "bf16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*((?:\([^()]*\))|(?:[\w]+\[[\d,]*\](?:\{[^}]*\})?)|(?:[\w]+\[\]))\s*"
    r"([\w\-]+)\(")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_COMP_HDR_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\((.*)\)\s*->")
_CALL_ATTR_RE = re.compile(
    r"(?:calls|to_apply|body|condition)=%?([\w.\-]+)")
_BRANCH_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_CONST_TRIP_RE = re.compile(r"constant\((\d+)\)")
_PARAM_RE = re.compile(r"%?([\w.\-]+)\s*:\s*((?:\([^)]*\))|(?:[\w]+\[[\d,]*\](?:\{[^}]*\})?)|(?:[\w]+\[\]))")


def shape_elems_bytes(type_str: str):
    elems_total, bytes_total = 0, 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        elems_total += n
        bytes_total += n * _DTYPE_BYTES[dt]
    return elems_total, bytes_total


def shape_bytes(type_str: str) -> int:
    return shape_elems_bytes(type_str)[1]


def shape_dims(type_str: str):
    m = _SHAPE_RE.search(type_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


class Instr:
    __slots__ = ("name", "type", "opcode", "operands", "line")

    def __init__(self, name, type_, opcode, operands, line):
        self.name, self.type, self.opcode = name, type_, opcode
        self.operands, self.line = operands, line


class Computation:
    def __init__(self, name, entry=False):
        self.name = name
        self.entry = entry
        self.instrs: list[Instr] = []
        self.symbols: dict[str, str] = {}       # instr/param name -> type str


def parse_module(hlo: str) -> tuple[dict, str]:
    comps: dict[str, Computation] = {}
    cur = None
    entry_name = None
    for raw in hlo.splitlines():
        line = raw.rstrip()
        if cur is None:
            m = _COMP_HDR_RE.match(line.strip())
            if m and line.rstrip().endswith("{"):
                cur = Computation(m.group(2), entry=bool(m.group(1)))
                if m.group(1):
                    entry_name = m.group(2)
                for pname, ptype in _PARAM_RE.findall(m.group(3)):
                    cur.symbols[pname] = ptype
            continue
        if line.strip() == "}":
            comps[cur.name] = cur
            cur = None
            continue
        im = _INSTR_RE.match(line)
        if im:
            name, type_, opcode = im.group(1), im.group(2), im.group(3)
            body = line[im.end():]
            depth = 1
            i = 0
            while i < len(body) and depth:
                if body[i] == "(":
                    depth += 1
                elif body[i] == ")":
                    depth -= 1
                i += 1
            operands = re.findall(r"%([\w.\-]+)", body[:i])
            cur.symbols[name] = type_
            cur.instrs.append(Instr(name, type_, opcode, operands, line))
    if cur is not None:
        comps[cur.name] = cur
    return comps, entry_name


def _dot_flops(instr: Instr, comp: Computation) -> float:
    out_elems, _ = shape_elems_bytes(instr.type)
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", instr.line)
    contract = 1
    if m and instr.operands:
        lhs_type = comp.symbols.get(instr.operands[0])
        if lhs_type:
            dims = shape_dims(lhs_type)
            for idx in m.group(1).split(","):
                if idx and int(idx) < len(dims):
                    contract *= dims[int(idx)]
    return 2.0 * out_elems * contract


_SKIP_BYTES_OPS = {"parameter", "get-tuple-element", "tuple", "bitcast",
                   "constant", "after-all", "partition-id", "replica-id",
                   "while", "conditional", "copy-start", "copy-done"}


def _fused_root(ins: Instr, comps: dict):
    m = re.search(r"calls=%?([\w.\-]+)", ins.line)
    if m and m.group(1) in comps:
        c = comps[m.group(1)]
        if c.instrs:
            return c.instrs[-1], c
    return None, None


def _op_bytes(ins: Instr, comp: Computation, comps: dict) -> int:
    """HBM-traffic model for one top-level op: every materialized buffer is
    written once and read ~once => 2 x output bytes.  Slice-touching ops
    (incl. the scan residual-stacking DUS fusions) count slice traffic, not
    the whole (L, ...) buffer.  Counting operands too would multiply-count
    high-fanout buffers; this outputs-only model is the documented
    methodology for the §Roofline memory term."""
    op = ins.opcode
    if op == "dynamic-update-slice":
        upd = comp.symbols.get(ins.operands[1]) if len(ins.operands) > 1 else None
        return 2 * shape_bytes(upd) if upd else shape_bytes(ins.type)
    if op == "fusion":
        # any fusion containing DUS ops is a slice-write (scan stacking /
        # cache update), possibly with convert-wrapped roots
        m = re.search(r"calls=%?([\w.\-]+)", ins.line)
        fc = comps.get(m.group(1)) if m else None
        if fc is not None:
            dus = [i for i in fc.instrs if i.opcode == "dynamic-update-slice"]
            if dus:
                b = 0
                for d in dus:
                    upd = (fc.symbols.get(d.operands[1])
                           if len(d.operands) > 1 else None)
                    b += 2 * shape_bytes(upd) if upd else 0
                return b
    return 2 * shape_bytes(ins.type)


def analyze(hlo: str) -> dict:
    comps, entry = parse_module(hlo)

    # call graph: comp -> [(child, multiplier)]
    children = defaultdict(list)
    fusion_called = set()
    trip_counts = {}
    for cname, comp in comps.items():
        for ins in comp.instrs:
            if ins.opcode == "while":
                m = re.search(r"body=%?([\w.\-]+)", ins.line)
                c = re.search(r"condition=%?([\w.\-]+)", ins.line)
                tm = _TRIP_RE.search(ins.line)     # XLA backend_config
                if tm:
                    trip = int(tm.group(1))
                else:
                    trip = 1
                    if c and c.group(1) in comps:
                        consts = []
                        for l2 in comps[c.group(1)].instrs:
                            consts += [int(x) for x in
                                       _CONST_TRIP_RE.findall(l2.line)]
                        if consts:
                            trip = max(consts)
                if m:
                    children[cname].append((m.group(1), trip))
                    trip_counts[m.group(1)] = trip
                if c:
                    children[cname].append((c.group(1), trip))
            elif ins.opcode in ("fusion", "reduce", "reduce-window", "map",
                                "scatter", "sort", "call", "custom-call",
                                "select-and-scatter", "reduce-scatter",
                                "all-reduce"):
                for m in _CALL_ATTR_RE.finditer(ins.line):
                    children[cname].append((m.group(1), 1))
                    fusion_called.add(m.group(1))
            elif ins.opcode == "conditional":
                b = _BRANCH_RE.search(ins.line)
                if b:
                    for br in re.findall(r"%?([\w.\-]+)", b.group(1)):
                        children[cname].append((br, 1))

    # execution multiplier per computation (walk from entry)
    mult = defaultdict(float)
    entry = entry or next(iter(comps))
    stack = [(entry, 1.0, 0)]
    while stack:
        cname, m_, depth = stack.pop()
        if depth > 32:
            continue
        mult[cname] += m_
        for child, trip in children.get(cname, ()):
            stack.append((child, m_ * trip, depth + 1))

    flops = 0.0
    hbm = 0.0
    coll = defaultdict(float)
    for cname, comp in comps.items():
        m_ = mult.get(cname, 0.0)
        if m_ == 0.0:
            continue
        for ins in comp.instrs:
            if ins.opcode == "dot":
                flops += m_ * _dot_flops(ins, comp)
            elif ins.opcode == "convolution":
                # rough: 2 * out * kernel-spatial * in-channels unknown -> out*2
                out_e, _ = shape_elems_bytes(ins.type)
                flops += m_ * 2.0 * out_e
            kind = next((k for k in COLLECTIVES
                         if ins.opcode in (k, k + "-start")), None)
            if kind:
                b = m_ * shape_bytes(ins.type)
                coll[kind] += b
                # CPU lowering promotes bf16 dot outputs to f32, so
                # activation all-reduces appear at 2x their TPU width —
                # tracked separately for the corrected collective term.
                if "f32[" in ins.type and kind in ("all-reduce",
                                                   "reduce-scatter"):
                    coll["_f32_reduce"] += b
            # HBM bytes: top-level ops of non-fused computations.
            # Slice-touching ops count slice traffic, not whole buffers
            # (scan residual stacking would otherwise count the full
            # (L, ...) buffer once per layer).
            if cname not in fusion_called and ins.opcode not in _SKIP_BYTES_OPS:
                hbm += m_ * _op_bytes(ins, comp, comps)
    f32r = coll.pop("_f32_reduce", 0.0)
    coll["total"] = sum(v for k, v in coll.items() if k != "total")
    # corrected: f32 reduces counted at bf16 width (the TPU value)
    coll["total_bf16_corrected"] = coll["total"] - 0.5 * f32r
    return {"flops": flops, "hbm_bytes": hbm,
            "collectives": {k: int(v) for k, v in coll.items()},
            "trip_counts": trip_counts}


def collective_bytes(hlo: str) -> dict:
    return analyze(hlo)["collectives"]


def roofline_terms(flops: float, hbm_bytes: float, coll_bytes: float,
                   n_chips: int, *, peak_flops: float, hbm_bw: float,
                   ici_bw: float) -> dict:
    """Three roofline terms in seconds (all inputs per-device)."""
    compute_t = flops / peak_flops
    memory_t = hbm_bytes / hbm_bw
    coll_t = coll_bytes / ici_bw
    dom = max(("compute", compute_t), ("memory", memory_t),
              ("collective", coll_t), key=lambda kv: kv[1])
    return {"compute_s": compute_t, "memory_s": memory_t,
            "collective_s": coll_t, "bottleneck": dom[0]}
