"""dwork-scheduled serving driver: request batches as dwork tasks.

A TaskServer holds generation requests; serving workers Steal batches
(batch size chosen by the METG model for the worker count — the paper's
granularity guidance automated), run prefill + greedy decode, Complete.
Worker crashes requeue their requests (Exit / lease expiry).

    PYTHONPATH=src python -m repro.launch.serve --arch gemma2-2b --reduced \
        --requests 12 --max-new 8
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.dwork import Client, InProcTransport, TaskServer
from repro.core.metg import METGModel, pick_batch_size
from repro.models.common import Options
from repro.models.model import build_model
from repro.runtime.serve_step import greedy_generate


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2-2b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--workers", type=int, default=1)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch).reduced() if args.reduced else get_config(args.arch)
    model = build_model(cfg, Options(q_block=64, kv_block=64, moe_group=64))
    params = model.init(jax.random.PRNGKey(0))

    srv = TaskServer(lease_timeout=120.0)
    driver = Client(InProcTransport(srv), "driver")
    rng = np.random.default_rng(0)
    prompts = {}
    for i in range(args.requests):
        name = f"req{i}"
        prompts[name] = rng.integers(
            2, cfg.vocab_size, size=args.prompt_len).astype(np.int32)
        driver.create(name, meta={"len": args.prompt_len})

    # METG-aware batch size for this worker count
    per_req_s = 0.05
    batch = min(args.requests,
                pick_batch_size("dwork", args.workers, per_req_s,
                                model=METGModel.from_paper()))
    print(f"[serve] METG-chosen batch size: {batch}")

    worker = Client(InProcTransport(srv), "w0")
    done = 0
    t0 = time.time()
    while True:
        resp = worker.steal(n=batch)
        if type(resp).__name__ == "ExitResp":
            break
        if type(resp).__name__ == "NotFound":
            time.sleep(0.01)
            continue
        names = [n for n, _ in resp.tasks]
        toks = jnp.asarray(np.stack([prompts[n] for n in names]))
        b = {"tokens": toks}
        if cfg.mrope:
            B, S = toks.shape
            b["mrope_positions"] = jnp.broadcast_to(
                jnp.arange(S)[None, None], (3, B, S))
        if cfg.family == "audio":
            b["encoder_frames"] = jnp.zeros(
                (toks.shape[0], cfg.encoder.n_frames, cfg.d_model),
                jnp.bfloat16)
        out = greedy_generate(model, params, b, args.max_new,
                              args.prompt_len + args.max_new + 1)
        assert out.shape == (len(names), args.max_new)
        assert not bool(jnp.any(out < 0))
        for n in names:
            worker.complete(n)
            done += 1
        print(f"[serve] batch of {len(names)} done "
              f"({done}/{args.requests}, {time.time()-t0:.1f}s)")
    print(f"[serve] all {done} requests served; stats: {srv.stats()}")
    return done


if __name__ == "__main__":
    main()
