"""Continuous-serving driver: generation requests through the futures
client's resident engine + METG-batching frontend.

The serving session rides the same front door as everything else
(`repro.client.Client`): `client.serve(execute_batch)` attaches a
bounded-admission `Frontend` that coalesces requests into engine tasks
sized by the METG model for the live worker count (the paper's
granularity guidance automated) or by the max-wait deadline, and the
resident engine dispatches them with faults/leases/tracing intact — a
worker crash requeues its in-flight requests.  Per-request p50/p95/p99
latency comes straight from the trace.

    PYTHONPATH=src python -m repro.launch.serve --arch gemma2-2b --reduced \
        --requests 12 --max-new 8
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.client import Client
from repro.configs import get_config
from repro.models.common import Options
from repro.models.model import build_model
from repro.runtime.serve_step import greedy_generate


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2-2b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--workers", type=int, default=1)
    ap.add_argument("--max-wait-ms", type=float, default=20.0,
                    help="frontend deadline before a partial batch ships")
    ap.add_argument("--stats-port", type=int, default=None,
                    help="serve /stats, /health, /metrics on this port "
                         "while requests run (0 = ephemeral; see "
                         "python -m repro.core.obs.top)")
    ap.add_argument("--trace-out", default=None,
                    help="write the session as a Perfetto-loadable "
                         "Chrome trace (.trace.json) at exit")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch).reduced() if args.reduced else get_config(args.arch)
    model = build_model(cfg, Options(q_block=64, kv_block=64, moe_group=64))
    params = model.init(jax.random.PRNGKey(0))

    def execute_batch(prompts):
        toks = jnp.asarray(np.stack(prompts))
        b = {"tokens": toks}
        if cfg.mrope:
            B, S = toks.shape
            b["mrope_positions"] = jnp.broadcast_to(
                jnp.arange(S)[None, None], (3, B, S))
        if cfg.family == "audio":
            b["encoder_frames"] = jnp.zeros(
                (toks.shape[0], cfg.encoder.n_frames, cfg.d_model),
                jnp.bfloat16)
        out = greedy_generate(model, params, b, args.max_new,
                              args.prompt_len + args.max_new + 1)
        assert out.shape == (len(prompts), args.max_new)
        assert not bool(jnp.any(out < 0))
        return [np.asarray(row) for row in out]

    client = Client(scheduler="dwork", workers=args.workers,
                    lease_timeout=120.0)
    if args.stats_port is not None:
        srv = client.stats_server(port=args.stats_port)
        print(f"[serve] live stats at {srv.url}/stats "
              f"(/health, /metrics; dashboard: python -m "
              f"repro.core.obs.top --url {srv.url})")
    frontend = client.serve(execute_batch,
                            max_queue=max(args.requests, 16),
                            max_batch=max(args.requests, 1),
                            max_wait_s=args.max_wait_ms * 1e-3,
                            per_request_s0=0.05)
    print(f"[serve] METG batch target for {args.workers} worker(s): "
          f"{frontend.target_batch()}")

    rng = np.random.default_rng(0)
    t0 = time.time()
    reqs = [frontend.submit(rng.integers(2, cfg.vocab_size,
                                         size=args.prompt_len)
                            .astype(np.int32))
            for _ in range(args.requests)]
    done = 0
    for r in reqs:
        assert r.wait(600.0), f"request {r.name} never completed"
        assert r.ok, f"request {r.name} failed: {r.error}"
        assert r.value.shape == (args.max_new,)
        done += 1
    report = client.close()
    if args.trace_out:
        report.trace.to_chrome_trace(args.trace_out)
        print(f"[serve] Chrome trace written to {args.trace_out} "
              f"(open in https://ui.perfetto.dev)")
    lat = report.trace.latency_report()
    print(f"[serve] all {done} requests served in {time.time() - t0:.1f}s; "
          f"batches={lat.n_batches} mean_batch={lat.mean_batch:.1f}")
    print(f"[serve] latency ms: p50={lat.p50_s * 1e3:.1f} "
          f"p95={lat.p95_s * 1e3:.1f} p99={lat.p99_s * 1e3:.1f}")
    print(f"[serve] server stats: {report.backend_stats}")
    return done


if __name__ == "__main__":
    main()
