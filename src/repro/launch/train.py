"""End-to-end training driver (example-scale on CPU, mesh-ready).

    PYTHONPATH=src python -m repro.launch.train --arch gemma2-2b --reduced \
        --steps 50 --global-batch 8 --seq 128 --ckpt-dir /tmp/run1

Features exercised: mpi-list data pipeline, AdamW + clipping + schedule,
remat/microbatching, async checkpointing with restart (--resume picks up
the latest step), metrics JSONL.
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import jax
import numpy as np

from repro.checkpoint import ckpt
from repro.configs import RunConfig, get_config
from repro.data.pipeline import Pipeline
from repro.models.common import Options, param_count
from repro.models.model import build_model
from repro.optim.adamw import init_opt
from repro.runtime.train_step import make_train_step


def build(args):
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if args.layers:
        cfg = cfg.replace(n_layers=args.layers)
    if args.d_model:
        cfg = cfg.replace(d_model=args.d_model,
                          d_ff=args.d_ff or 4 * args.d_model,
                          head_dim=max(32, args.d_model // cfg.n_heads))
    opts = Options(q_block=min(512, args.seq), kv_block=min(512, args.seq),
                   moe_group=min(1024, args.global_batch * args.seq),
                   remat=args.remat)
    model = build_model(cfg, opts)
    rc = RunConfig(remat=args.remat, microbatches=args.microbatches,
                   lr=args.lr, warmup_steps=min(100, args.steps // 10 + 1),
                   total_steps=args.steps, seed=args.seed)
    return cfg, model, rc


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2-2b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--layers", type=int, default=0)
    ap.add_argument("--d-model", type=int, default=0)
    ap.add_argument("--d-ff", type=int, default=0)
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--remat", default="none")
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--metrics-out", default="")
    args = ap.parse_args(argv)

    cfg, model, rc = build(args)
    key = jax.random.PRNGKey(rc.seed)
    params = model.init(key)
    opt_state = init_opt(params, rc)
    print(f"[train] arch={cfg.name} params={param_count(params):,}")

    start_step = 0
    ckpter = None
    if args.ckpt_dir:
        ckpter = ckpt.AsyncCheckpointer(args.ckpt_dir)
        if args.resume:
            last = ckpt.latest_step(args.ckpt_dir)
            if last is not None:
                abs_tree = jax.tree_util.tree_map(
                    lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                    {"params": params, "opt": opt_state})
                tree = ckpt.restore(args.ckpt_dir, last, abs_tree)
                params, opt_state = tree["params"], tree["opt"]
                start_step = last
                print(f"[train] resumed from step {last}")

    pipe = Pipeline(cfg.vocab_size, args.seq, args.global_batch, seed=rc.seed)
    step_fn = jax.jit(make_train_step(model, rc), donate_argnums=(0, 1))

    metrics_path = Path(args.metrics_out) if args.metrics_out else None
    if metrics_path:
        metrics_path.parent.mkdir(parents=True, exist_ok=True)
    logf = open(metrics_path, "a") if metrics_path else None

    t0 = time.time()
    losses = []
    for i, batch in enumerate(pipe.batches(args.steps - start_step)):
        step = start_step + i + 1
        jb = {k: jax.numpy.asarray(v) for k, v in batch.items()}
        if cfg.mrope:
            B, S = jb["tokens"].shape
            jb["mrope_positions"] = jax.numpy.broadcast_to(
                jax.numpy.arange(S)[None, None], (3, B, S))
        if cfg.family == "audio":
            B = jb["tokens"].shape[0]
            jb["encoder_frames"] = jax.numpy.zeros(
                (B, cfg.encoder.n_frames, cfg.d_model), jax.numpy.bfloat16)
        params, opt_state, m = step_fn(params, opt_state, jb)
        loss = float(m["loss"])
        losses.append(loss)
        rec = {"step": step, "loss": loss,
               "grad_norm": float(m["grad_norm"]), "lr": float(m["lr"]),
               "wall_s": round(time.time() - t0, 2)}
        if logf:
            logf.write(json.dumps(rec) + "\n")
            logf.flush()
        if step % max(1, args.steps // 10) == 0 or step == args.steps:
            print(f"[train] step {step} loss {loss:.4f} "
                  f"gnorm {rec['grad_norm']:.3f}")
        if ckpter and (step % args.ckpt_every == 0 or step == args.steps):
            ckpter.save(step, {"params": params, "opt": opt_state},
                        {"loss": loss})
    if ckpter:
        ckpter.wait()
    assert np.isfinite(losses).all(), "NaN/inf loss"
    if len(losses) > 10:
        assert np.mean(losses[-5:]) < np.mean(losses[:5]), \
            "loss did not decrease"
    print(f"[train] done: first {losses[0]:.4f} -> last {losses[-1]:.4f}")
    return losses


if __name__ == "__main__":
    main()
