"""Contributor dump: lower one cell and print the top flop/byte/collective
ops with execution multipliers — the §Perf profiling tool (our 'profile' is
the lowered IR, per the dry-run methodology)."""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

import argparse
import re
from collections import defaultdict

import repro.launch.hlo_analysis as ha


def multipliers(comps, entry):
    children = defaultdict(list)
    fusion_called = set()
    for cname, comp in comps.items():
        for ins in comp.instrs:
            if ins.opcode == "while":
                m = re.search(r"body=%?([\w.\-]+)", ins.line)
                c = re.search(r"condition=%?([\w.\-]+)", ins.line)
                tm = ha._TRIP_RE.search(ins.line)
                trip = int(tm.group(1)) if tm else 1
                if m:
                    children[cname].append((m.group(1), trip))
                if c:
                    children[cname].append((c.group(1), trip))
            elif ins.opcode in ("fusion", "reduce", "scatter", "sort", "call",
                                "custom-call", "reduce-scatter", "all-reduce",
                                "map", "reduce-window", "select-and-scatter"):
                for m in ha._CALL_ATTR_RE.finditer(ins.line):
                    children[cname].append((m.group(1), 1))
                    fusion_called.add(m.group(1))
            elif ins.opcode == "conditional":
                b = ha._BRANCH_RE.search(ins.line)
                if b:
                    for br in re.findall(r"%?([\w.\-]+)", b.group(1)):
                        children[cname].append((br, 1))
    mult = defaultdict(float)
    stack = [(entry, 1.0, 0)]
    while stack:
        cn, m_, d = stack.pop()
        if d > 32:
            continue
        mult[cn] += m_
        for ch, t in children.get(cn, ()):
            stack.append((ch, m_ * t, d + 1))
    return mult, fusion_called


def dump(hlo: str, kind: str = "bytes", top: int = 20):
    comps, entry = ha.parse_module(hlo)
    mult, fusion_called = multipliers(comps, entry)
    rows = []
    for cname, comp in comps.items():
        m_ = mult.get(cname, 0.0)
        if not m_:
            continue
        for ins in comp.instrs:
            meta = re.search(r'op_name="([^"]+)"', ins.line)
            tag = meta.group(1)[-70:] if meta else ins.opcode
            if kind == "collective":
                k = next((k for k in ha.COLLECTIVES
                          if ins.opcode in (k, k + "-start")), None)
                if k:
                    rows.append((m_ * ha.shape_bytes(ins.type), m_, k,
                                 ins.type[:40], tag))
            elif kind == "flops":
                if ins.opcode == "dot":
                    rows.append((m_ * ha._dot_flops(ins, comp), m_, "dot",
                                 ins.type[:40], tag))
            else:
                if cname in fusion_called or ins.opcode in ha._SKIP_BYTES_OPS:
                    continue
                rows.append((m_ * ha._op_bytes(ins, comp, comps), m_,
                             ins.opcode, ins.type[:40], tag))
    rows.sort(reverse=True)
    unit = {"bytes": 1e9, "collective": 1e9, "flops": 1e12}[kind]
    suf = {"bytes": "GB", "collective": "GB", "flops": "TF"}[kind]
    for r in rows[:top]:
        print(f"{r[0]/unit:10.2f}{suf} x{r[1]:6.0f} {r[2]:18s} {r[3]:40s} {r[4]}")
    print(f"total: {sum(r[0] for r in rows)/unit:.2f}{suf}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--kind", default="bytes",
                    choices=["bytes", "flops", "collective"])
    ap.add_argument("--top", type=int, default=20)
    # pass-through knobs
    for f in ("remat",):
        ap.add_argument(f"--{f}", default="none")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--probs-bf16", action="store_true")
    ap.add_argument("--skip-masked-blocks", action="store_true")
    ap.add_argument("--shard-params-2d", action="store_true")
    ap.add_argument("--param-dtype", default="float32")
    ap.add_argument("--seq-shard-kv", action="store_true")
    ap.add_argument("--grad-compress", default="none")
    args = ap.parse_args()

    from repro.configs import RunConfig
    from repro.models.common import Options
    import repro.launch.dryrun as dr

    captured = {}
    orig = ha.analyze

    def cap(hlo):
        captured["hlo"] = hlo
        return orig(hlo)

    dr.analyze = cap
    rc = RunConfig(remat=args.remat, microbatches=args.microbatches,
                   param_dtype=args.param_dtype,
                   grad_compress=args.grad_compress,
                   seq_shard_kv=args.seq_shard_kv,
                   shard_params_2d=args.shard_params_2d)
    opts = Options(remat=args.remat, probs_bf16=args.probs_bf16,
                   skip_masked_blocks=args.skip_masked_blocks)
    dr.lower_cell(args.arch, args.shape, False, rc, opts)
    dump(captured["hlo"], args.kind, args.top)


if __name__ == "__main__":
    main()
