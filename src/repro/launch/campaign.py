"""pmake-orchestrated training campaign (the paper's Fig. 1 pattern applied
to the framework): shard-train -> summarize, file-synced and restartable.

    PYTHONPATH=src python -m repro.launch.campaign --workdir /tmp/camp \
        --shards 2 --steps 6

Each `train` task is a real popen'd `repro.launch.train` run producing a
metrics file + checkpoint; `summarize` aggregates shard metrics.  Re-running
the campaign rebuilds nothing (outputs exist) — campaign-level fault
tolerance exactly as in pmake's design.
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

from repro.core.pmake import PMake

RULES_TMPL = """
train:
  resources: {{time: 10, nrs: 1, cpu: 1}}
  out:
    metrics: "shard_{{n}}.jsonl"
  setup: export PYTHONPATH={src}
  script: |
    {python} -m repro.launch.train --arch {arch} --reduced --steps {steps} \\
      --global-batch {batch} --seq {seq} --seed {{n}} \\
      --metrics-out shard_{{n}}.jsonl
summarize:
  resources: {{time: 1, nrs: 1, cpu: 1}}
  inp:
    loop:
  out:
    report: "report.json"
  setup: export PYTHONPATH={src}
  script: |
    {python} -m repro.launch.campaign --summarize-dir . --shards {shards}
"""

TARGETS_TMPL = """
campaign:
  dirname: .
  out:
    report: "report.json"
  loop:
    n: "range({shards})"
  tgt:
    metrics: "shard_{{n}}.jsonl"
"""


def summarize(directory: str, shards: int):
    rows = []
    for n in range(shards):
        path = Path(directory) / f"shard_{n}.jsonl"
        recs = [json.loads(l) for l in path.read_text().splitlines() if l]
        rows.append({"shard": n, "steps": len(recs),
                     "first_loss": recs[0]["loss"],
                     "last_loss": recs[-1]["loss"]})
    report = {"shards": rows,
              "mean_last_loss": sum(r["last_loss"] for r in rows) / len(rows)}
    (Path(directory) / "report.json").write_text(json.dumps(report, indent=1))
    print(json.dumps(report, indent=1))


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--workdir", default="/tmp/repro_campaign")
    ap.add_argument("--arch", default="gemma2-2b")
    ap.add_argument("--shards", type=int, default=2)
    ap.add_argument("--steps", type=int, default=6)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--nodes", type=int, default=2)
    ap.add_argument("--summarize-dir", default="")
    args = ap.parse_args(argv)

    if args.summarize_dir:
        summarize(args.summarize_dir, args.shards)
        return

    src = str(Path(__file__).resolve().parents[2])
    rules = RULES_TMPL.format(python=sys.executable, arch=args.arch,
                              steps=args.steps, batch=args.batch,
                              seq=args.seq, src=src, shards=args.shards)
    # summarize depends on every shard metrics file
    rules = rules.replace(
        "  inp:\n    loop:\n",
        "  inp:\n" + "".join(
            f"    m{n}: \"shard_{n}.jsonl\"\n" for n in range(args.shards)))
    targets = TARGETS_TMPL.format(shards=args.shards)
    Path(args.workdir).mkdir(parents=True, exist_ok=True)
    (Path(args.workdir) / "rules.yaml").write_text(rules)
    (Path(args.workdir) / "targets.yaml").write_text(targets)

    pm = PMake(rules, targets, root=args.workdir, total_nodes=args.nodes)
    # EFT check: train tasks (with the summarize successor) outrank summarize
    stats = pm.run()
    print(f"[campaign] {stats}")
    assert stats["errors"] == 0, "campaign had failures"
    return stats


if __name__ == "__main__":
    main()
