import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede any jax import/init: the multi-pod dry-run builds meshes of
# 512 placeholder host devices. (Smoke tests / benches never import this.)

import argparse
import json
import subprocess
import sys
import time
from pathlib import Path

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs import (ARCH_IDS, SHAPES, RunConfig, applicable_shapes,
                           get_config, input_specs)
from repro.launch.hlo_analysis import analyze, roofline_terms
from repro.launch.mesh import (HBM_BW, ICI_BW, PEAK_FLOPS_BF16,
                               make_production_mesh)
from repro.models.common import Options, mesh_context, param_count
from repro.models.model import build_model
from repro.optim.adamw import abstract_opt
from repro.runtime.sharding import (batch_specs, cache_specs, logical_rules,
                                    opt_state_specs, param_specs,
                                    param_specs_2d, to_named)
from repro.runtime.serve_step import make_decode_step, make_prefill_step
from repro.runtime.train_step import make_train_step

RESULTS_DIR = Path(__file__).resolve().parents[3] / "benchmarks" / "results" / "dryrun"


def model_flops(cfg, shape) -> float:
    """6·N_active·D (training) or 2·N_active·D (fwd-only) useful-FLOPs model."""
    model = build_model(cfg)
    n = param_count(jax.eval_shape(
        lambda k: model.init(k), jax.random.PRNGKey(0)))
    if cfg.moe is not None:
        m = cfg.moe
        expert_params = (cfg.n_layers - m.first_dense_layers) * m.n_experts \
            * 3 * cfg.d_model * m.d_expert
        active = n - expert_params + expert_params * m.top_k / m.n_experts
    else:
        active = n
    # embedding rows don't multiply
    active -= cfg.padded_vocab * cfg.d_model
    if shape.mode == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * active * tokens
    if shape.mode == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * active * tokens
    return 2.0 * active * shape.global_batch        # decode: one token/seq


def lower_cell(arch: str, shape_name: str, multi_pod: bool, rc: RunConfig,
               opts: Options) -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.devices.size
    model = build_model(cfg, opts)
    rules = logical_rules(mesh, global_batch=shape.global_batch,
                          seq_shard_kv=rc.seq_shard_kv,
                          shard_params_2d=rc.shard_params_2d)

    abstract_params = model.init_abstract()
    if rc.param_dtype != "float32":
        pd = jnp.dtype(rc.param_dtype)
        abstract_params = jax.tree_util.tree_map(
            lambda x: jax.ShapeDtypeStruct(x.shape, pd)
            if jnp.issubdtype(x.dtype, jnp.floating) else x, abstract_params)
    pspecs = param_specs(abstract_params, cfg)
    if rc.shard_params_2d:
        pspecs = param_specs_2d(pspecs, abstract_params, mesh)
    bspecs = batch_specs(cfg, shape, mesh)
    batch_abs = input_specs(cfg, shape)

    t0 = time.time()
    with mesh_context(mesh, rules):
        if shape.mode == "train":
            opt_abs = abstract_opt(abstract_params, rc)
            ospecs = opt_state_specs(pspecs, abstract_params, mesh, rc.zero1)
            ospecs = type(opt_abs)(count=P(), m=ospecs, v=ospecs)
            step = make_train_step(model, rc)
            jitted = jax.jit(
                step,
                in_shardings=(to_named(mesh, pspecs), to_named(mesh, ospecs),
                              to_named(mesh, bspecs)),
                out_shardings=(to_named(mesh, pspecs), to_named(mesh, ospecs),
                               None),
                donate_argnums=(0, 1))
            lowered = jitted.lower(abstract_params, opt_abs, batch_abs)
        elif shape.mode == "prefill":
            step = make_prefill_step(model)
            jitted = jax.jit(
                step, in_shardings=(to_named(mesh, pspecs),
                                    to_named(mesh, bspecs)),
                out_shardings=None)
            lowered = jitted.lower(abstract_params, batch_abs)
        else:  # decode
            cache_abs = model.init_cache(shape.global_batch, shape.seq_len,
                                         abstract=True)
            cspecs = cache_specs(cfg, cache_abs, mesh,
                                 global_batch=shape.global_batch,
                                 seq_shard_kv=rc.seq_shard_kv)
            step = make_decode_step(model)
            jitted = jax.jit(
                step,
                in_shardings=(to_named(mesh, pspecs),
                              to_named(mesh, bspecs["tokens"]),
                              to_named(mesh, bspecs["positions"]),
                              to_named(mesh, cspecs)),
                out_shardings=(to_named(mesh, bspecs["tokens"]),
                               to_named(mesh, cspecs)),
                donate_argnums=(3,))
            lowered = jitted.lower(abstract_params, batch_abs["tokens"],
                                   batch_abs["positions"], cache_abs)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    ana = analyze(hlo)                 # trip-count-corrected, per-device
    coll = ana["collectives"]

    flops = float(ana["flops"])
    hbm = float(ana["hbm_bytes"])
    terms = roofline_terms(flops, hbm,
                           coll.get("total_bf16_corrected",
                                    coll.get("total", 0)),
                           n_chips, peak_flops=PEAK_FLOPS_BF16,
                           hbm_bw=HBM_BW, ici_bw=ICI_BW)
    terms["collective_uncorrected_s"] = coll.get("total", 0) / ICI_BW
    mf = model_flops(cfg, shape)
    rec = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16", "chips": int(n_chips),
        "mode": shape.mode, "ok": True,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "hlo_flops_per_device": flops, "hlo_bytes_per_device": hbm,
        "hlo_flops": flops * n_chips, "hlo_bytes": hbm * n_chips,
        "xla_cost_flops_per_device_loops_once": float(cost.get("flops", 0.0)),
        "xla_cost_bytes_per_device_loops_once": float(
            cost.get("bytes accessed", 0.0)),
        "collective_bytes": coll,
        "memory": {
            "argument_bytes_per_device": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes_per_device": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes_per_device": getattr(mem, "temp_size_in_bytes", None),
            "code_bytes": getattr(mem, "generated_code_size_in_bytes", None),
        },
        "roofline": terms,
        "model_flops": mf,
        "useful_flops_ratio": (mf / (flops * n_chips)) if flops else None,
        "rc": {"remat": rc.remat, "microbatches": rc.microbatches,
               "zero1": rc.zero1, "param_dtype": rc.param_dtype,
               "seq_shard_kv": rc.seq_shard_kv,
               "grad_compress": rc.grad_compress},
        "opts": {"q_block": opts.q_block, "kv_block": opts.kv_block,
                 "skip_masked_blocks": opts.skip_masked_blocks,
                 "mla_absorb": opts.mla_absorb, "moe_group": opts.moe_group,
                 "probs_bf16": opts.probs_bf16,
                 "shard_params_2d": rc.shard_params_2d},
    }
    return rec


def cell_filename(arch, shape_name, multi_pod, tag=""):
    mesh = "2x16x16" if multi_pod else "16x16"
    t = f"__{tag}" if tag else ""
    return RESULTS_DIR / f"{arch}__{shape_name}__{mesh}{t}.json"


def run_one(args) -> int:
    rc = RunConfig(remat=args.remat, microbatches=args.microbatches,
                   zero1=not args.no_zero1, param_dtype=args.param_dtype,
                   seq_shard_kv=args.seq_shard_kv,
                   grad_compress=args.grad_compress,
                   adam_state_dtype=args.adam_state_dtype,
                   shard_params_2d=args.shard_params_2d)
    opts = Options(q_block=args.q_block, kv_block=args.kv_block,
                   skip_masked_blocks=args.skip_masked_blocks,
                   mla_absorb=args.mla_absorb, moe_group=args.moe_group,
                   remat=args.remat, probs_bf16=args.probs_bf16)
    out = cell_filename(args.arch, args.shape, args.multi_pod, args.tag)
    out.parent.mkdir(parents=True, exist_ok=True)
    try:
        rec = lower_cell(args.arch, args.shape, args.multi_pod, rc, opts)
    except Exception as e:  # noqa: BLE001 - recorded, not swallowed
        rec = {"arch": args.arch, "shape": args.shape,
               "mesh": "2x16x16" if args.multi_pod else "16x16",
               "ok": False, "error": f"{type(e).__name__}: {e}"}
        out.write_text(json.dumps(rec, indent=1))
        print(json.dumps(rec, indent=1))
        return 1
    out.write_text(json.dumps(rec, indent=1))
    print(json.dumps({k: rec[k] for k in
                      ("arch", "shape", "mesh", "ok", "compile_s", "hlo_flops",
                       "roofline", "useful_flops_ratio")}, indent=1))
    return 0


def run_all(args) -> int:
    """Spawn one subprocess per cell (compile isolation + fresh XLA state)."""
    fails = []
    meshes = [False, True] if args.meshes == "both" else [args.meshes == "multipod"]
    for arch in (args.archs.split(",") if args.archs else ARCH_IDS):
        cfg = get_config(arch)
        for shape_name, status in applicable_shapes(cfg).items():
            if args.shapes and shape_name not in args.shapes.split(","):
                continue
            for mp in meshes:
                out = cell_filename(arch, shape_name, mp, args.tag)
                if status != "run":
                    out.parent.mkdir(parents=True, exist_ok=True)
                    out.write_text(json.dumps({
                        "arch": arch, "shape": shape_name,
                        "mesh": "2x16x16" if mp else "16x16",
                        "ok": None, "skipped": status}, indent=1))
                    continue
                if out.exists() and not args.force:
                    rec = json.loads(out.read_text())
                    if rec.get("ok"):
                        continue
                mode = SHAPES[shape_name].mode
                # train defaults: full remat + 4 microbatches (activation
                # memory does not fit otherwise); serving: none needed.
                remat = args.remat
                mb = args.microbatches
                if mode == "train" and remat == "none" and mb == 1:
                    remat, mb = "full", 4
                cmd = [sys.executable, "-m", "repro.launch.dryrun",
                       "--arch", arch, "--shape", shape_name,
                       "--remat", remat, "--microbatches", str(mb)]
                if mp:
                    cmd.append("--multi-pod")
                for flag in ("param_dtype", "grad_compress",
                             "adam_state_dtype", "tag"):
                    v = getattr(args, flag)
                    if v:
                        cmd += [f"--{flag.replace('_', '-')}", str(v)]
                for flag in ("q_block", "kv_block", "moe_group"):
                    cmd += [f"--{flag.replace('_', '-')}",
                            str(getattr(args, flag))]
                for flag in ("skip_masked_blocks", "mla_absorb", "no_zero1"):
                    if getattr(args, flag):
                        cmd.append(f"--{flag.replace('_', '-')}")
                if args.seq_shard_kv or shape_name == "long_500k":
                    cmd.append("--seq-shard-kv")
                print("::", " ".join(cmd), flush=True)
                r = subprocess.run(cmd, timeout=args.cell_timeout)
                if r.returncode != 0:
                    fails.append((arch, shape_name, mp))
    if fails:
        print("FAILED CELLS:", fails)
    return 1 if fails else 0


def main():
    ap = argparse.ArgumentParser(description="multi-pod dry-run")
    ap.add_argument("--arch")
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--archs", default="")
    ap.add_argument("--shapes", default="")
    ap.add_argument("--meshes", default="both",
                    choices=["both", "pod", "multipod"])
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--tag", default="")
    ap.add_argument("--cell-timeout", type=int, default=3600)
    # RunConfig / Options knobs (perf hillclimb levers)
    ap.add_argument("--remat", default="none", choices=["none", "dots", "full"])
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--no-zero1", action="store_true")
    ap.add_argument("--param-dtype", default="float32")
    ap.add_argument("--adam-state-dtype", default="float32")
    ap.add_argument("--grad-compress", default="none")
    ap.add_argument("--seq-shard-kv", action="store_true")
    ap.add_argument("--q-block", type=int, default=1024)
    ap.add_argument("--kv-block", type=int, default=1024)
    ap.add_argument("--moe-group", type=int, default=1024)
    ap.add_argument("--skip-masked-blocks", action="store_true")
    ap.add_argument("--mla-absorb", action="store_true")
    ap.add_argument("--probs-bf16", action="store_true")
    ap.add_argument("--shard-params-2d", action="store_true")
    args = ap.parse_args()
    if args.all:
        sys.exit(run_all(args))
    if not args.arch or not args.shape:
        ap.error("--arch and --shape required (or --all)")
    sys.exit(run_one(args))


if __name__ == "__main__":
    main()
