"""jit'd wrapper: Pallas on TPU, interpret-mode execution elsewhere."""
from __future__ import annotations

from functools import partial

import jax

from repro.kernels.tiled_matmul.kernel import tiled_matmul_pallas
from repro.kernels.tiled_matmul.ref import tiled_matmul_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@partial(jax.jit, static_argnames=("bm", "bn", "bk", "force_interpret"))
def tiled_matmul(a, b, *, bm: int = 256, bn: int = 256, bk: int = 256,
                 force_interpret: bool = False):
    """C = A^T B via the Pallas kernel (interpret=True off-TPU)."""
    interpret = force_interpret or not _on_tpu()
    return tiled_matmul_pallas(a, b, bm=bm, bn=bn, bk=bk, interpret=interpret)


__all__ = ["tiled_matmul", "tiled_matmul_ref"]
