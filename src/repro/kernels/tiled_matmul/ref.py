"""Oracle: C = A^T B (the paper's §3 benchmark operation)."""
from __future__ import annotations

import jax.numpy as jnp


def tiled_matmul_ref(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """a: (K, M), b: (K, N) -> (M, N) in fp32 accumulation."""
    return jnp.einsum("km,kn->mn", a, b,
                      preferred_element_type=jnp.float32).astype(a.dtype)
