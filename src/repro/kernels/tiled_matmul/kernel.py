"""Pallas TPU kernel: C = A^T B with MXU-aligned VMEM tiling.

The paper benchmarks schedulers with tiled single-precision A^T B (wave-
function overlap building block).  TPU adaptation: (bm, bn, bk) blocks are
multiples of 128 to fill the 128x128 MXU; A and B tiles stream HBM->VMEM
along the contraction grid dim with an fp32 VMEM accumulator, written out
on the last k-step.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _matmul_kernel(a_ref, b_ref, c_ref, acc_ref, *, n_k: int):
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # A tile is (bk, bm): contract over the leading (k) dim => A^T @ B
    acc_ref[...] += jax.lax.dot_general(
        a_ref[...], b_ref[...], (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(ik == n_k - 1)
    def _flush():
        c_ref[...] = acc_ref[...].astype(c_ref.dtype)


def tiled_matmul_pallas(a, b, *, bm: int = 256, bn: int = 256, bk: int = 256,
                        interpret: bool = False):
    """a: (K, M), b: (K, N) -> C (M, N)."""
    K, M = a.shape
    K2, N = b.shape
    assert K == K2, (a.shape, b.shape)
    bm, bn, bk = min(bm, M), min(bn, N), min(bk, K)
    assert M % bm == 0 and N % bn == 0 and K % bk == 0, (M, N, K, bm, bn, bk)
    n_k = K // bk
    grid = (M // bm, N // bn, n_k)
    kernel = functools.partial(_matmul_kernel, n_k=n_k)
    try:
        params = pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"))
    except (AttributeError, TypeError):  # older pallas naming
        params = pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"))
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bk, bm), lambda i, j, k: (k, i)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), a.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        compiler_params=params,
        interpret=interpret,
    )(a, b)
