"""jit'd wrapper with interpret fallback off-TPU."""
from __future__ import annotations

from functools import partial

import jax

from repro.kernels.rwkv6_scan.kernel import wkv6_pallas
from repro.kernels.rwkv6_scan.ref import wkv6_ref, wkv6_sequential_ref


@partial(jax.jit, static_argnames=("chunk", "force_interpret"))
def wkv6(r, k, v, logw, u, *, chunk: int = 32, force_interpret: bool = False):
    interpret = force_interpret or jax.default_backend() != "tpu"
    return wkv6_pallas(r, k, v, logw, u, chunk=chunk, interpret=interpret)


__all__ = ["wkv6", "wkv6_ref", "wkv6_sequential_ref"]
