"""Oracle for the chunked WKV6 recurrence — delegates to the model-side
chunk function (`repro.models.rwkv._wkv_chunk`) so kernel and model share
one definition of the math."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.rwkv import _wkv_chunk


def wkv6_ref(r, k, v, logw, u, *, chunk: int = 32, initial_state=None):
    """r/k/v/logw: (B, S, H, hd) fp32; u: (H, hd).
    Returns (y (B,S,H,hd), final_state (B,H,hd,hd))."""
    B, S, H, hd = r.shape
    Q = min(chunk, S)
    assert S % Q == 0
    nC = S // Q
    resh = lambda a: a.reshape(B, nC, Q, H, hd).transpose(1, 0, 2, 3, 4)
    cumw = jnp.cumsum(logw.reshape(B, nC, Q, H, hd), axis=2).transpose(1, 0, 2, 3, 4)
    S0 = (initial_state if initial_state is not None
          else jnp.zeros((B, H, hd, hd), jnp.float32))
    us = jnp.broadcast_to(u, (nC,) + u.shape)
    step = lambda c, b: _wkv_chunk(c, b, H=H, hd=hd)
    S_fin, Ys = jax.lax.scan(step, S0, (cumw, resh(r), resh(k), resh(v), us))
    return Ys.transpose(1, 0, 2, 3, 4).reshape(B, S, H, hd), S_fin


def wkv6_sequential_ref(r, k, v, logw, u):
    """Step-by-step recurrence (independent formulation for cross-checks)."""
    B, S, H, hd = r.shape
    S0 = jnp.zeros((B, H, hd, hd), jnp.float32)

    def step(state, t):
        rt, kt, vt, wt = r[:, t], k[:, t], v[:, t], jnp.exp(logw[:, t])
        kv = jnp.einsum("bhk,bhv->bhkv", kt, vt)
        y = jnp.einsum("bhk,bhkv->bhv", rt, state + u[None, ..., None] * kv)
        state = state * wt[..., None] + kv
        return state, y

    S_fin, ys = jax.lax.scan(step, S0, jnp.arange(S))
    return jnp.moveaxis(ys, 0, 1), S_fin
