"""Pallas TPU kernel: chunked RWKV6 WKV recurrence.

Grid (B*H, n_chunks): the chunk dim is sequential, carrying the (hd x hd)
state in VMEM scratch.  Per chunk the intra-chunk decayed products
exp(cum_excl[t,d] - cumw[j,d]) are <= 1 (numerically safe), computed as a
(Q, Q, hd) VMEM tensor — the TPU adaptation of the fla-style kernel
(no warp shuffles needed; the MXU consumes the (Q,Q) contraction).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _wkv_kernel(r_ref, k_ref, v_ref, logw_ref, u_ref, o_ref, state_ref, *,
                Q: int, hd: int):
    ic = pl.program_id(1)

    @pl.when(ic == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    r = r_ref[0].astype(jnp.float32)            # (Q, hd)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    logw = logw_ref[0].astype(jnp.float32)
    u = u_ref[0].astype(jnp.float32)            # (hd,)

    cumw = jnp.cumsum(logw, axis=0)             # (Q, hd)
    cum_excl = cumw - logw
    # intra-chunk: A[t,j] = sum_d r[t,d] k[j,d] exp(cum_excl[t,d]-cumw[j,d])
    diff = cum_excl[:, None, :] - cumw[None, :, :]            # (Q,Q,hd)
    mask = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0) > \
        jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    E = jnp.exp(jnp.where(mask[..., None], diff, -1e9))
    A = jnp.einsum("td,jd,tjd->tj", r, k, E)
    y = jax.lax.dot_general(A, v, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    # bonus diagonal
    y = y + jnp.sum(r * u[None, :] * k, axis=-1, keepdims=True) * v
    # inter-chunk from carried state
    rd = r * jnp.exp(cum_excl)
    y = y + jax.lax.dot_general(rd, state_ref[...], (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)
    # state update
    dec_end = jnp.exp(cumw[-1:][0][None, :] - cumw)           # (Q, hd)
    state_ref[...] = (state_ref[...] * jnp.exp(cumw[-1])[:, None]
                      + jax.lax.dot_general(
                          (k * dec_end), v, (((0,), (0,)), ((), ())),
                          preferred_element_type=jnp.float32))
    o_ref[0] = y.astype(o_ref.dtype)


def wkv6_pallas(r, k, v, logw, u, *, chunk: int = 32, interpret: bool = False):
    """r/k/v/logw: (B, S, H, hd); u: (H, hd) -> y (B,S,H,hd)."""
    B, S, H, hd = r.shape
    Q = min(chunk, S)
    assert S % Q == 0
    nC = S // Q
    flat = lambda a: a.transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    u_bh = jnp.broadcast_to(u[None], (B, H, hd)).reshape(B * H, hd)
    grid = (B * H, nC)
    kernel = functools.partial(_wkv_kernel, Q=Q, hd=hd)
    try:
        params = pltpu.CompilerParams(
            dimension_semantics=("parallel", "arbitrary"))
    except (AttributeError, TypeError):
        params = pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "arbitrary"))
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, Q, hd), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, Q, hd), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, Q, hd), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, Q, hd), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, hd), lambda b, c: (b, 0)),
        ],
        out_specs=pl.BlockSpec((1, Q, hd), lambda b, c: (b, c, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, S, hd), r.dtype),
        scratch_shapes=[pltpu.VMEM((hd, hd), jnp.float32)],
        compiler_params=params,
        interpret=interpret,
    )(flat(r), flat(k), flat(v), flat(logw), u_bh)
    return out.reshape(B, H, S, hd).transpose(0, 2, 1, 3)
