"""jit'd wrapper with interpret fallback off-TPU."""
from __future__ import annotations

from functools import partial

import jax

from repro.kernels.flash_attention.kernel import flash_attention_pallas
from repro.kernels.flash_attention.ref import flash_attention_ref


@partial(jax.jit, static_argnames=("causal", "window", "logit_softcap",
                                   "scale", "bq", "bk", "force_interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    logit_softcap: float = 0.0, scale: float = None,
                    bq: int = 512, bk: int = 512,
                    force_interpret: bool = False):
    interpret = force_interpret or jax.default_backend() != "tpu"
    return flash_attention_pallas(q, k, v, causal=causal, window=window,
                                  logit_softcap=logit_softcap, scale=scale,
                                  bq=bq, bk=bk, interpret=interpret)


__all__ = ["flash_attention", "flash_attention_ref"]
