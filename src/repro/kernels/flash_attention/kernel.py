"""Pallas TPU kernel: fused causal flash attention (prefill/training fwd).

Grid (B*H, n_q, n_kv); the kv dim is the innermost sequential ("arbitrary")
dim so the online-softmax state (m, l, acc) lives in VMEM scratch across kv
steps and the output block is written once on the last visited kv step.
Causal block-skipping uses pl.when, so out-of-triangle blocks issue no MXU
work — the kernel-level version of the model path's `skip_masked_blocks`.

VMEM per step: q(bq,hd) + k/v(bk,hd) + scores(bq,bk) + acc(bq,hd) — sized
for bq=bk=512, hd<=256 within the ~16 MB v5e VMEM budget.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -2.0e38


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  scale: float, logit_softcap: float, window: int,
                  causal: bool, bq: int, bk: int, n_kv: int):
    iq = pl.program_id(1)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    needed = True
    if causal:
        needed = ik * bk <= (iq + 1) * bq - 1
    if window:
        needed = jnp.logical_and(
            needed, (ik + 1) * bk - 1 >= iq * bq - window + 1)

    @pl.when(needed)
    def _compute():
        q = q_ref[0].astype(jnp.float32)          # (bq, hd)
        k = k_ref[0].astype(jnp.float32)          # (bk, hd)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if logit_softcap:
            s = logit_softcap * jnp.tanh(s / logit_softcap)
        qpos = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        allow = jnp.ones((bq, bk), bool)
        if causal:
            allow &= qpos >= kpos
        if window:
            allow &= (qpos - kpos) < window
        s = jnp.where(allow, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, -1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v_ref[0].astype(jnp.float32), (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ik == n_kv - 1)
    def _flush():
        o_ref[0] = (acc_ref[...]
                    / jnp.maximum(l_ref[...], 1e-37)).astype(o_ref.dtype)


def flash_attention_pallas(q, k, v, *, causal: bool = True, window: int = 0,
                           logit_softcap: float = 0.0, scale: float = None,
                           bq: int = 512, bk: int = 512,
                           interpret: bool = False):
    """q/k/v: (B, H, S, hd) -> (B, H, S, hd)."""
    B, H, S, hd = q.shape
    T = k.shape[2]
    scale = scale if scale is not None else hd ** -0.5
    bq, bk = min(bq, S), min(bk, T)
    assert S % bq == 0 and T % bk == 0
    qf = q.reshape(B * H, S, hd)
    kf = k.reshape(B * H, T, hd)
    vf = v.reshape(B * H, T, hd)
    grid = (B * H, S // bq, T // bk)
    kernel = functools.partial(
        _flash_kernel, scale=scale, logit_softcap=logit_softcap,
        window=window, causal=causal, bq=bq, bk=bk, n_kv=T // bk)
    try:
        params = pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"))
    except (AttributeError, TypeError):
        params = pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"))
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, hd), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, hd), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, hd), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, S, hd), q.dtype),
        scratch_shapes=[pltpu.VMEM((bq, 1), jnp.float32),
                        pltpu.VMEM((bq, 1), jnp.float32),
                        pltpu.VMEM((bq, hd), jnp.float32)],
        compiler_params=params,
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(B, H, S, hd)
