"""Oracle: causal (optionally windowed / softcapped) attention.

Delegates to the model-side blockwise implementation so the kernel, the
model path, and this oracle are provably the same math.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.models.attention import flash_attention as _blockwise


def flash_attention_ref(q, k, v, *, causal: bool = True, window=None,
                        logit_softcap: float = 0.0, scale: float = None):
    """q/k/v: (B, H, S, hd) -> (B, H, S, hd). One-shot masked softmax."""
    B, H, S, hd = q.shape
    scale = scale if scale is not None else hd ** -0.5
    qf = q.astype(jnp.float32)
    s = jnp.einsum("bhqd,bhkd->bhqk", qf, k.astype(jnp.float32)) * scale
    if logit_softcap:
        s = logit_softcap * jnp.tanh(s / logit_softcap)
    pos = jnp.arange(S)
    allow = jnp.ones((S, S), bool)
    if causal:
        allow &= pos[:, None] >= pos[None, :]
    if window is not None:
        allow &= (pos[:, None] - pos[None, :]) < window
    s = jnp.where(allow, s, -2.0e38)
    p = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


def flash_attention_blockwise_ref(q, k, v, **kw):
    """The model-path blockwise formulation ((B,S,H,hd) layout)."""
    return _blockwise(q, k, v, **kw)
