"""Oracle for the chunked Mamba2 SSD scan — delegates to the model-side
chunk function (`repro.models.mamba2._ssd_chunk`)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.mamba2 import _ssd_chunk


def ssd_ref(xdt, dA, B_, C_, *, chunk: int = 64, initial_state=None):
    """xdt: (B,S,H,hd) [= dt*x]; dA: (B,S,H); B_/C_: (B,S,G,N).
    Returns (Y (B,S,H,hd), final_state (B,H,hd,N))."""
    Bb, S, H, hd = xdt.shape
    G, N = B_.shape[2], B_.shape[3]
    Q = min(chunk, S)
    assert S % Q == 0
    nC = S // Q
    cum = jnp.cumsum(dA.reshape(Bb, nC, Q, H), axis=2).transpose(1, 0, 2, 3)
    blks = (cum,
            B_.reshape(Bb, nC, Q, G, N).transpose(1, 0, 2, 3, 4),
            C_.reshape(Bb, nC, Q, G, N).transpose(1, 0, 2, 3, 4),
            xdt.reshape(Bb, nC, Q, H, hd).transpose(1, 0, 2, 3, 4))
    S0 = (initial_state if initial_state is not None
          else jnp.zeros((Bb, H, hd, N), jnp.float32))
    step = lambda c, b: _ssd_chunk(c, b, H=H, G=G, N=N, hd=hd)
    S_fin, Ys = jax.lax.scan(step, S0, blks)
    return Ys.transpose(1, 0, 2, 3, 4).reshape(Bb, S, H, hd), S_fin


def ssd_sequential_ref(xdt, dA, B_, C_):
    """Step recurrence S_t = exp(dA_t) S_{t-1} + xdt_t B_t ; y_t = C_t S_t."""
    Bb, S, H, hd = xdt.shape
    G, N = B_.shape[2], B_.shape[3]
    Hg = H // G
    S0 = jnp.zeros((Bb, G, Hg, hd, N), jnp.float32)

    def step(state, t):
        x = xdt[:, t].reshape(Bb, G, Hg, hd)
        a = jnp.exp(dA[:, t]).reshape(Bb, G, Hg)
        state = state * a[..., None, None] + jnp.einsum(
            "bghd,bgn->bghdn", x, B_[:, t])
        y = jnp.einsum("bgn,bghdn->bghd", C_[:, t], state)
        return state, y.reshape(Bb, H, hd)

    S_fin, ys = jax.lax.scan(step, S0, jnp.arange(S))
    return jnp.moveaxis(ys, 0, 1), S_fin.reshape(Bb, H, hd, N)
