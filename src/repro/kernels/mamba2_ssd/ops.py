"""jit'd wrapper: Pallas on TPU (G==1), oracle fallback otherwise."""
from __future__ import annotations

from functools import partial

import jax

from repro.kernels.mamba2_ssd.kernel import ssd_pallas
from repro.kernels.mamba2_ssd.ref import ssd_ref, ssd_sequential_ref


@partial(jax.jit, static_argnames=("chunk", "force_interpret"))
def ssd(xdt, dA, B_, C_, *, chunk: int = 64, force_interpret: bool = False):
    if B_.shape[2] != 1:
        return ssd_ref(xdt, dA, B_, C_, chunk=chunk)[0]
    interpret = force_interpret or jax.default_backend() != "tpu"
    return ssd_pallas(xdt, dA, B_, C_, chunk=chunk, interpret=interpret)


__all__ = ["ssd", "ssd_ref", "ssd_sequential_ref"]
