"""Pallas TPU kernel: chunked Mamba2 SSD scan (n_groups == 1).

Grid (B, n_chunks): chunk dim sequential, carrying the (H, hd, N) state in
VMEM scratch.  Per chunk: the (Q,Q) C·B score matrix hits the MXU once and
is reused by every head; the per-head decay mask is a (Q,Q,H) VMEM tensor.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(xdt_ref, dA_ref, b_ref, c_ref, o_ref, state_ref, *,
                Q: int, H: int, hd: int, N: int):
    ic = pl.program_id(1)

    @pl.when(ic == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    xdt = xdt_ref[0].astype(jnp.float32)         # (Q, H, hd)
    dA = dA_ref[0].astype(jnp.float32)           # (Q, H)
    Bc = b_ref[0].astype(jnp.float32)            # (Q, N)   (G == 1)
    Cc = c_ref[0].astype(jnp.float32)            # (Q, N)

    cum = jnp.cumsum(dA, axis=0)                 # (Q, H)
    scores = jax.lax.dot_general(Cc, Bc, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)  # (Q,Q)
    mask = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    L = jnp.exp(jnp.where(mask[..., None],
                          cum[:, None, :] - cum[None, :, :], -1e9))  # (Q,Q,H)
    M = L * scores[..., None]
    Y = jnp.einsum("tjh,jhd->thd", M, xdt)
    # inter-chunk
    Y = Y + jnp.einsum("tn,hdn->thd", Cc, state_ref[...]) \
        * jnp.exp(cum)[..., None]
    # state update
    dec_end = jnp.exp(cum[-1][None, :] - cum)                 # (Q, H)
    state_ref[...] = (state_ref[...] * jnp.exp(cum[-1])[:, None, None]
                      + jnp.einsum("jh,jhd,jn->hdn", dec_end, xdt, Bc))
    o_ref[0] = Y.astype(o_ref.dtype)


def ssd_pallas(xdt, dA, B_, C_, *, chunk: int = 64, interpret: bool = False):
    """xdt (B,S,H,hd); dA (B,S,H); B_/C_ (B,S,1,N) -> Y (B,S,H,hd)."""
    Bb, S, H, hd = xdt.shape
    G, N = B_.shape[2], B_.shape[3]
    assert G == 1, "ssd_pallas supports n_groups == 1 (ops falls back)"
    Q = min(chunk, S)
    assert S % Q == 0
    grid = (Bb, S // Q)
    kernel = functools.partial(_ssd_kernel, Q=Q, H=H, hd=hd, N=N)
    try:
        params = pltpu.CompilerParams(
            dimension_semantics=("parallel", "arbitrary"))
    except (AttributeError, TypeError):
        params = pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "arbitrary"))
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, Q, H, hd), lambda b, c: (b, c, 0, 0)),
            pl.BlockSpec((1, Q, H), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, Q, N), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, Q, N), lambda b, c: (b, c, 0)),
        ],
        out_specs=pl.BlockSpec((1, Q, H, hd), lambda b, c: (b, c, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((Bb, S, H, hd), xdt.dtype),
        scratch_shapes=[pltpu.VMEM((H, hd, N), jnp.float32)],
        compiler_params=params,
        interpret=interpret,
    )(xdt, dA, B_[:, :, 0], C_[:, :, 0])
