"""Pallas TPU kernels for the framework's compute hot-spots.

  tiled_matmul    A^T B single-precision tiled matmul — the paper's METG
                  benchmark workload (§3), MXU-tiled for TPU
  flash_attention fused causal attention w/ online softmax (prefill path)
  rwkv6_scan      chunked WKV recurrence (RWKV6 time-mix inner loop)
  mamba2_ssd      chunked state-space-dual scan (Mamba2 inner loop)

Each kernel ships kernel.py (pl.pallas_call + BlockSpec VMEM tiling),
ops.py (jit'd wrapper with interpret-mode fallback on CPU), and ref.py
(pure-jnp oracle used by the models and the allclose test sweeps).
"""
