"""Scheduler backends: the engine's view of a task database.

A backend adapts a concrete scheduler state (dwork `TaskServer`, sharded
`ShardedHub`) to the uniform protocol the worker pool speaks — the same
five verbs as the paper's Table 2 wire API:

    create(name, deps, meta)            Create
    steal(worker, n) -> tasks|EMPTY|DONE   Steal -> TaskMsg|NotFound|Exit
    complete(worker, name, ok)          Complete (ok=False poisons succs)
    exit_worker(worker)                 Exit (recycle assignment)

Every call is timed and emitted as an `rpc` trace event — the measured
analog of the paper's 23 us per-task RTT (Table 4).
"""
from __future__ import annotations

import time
from typing import Optional

from repro.core.dwork.api import (Complete, Create, Exit, ExitResp, NotFound,
                                  Steal, TaskMsg)
from repro.core.dwork.server import TaskServer
from repro.core.dwork.sharded import ShardedHub
from repro.core.engine.model import REQUEUED, RPC

# steal() sentinels
EMPTY = "empty"                 # nothing ready now, but work remains
DONE = "done"                   # every task reached a terminal state


class ServerBackend:
    """Engine backend over a single dwork `TaskServer` (paper §2.2)."""

    def __init__(self, server: Optional[TaskServer] = None, *,
                 lease_timeout: Optional[float] = None, clock=None,
                 tracer=None):
        self.server = server or TaskServer(lease_timeout=lease_timeout,
                                           clock=clock)
        self.tracer = tracer

    # ------------------------------------------------------------ timing
    def _call(self, op: str, msg):
        t0 = time.perf_counter()
        resp = self.server.handle(msg)
        if self.tracer is not None:
            self.tracer.emit(RPC, op=op, dt=time.perf_counter() - t0)
        return resp

    def _note_requeues(self, before: int):
        n = self.server.counters["requeued"] - before
        if n > 0 and self.tracer is not None:
            self.tracer.emit(REQUEUED, n=n, via="lease")

    # ---------------------------------------------------------- protocol
    def create(self, name: str, deps=(), meta=None):
        self._call("create", Create(task=name, deps=list(deps),
                                    meta=dict(meta or {})))

    def steal(self, worker: str, n: int = 1):
        before = self.server.counters["requeued"]
        resp = self._call("steal", Steal(worker=worker, n=n))
        self._note_requeues(before)
        if isinstance(resp, TaskMsg):
            return list(resp.tasks)
        if isinstance(resp, ExitResp):
            return DONE
        return EMPTY

    def complete(self, worker: str, name: str, ok: bool = True):
        self._call("complete", Complete(worker=worker, task=name, ok=ok))

    def exit_worker(self, worker: str):
        before = self.server.counters["requeued"]
        self._call("exit", Exit(worker=worker))
        n = self.server.counters["requeued"] - before
        if n > 0 and self.tracer is not None:
            self.tracer.emit(REQUEUED, worker=worker, n=n, via="exit")
        return n

    def errors(self) -> set:
        return set(self.server.errors)

    def stats(self) -> dict:
        return self.server.stats()


class ShardedBackend:
    """Engine backend over a `ShardedHub` — sharded routing with worker
    affinity and cross-shard stealing (paper §6 expansion item 4)."""

    def __init__(self, hub: Optional[ShardedHub] = None, *, shards: int = 2,
                 lease_timeout: Optional[float] = None, clock=None,
                 tracer=None):
        self.hub = hub or ShardedHub(shards, lease_timeout=lease_timeout,
                                     clock=clock)
        self.tracer = tracer
        self._shard_of: dict[str, int] = {}   # stolen task -> serving shard

    def _emit_rpc(self, op: str, dt: float):
        if self.tracer is not None:
            self.tracer.emit(RPC, op=op, dt=dt)

    def create(self, name: str, deps=(), meta=None):
        t0 = time.perf_counter()
        self.hub.create(name, deps=deps, meta=meta)
        self._emit_rpc("create", time.perf_counter() - t0)

    def steal(self, worker: str, n: int = 1):
        t0 = time.perf_counter()
        affinity = None
        if worker.rsplit("w", 1)[-1].isdigit():
            affinity = int(worker.rsplit("w", 1)[-1])
        resp, shard = self.hub.steal(worker, n=n, affinity=affinity)
        self._emit_rpc("steal", time.perf_counter() - t0)
        if isinstance(resp, TaskMsg):
            for name, _meta in resp.tasks:
                self._shard_of[name] = shard
            return list(resp.tasks)
        if isinstance(resp, ExitResp):
            return DONE
        return EMPTY

    def complete(self, worker: str, name: str, ok: bool = True):
        shard = self._shard_of.pop(name, None)
        if shard is None:
            # duplicate completion (e.g. clearing a suppressed re-steal's
            # assignment): route by the hub's authoritative home map —
            # never guess a shard
            shard = self.hub.home.get(name)
            if shard is None:
                return
        t0 = time.perf_counter()
        self.hub.complete(worker, name, shard, ok=ok)
        self._emit_rpc("complete", time.perf_counter() - t0)

    def exit_worker(self, worker: str):
        before = sum(s.counters["requeued"] for s in self.hub.shards)
        self.hub.exit_worker(worker)
        n = sum(s.counters["requeued"] for s in self.hub.shards) - before
        if n > 0 and self.tracer is not None:
            self.tracer.emit(REQUEUED, worker=worker, n=n, via="exit")
        return n

    def errors(self) -> set:
        return {t for s in self.hub.shards for t in s.errors
                if not t.startswith("__")}

    def stats(self) -> dict:
        return self.hub.stats()
