"""Scheduler backends: the engine's view of a task database.

A backend adapts a concrete scheduler state (dwork `TaskServer`, sharded
`ShardedHub`, or a TaskServer behind a forwarding tree) to the uniform
protocol the worker pool speaks — the paper's Table 2 wire API:

    create(name, deps, meta)            Create
    steal(worker, n) -> tasks|EMPTY|DONE   Steal -> TaskMsg|NotFound|Exit
    complete(worker, name, ok)          Complete (ok=False poisons succs)
    complete_steal(worker, done, n)     CompleteSteal: batched completions
                                        piggybacked on the next steal —
                                        ONE round-trip per batch in both
                                        protocol directions (Fig. 2)
    exit_worker(worker)                 Exit (recycle assignment)
    cancel(name) -> bool                Cancel: withdraw an unleased task
                                        (futures client; framework extension)
    prune_terminal() -> int             drop terminal history entries
                                        (bounded state; maintenance hook)
    close()                             release transports (tree sockets)

Every call is timed and emitted as an `rpc` trace event — the measured
analog of the paper's 23 us per-task RTT (Table 4).  `TreeBackend` hops
additionally emit `op="hop:<label>"` events so `OverheadReport.rpc_by_op`
attributes forwarding-tree latency per level.
"""
from __future__ import annotations

import math
import time
from typing import Optional

from repro.core.dwork.api import (Cancel, Complete, CompleteSteal, Create,
                                  Exit, ExitResp, NotFound, Steal, TaskMsg)
from repro.core.dwork.server import TaskServer
from repro.core.dwork.sharded import ShardedHub
from repro.core.engine.model import REQUEUED, RPC

# steal() sentinels
EMPTY = "empty"                 # nothing ready now, but work remains
DONE = "done"                   # every task reached a terminal state


def _steal_result(resp):
    """Decode a Steal/CompleteSteal response into the engine's uniform
    (tasks | EMPTY | DONE) — one ladder shared by every backend."""
    if isinstance(resp, TaskMsg):
        return [tuple(t) for t in resp.tasks]
    if isinstance(resp, ExitResp):
        return DONE
    return EMPTY


class ServerBackend:
    """Engine backend over a single dwork `TaskServer` (paper §2.2)."""

    def __init__(self, server: Optional[TaskServer] = None, *,
                 lease_timeout: Optional[float] = None, clock=None,
                 tracer=None):
        self.server = server or TaskServer(lease_timeout=lease_timeout,
                                           clock=clock)
        self.tracer = tracer

    # ------------------------------------------------------------ timing
    def _request(self, msg):
        """Deliver one protocol message — subclasses reroute this (the
        tree sends it over the calling worker's forwarder connection)."""
        return self.server.handle(msg)

    def _call(self, op: str, msg):
        tracer = self.tracer
        if tracer is None or not tracer.sample_rpc():
            # unsampled: skip the perf_counter pair AND the event
            # allocation — the call is still counted in tracer.rpc_seen
            return self._request(msg)
        t0 = time.perf_counter()
        resp = self._request(msg)
        tracer.emit(RPC, op=op, dt=time.perf_counter() - t0)
        return resp

    def _note_requeues(self, before: int):
        n = self.server.counters["requeued"] - before
        if n > 0 and self.tracer is not None:
            self.tracer.emit(REQUEUED, n=n, via="lease")

    # ---------------------------------------------------------- protocol
    def create(self, name: str, deps=(), meta=None):
        self._call("create", Create(task=name, deps=list(deps),
                                    meta=dict(meta or {})))

    def create_many(self, tasks: list):
        """Batched Create — `tasks` is [(name, deps, meta), ...].  One
        timed `rpc` event and one server lock hold cover the whole batch
        (the resident engine's mailbox ingest path: per-create timing
        apparatus would otherwise rival the create itself)."""
        tracer = self.tracer
        if tracer is None or not tracer.sample_rpc():
            self.server.create_bulk(tasks)
            return
        t0 = time.perf_counter()
        self.server.create_bulk(tasks)
        tracer.emit(RPC, op="create_many", dt=time.perf_counter() - t0,
                    n=len(tasks))

    def steal(self, worker: str, n: int = 1):
        before = self.server.counters["requeued"]
        resp = self._call("steal", Steal(worker=worker, n=n))
        self._note_requeues(before)
        return _steal_result(resp)

    def complete(self, worker: str, name: str, ok: bool = True):
        self._call("complete", Complete(worker=worker, task=name, ok=ok))

    def complete_steal(self, worker: str, done, n: int = 0):
        """Batched completions + the next steal in ONE round-trip."""
        before = self.server.counters["requeued"]
        resp = self._call("complete_steal",
                          CompleteSteal(worker=worker, done=list(done), n=n))
        self._note_requeues(before)
        return _steal_result(resp) if n > 0 else EMPTY

    def exit_worker(self, worker: str):
        before = self.server.counters["requeued"]
        self._call("exit", Exit(worker=worker))
        n = self.server.counters["requeued"] - before
        if n > 0 and self.tracer is not None:
            self.tracer.emit(REQUEUED, worker=worker, n=n, via="exit")
        return n

    def cancel(self, name: str) -> bool:
        """Withdraw an unleased, non-terminal task (futures-client cancel);
        False means the cancel lost the race (stolen/terminal/unknown)."""
        resp = self._call("cancel", Cancel(task=name))
        return isinstance(resp, ExitResp)

    def prune_terminal(self, keep=()) -> int:
        """Drop terminal entries from the server history tables (bounded
        state for resident services; see TaskServer.prune_terminal)."""
        return len(self.server.prune_terminal(keep=keep))

    def errors(self) -> set:
        return set(self.server.errors)

    def ready_depth(self) -> int:
        """Tasks ready-to-steal right now (no RPC — a monitoring probe for
        the serving layer's queue-depth accounting, not a protocol verb)."""
        return len(self.server.ready)

    def stats(self) -> dict:
        return self.server.stats()

    def close(self):
        pass


class ShardedBackend:
    """Engine backend over a `ShardedHub` — sharded routing with worker
    affinity and cross-shard stealing (paper §6 expansion item 4)."""

    def __init__(self, hub: Optional[ShardedHub] = None, *, shards: int = 2,
                 lease_timeout: Optional[float] = None, clock=None,
                 tracer=None):
        self.hub = hub or ShardedHub(shards, lease_timeout=lease_timeout,
                                     clock=clock)
        self.tracer = tracer
        self._shard_of: dict[str, int] = {}   # stolen task -> serving shard

    def _sampled(self) -> bool:
        return self.tracer is not None and self.tracer.sample_rpc()

    def _emit_rpc(self, op: str, dt: float):
        self.tracer.emit(RPC, op=op, dt=dt)

    @staticmethod
    def _affinity(worker: str):
        """Shard affinity from the engine's worker naming (w<i>)."""
        tail = worker.rsplit("w", 1)[-1]
        return int(tail) if tail.isdigit() else None

    def create(self, name: str, deps=(), meta=None):
        sampled = self._sampled()
        t0 = time.perf_counter() if sampled else 0.0
        self.hub.create(name, deps=deps, meta=meta)
        if sampled:
            self._emit_rpc("create", time.perf_counter() - t0)

    def create_many(self, tasks: list):
        sampled = self._sampled()
        t0 = time.perf_counter() if sampled else 0.0
        for name, deps, meta in tasks:
            self.hub.create(name, deps=deps, meta=meta)
        if sampled:
            self._emit_rpc("create_many", time.perf_counter() - t0)

    def steal(self, worker: str, n: int = 1):
        sampled = self._sampled()
        t0 = time.perf_counter() if sampled else 0.0
        resp, shard = self.hub.steal(worker, n=n,
                                     affinity=self._affinity(worker))
        if sampled:
            self._emit_rpc("steal", time.perf_counter() - t0)
        if isinstance(resp, TaskMsg):
            for name, _meta in resp.tasks:
                self._shard_of[name] = shard
            return list(resp.tasks)
        if isinstance(resp, ExitResp):
            return DONE
        return EMPTY

    def complete(self, worker: str, name: str, ok: bool = True):
        shard = self._shard_of.pop(name, None)
        if shard is None:
            # duplicate completion (e.g. a late report for a re-stolen
            # task): route by the hub's authoritative home map — never
            # guess a shard
            shard = self.hub.home.get(name)
            if shard is None:
                return
        sampled = self._sampled()
        t0 = time.perf_counter() if sampled else 0.0
        self.hub.complete(worker, name, shard, ok=ok)
        if sampled:
            self._emit_rpc("complete", time.perf_counter() - t0)

    def complete_steal(self, worker: str, done, n: int = 0):
        """Batched completions grouped per home shard, then the next steal
        — one timed backend round-trip for the whole batch."""
        sampled = self._sampled()
        t0 = time.perf_counter() if sampled else 0.0
        routed = []
        for name, ok in done:
            shard = self._shard_of.pop(name, None)
            if shard is None:
                shard = self.hub.home.get(name)
                if shard is None:
                    continue
            routed.append((name, ok, shard))
        resp, shard = self.hub.complete_steal(
            worker, routed, n=n, affinity=self._affinity(worker))
        out = EMPTY
        if n > 0:
            if isinstance(resp, TaskMsg):
                for name, _meta in resp.tasks:
                    self._shard_of[name] = shard
                out = list(resp.tasks)
            elif isinstance(resp, ExitResp):
                out = DONE
        if sampled:
            self._emit_rpc("complete_steal", time.perf_counter() - t0)
        return out

    def exit_worker(self, worker: str):
        before = sum(s.counters["requeued"] for s in self.hub.shards)
        self.hub.exit_worker(worker)
        n = sum(s.counters["requeued"] for s in self.hub.shards) - before
        if n > 0 and self.tracer is not None:
            self.tracer.emit(REQUEUED, worker=worker, n=n, via="exit")
        return n

    def cancel(self, name: str) -> bool:
        sampled = self._sampled()
        t0 = time.perf_counter() if sampled else 0.0
        ok = self.hub.cancel(name)
        if sampled:
            self._emit_rpc("cancel", time.perf_counter() - t0)
        return ok

    def prune_terminal(self, keep=()) -> int:
        return self.hub.prune_terminal(keep=keep)

    def errors(self) -> set:
        return {t for s in self.hub.shards for t in s.errors
                if not t.startswith("__")}

    def ready_depth(self) -> int:
        return sum(len(s.ready) for s in self.hub.shards)

    def stats(self) -> dict:
        return self.hub.stats()

    def close(self):
        pass


class TreeBackend(ServerBackend):
    """ServerBackend whose workers reach the hub through a
    message-forwarding tree (paper §4-§5): the TaskServer is hosted behind
    a TCP frame server, `levels` layers of `Forwarder`s relay frames with
    a shared pipelined upstream link per node, and each worker holds one
    connection to its leaf forwarder (`fanout` workers per leaf).

    Every worker-side call is timed end-to-end as an `rpc` event; each
    forwarder hop additionally emits `op="hop:L<level>"` events, so
    `OverheadReport.rpc_by_op` attributes where tree latency accrues.
    """

    def __init__(self, server: Optional[TaskServer] = None, *,
                 workers: int = 1, fanout: int = 4, levels: int = 1,
                 lease_timeout: Optional[float] = None, clock=None,
                 tracer=None):
        # lazy import: client.py is also imported by forwarder.py
        from repro.core.dwork.client import TCPServer, TCPTransport

        self.forwarders: list = []    # exists before the tracer setter runs
        super().__init__(server=server, lease_timeout=lease_timeout,
                         clock=clock, tracer=tracer)
        self.fanout = max(int(fanout), 1)
        self.levels = max(int(levels), 1)
        self._TCPTransport = TCPTransport
        self.tcp = TCPServer(("127.0.0.1", 0), self.server)
        self.tcp.serve_background()
        self.forwarders = self._build_tree(max(int(workers), 1))
        self.leaves = self.forwarders[-1]
        self._conn: dict[str, object] = {}    # worker -> TCPTransport
        self._boss = None                     # create/stats link to the hub
        self._next_leaf = 0

    def _build_tree(self, workers: int):
        """Build `levels` forwarder layers bottom-up in size, top-down in
        wiring: layer 1 feeds the hub, the leaf layer serves workers."""
        from repro.core.dwork.forwarder import Forwarder

        n_leaves = max(1, math.ceil(workers / self.fanout))
        sizes = [n_leaves]
        for _ in range(self.levels - 1):
            sizes.append(max(1, math.ceil(sizes[-1] / self.fanout)))
        sizes.reverse()                       # top (hub-facing) first
        layers = []
        upstreams = [self.tcp.server_address]
        for level, size in enumerate(sizes, start=1):
            layer = []
            for i in range(size):
                up = upstreams[i % len(upstreams)]
                fwd = Forwarder(("127.0.0.1", 0), up, tracer=self.tracer,
                                label=f"L{level}")
                fwd.serve_background()
                layer.append(fwd)
            upstreams = [f.server_address for f in layer]
            layers.append(layer)
        return layers

    @property
    def tracer(self):
        return self._tracer

    @tracer.setter
    def tracer(self, tracer):
        # the Forwarders capture the tracer at construction; a backend
        # built without one (and patched later by Engine.__init__) must
        # propagate it or every hop:L<k> event is silently lost
        self._tracer = tracer
        for layer in self.forwarders:
            for fwd in layer:
                fwd.tracer = tracer

    # --------------------------------------------------------- transports
    def _transport(self, worker: str):
        tr = self._conn.get(worker)
        if tr is None:
            leaf = self.leaves[self._next_leaf % len(self.leaves)]
            self._next_leaf += 1
            tr = self._TCPTransport(*leaf.server_address)
            self._conn[worker] = tr
        return tr

    def _request(self, msg):
        """Route the shared protocol verbs over real sockets: worker
        messages go through the calling worker's forwarder connection,
        worker-less ones (Create) over the boss link to the hub."""
        worker = getattr(msg, "worker", None)
        if worker is None:
            if self._boss is None:            # boss talks to the hub direct
                self._boss = self._TCPTransport(*self.tcp.server_address)
            return self._boss.request(msg)
        return self._transport(worker).request(msg)

    def create_many(self, tasks: list):
        """Tree path: each Create crosses the boss link individually (the
        wire has no batched Create verb) — one timed rpc event covers the
        batch."""
        tracer = self.tracer
        sampled = tracer is not None and tracer.sample_rpc()
        t0 = time.perf_counter() if sampled else 0.0
        for name, deps, meta in tasks:
            self._request(Create(task=name, deps=list(deps),
                                 meta=dict(meta or {})))
        if sampled:
            tracer.emit(RPC, op="create_many", dt=time.perf_counter() - t0,
                        n=len(tasks))

    # ------------------------------------------------------ introspection
    def stats(self) -> dict:
        stats = self.server.stats()
        stats["tree"] = {
            "levels": self.levels, "fanout": self.fanout,
            "forwarders": [len(layer) for layer in self.forwarders],
            "relayed": [sum(f.relayed for f in layer)
                        for layer in self.forwarders],
        }
        return stats

    def close(self):
        for tr in self._conn.values():
            tr.close()
        self._conn.clear()
        if self._boss is not None:
            self._boss.close()
            self._boss = None
        for layer in reversed(self.forwarders):
            for fwd in layer:
                fwd.close()
        self.tcp.shutdown()
        self.tcp.server_close()
