"""Scheduler backends: the engine's view of a task database.

A backend adapts a concrete scheduler state (dwork `TaskServer`, sharded
`ShardedHub`, or a TaskServer behind a forwarding tree) to the uniform
protocol the worker pool speaks — the paper's Table 2 wire API:

    create(name, deps, meta)            Create
    steal(worker, n) -> tasks|EMPTY|DONE   Steal -> TaskMsg|NotFound|Exit
    complete(worker, name, ok)          Complete (ok=False poisons succs)
    complete_steal(worker, done, n)     CompleteSteal: batched completions
                                        piggybacked on the next steal —
                                        ONE round-trip per batch in both
                                        protocol directions (Fig. 2)
    exit_worker(worker)                 Exit (recycle assignment)
    cancel(name) -> bool                Cancel: withdraw an unleased task
                                        (futures client; framework extension)
    prune_terminal() -> int             drop terminal history entries
                                        (bounded state; maintenance hook)
    close()                             release transports (tree sockets)

Every call is timed and emitted as an `rpc` trace event — the measured
analog of the paper's 23 us per-task RTT (Table 4).  `TreeBackend` hops
additionally emit `op="hop:<label>"` events so `OverheadReport.rpc_by_op`
attributes forwarding-tree latency per level.
"""
from __future__ import annotations

import math
import time
from typing import Optional

from repro.core.dwork.api import (Cancel, Complete, CompleteSteal, Create,
                                  Exit, ExitResp, NotFound, Steal, TaskMsg,
                                  Transfer)
from repro.core.dwork.server import TaskServer
from repro.core.dwork.sharded import ShardedHub
from repro.core.engine.model import REQUEUED, RPC

# steal() sentinels
EMPTY = "empty"                 # nothing ready now, but work remains
DONE = "done"                   # every task reached a terminal state


def _steal_result(resp):
    """Decode a Steal/CompleteSteal response into the engine's uniform
    (tasks | EMPTY | DONE) — one ladder shared by every backend."""
    if isinstance(resp, TaskMsg):
        return [tuple(t) for t in resp.tasks]
    if isinstance(resp, ExitResp):
        return DONE
    return EMPTY


class ServerBackend:
    """Engine backend over a single dwork `TaskServer` (paper §2.2)."""

    n_shards = 1          # dispatch-rate multiplier for the METG laws

    def __init__(self, server: Optional[TaskServer] = None, *,
                 lease_timeout: Optional[float] = None, clock=None,
                 tracer=None):
        self.server = server or TaskServer(lease_timeout=lease_timeout,
                                           clock=clock)
        self.tracer = tracer
        # optional rpc-latency sink (repro.core.obs.RpcMetrics): fed the
        # same sampled timings the trace gets, so rpc_sample= thins both
        self.metrics = None
        # optional write-ahead journal (engine.journal.Journal): requeue
        # events are backend-observed, so the engine hands its journal
        # down for the ["rq", n, via] records
        self.journal = None

    # ------------------------------------------------------------ timing
    def _request(self, msg):
        """Deliver one protocol message — subclasses reroute this (the
        tree sends it over the calling worker's forwarder connection)."""
        return self.server.handle(msg)

    def _requeued_total(self) -> int:
        """Requeue counter across the whole backing store (subclasses
        with several servers sum them)."""
        return self.server.counters["requeued"]

    def _call(self, op: str, msg):
        tracer = self.tracer
        if tracer is None or not tracer.sample_rpc():
            # unsampled: skip the perf_counter pair AND the event
            # allocation — the call is still counted in tracer.rpc_seen
            return self._request(msg)
        t0 = time.perf_counter()
        resp = self._request(msg)
        dt = time.perf_counter() - t0
        tracer.emit(RPC, op=op, dt=dt)
        m = self.metrics
        if m is not None:
            m.observe(op, dt)
        return resp

    def _note_requeues(self, before: int):
        n = self._requeued_total() - before
        if n > 0:
            if self.tracer is not None:
                self.tracer.emit(REQUEUED, n=n, via="lease")
            if self.journal is not None:
                self.journal.append_requeue(n, "lease")

    # ---------------------------------------------------------- protocol
    def create(self, name: str, deps=(), meta=None):
        self._call("create", Create(task=name, deps=list(deps),
                                    meta=dict(meta or {})))

    def create_many(self, tasks: list):
        """Batched Create — `tasks` is [(name, deps, meta), ...].  One
        timed `rpc` event and one server lock hold cover the whole batch
        (the resident engine's mailbox ingest path: per-create timing
        apparatus would otherwise rival the create itself)."""
        tracer = self.tracer
        if tracer is None or not tracer.sample_rpc():
            self.server.create_bulk(tasks)
            return
        t0 = time.perf_counter()
        self.server.create_bulk(tasks)
        dt = time.perf_counter() - t0
        tracer.emit(RPC, op="create_many", dt=dt, n=len(tasks))
        m = self.metrics
        if m is not None:
            m.observe("create_many", dt)

    def steal(self, worker: str, n: int = 1):
        before = self._requeued_total()
        resp = self._call("steal", Steal(worker=worker, n=n))
        self._note_requeues(before)
        return _steal_result(resp)

    def complete(self, worker: str, name: str, ok: bool = True):
        self._call("complete", Complete(worker=worker, task=name, ok=ok))

    def complete_steal(self, worker: str, done, n: int = 0):
        """Batched completions + the next steal in ONE round-trip."""
        before = self._requeued_total()
        resp = self._call("complete_steal",
                          CompleteSteal(worker=worker, done=list(done), n=n))
        self._note_requeues(before)
        return _steal_result(resp) if n > 0 else EMPTY

    def exit_worker(self, worker: str):
        before = self._requeued_total()
        self._call("exit", Exit(worker=worker))
        n = self._requeued_total() - before
        if n > 0:
            if self.tracer is not None:
                self.tracer.emit(REQUEUED, worker=worker, n=n, via="exit")
            if self.journal is not None:
                self.journal.append_requeue(n, "exit")
        return n

    def cancel(self, name: str) -> bool:
        """Withdraw an unleased, non-terminal task (futures-client cancel);
        False means the cancel lost the race (stolen/terminal/unknown)."""
        resp = self._call("cancel", Cancel(task=name))
        return isinstance(resp, ExitResp)

    def transfer(self, worker: str, name: str, new_deps=()):
        """Table-2 Transfer: put `worker`'s leased task back into the
        queue, blocked on `new_deps` (dynamic task graphs; the engine's
        lost-value recompute path requeues dependents through this)."""
        return self._call("transfer",
                          Transfer(worker=worker, task=name,
                                   new_deps=list(new_deps)))

    def prune_terminal(self, keep=()) -> int:
        """Drop terminal entries from the server history tables (bounded
        state for resident services; see TaskServer.prune_terminal)."""
        return len(self.server.prune_terminal(keep=keep))

    def errors(self) -> set:
        return set(self.server.errors)

    def ready_depth(self) -> int:
        """Tasks ready-to-steal right now (no RPC — a monitoring probe for
        the serving layer's queue-depth accounting, not a protocol verb)."""
        return len(self.server.ready)

    def ready_depths(self) -> list:
        """Per-shard ready depths (monitoring probe; one entry here)."""
        return [self.ready_depth()]

    def stats(self) -> dict:
        return self.server.stats()

    def close(self):
        pass


class ShardedBackend:
    """Engine backend over a `ShardedHub` — sharded routing with worker
    affinity and cross-shard stealing (paper §6 expansion item 4)."""

    def __init__(self, hub: Optional[ShardedHub] = None, *, shards: int = 2,
                 lease_timeout: Optional[float] = None, clock=None,
                 tracer=None):
        self.hub = hub or ShardedHub(shards, lease_timeout=lease_timeout,
                                     clock=clock)
        self.tracer = tracer
        self.metrics = None                   # see ServerBackend.metrics
        self.journal = None                   # see ServerBackend.journal
        self._shard_of: dict[str, int] = {}   # stolen task -> serving shard

    @property
    def n_shards(self) -> int:
        return len(self.hub.shards)

    def _sampled(self) -> bool:
        return self.tracer is not None and self.tracer.sample_rpc()

    def _emit_rpc(self, op: str, dt: float):
        self.tracer.emit(RPC, op=op, dt=dt)
        m = self.metrics
        if m is not None:
            m.observe(op, dt)

    def _requeued_total(self) -> int:
        return self.hub.requeued_total()

    # shard affinity from the engine's worker naming (w<i>) — one
    # definition, shared with the hub's own wire-boundary routing
    _affinity = staticmethod(ShardedHub._affinity)

    def create(self, name: str, deps=(), meta=None):
        sampled = self._sampled()
        t0 = time.perf_counter() if sampled else 0.0
        self.hub.create(name, deps=deps, meta=meta)
        if sampled:
            self._emit_rpc("create", time.perf_counter() - t0)

    def create_many(self, tasks: list):
        sampled = self._sampled()
        t0 = time.perf_counter() if sampled else 0.0
        for name, deps, meta in tasks:
            self.hub.create(name, deps=deps, meta=meta)
        if sampled:
            self._emit_rpc("create_many", time.perf_counter() - t0)

    def steal(self, worker: str, n: int = 1):
        sampled = self._sampled()
        t0 = time.perf_counter() if sampled else 0.0
        resp, shard = self.hub.steal(worker, n=n,
                                     affinity=self._affinity(worker))
        if sampled:
            self._emit_rpc("steal", time.perf_counter() - t0)
        if isinstance(resp, TaskMsg):
            for name, _meta in resp.tasks:
                self._shard_of[name] = shard
            return list(resp.tasks)
        if isinstance(resp, ExitResp):
            return DONE
        return EMPTY

    def complete(self, worker: str, name: str, ok: bool = True):
        shard = self._shard_of.pop(name, None)
        if shard is None:
            # duplicate completion (e.g. a late report for a re-stolen
            # task): route by the hub's authoritative home map — never
            # guess a shard
            shard = self.hub.home.get(name)
            if shard is None:
                return
        sampled = self._sampled()
        t0 = time.perf_counter() if sampled else 0.0
        self.hub.complete(worker, name, shard, ok=ok)
        if sampled:
            self._emit_rpc("complete", time.perf_counter() - t0)

    def complete_steal(self, worker: str, done, n: int = 0):
        """Batched completions grouped per home shard, then the next steal
        — one timed backend round-trip for the whole batch."""
        sampled = self._sampled()
        t0 = time.perf_counter() if sampled else 0.0
        routed = []
        for name, ok in done:
            shard = self._shard_of.pop(name, None)
            if shard is None:
                shard = self.hub.home.get(name)
                if shard is None:
                    continue
            routed.append((name, ok, shard))
        resp, shard = self.hub.complete_steal(
            worker, routed, n=n, affinity=self._affinity(worker))
        out = EMPTY
        if n > 0:
            if isinstance(resp, TaskMsg):
                for name, _meta in resp.tasks:
                    self._shard_of[name] = shard
                out = list(resp.tasks)
            elif isinstance(resp, ExitResp):
                out = DONE
        if sampled:
            self._emit_rpc("complete_steal", time.perf_counter() - t0)
        return out

    def exit_worker(self, worker: str):
        before = self.hub.requeued_total()
        self.hub.exit_worker(worker)
        n = self.hub.requeued_total() - before
        if n > 0:
            if self.tracer is not None:
                self.tracer.emit(REQUEUED, worker=worker, n=n, via="exit")
            if self.journal is not None:
                self.journal.append_requeue(n, "exit")
        return n

    def cancel(self, name: str) -> bool:
        sampled = self._sampled()
        t0 = time.perf_counter() if sampled else 0.0
        ok = self.hub.cancel(name)
        if sampled:
            self._emit_rpc("cancel", time.perf_counter() - t0)
        return ok

    def transfer(self, worker: str, name: str, new_deps=()):
        """Transfer routed to the task's home shard (with held-proxy
        mediation for cross-shard new deps — see ShardedHub.transfer)."""
        sampled = self._sampled()
        t0 = time.perf_counter() if sampled else 0.0
        resp = self.hub.transfer(worker, name, new_deps=list(new_deps))
        if sampled:
            self._emit_rpc("transfer", time.perf_counter() - t0)
        return resp

    def prune_terminal(self, keep=()) -> int:
        return self.hub.prune_terminal(keep=keep)

    def errors(self) -> set:
        return self.hub.user_errors()

    def ready_depth(self) -> int:
        return self.hub.ready_depth()

    def ready_depths(self) -> list:
        return [len(s.ready) for s in self.hub.shards]

    def stats(self) -> dict:
        return self.hub.stats()

    def close(self):
        pass


class TreeBackend(ServerBackend):
    """ServerBackend whose workers reach the hub through a
    message-forwarding tree (paper §4-§5): the hub is hosted behind TCP
    frame servers, `levels` layers of `Forwarder`s relay frames with a
    shared pipelined upstream link per node, and each worker holds one
    connection to its leaf forwarder (`fanout` workers per leaf).

    With `shards > 1` (or a caller-supplied `hub=`) the two scaling
    levers COMPOSE (paper §6 item 4 behind §4): the top-level layer is
    built from `ShardRouter`s instead of blind relays — each decodes the
    frames the tree delivers and routes the Table-2 verbs by task hash
    to per-shard TaskServers, each behind its own TCP frame server,
    through the shared `ShardedHub` routing state (affinity steals,
    cross-shard `__notify__` mediation, CompleteSteal split/merge).
    Worker-less verbs (Create/Cancel) ride the boss link into a router,
    so cross-shard dependency and poison traffic enters through the
    same apex the workers use.

    Every worker-side call is timed end-to-end as an `rpc` event; each
    forwarder hop additionally emits `op="hop:L<level>"` events, and
    each per-shard round-trip behind a router emits
    `op="hop:L1:s<shard>"`, so `OverheadReport.rpc_by_op` attributes
    where tree latency accrues — per level, and per shard at the apex.
    """

    def __init__(self, server: Optional[TaskServer] = None, *,
                 workers: int = 1, fanout: int = 4, levels: int = 1,
                 shards: int = 1, hub: Optional[ShardedHub] = None,
                 lease_timeout: Optional[float] = None, clock=None,
                 tracer=None):
        # lazy import: client.py is also imported by forwarder.py
        from repro.core.dwork.client import TCPServer, TCPTransport

        self.forwarders: list = []    # exists before the tracer setter runs
        self.metrics = None           # see ServerBackend.metrics
        self.journal = None           # see ServerBackend.journal
        self._shard_links = None
        self._shard_tcp: list = []
        n_shards = len(hub.shards) if hub is not None else max(int(shards), 1)
        if hub is not None or n_shards > 1:
            if server is not None:
                raise ValueError("pass server= for a single hub OR "
                                 "hub=/shards>1 for a sharded one, not both")
            self.hub = hub or ShardedHub(n_shards,
                                         lease_timeout=lease_timeout,
                                         clock=clock)
            self.server = None
            self.tracer = tracer
        else:
            self.hub = None
            super().__init__(server=server, lease_timeout=lease_timeout,
                             clock=clock, tracer=tracer)
        self.n_shards = n_shards
        self.fanout = max(int(fanout), 1)
        self.levels = max(int(levels), 1)
        self._TCPTransport = TCPTransport
        if self.hub is not None:
            from repro.core.dwork.forwarder import ShardLinks

            # one TCP frame server per shard: the per-shard verbs cross a
            # real wire, so the hop:L1:s<j> fan-out timings are honest
            self._shard_tcp = [TCPServer(("127.0.0.1", 0), s)
                               for s in self.hub.shards]
            for t in self._shard_tcp:
                t.serve_background()
            self._shard_links = ShardLinks(
                [t.server_address for t in self._shard_tcp],
                tracer=self.tracer)
            self.hub.sender = self._shard_links
            self.tcp = None
        else:
            self.tcp = TCPServer(("127.0.0.1", 0), self.server)
            self.tcp.serve_background()
        self.forwarders = self._build_tree(max(int(workers), 1))
        self.leaves = self.forwarders[-1]
        self._conn: dict[str, object] = {}    # worker -> TCPTransport
        self._boss = None                     # create/stats link to the hub
        self._next_leaf = 0

    def _build_tree(self, workers: int):
        """Build `levels` forwarder layers bottom-up in size, top-down in
        wiring: layer 1 feeds the hub, the leaf layer serves workers.
        Sharded hub: the layer-1 nodes are `ShardRouter`s (hash routing
        at the apex) sharing one hub + one set of per-shard links."""
        from repro.core.dwork.forwarder import Forwarder, ShardRouter

        n_leaves = max(1, math.ceil(workers / self.fanout))
        sizes = [n_leaves]
        for _ in range(self.levels - 1):
            sizes.append(max(1, math.ceil(sizes[-1] / self.fanout)))
        sizes.reverse()                       # top (hub-facing) first
        layers = []
        upstreams = [self.tcp.server_address] if self.tcp is not None else []
        for level, size in enumerate(sizes, start=1):
            layer = []
            for i in range(size):
                if level == 1 and self.hub is not None:
                    node = ShardRouter(("127.0.0.1", 0), self.hub,
                                       tracer=self.tracer, label=f"L{level}")
                else:
                    up = upstreams[i % len(upstreams)]
                    node = Forwarder(("127.0.0.1", 0), up,
                                     tracer=self.tracer, label=f"L{level}")
                node.serve_background()
                layer.append(node)
            upstreams = [f.server_address for f in layer]
            layers.append(layer)
        return layers

    @property
    def tracer(self):
        return self._tracer

    @tracer.setter
    def tracer(self, tracer):
        # the Forwarders (and the sharded hub's per-shard links) capture
        # the tracer at construction; a backend built without one (and
        # patched later by Engine.__init__) must propagate it or every
        # hop:L<k>[:s<j>] event is silently lost
        self._tracer = tracer
        for layer in self.forwarders:
            for fwd in layer:
                fwd.tracer = tracer
        links = getattr(self, "_shard_links", None)
        if links is not None:
            links.tracer = tracer

    def _requeued_total(self) -> int:
        if self.hub is not None:
            return self.hub.requeued_total()
        return self.server.counters["requeued"]

    # --------------------------------------------------------- transports
    def _transport(self, worker: str):
        tr = self._conn.get(worker)
        if tr is None:
            leaf = self.leaves[self._next_leaf % len(self.leaves)]
            self._next_leaf += 1
            tr = self._TCPTransport(*leaf.server_address)
            self._conn[worker] = tr
        return tr

    def _request(self, msg):
        """Route the shared protocol verbs over real sockets: worker
        messages go through the calling worker's forwarder connection,
        worker-less ones (Create/Cancel) over the boss link — to the hub
        direct, or into a top-level router when the hub is sharded (the
        cross-shard `__notify__` fan-out rides the boss link's frames)."""
        worker = getattr(msg, "worker", None)
        if worker is None:
            if self._boss is None:
                addr = (self.forwarders[0][0].server_address
                        if self.hub is not None else self.tcp.server_address)
                self._boss = self._TCPTransport(*addr)
            return self._boss.request(msg)
        return self._transport(worker).request(msg)

    def create_many(self, tasks: list):
        """Tree path: each Create crosses the boss link individually (the
        wire has no batched Create verb) — one timed rpc event covers the
        batch."""
        tracer = self.tracer
        sampled = tracer is not None and tracer.sample_rpc()
        t0 = time.perf_counter() if sampled else 0.0
        for name, deps, meta in tasks:
            self._request(Create(task=name, deps=list(deps),
                                 meta=dict(meta or {})))
        if sampled:
            dt = time.perf_counter() - t0
            tracer.emit(RPC, op="create_many", dt=dt, n=len(tasks))
            m = self.metrics
            if m is not None:
                m.observe("create_many", dt)

    # ------------------------------------------------------ introspection
    def prune_terminal(self, keep=()) -> int:
        if self.hub is not None:
            return self.hub.prune_terminal(keep=keep)
        return super().prune_terminal(keep=keep)

    def errors(self) -> set:
        if self.hub is not None:
            return self.hub.user_errors()
        return super().errors()

    def ready_depth(self) -> int:
        if self.hub is not None:
            return self.hub.ready_depth()
        return super().ready_depth()

    def ready_depths(self) -> list:
        if self.hub is not None:
            return [len(s.ready) for s in self.hub.shards]
        return super().ready_depths()

    def stats(self) -> dict:
        stats = self.hub.stats() if self.hub is not None \
            else self.server.stats()
        stats["tree"] = {
            "levels": self.levels, "fanout": self.fanout,
            "shards": self.n_shards,
            "forwarders": [len(layer) for layer in self.forwarders],
            "relayed": [sum(f.relayed for f in layer)
                        for layer in self.forwarders],
        }
        return stats

    def close(self):
        for tr in self._conn.values():
            tr.close()
        self._conn.clear()
        if self._boss is not None:
            self._boss.close()
            self._boss = None
        for layer in reversed(self.forwarders):
            for fwd in layer:
                fwd.close()
        if self._shard_links is not None:
            # hand the hub back to in-process dispatch: a caller-supplied
            # hub must stay usable after the tree is torn down (its verbs
            # would otherwise hit the dead links forever)
            if self.hub.sender is self._shard_links:
                self.hub.sender = None
            self._shard_links.close()
        for t in self._shard_tcp:
            t.shutdown()
            t.server_close()
        if self.tcp is not None:
            self.tcp.shutdown()
            self.tcp.server_close()
