"""Engine data model: tasks, results, and wall-clock-stamped trace events.

Lifecycle (mirrors the paper's Fig. 2 message protocol, generalized to all
three schedulers):

    created  -> ready -> stolen -> run_start -> run_end -> completed
                  ^                                     -> failed
                  |________ requeued (Exit / lease expiry / Transfer) __|

Mapping to Fig. 2 / Table 2 messages:
    created   <- Create(task, deps)        (dwork) / build_graph (pmake)
    stolen    <- Steal -> TaskMsg          (dwork) / greedy launch (pmake)
                                           / rank-block dispatch (mpi-list)
    completed <- Complete(worker, task, ok=True)
    failed    <- Complete(ok=False)        (poisons transitive successors)
    requeued  <- Exit(worker) recycle, lease-timeout reap, or Transfer
"""
from __future__ import annotations

import itertools
import random
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Optional

# ---------------------------------------------------------------- events
CREATED = "created"
READY = "ready"
STOLEN = "stolen"
RUN_START = "run_start"
RUN_END = "run_end"
COMPLETED = "completed"
FAILED = "failed"
REQUEUED = "requeued"
RETRIED = "retried"             # transient failure re-enqueued (RetryPolicy)
CANCELLED = "cancelled"         # client cancel before the task was stolen
WORKER_DEAD = "worker_dead"
RPC = "rpc"                     # one scheduler round-trip (the paper's RTT)
XFER = "xfer"                   # one dependency-value transfer (extra:
                                # path="peer"|"hub", n=bytes, dt=seconds)

# serving-layer events (repro.core.serving): one *request* may ride a
# coalesced batch task, so its lifecycle is traced separately from tasks
REQ_ENQUEUED = "req_enqueued"   # admitted to the frontend queue
REQ_DONE = "req_done"           # response delivered (extra: latency_s, ok)
REQ_REJECTED = "req_rejected"   # bounced by admission backpressure
REQ_TIMEOUT = "req_timeout"     # queued past its deadline (never dispatched)
BATCH_FORMED = "batch_formed"   # requests coalesced into one engine task

TERMINAL = (COMPLETED, FAILED)


class WorkerCrash(Exception):
    """Raise from inside an `execute` callback to simulate (or propagate) a
    fatal worker failure.  The engine marks the raising worker dead,
    announces its Exit so the in-flight task and everything it still holds
    is requeued (never marked failed), and keeps dispatching on the
    surviving workers — the paper's node-failure recovery, triggerable from
    application code (runtime.elastic uses it for crash drills)."""


class TraceEvent:
    """One lifecycle/rpc event.  A plain slotted class (not a dataclass):
    it is allocated 4-5 times per task on the hot path, and the per-event
    dict + generated __init__ of a dataclass are measurable there."""

    __slots__ = ("t", "event", "task", "worker", "extra")

    def __init__(self, t: float, event: str, task: Optional[str] = None,
                 worker: Optional[str] = None, extra: Optional[dict] = None):
        self.t = t
        self.event = event
        self.task = task
        self.worker = worker
        self.extra = extra if extra is not None else {}

    def __repr__(self):
        return (f"TraceEvent(t={self.t!r}, event={self.event!r}, "
                f"task={self.task!r}, worker={self.worker!r}, "
                f"extra={self.extra!r})")


@dataclass(frozen=True)
class RetryPolicy:
    """Transient-failure handling for task executions.

    A failed execution (raise, ok=False, or an injected fault) is
    re-enqueued with seeded-jitter exponential backoff until it has run
    `max_attempts` times; only exhaustion marks the task failed and
    poisons its successors.  `retry_on` (substrings matched against the
    error repr) limits which failures count as transient — anything else
    fails immediately.  `WorkerCrash` is never retried: a dying worker's
    assignment is requeued by the Exit/lease machinery, not by policy.

    Backoff for attempt k (1-based) is `backoff * 2**(k-1)` scaled by a
    seeded uniform jitter in [1, 1+jitter] — keyed by (seed, task,
    attempt), so the delay is a pure function of the plan, independent
    of execution order (the same determinism contract as `FaultPlan`).
    Retried tasks keep their scheduler-side assignment, so a retry costs
    no extra protocol round-trip — only the backoff delay, which trades
    against METG: keep `backoff` well under the task duration times
    `max_attempts` or retries dominate the overhead budget (see
    docs/robustness.md)."""
    max_attempts: int = 3
    backoff: float = 0.0
    jitter: float = 0.5
    seed: int = 0
    retry_on: Optional[tuple] = None

    def should_retry(self, attempt: int, error: Optional[str] = None) -> bool:
        """Is a re-run allowed after `attempt` executions (1-based) ended
        with `error`?"""
        if attempt >= self.max_attempts:
            return False
        if self.retry_on is None:
            return True
        err = error or ""
        return any(pat in err for pat in self.retry_on)

    def delay_s(self, task: str, attempt: int) -> float:
        """Seeded-jitter backoff before re-run number `attempt + 1`."""
        if self.backoff <= 0.0:
            return 0.0
        base = self.backoff * (2.0 ** max(attempt - 1, 0))
        if self.jitter <= 0.0:
            return base
        u = random.Random(f"{self.seed}:retry:{task}:{attempt}").random()
        return base * (1.0 + self.jitter * u)


@dataclass
class EngineTask:
    """A unit of work submitted to the engine.

    `fn` is an optional zero-arg callable producing the task's value (used
    by the mpi-list adapter and examples); schedulers that execute by name
    (dwork's `execute(name, meta)`, pmake's script runner) leave it None.
    `slots` is the number of pool slots the task occupies while running
    (pmake: nodes, `nrs`); `priority` is greedy-highest-first (pmake EFT);
    `retry` overrides the engine-wide `RetryPolicy` for this task.
    """
    name: str
    fn: Optional[Callable[[], Any]] = None
    deps: tuple = ()
    meta: dict = field(default_factory=dict)
    slots: int = 1
    priority: float = 0.0
    retry: Optional[RetryPolicy] = None


@dataclass(slots=True)
class TaskResult:
    task: str
    ok: bool
    worker: str
    t_start: float = 0.0        # real clock (perf_counter) run span
    t_end: float = 0.0
    value: Any = None
    error: Optional[str] = None
    virtual_s: float = 0.0      # injected straggler time (never slept)
    crashed: bool = False       # WorkerCrash: requeue, don't record/fail

    @property
    def duration_s(self) -> float:
        return (self.t_end - self.t_start) + self.virtual_s


class ManualClock:
    """Deterministic clock for tests: advances `tick` seconds per call plus
    whatever `advance()` adds.  Using it for both the trace recorder and the
    task server's lease clock makes heartbeat/lease expiry a pure function
    of the number of scheduler operations — no wall-clock dependence."""

    def __init__(self, start: float = 0.0, tick: float = 0.0):
        self.now = start
        self.tick = tick

    def __call__(self) -> float:
        self.now += self.tick
        return self.now

    def advance(self, dt: float) -> float:
        self.now += dt
        return self.now


_seq = itertools.count()


def next_seq() -> int:
    """Monotonic tie-breaker for priority scheduling (stable FIFO)."""
    return next(_seq)


# the default trace clock IS perf_counter — no wrapper frame on the hot path
real_clock = time.perf_counter
