"""The unified worker pool: one execution substrate for all three schedulers.

A single dispatch loop drives virtual workers against a scheduler backend
(`ServerBackend` / `ShardedBackend` / `TreeBackend`), generalizing the
paper's three execution loops:

  * dwork  (§2.2) — workers Steal-n batches and Complete tasks; the loop
    IS the paper's Fig. 2 CLIENT-LOOP, with per-worker fault injection.
  * pmake  (§2.1) — tasks carry `slots` (nodes) and `priority` (EFT);
    the launch step is pmake's "greedy highest-priority-first onto free
    nodes", with `capacity` total slots.
  * mpi-list (§2.3) — each bulk step submits one task per rank; per-rank
    times (plus injected straggler jitter) feed the Gumbel sync-gap model.

Transports:
  * "inproc" — tasks run inline in the dispatch loop; fully deterministic
    (round-robin steal order, no threads, injectable clock) — the default
    for tests, fault injection, and pure-overhead measurement.
  * "thread" — a slot-bounded thread pool; real concurrency for workloads
    that block (pmake's popen'd scripts).
  * "tree"   — like inproc, but every worker RPC crosses a real TCP
    message-forwarding tree (paper §4): `tree_fanout` workers per leaf
    `Forwarder`, `tree_levels` relay layers, pipelined shared upstream
    links, per-hop `rpc` trace events.

Hot path: completions are buffered per worker and piggybacked onto that
worker's next steal as ONE `CompleteSteal` round-trip (the Fig. 2
batch-then-drain rhythm — `steal_n` amortizes both protocol directions),
the pending set is a priority heap with incrementally-maintained
per-worker outstanding counts (no per-round rescans/sorts), and every
lifecycle transition is emitted to the `TraceRecorder`, from which
`tracing.OverheadReport` computes empirical per-task overhead and METG.
"""
from __future__ import annotations

import time
from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass, field
from heapq import heapify, heappop, heappush
from typing import Callable, Optional

from repro.core.engine.backends import (DONE, EMPTY, ServerBackend,
                                        ShardedBackend, TreeBackend)
from repro.core.engine.faults import FaultPlan
from repro.core.engine.model import (COMPLETED, CREATED, FAILED, READY,
                                     RUN_END, RUN_START, STOLEN, WORKER_DEAD,
                                     EngineTask, TaskResult)
from repro.core.engine.tracing import OverheadReport, TraceRecorder

TRANSPORTS = ("inproc", "thread", "tree")


@dataclass
class EngineReport:
    results: dict                      # task -> TaskResult (last execution)
    trace: TraceRecorder
    workers: int                       # effective parallelism (overhead math)
    wall_s: float
    pool_workers: int = 1              # configured pool size (reporting)
    errors: set = field(default_factory=set)
    stalled: bool = False
    backend_stats: dict = field(default_factory=dict)

    @property
    def completed(self) -> set:
        return {n for n, r in self.results.items() if r.ok}

    def overhead(self) -> OverheadReport:
        return self.trace.report(workers=self.workers)


class Engine:
    def __init__(self, *, workers: int = 1, capacity: Optional[int] = None,
                 transport: str = "inproc", steal_n: int = 1, shards: int = 1,
                 backend=None, tracer: Optional[TraceRecorder] = None,
                 faults: Optional[FaultPlan] = None, clock=None,
                 lease_timeout: Optional[float] = None, poll: float = 0.001,
                 max_idle_rounds: Optional[int] = None, tree_fanout: int = 4,
                 tree_levels: int = 1):
        if transport not in TRANSPORTS:
            raise ValueError(f"unknown transport {transport!r}")
        if transport == "tree" and shards > 1:
            raise ValueError("tree transport forwards to a single hub; "
                             "use shards=1 (shard the hub behind it instead)")
        self.workers = max(int(workers), 0)
        self.capacity = capacity if capacity is not None else max(workers, 1)
        self.transport = transport
        self.steal_n = max(int(steal_n), 1)
        self.faults = faults
        self.poll = poll
        self.lease_timeout = lease_timeout
        self.tracer = tracer or TraceRecorder(clock=clock)
        self._owns_backend = backend is None
        if backend is None:
            if transport == "tree":
                backend = TreeBackend(workers=self.workers,
                                      fanout=tree_fanout, levels=tree_levels,
                                      lease_timeout=lease_timeout,
                                      clock=clock, tracer=self.tracer)
            elif shards > 1:
                backend = ShardedBackend(shards=shards,
                                         lease_timeout=lease_timeout,
                                         clock=clock, tracer=self.tracer)
            else:
                backend = ServerBackend(lease_timeout=lease_timeout,
                                        clock=clock, tracer=self.tracer)
        elif getattr(backend, "tracer", None) is None:
            backend.tracer = self.tracer
        self.backend = backend
        # long enough for a heartbeat lease to expire while idling
        if max_idle_rounds is None:
            max_idle_rounds = 500
            if lease_timeout:
                max_idle_rounds = max(500, int(2 * lease_timeout / poll))
        self.max_idle_rounds = max_idle_rounds
        # engine-local task registry (fn/priority/slots + ready tracking)
        self.tasks: dict[str, EngineTask] = {}
        self._waiting: dict[str, set] = {}
        self._succs: dict[str, list] = {}

    # ------------------------------------------------------------- submit
    def submit(self, name: str, fn: Optional[Callable] = None, *,
               deps=(), meta: Optional[dict] = None, priority: float = 0.0,
               slots: int = 1) -> EngineTask:
        """Register a task.  Submit producers before dependents: the task
        server forward-declares an unknown dep as a READY stub and treats
        a later Create of the same name as a no-op (dwork §2.2 semantics),
        so a dependent submitted first would run before its producer."""
        task = EngineTask(name=name, fn=fn, deps=tuple(deps),
                          meta=dict(meta or {}), slots=max(int(slots), 1),
                          priority=priority)
        self.tasks[name] = task
        self.backend.create(name, deps=task.deps, meta=task.meta)
        self.tracer.emit(CREATED, task=name)
        if task.deps:
            self._waiting[name] = set(task.deps)
            for d in task.deps:
                self._succs.setdefault(d, []).append(name)
        else:
            self.tracer.emit(READY, task=name)
        return task

    def _on_terminal(self, name: str):
        for succ in self._succs.pop(name, []):
            w = self._waiting.get(succ)
            if w is None:
                continue
            w.discard(name)
            if not w:
                del self._waiting[succ]
                self.tracer.emit(READY, task=succ)

    # -------------------------------------------------------------- exec
    def _execute_registered(self, name: str, meta: dict):
        task = self.tasks.get(name)
        if task is None or task.fn is None:
            return (True, None)
        return (True, task.fn())

    def _run_one(self, exec_fn, name: str, meta: dict,
                 worker: str) -> TaskResult:
        tracer = self.tracer
        tracer.emit4(RUN_START, name, worker)
        t0 = time.perf_counter()
        ok, value, err = True, None, None
        try:
            out = exec_fn(name, meta)
            if isinstance(out, tuple):
                ok, value = bool(out[0]), out[1]
            elif out is None:
                ok = True
            elif isinstance(out, bool):
                ok = out
            else:
                ok, value = True, out
        except Exception as e:                        # noqa: BLE001
            ok, err = False, repr(e)
        t1 = time.perf_counter()
        virtual = 0.0
        if self.faults is not None:
            virtual = self.faults.delay_s(name, worker)
            if self.faults.force_fail(name, worker):
                ok, err = False, err or "injected fault"
            tracer.emit(RUN_END, task=name, worker=worker, virtual_s=virtual)
        else:
            tracer.emit4(RUN_END, name, worker)
        return TaskResult(task=name, ok=ok, worker=worker, t_start=t0,
                          t_end=t1, value=value, error=err,
                          virtual_s=virtual)

    # --------------------------------------------------------------- run
    def run(self, execute: Optional[Callable] = None) -> EngineReport:
        """Run until every task reaches a terminal state (or all workers
        die / the pool stalls).  `execute(name, meta)` may return bool,
        (ok, value), or None (success); default runs the submitted `fn`."""
        exec_fn = execute or self._execute_registered
        t_wall0 = time.perf_counter()
        alive = [f"w{i}" for i in range(self.workers)]
        n_alive = max(len(alive), 1)
        dead: set[str] = set()
        steals = {w: 0 for w in alive}
        done_flag = {w: False for w in alive}
        # hot-path state, all maintained incrementally (no per-round scans):
        heap: list = []                # (-priority, seq, item) pending launch
        n_pending = 0
        pending_names: set[str] = set()
        outstanding = {w: 0 for w in alive}   # stolen, not yet finished
        finished = {w: [] for w in alive}     # (name, ok) awaiting piggyback
        running: dict[str, dict] = {}         # thread transport in-flight
        results: dict[str, TaskResult] = {}
        free = self.capacity
        idle_rounds = 0
        stalled = False
        pending_limit = max(self.workers, 1) * self.steal_n + self.capacity
        inline = self.transport != "thread"
        pool = (None if inline
                else ThreadPoolExecutor(max_workers=self.capacity))
        # local bindings keep the per-round constant cost down
        emit = self.tracer.emit
        emit4 = self.tracer.emit4
        complete_steal = self.backend.complete_steal
        run_one = self._run_one
        on_terminal = self._on_terminal
        priority_of = self._priority_of
        steal_n = self.steal_n
        capacity = self.capacity
        faults = self.faults
        # fault-free inline runs drain a priority-0 batch straight from
        # the steal response — no heap round-trip, no pending bookkeeping.
        # (With faults the slow path keeps the steal->death->launch window
        # so a dying worker observably holds stolen-but-unstarted tasks.)
        fast_drain = inline and faults is None
        seq = 0
        rounds = 0
        # launch gate: popping the heap is pointless until something can
        # change the outcome (a slot freed, new steals, a death scrub) —
        # without it a full backlog gets drained/re-pushed every poll
        try_launch = True
        try:
            while True:
                rounds += 1
                progress = False
                # 1) reap finished thread-pool tasks into per-worker batches
                if running:
                    for name in [n for n, r in running.items()
                                 if r["fut"].done()]:
                        rec = running.pop(name)
                        free += rec["slots"]
                        progress = True
                        try_launch = True
                        w = rec["worker"]
                        if w in dead:
                            continue  # lost completion: requeued via Exit
                        outstanding[w] -= 1
                        res: TaskResult = rec["fut"].result()
                        results[name] = res
                        finished[w].append((name, res.ok))
                        emit(COMPLETED if res.ok else FAILED, task=name,
                             worker=w, error=res.error)
                        if res.ok:  # failed tasks never ready their succs
                            self._on_terminal(name)
                # 2) complete+steal — one RPC flushes a worker's finished
                # batch AND steals its next one (Fig. 2 batch-then-drain);
                # a worker steals only while it holds fewer than steal_n
                # outstanding tasks; rotation keeps the order fair
                if n_alive == 1:
                    rotation = alive
                else:
                    start = rounds % n_alive
                    rotation = alive[start:] + alive[:start]
                for w in rotation:
                    if w in dead:
                        continue
                    batch = finished[w]
                    want_steal = (not done_flag[w]
                                  and outstanding[w] < steal_n
                                  and n_pending < pending_limit)
                    if not batch and not want_steal:
                        continue
                    got = complete_steal(w, batch,
                                         steal_n if want_steal else 0)
                    if batch:
                        finished[w] = []
                        progress = True
                    if not want_steal:
                        continue
                    if got == DONE:
                        done_flag[w] = True
                    elif got != EMPTY:
                        steals[w] += len(got)
                        accepted = []
                        for name, meta in got:
                            rec = running.get(name)
                            if (name in pending_names or name in results
                                    or (rec is not None
                                        and rec["worker"] not in dead)):
                                # duplicate steal after a lease-expiry
                                # requeue while a LIVE copy is still held
                                # (pending, in flight, or complete-pending):
                                # the copy's Complete clears every stale
                                # assignment server-side, so just drop it.
                                # A copy held only by a DEAD worker is
                                # accepted — its completion was discarded,
                                # so this re-steal is the only way forward.
                                continue
                            accepted.append((name, meta))
                        if not accepted:
                            continue
                        progress = True
                        # drain a batch inline ONLY when nothing in it (or
                        # already pending) carries a priority — otherwise a
                        # prio-0 item would run before a higher-priority
                        # one later in the same batch/heap
                        drain = fast_drain and not heap and all(
                            priority_of(name, meta) == 0.0
                            for name, meta in accepted)
                        if drain:
                            for name, meta in accepted:
                                # steal order == seq order: complete rides
                                # on this worker's next CompleteSteal
                                emit4(STOLEN, name, w)
                                res = run_one(exec_fn, name, meta, w)
                                results[name] = res
                                finished[w].append((name, res.ok))
                                if res.ok:
                                    emit4(COMPLETED, name, w)
                                    on_terminal(name)
                                else:
                                    emit(FAILED, task=name, worker=w,
                                         error=res.error)
                            continue
                        for name, meta in accepted:
                            emit4(STOLEN, name, w)
                            pending_names.add(name)
                            outstanding[w] += 1
                            seq += 1
                            heappush(heap, (
                                -priority_of(name, meta), seq,
                                {"name": name, "meta": meta, "worker": w,
                                 "slots": self._slots_of(name, meta)}))
                            n_pending += 1
                        try_launch = True
                # 3) fault injection: worker deaths (between steal & launch,
                #    so a dying worker holds stolen-but-unstarted tasks)
                if faults is not None:
                    scrub = False
                    for w in alive:
                        if w in dead:
                            continue
                        if faults.should_die(w, steals[w]):
                            dead.add(w)
                            silent = faults.dies_silently(w)
                            emit(WORKER_DEAD, worker=w, silent=silent)
                            if finished[w]:
                                # already-reported completions (step 2 ran
                                # first) — flush the stragglers so a result
                                # the engine recorded is never lost
                                complete_steal(w, finished[w], 0)
                                finished[w] = []
                            scrub = True
                            if not silent:
                                # announced death: Exit recycles assignment
                                self.backend.exit_worker(w)
                            # silent death: heartbeat-lease expiry recycles
                            progress = True
                    if scrub and heap:
                        kept = [e for e in heap if e[2]["worker"] not in dead]
                        if len(kept) != len(heap):
                            for e in heap:
                                if e[2]["worker"] in dead:
                                    pending_names.discard(e[2]["name"])
                            heap = kept
                            heapify(heap)
                            n_pending = len(heap)
                            try_launch = True
                # 4) launch: greedy highest-priority-first into free slots
                if heap and try_launch:
                    try_launch = False
                    held = []
                    while heap:
                        entry = heappop(heap)
                        it = entry[2]
                        name = it["name"]
                        if it["worker"] in dead:      # late scrub
                            pending_names.discard(name)
                            n_pending -= 1
                            continue
                        if name in running:
                            # a dead worker's copy is still in flight;
                            # wait for it to drain before re-launching
                            held.append(entry)
                            continue
                        slots = min(it["slots"], capacity)
                        if slots > free:
                            held.append(entry)
                            continue
                        pending_names.discard(name)
                        n_pending -= 1
                        w = it["worker"]
                        if inline:
                            res = self._run_one(exec_fn, name, it["meta"], w)
                            outstanding[w] -= 1
                            results[name] = res
                            finished[w].append((name, res.ok))
                            emit(COMPLETED if res.ok else FAILED, task=name,
                                 worker=w, error=res.error)
                            if res.ok:
                                self._on_terminal(name)
                        else:
                            free -= slots
                            fut = pool.submit(self._run_one, exec_fn, name,
                                              it["meta"], w)
                            running[name] = {"worker": w, "fut": fut,
                                             "slots": slots}
                        progress = True
                    for entry in held:
                        heappush(heap, entry)
                # 5) termination
                if not running and not n_pending:
                    live = [w for w in alive if w not in dead]
                    if not live:
                        # every worker died: unless one of them saw the
                        # server's DONE first, work remains unserved —
                        # that is a stall, not a clean finish
                        stalled = not any(done_flag.values())
                        break
                    if all(done_flag[w] for w in live) \
                            and not any(finished[w] for w in live):
                        break
                if progress:
                    idle_rounds = 0
                elif not running:
                    idle_rounds += 1
                    if idle_rounds >= self.max_idle_rounds:
                        stalled = True   # unresolvable (cycle / all leased)
                        break
                    time.sleep(self.poll)
                else:
                    time.sleep(self.poll)
        finally:
            if pool is not None:
                pool.shutdown(wait=True)
            if self._owns_backend:
                # in the finally so a mid-run RPC failure can't leak the
                # tree's sockets/threads; stats()/errors() below only
                # read in-process state and stay valid after close
                self.backend.close()
        # effective parallelism: the inline transports run tasks serially,
        # and the thread pool is sized by `capacity`, so overhead
        # accounting must not multiply wall time by phantom workers
        eff_workers = 1 if inline else min(self.workers, self.capacity)
        return EngineReport(
            results=results, trace=self.tracer, workers=eff_workers,
            pool_workers=self.workers,
            wall_s=time.perf_counter() - t_wall0,
            errors=self.backend.errors(), stalled=stalled,
            backend_stats=self.backend.stats())

    # ------------------------------------------------------------ helpers
    def _priority_of(self, name: str, meta: dict) -> float:
        task = self.tasks.get(name)
        if task is not None:
            return task.priority
        return float(meta.get("priority", 0.0)) if meta else 0.0

    def _slots_of(self, name: str, meta: dict) -> int:
        task = self.tasks.get(name)
        if task is not None:
            return task.slots
        return int(meta.get("slots", 1)) if meta else 1
