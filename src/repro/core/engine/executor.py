"""The unified worker pool: one execution substrate for all three schedulers.

A single dispatch loop drives virtual workers against a scheduler backend
(`ServerBackend` / `ShardedBackend` / `TreeBackend`), generalizing the
paper's three execution loops:

  * dwork  (§2.2) — workers Steal-n batches and Complete tasks; the loop
    IS the paper's Fig. 2 CLIENT-LOOP, with per-worker fault injection.
  * pmake  (§2.1) — tasks carry `slots` (nodes) and `priority` (EFT);
    the launch step is pmake's "greedy highest-priority-first onto free
    nodes", with `capacity` total slots.
  * mpi-list (§2.3) — each bulk step submits one task per rank; per-rank
    times (plus injected straggler jitter) feed the Gumbel sync-gap model.

Transports:
  * "inproc" — tasks run inline in the dispatch loop; fully deterministic
    (round-robin steal order, no threads, injectable clock) — the default
    for tests, fault injection, and pure-overhead measurement.
  * "thread" — a slot-bounded thread pool; real concurrency for workloads
    that block (pmake's popen'd scripts).
  * "tree"   — like inproc, but every worker RPC crosses a real TCP
    message-forwarding tree (paper §4): `tree_fanout` workers per leaf
    `Forwarder`, `tree_levels` relay layers, pipelined shared upstream
    links, per-hop `rpc` trace events.

Modes:
  * batch (default) — `run()` drains a pre-submitted task universe and
    returns when every task reaches a terminal state (or the pool stalls).
  * resident (`Engine(resident=True)`) — `start()` runs the same dispatch
    loop open-ended in a background thread; `submit()` keeps accepting
    work while workers are live (thread-safe), `drain()` blocks until the
    submitted universe is terminal, `shutdown()` stops the loop and
    returns the `EngineReport`.  `add_worker()` / `lose_worker()` change
    pool membership on the fly, and `self.steal_n` is re-read every round
    so batch size can track the live worker count.  Faults, heartbeat
    leases, and lifecycle tracing behave exactly as in batch mode; a
    server-side "all done" is treated as "idle" rather than termination
    until `shutdown()` is requested.  While idle, steals back off to one
    probe per `IDLE_PROBE_ROUNDS` rounds (a new `submit()` wakes the pool
    immediately via a submission epoch) so an idle service doesn't flood
    the trace with empty round-trips.  `repro.core.serving.Frontend`
    layers admission control and dynamic request batching on top.

Hot path: completions are buffered per worker and piggybacked onto that
worker's next steal as ONE `CompleteSteal` round-trip (the Fig. 2
batch-then-drain rhythm — `steal_n` amortizes both protocol directions),
the pending set is a priority heap with incrementally-maintained
per-worker outstanding counts (no per-round rescans/sorts), and every
lifecycle transition is emitted to the `TraceRecorder`, from which
`tracing.OverheadReport` computes empirical per-task overhead and METG.
"""
from __future__ import annotations

import threading
import time
from collections import deque
from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass, field
from heapq import heapify, heappop, heappush
from pathlib import Path
from typing import Callable, Optional

from repro.core.dwork.api import Fetch, ValueMsg
from repro.core.engine.backends import DONE, EMPTY
from repro.core.engine.comm import core as comm_core
from repro.core.engine.comm.serialize import RemoteValue, dumps_call, loads
from repro.core.engine.faults import FaultPlan
from repro.core.engine.journal import Journal
from repro.core.engine.model import (CANCELLED, COMPLETED, CREATED, FAILED,
                                     READY, REQUEUED, RETRIED, RUN_END,
                                     RUN_START, STOLEN, WORKER_DEAD, XFER,
                                     EngineTask, RetryPolicy, TaskResult,
                                     WorkerCrash)
from repro.core.engine.tracing import OverheadReport, TraceRecorder

# transport families live in the comm registry (repro.core.engine.comm);
# this tuple stays as the public "what can I pass" surface
TRANSPORTS = comm_core.transport_names()

# resident idle backoff: with no pending submissions, each worker probes
# the server once per this many rounds (lease reaping still happens on
# probes); a submit() bumps the epoch and re-enables steals immediately
IDLE_PROBE_ROUNDS = 16


@dataclass
class EngineReport:
    results: dict                      # task -> TaskResult (last execution)
    trace: TraceRecorder
    workers: int                       # effective parallelism (overhead math)
    wall_s: float
    pool_workers: int = 1              # configured pool size (reporting)
    errors: set = field(default_factory=set)
    stalled: bool = False
    backend_stats: dict = field(default_factory=dict)

    @property
    def completed(self) -> set:
        return {n for n, r in self.results.items() if r.ok}

    def overhead(self) -> OverheadReport:
        return self.trace.report(workers=self.workers)


class Engine:
    def __init__(self, *, workers: int = 1, capacity: Optional[int] = None,
                 transport: str = "inproc", steal_n: int = 1, shards: int = 1,
                 backend=None, tracer: Optional[TraceRecorder] = None,
                 faults: Optional[FaultPlan] = None, clock=None,
                 lease_timeout: Optional[float] = None, poll: float = 0.001,
                 max_idle_rounds: Optional[int] = None, tree_fanout: int = 4,
                 tree_levels: int = 1, resident: bool = False,
                 keep_results: bool = True,
                 on_result: Optional[Callable] = None,
                 retry: Optional[RetryPolicy] = None,
                 journal=None, proc_host: str = "127.0.0.1",
                 proc_port: int = 0, heartbeat_s: float = 0.5,
                 inline_bytes: int = 65536,
                 spill_bytes: int = 64 * 1024 * 1024):
        fam = comm_core.family(transport)   # raises on an unknown name
        self.workers = max(int(workers), 0)
        self.capacity = capacity if capacity is not None else max(workers, 1)
        self.transport = transport
        self.steal_n = max(int(steal_n), 1)
        self.faults = faults
        # engine-wide transient-failure policy (per-task `retry=` on
        # submit overrides it); None = a failure poisons immediately
        self.retry = retry
        # durable control plane: a write-ahead `Journal` (or a directory
        # path, which constructs and OWNS one — closed when the dispatch
        # loop exits).  Off by default: journaling is opt-in so the
        # fault-free hot path pays only a None check.
        self._owns_journal = isinstance(journal, (str, Path))
        self.journal = Journal(journal) if self._owns_journal else journal
        self.poll = poll
        self.lease_timeout = lease_timeout
        self.heartbeat_s = max(float(heartbeat_s), 0.05)
        # peer-to-peer data plane knobs (transport="proc"): results above
        # `inline_bytes` serialized payload stay in the producing worker's
        # local store (the hub tracks the LOCATION); `spill_bytes` is each
        # worker's LRU byte budget before owned values spill to the hub
        self.inline_bytes = max(int(inline_bytes), 0)
        self.spill_bytes = max(int(spill_bytes), 0)
        self.resident = bool(resident)
        # result plumbing for the futures client: `on_result(name, ok,
        # res, error)` fires exactly once per task name, at its FIRST
        # terminal transition (requeued re-executions never re-fire),
        # always outside the engine lock so the handler may call back in.
        # `res` is the TaskResult when the task executed here, None for
        # poisoned/cancelled/fail-fast tasks.  The handler must not raise
        # (a raise would kill the dispatch loop); the client guards its
        # user-visible callbacks itself.
        self.on_result = on_result
        # called (once, from the dying loop thread) if the resident
        # dispatch loop exits with an error, so a client can fail its
        # pending futures instead of leaving waiters hanging until
        # shutdown() re-raises
        self.on_loop_error: Optional[Callable] = None
        # resident services that hold results elsewhere (futures) can opt
        # out of the EngineReport.results history table (bounded state)
        self.keep_results = bool(keep_results)
        self.tracer = tracer or TraceRecorder(clock=clock)
        self._owns_backend = backend is None
        if backend is None:
            # the comm registry owns the backend recipe per transport
            # family (shards > 1 composes inside each builder: a
            # ShardedHub behind the tree, or sharded under proc)
            backend = fam.make_backend(
                workers=self.workers, shards=shards,
                lease_timeout=lease_timeout, clock=clock,
                tracer=self.tracer, tree_fanout=tree_fanout,
                tree_levels=tree_levels, steal_n=self.steal_n,
                resident=self.resident, proc_host=proc_host,
                proc_port=proc_port, heartbeat_s=self.heartbeat_s,
                inline_bytes=self.inline_bytes,
                spill_bytes=self.spill_bytes)
        else:
            if getattr(backend, "tracer", None) is None:
                backend.tracer = self.tracer
            if transport == "proc":
                from repro.core.engine.comm.proc import ProcBackend

                if not isinstance(backend, ProcBackend):
                    # a caller-supplied TaskServer/hub adaptation (the
                    # run_pool shim): front it with the process door.
                    # The wrapper's listener/processes are ours to close
                    # even though the inner backend is not.
                    backend = ProcBackend(
                        backend, host=proc_host, port=proc_port,
                        steal_n=self.steal_n, resident=self.resident,
                        heartbeat_s=self.heartbeat_s, owns_inner=False,
                        inline_bytes=self.inline_bytes,
                        spill_bytes=self.spill_bytes)
                    self._owns_backend = True
        self.backend = backend
        if self.journal is not None:
            # backends journal the requeue records their verbs observe
            # (Exit recycling, lease expiry) — the engine journals
            # create/terminal itself
            backend.journal = self.journal
        # the dispatch-rate multiplier the METG retunes see (serving
        # batch targets, elastic steal_n): authoritative from the
        # backend, so a caller-supplied hub/backend is counted too
        self.shards = getattr(backend, "n_shards", max(int(shards), 1))
        # long enough for a heartbeat lease to expire while idling
        if max_idle_rounds is None:
            max_idle_rounds = 500
            if lease_timeout:
                max_idle_rounds = max(500, int(2 * lease_timeout / poll))
        self.max_idle_rounds = max_idle_rounds
        # engine-local task registry (fn/priority/slots + ready tracking)
        self.tasks: dict[str, EngineTask] = {}
        self._waiting: dict[str, set] = {}
        self._succs: dict[str, list] = {}
        self._pass_worker = False
        # ---------------------------------------------- resident-mode state
        # _cond guards the registry + counters that submit() (any thread)
        # and the dispatch loop both touch; batch mode never takes it.
        # Built over a plain Lock: the re-entrancy of the default RLock is
        # never needed, and both threads take this once per task/batch, so
        # acquisition cost is on the submit hot path.
        self._cond = threading.Condition(threading.Lock())
        self._inflight = 0              # submitted, not yet terminal
        self._terminal: set[str] = set()
        self._failed: set[str] = set()
        self._epoch = 0                 # bumped on submit/requeue: wakes idle
        # resident submissions go through a mailbox: submit() appends
        # under a SHORT _cond hold (atomic w.r.t. cancel and the prune
        # keep-set) and the dispatch loop ingests in batches on its own
        # thread — the single-writer rule that keeps client threads off
        # the server lock on every task.  `_unsent` tracks names still
        # in the mailbox so cancel() can withdraw them engine-side.
        self._mailbox: deque = deque()
        self._unsent: set[str] = set()
        self._commands: deque = deque()  # ("add"|"lose", worker) membership
        self._live = self.workers       # live (not dead) worker count
        self._next_wid = self.workers   # auto worker naming for add_worker()
        self._stop = False              # drain-then-exit requested
        self._abort = False             # exit now, abandon pending work
        self._thread: Optional[threading.Thread] = None
        self._report: Optional[EngineReport] = None
        self._loop_error: Optional[BaseException] = None
        # -------------------------------------------------- observability
        # plain tables the dispatch loop maintains unconditionally (one
        # list-slot hit per completion); `repro.core.obs` reads them via
        # zero-cost callback instruments, and worker_stats()/
        # tasks_done_total() are the monitoring probes over them
        self.worker_deaths = 0
        self.exec_failed = 0                  # executions raised / not-ok
        self.retries_total = 0                # re-enqueues by RetryPolicy
        self._attempts: dict[str, int] = {}   # failed executions per task
        self._wstats: dict[str, list] = {}    # worker -> [done_n, busy_s]
        self._dead_workers: set = set()
        # ---------------------------------------------- data plane (proc)
        # transfer attribution: per-path [count, bytes, seconds] totals
        # (every fetch is counted — xfer events are not sampled), plus an
        # optional obs sink (repro.core.obs wires XferMetrics here)
        self.xfer_totals = {"peer": [0, 0, 0.0], "hub": [0, 0, 0.0]}
        self.xfer_metrics = None
        self.xfer_lost_total = 0              # lost-value recomputes issued
        self._xfer_lock = threading.Lock()    # totals vs. Future.result()
        self._xfer_conns: dict = {}           # data_addr -> Comm (engine)
        self._xfer_attempts: dict = {}        # lost name -> recompute count
        self._xfer_pending: dict = {}         # lost name -> recompute alias
        self._xfer_wanted: set = set()        # reader-requested recomputes
        self._loop_live = False               # dispatch loop can recompute
        # names whose payloads must survive prune_terminal: a done future
        # holding a RemoteValue that was lifted into a later submit's
        # arguments (the dependent has no dep edge the keep-set would see)
        self._pinned: set = set()

    # ------------------------------------------------------------- submit
    def submit(self, name: str, fn: Optional[Callable] = None, *,
               deps=(), meta: Optional[dict] = None, priority: float = 0.0,
               slots: int = 1,
               retry: Optional[RetryPolicy] = None) -> EngineTask:
        """Register a task.  Submit producers before dependents: the task
        server forward-declares an unknown dep as a READY stub and treats
        a later Create of the same name as a no-op (dwork §2.2 semantics),
        so a dependent submitted first would run before its producer.
        In resident mode this is thread-safe and may be called while the
        dispatch loop is running.  `retry` overrides the engine-wide
        `RetryPolicy` for this task."""
        if self.transport == "proc" and fn is not None:
            meta = dict(meta or {})
            if "__call__" not in meta:
                # pack the callable for the worker process NOW: an
                # unpicklable fn raises SerializationError at submit
                # time, naming the task — never opaquely in a worker
                meta["__call__"] = dumps_call(fn, task=name)
        task = EngineTask(name=name, fn=fn, deps=tuple(deps),
                          meta=dict(meta or {}), slots=max(int(slots), 1),
                          priority=priority, retry=retry)
        if not self.resident:
            self.tasks[name] = task
            self.backend.create(name, deps=task.deps, meta=task.meta)
            if self.journal is not None:
                self.journal.append_create(name, task.deps, task.meta)
            if task.deps:
                # deps ride the CREATED event so a saved/exported trace is
                # self-describing for critical-path analysis; dep-less
                # tasks (the dispatch hot path) keep the bare emit
                self.tracer.emit(CREATED, task=name, deps=list(task.deps))
                self._waiting[name] = set(task.deps)
                for d in task.deps:
                    self._succs.setdefault(d, []).append(name)
            else:
                self.tracer.emit(CREATED, task=name)
                self.tracer.emit(READY, task=name)
            return task
        # resident: mailbox enqueue.  The dispatch loop ingests creates in
        # batches at the top of its round (graph registration, failed-dep
        # fail-fast, server Create, _inflight accounting — all on the
        # loop thread), so a submitting client thread never crosses the
        # SERVER lock per task — the cross-thread lock+GIL ping-pong that
        # used to dominate per-future overhead.  The short _cond hold
        # here is cheap (the loop takes _cond per round/batch, not per
        # task) and makes submission atomic w.r.t. prune_terminal's
        # keep-set snapshot.  The task server keys history by name
        # forever, so a duplicate Create is a server-side no-op —
        # accepting one here would count an _inflight slot that never
        # drains and wedge drain()/shutdown(): names are single-use.
        with self._cond:
            if name in self.tasks:
                raise ValueError(f"task name {name!r} already submitted "
                                 "(resident task names are single-use)")
            self.tasks[name] = task
            self._unsent.add(name)
            self._mailbox.append(task)
            self._epoch += 1   # wakes an idle-probing loop immediately
        return task

    def _ingest_mailbox(self):
        """Dispatch-thread ingestion of mailboxed submissions: register
        the engine-side graph, fail-fast tasks whose producer already
        failed, count `_inflight`, then Create server-side — the
        single-writer half of the mailboxed resident submit()."""
        notify = self.on_result
        pending: list = []
        creates: list = []
        emit = self.tracer.emit
        with self._cond:
            while self._mailbox:
                task = self._mailbox.popleft()
                name = task.name
                self._unsent.discard(name)
                if name in self._terminal:
                    continue                      # cancelled before ingest
                live = None
                if task.deps:
                    failed_dep = next((d for d in task.deps
                                       if d in self._failed), None)
                    if failed_dep is not None:
                        # the producer already failed: creating this
                        # server-side would dangle forever (the server
                        # poisons successors at failure time, not at
                        # create time) — fail it engine-side
                        self._terminal.add(name)
                        self._failed.add(name)
                        why = f"dependency {failed_dep} failed"
                        emit(CREATED, task=name, deps=list(task.deps))
                        emit(FAILED, task=name, error=why)
                        j = self.journal
                        if j is not None:
                            j.append_create(name, task.deps, task.meta)
                            j.append_terminal(name, False, why)
                        if notify is not None:
                            pending.append((name, False, None, why))
                        continue
                    live = [d for d in task.deps
                            if d not in self._terminal]
                    if live:
                        self._waiting[name] = set(live)
                        for d in live:
                            self._succs.setdefault(d, []).append(name)
                self._inflight += 1
                creates.append((task, not live))
            if self._inflight <= 0:
                self._cond.notify_all()   # every ingested task failed fast
        if creates:
            self.backend.create_many(
                [(t.name, t.deps, t.meta) for t, _ in creates])
            # CREATED/READY stamped here, on the loop thread, so a
            # submitting client thread adds no events (and no span) of
            # its own — the dispatch window stays the measured quantity,
            # exactly as on the batch path where creation precedes run()
            j = self.journal
            for task, ready in creates:
                if j is not None:
                    j.append_create(task.name, task.deps, task.meta)
                if task.deps:
                    emit(CREATED, task=task.name, deps=list(task.deps))
                else:
                    emit(CREATED, task=task.name)
                if ready:
                    emit(READY, task=task.name)
        for note in pending:
            notify(*note)

    def _on_terminal(self, name: str):
        if self.resident:
            with self._cond:
                self._on_terminal_unlocked(name)
        else:
            self._on_terminal_unlocked(name)

    def _on_terminal_unlocked(self, name: str):
        if name not in self._succs:
            return
        for succ in self._succs.pop(name):
            w = self._waiting.get(succ)
            if w is None:
                continue
            w.discard(name)
            if not w:
                del self._waiting[succ]
                self.tracer.emit(READY, task=succ)

    def _note_terminal(self, name: str, ok: bool, res=None,
                       error: Optional[str] = None):
        """Terminal bookkeeping: count a task's FIRST terminal state so
        `drain()` can wait on the submitted universe, and deliver it to
        `on_result` exactly once.  A failure walks the engine-side
        successor graph the way the server poisons its own, so
        transitively-doomed tasks count as terminal too.  Notifications
        fire after the lock is released (the handler may call back into
        the engine)."""
        notify = self.on_result
        pending: list = []
        with self._cond:
            n = self._note_locked(name, ok, res, error,
                                  pending, notify is not None)
            self._inflight -= n
            if self._inflight <= 0:
                self._cond.notify_all()
        for note in pending:
            notify(*note)

    def _note_terminal_many(self, batch: list):
        """Batched `_note_terminal` + successor readying: ONE lock hold
        for a whole completion batch.  The dispatch loop calls this once
        per drained steal batch, so the lock ping-pong with a submitting
        client thread amortizes over `steal_n` tasks instead of hitting
        every task (measurably so: per-future client overhead)."""
        notify = self.on_result
        want = notify is not None
        pending: list = []
        with self._cond:
            n = 0
            for name, ok, res in batch:
                if ok:
                    self._on_terminal_unlocked(name)
                n += self._note_locked(name, ok, res, None, pending, want)
            self._inflight -= n
            if self._inflight <= 0:
                self._cond.notify_all()
        for note in pending:
            notify(*note)

    def _note_locked(self, name: str, ok: bool, res, error,
                     pending: list, want: bool) -> int:
        """Shared terminal-transition body (caller holds `_cond`): returns
        how many tasks reached terminal (1 + poisoned successors), and
        appends `on_result` notifications to `pending` when `want`.  A
        name absent from the task registry is a resurrected server stub
        (a pruned name re-declared as a dependency): it is remembered as
        terminal so it can't loop, but contributes no inflight count and
        no notification — it was never a submitted task."""
        if name in self._terminal:
            return 0
        self._terminal.add(name)
        self._attempts.pop(name, None)      # bounded retry state
        known = name in self.tasks
        if error is None and res is not None:
            error = res.error
        if want and known:
            pending.append((name, ok, res, error))
        j = self.journal
        if j is not None:
            if ok:
                j.append_terminal(name, True)
            elif error == "cancelled" and res is None:
                j.append_cancel(name)
            else:
                j.append_terminal(name, False, error)
        n = 1 if known else 0
        if not ok:
            self._failed.add(name)
            stack = [name]
            while stack:
                for succ in self._succs.pop(stack.pop(), []):
                    self._waiting.pop(succ, None)
                    if succ in self._terminal:
                        continue
                    self._terminal.add(succ)
                    self._failed.add(succ)
                    self._attempts.pop(succ, None)
                    why = f"poisoned by {name}"
                    self.tracer.emit(FAILED, task=succ, error=why)
                    if j is not None:
                        j.append_terminal(succ, False, why)
                    if want:
                        pending.append((succ, False, None, why))
                    n += 1
                    stack.append(succ)
        return n

    # ---------------------------------------------------- resident control
    def start(self, execute: Optional[Callable] = None, *,
              pass_worker: bool = False) -> "Engine":
        """Launch the dispatch loop in a background thread (resident mode
        only).  `execute(name, meta)` as in `run()`; with
        `pass_worker=True` the callback receives `(name, meta, worker)` so
        per-worker behavior (runtime.elastic) needs no engine surgery."""
        if not self.resident:
            raise RuntimeError("start() requires Engine(resident=True); "
                               "use run() for batch mode")
        if self._thread is not None:
            raise RuntimeError("engine already started")
        self._stop = self._abort = False
        self._report = None
        self._loop_error = None
        self._thread = threading.Thread(
            target=self._serve, args=(execute, pass_worker),
            name="engine-resident", daemon=True)
        self._thread.start()
        return self

    def _serve(self, execute, pass_worker):
        try:
            self._report = self.run(execute, pass_worker=pass_worker)
        except BaseException as e:  # noqa: BLE001 — surfaced by shutdown()
            self._loop_error = e
        finally:
            with self._cond:
                self._cond.notify_all()   # unblock drain() on a loop crash
            if self._loop_error is not None \
                    and self.on_loop_error is not None:
                try:
                    self.on_loop_error(self._loop_error)
                except Exception:    # noqa: BLE001 — the loop is already
                    pass             # dead; shutdown() reports the cause

    def drain(self, timeout: Optional[float] = None) -> bool:
        """Block until every submitted task is terminal (True) or the
        timeout expires (False).  Does not stop the loop.  With a
        journal attached, a successful drain syncs it — "drained" then
        also means "durable"."""
        with self._cond:
            ok = self._cond.wait_for(
                lambda: (self._inflight <= 0 and not self._mailbox)
                or self._loop_error is not None,
                timeout)
        if ok and self.journal is not None:
            self.journal.sync()
        return ok

    def shutdown(self, *, drain: bool = True,
                 timeout: Optional[float] = None) -> Optional[EngineReport]:
        """Stop the resident loop and return its EngineReport.  With
        `drain=True` (default) outstanding work finishes first; with
        `drain=False` pending work is abandoned (the server keeps it).
        Idempotent: shutting down a resident engine that was never
        started is a no-op returning None, and a second shutdown() is a
        no-op returning the first call's report — `Client.__exit__` and
        finalizers can call it unconditionally."""
        if not self.resident:
            raise RuntimeError("shutdown() requires Engine(resident=True); "
                               "batch mode returns its report from run()")
        if self._thread is None:
            return self._report
        if drain:
            self.drain(timeout)
        else:
            self._abort = True
        self._stop = True
        self._thread.join(timeout)
        if self._thread.is_alive():      # wedged mid-drain: force exit
            self._abort = True
            self._thread.join(timeout)
            if self._thread.is_alive():
                # execute() is blocked and cannot observe the abort flag;
                # keep the handle so a later shutdown() can retry, and
                # report the bounded stop honestly instead of hanging
                raise RuntimeError(
                    "resident loop did not stop within timeout "
                    "(execute blocked?)")
        self._thread = None
        if self._loop_error is not None:
            raise self._loop_error
        return self._report

    @property
    def started(self) -> bool:
        """True while the resident dispatch loop is running."""
        return self._thread is not None

    def live_workers(self) -> int:
        """Workers currently alive (pool size minus deaths) — the P that
        METG-aware batching should adapt to."""
        return max(self._live, 0)

    def tasks_done_total(self) -> int:
        """Task executions that reached COMPLETED/FAILED on a worker.
        Requeued re-executions count each time: this is the throughput
        counter the windowed tasks/s rate diffs, not the terminal-name
        count (`OverheadReport.n_tasks`)."""
        return sum(st[0] for st in list(self._wstats.values()))

    def dep_table(self) -> dict:
        """Monitoring snapshot of the dependency graph: task name ->
        tuple of dependency names, for every registered task that has
        dependencies.  Read under the GIL (approximate while the loop
        runs, like `worker_stats`); the critical-path analyzer
        (`repro.core.obs.critical_path`) joins it against the trace —
        exported traces carry the same edges on their CREATED events."""
        return {n: t.deps for n, t in list(self.tasks.items()) if t.deps}

    def worker_stats(self) -> dict:
        """Monitoring snapshot: worker -> {done, busy_s, alive}.  Read
        under the GIL from the loop's own tables — approximate while the
        loop runs, never blocking it.  `busy_s` sums real execution time
        (TaskResult t_start..t_end), so diffing two snapshots gives a
        per-worker busy fraction over the window."""
        dead = self._dead_workers
        return {w: {"done": st[0], "busy_s": st[1], "alive": w not in dead}
                for w, st in list(self._wstats.items())}

    def add_worker(self, name: Optional[str] = None) -> str:
        """Grow the live pool (resident mode): the worker joins the steal
        rotation at the top of the next dispatch round."""
        if not self.resident:
            raise RuntimeError("membership changes require "
                               "Engine(resident=True)")
        if name is None:
            name = f"w{self._next_wid}"
        self._next_wid += 1
        with self._cond:
            self._commands.append(("add", name))
            self._epoch += 1
        return name

    def lose_worker(self, name: str):
        """Driver-side failure detection (paper: Exit may be called by the
        user to recover from a node failure): mark the worker dead and
        recycle everything it still holds."""
        if not self.resident:
            raise RuntimeError("membership changes require "
                               "Engine(resident=True)")
        with self._cond:
            self._commands.append(("lose", name))
            self._epoch += 1

    def cancel(self, name: str) -> bool:
        """Withdraw a submitted task that no worker has stolen yet.  True
        means the task will never run: the server poisons it (and its
        transitive successors) under its own lock, so a concurrent Steal
        can never hand it out afterwards.  False means the cancel lost
        the race — the task is already stolen, terminal, or unknown.
        Cancellation counts as a failure terminal state: dependents are
        poisoned, drain() unblocks, and on_result fires with
        error=\"cancelled\"."""
        notify = self.on_result
        pending: list = []
        withdrawn = False
        with self._cond:
            if name not in self.tasks or name in self._terminal:
                return False
            if name in self._unsent:
                # still in the mailbox: withdraw it engine-side before the
                # loop ever ingests it (ingest skips terminal names).  The
                # withdrawn task itself was never counted in _inflight
                # (counting happens at ingest), but poisoned successors in
                # the walk WERE ingested — a dependent that forward-
                # declared this name as a string dep — so n-1 of the walk
                # must be decremented.  Unsent successors are not in
                # _succs and fail fast at their own ingest via _failed.
                self._unsent.discard(name)
                self.tracer.emit(CANCELLED, task=name)
                n = self._note_locked(name, False, None, "cancelled",
                                      pending, notify is not None)
                self._inflight -= (n - 1)
                if self._inflight <= 0:
                    self._cond.notify_all()
                withdrawn = True
        if withdrawn:
            for note in pending:
                notify(*note)
            return True
        if not self.backend.cancel(name):
            return False
        self.tracer.emit(CANCELLED, task=name)
        self._note_terminal(name, False, error="cancelled")
        return True

    def prune_terminal(self, *, backend: bool = True) -> int:
        """Bounded-state hook: drop terminal tasks from the engine-side
        history tables (tasks/_terminal/_failed) and, with `backend=True`,
        from the server's tables too.  Names still referenced as
        dependencies by a not-yet-ingested (mailboxed) submission are
        kept, so auto-pruning (`Client(prune_every=)`) cannot race a
        concurrent submit into resurrecting a pruned dep as a READY
        stub.  Beyond that, the contract matches
        `TaskServer.prune_terminal`: only prune names that no FUTURE
        submit will reference as a dependency (single-use names — the
        futures client and serving frontend satisfy this).  Returns the
        number of entries dropped across both layers."""
        with self._cond:
            keep: set = set(self._pinned)
            for task in self._mailbox:
                keep.update(task.deps)
            if self.transport == "proc":
                # a worker may still Fetch a dependency VALUE for any
                # in-flight dependent — keep those payloads fetchable
                for n, t in self.tasks.items():
                    if t.deps and n not in self._terminal:
                        keep.update(t.deps)
            prunable = [n for n in self._terminal
                        if n not in self._succs and n not in keep]
            for n in prunable:
                self._terminal.discard(n)
                self._failed.discard(n)
                self.tasks.pop(n, None)
            # the backend half runs under the same hold: submit() also
            # takes _cond, so no submission can slip a new dep reference
            # in while the server tables are being scanned with this
            # keep-set (lock order engine._cond -> server.lock is used
            # nowhere in reverse)
            n_backend = (self.backend.prune_terminal(keep=keep)
                         if backend else 0)
        return len(prunable) + n_backend

    def pin(self, name: str):
        """Exempt `name`'s payload from prune_terminal: a terminal task
        whose (remote) value is lifted into a later submission's
        arguments has no dependency edge the prune keep-set would see —
        the worker resolving the new task must still be able to Fetch
        it (the futures client pins lifted RemoteValue results)."""
        with self._cond:
            self._pinned.add(name)

    # ----------------------------------------------------------- recovery
    @classmethod
    def recover(cls, journal_dir, **engine_kw) -> "Engine":
        """Rebuild an engine from a journal directory after a crash.

        Replays checkpoint + WAL into the control-plane state, then:

          * terminal names (completed / failed / cancelled) seed the
            exactly-once accounting — they never re-run, never re-fire
            `on_result`, and dependents treat completed producers as
            satisfied;
          * every created-but-not-terminal task is re-submitted with its
            surviving dependencies, which re-marks leased-but-unfinished
            work from the crashed run as ready (the journal records no
            leases: an assignment that never completed is work to redo);
          * a pending task whose producer failed before the crash is
            poisoned immediately, exactly as the live engine would have.

        The returned engine journals into the SAME directory (appends
        continue where the crashed run stopped), so a recovered session
        is itself recoverable.  Task `fn` closures are not journaled —
        run the recovered engine with an `execute(name, meta)` callback
        (the by-name style of the dwork/pmake adapters), carrying
        whatever the callback needs in each task's `meta`.

        `engine_kw` is forwarded to the constructor (workers, transport,
        shards, resident=..., retry=..., ...).  Works with all three
        backends: recovery happens above the backend, which starts
        empty and receives the re-created universe."""
        state = Journal.replay(journal_dir)
        eng = cls(journal=str(journal_dir), **engine_kw)
        eng._recovered = state
        terminal = state.terminal()
        eng._terminal |= terminal
        eng._failed.update(state.failed)
        eng._failed.update(state.cancelled)
        completed = state.completed
        journal = eng.journal
        for name, deps, meta in state.pending():
            live = tuple(d for d in deps if d not in completed)
            bad = next((d for d in live if d in eng._failed), None)
            if bad is not None:
                eng._terminal.add(name)
                eng._failed.add(name)
                why = f"dependency {bad} failed"
                eng.tracer.emit(FAILED, task=name, error=why)
                journal.append_terminal(name, False, why)
                continue
            eng.submit(name, deps=live, meta=meta)
        return eng

    # -------------------------------------------------------------- exec
    def _execute_registered(self, name: str, meta: dict):
        task = self.tasks.get(name)
        if task is None or task.fn is None:
            return (True, None)
        return (True, task.fn())

    def _run_one(self, exec_fn, name: str, meta: dict,
                 worker: str) -> TaskResult:
        tracer = self.tracer
        tracer.emit4(RUN_START, name, worker)
        t0 = time.perf_counter()
        ok, value, err, crashed = True, None, None, False
        try:
            if self._pass_worker:
                out = exec_fn(name, meta, worker)
            else:
                out = exec_fn(name, meta)
            if isinstance(out, tuple):
                ok, value = bool(out[0]), out[1]
            elif out is None:
                ok = True
            elif isinstance(out, bool):
                ok = out
            else:
                ok, value = True, out
        except WorkerCrash as e:
            ok, err, crashed = False, repr(e), True
        except Exception as e:                        # noqa: BLE001
            ok, err = False, repr(e)
        t1 = time.perf_counter()
        virtual = 0.0
        if self.faults is not None:
            virtual = self.faults.delay_s(name, worker)
            if self.faults.force_fail(name, worker,
                                      self._attempts.get(name, 0)):
                ok, err = False, err or "injected fault"
            tracer.emit(RUN_END, task=name, worker=worker, virtual_s=virtual)
        else:
            tracer.emit4(RUN_END, name, worker)
        return TaskResult(task=name, ok=ok, worker=worker, t_start=t0,
                          t_end=t1, value=value, error=err,
                          virtual_s=virtual, crashed=crashed)

    # --------------------------------------------------------------- run
    def run(self, execute: Optional[Callable] = None, *,
            pass_worker: bool = False) -> EngineReport:
        """Run until every task reaches a terminal state (or all workers
        die / the pool stalls).  `execute(name, meta)` may return bool,
        (ok, value), or None (success); default runs the submitted `fn`.
        In resident mode the loop instead runs until `shutdown()`."""
        if self.transport == "proc":
            return self._run_proc(execute, pass_worker)
        exec_fn = execute or self._execute_registered
        self._pass_worker = pass_worker and execute is not None
        resident = self.resident
        t_wall0 = time.perf_counter()
        alive = [f"w{i}" for i in range(self.workers)]
        n_alive = max(len(alive), 1)
        peak_workers = len(alive)
        dead: set[str] = set()
        self._dead_workers = dead            # monitoring view (GIL reads)
        wstats = self._wstats
        for w in alive:
            wstats.setdefault(w, [0, 0.0])
        steals = {w: 0 for w in alive}
        done_flag = {w: False for w in alive}
        # hot-path state, all maintained incrementally (no per-round scans):
        heap: list = []                # (-priority, seq, item) pending launch
        n_pending = 0
        pending_names: set[str] = set()
        outstanding = {w: 0 for w in alive}   # stolen, not yet finished
        finished = {w: [] for w in alive}     # (name, ok) awaiting piggyback
        running: dict[str, dict] = {}         # thread transport in-flight
        results: dict[str, TaskResult] = {}
        free = self.capacity
        idle_rounds = 0
        stalled = False
        steal_n = self.steal_n
        pending_limit = max(self.workers, 1) * steal_n + self.capacity
        inline = self.transport != "thread"
        pool = (None if inline
                else ThreadPoolExecutor(max_workers=self.capacity))
        # local bindings keep the per-round constant cost down
        emit = self.tracer.emit
        emit4 = self.tracer.emit4
        complete_steal = self.backend.complete_steal
        run_one = self._run_one
        on_terminal = self._on_terminal
        # terminal accounting runs in resident mode (drain bookkeeping)
        # and whenever a result listener OR a journal is attached (the
        # journal records terminal transitions at the same chokepoint);
        # `_terminal` then doubles as the duplicate-steal guard so
        # `keep_results=False` sessions stay exactly-once too
        note_terminal = (self._note_terminal
                         if resident or self.on_result is not None
                         or self.journal is not None else None)
        note_many = self._note_terminal_many
        terminal_seen = self._terminal if note_terminal else ()
        record_results = self.keep_results or not resident
        priority_of = self._priority_of
        capacity = self.capacity
        faults = self.faults
        # fault-free inline runs drain a priority-0 batch straight from
        # the steal response — no heap round-trip, no pending bookkeeping.
        # (With faults the slow path keeps the steal->death->launch window
        # so a dying worker observably holds stolen-but-unstarted tasks.)
        fast_drain = inline and faults is None
        seq = 0
        rounds = 0
        quiet_epoch = -1            # resident idle gate (see IDLE_PROBE_...)
        # launch gate: popping the heap is pointless until something can
        # change the outcome (a slot freed, new steals, a death scrub) —
        # without it a full backlog gets drained/re-pushed every poll
        try_launch = True
        progress = False
        # retry plumbing: a transiently-failed execution is re-enqueued
        # onto the launch heap with a not-before stamp (seeded-jitter
        # backoff) instead of reporting Complete(ok=False) — the worker
        # keeps its scheduler-side assignment, so a retry costs zero
        # protocol round-trips.  backoff_wait marks a round where heap
        # entries were held for their backoff deadline only.
        retry_default = self.retry
        attempts = self._attempts
        backoff_wait = False

        def retry_delay(name: str, res: TaskResult):
            """None = fail for real; else the backoff before re-run."""
            task = self.tasks.get(name)
            pol = (task.retry if task is not None
                   and task.retry is not None else retry_default)
            if pol is None:
                return None
            attempt = attempts.get(name, 0) + 1
            attempts[name] = attempt
            if not pol.should_retry(attempt, res.error):
                return None
            return pol.delay_s(name, attempt)

        def schedule_retry(name: str, meta, w: str, delay: float):
            nonlocal seq, n_pending, try_launch
            self.retries_total += 1
            emit(RETRIED, task=name, worker=w, attempt=attempts[name],
                 delay_s=delay)
            pending_names.add(name)
            seq += 1
            heappush(heap, (
                -priority_of(name, meta), seq,
                {"name": name, "meta": meta, "worker": w,
                 "slots": self._slots_of(name, meta),
                 "t_ready": time.perf_counter() + delay}))
            n_pending += 1
            try_launch = True

        def bury(w: str, *, announce: bool, **extra):
            """Retire a dead worker mid-stream: flush the completions it
            already reported (a result the engine recorded is never lost),
            recycle its assignment (announced Exit; silent deaths rely on
            heartbeat-lease expiry), and scrub its pending launches."""
            nonlocal heap, n_pending, try_launch, progress
            dead.add(w)
            self.worker_deaths += 1
            emit(WORKER_DEAD, worker=w, **extra)
            if finished[w]:
                complete_steal(w, finished[w], 0)
                finished[w] = []
            if announce:
                self.backend.exit_worker(w)
            if heap:
                kept = [e for e in heap if e[2]["worker"] not in dead]
                if len(kept) != len(heap):
                    for e in heap:
                        if e[2]["worker"] in dead:
                            pending_names.discard(e[2]["name"])
                    heap = kept
                    heapify(heap)
                    n_pending = len(heap)
            try_launch = True
            progress = True
            self._live = len(alive) - len(dead)
            if resident:
                self._epoch += 1     # its requeued work is stealable again

        try:
            while True:
                rounds += 1
                progress = False
                backoff_wait = False
                stopping = not resident or self._stop
                # 0) resident: abort / membership commands / live retuning
                if resident:
                    if self._abort:
                        break
                    if self._mailbox:
                        self._ingest_mailbox()
                    if self._commands:
                        with self._cond:
                            cmds = list(self._commands)
                            self._commands.clear()
                        for cmd, w in cmds:
                            if cmd == "add":
                                if w in steals and w not in dead:
                                    continue            # already live
                                if w in dead:
                                    # a recovered node rejoining under its
                                    # old id: revive with a clean slate —
                                    # only copies still in flight from the
                                    # old incarnation stay attributed
                                    dead.discard(w)
                                    done_flag[w] = False
                                    finished[w] = []
                                    outstanding[w] = sum(
                                        1 for r in running.values()
                                        if r["worker"] == w)
                                else:
                                    alive.append(w)
                                    steals[w] = 0
                                    done_flag[w] = False
                                    outstanding[w] = 0
                                    finished[w] = []
                                wstats.setdefault(w, [0, 0.0])
                                self._live = len(alive) - len(dead)
                                peak_workers = max(peak_workers, len(alive))
                            elif cmd == "lose" and w in steals \
                                    and w not in dead:
                                bury(w, announce=True, reason="lose")
                        n_alive = max(len(alive), 1)
                    # steal_n is re-read every round so membership-aware
                    # batching (elastic: pick_batch_size on remesh) applies
                    # without restarting the loop
                    steal_n = max(int(self.steal_n), 1)
                    pending_limit = n_alive * steal_n + capacity
                    epoch0 = self._epoch
                    steal_ok = (stopping or epoch0 != quiet_epoch
                                or rounds % IDLE_PROBE_ROUNDS == 0)
                else:
                    steal_ok = True
                # 1) reap finished thread-pool tasks into per-worker batches
                if running:
                    for name in [n for n, r in running.items()
                                 if r["fut"].done()]:
                        rec = running.pop(name)
                        free += rec["slots"]
                        progress = True
                        try_launch = True
                        w = rec["worker"]
                        if w in dead:
                            continue  # lost completion: requeued via Exit
                        res: TaskResult = rec["fut"].result()
                        if res.crashed:
                            bury(w, announce=True, crash=True)
                            continue
                        outstanding[w] -= 1
                        st = wstats[w]
                        if not res.ok:
                            delay = retry_delay(name, res)
                            if delay is not None:
                                # transient: the worker keeps its
                                # assignment; re-enqueue after backoff
                                st[1] += res.t_end - res.t_start
                                outstanding[w] += 1
                                schedule_retry(name, rec["meta"], w, delay)
                                continue
                        st[0] += 1
                        st[1] += res.t_end - res.t_start
                        if not res.ok:
                            self.exec_failed += 1
                        if record_results:
                            results[name] = res
                        if note_terminal:
                            note_terminal(name, res.ok, res)
                        finished[w].append((name, res.ok))
                        emit(COMPLETED if res.ok else FAILED, task=name,
                             worker=w, error=res.error)
                        if res.ok:  # failed tasks never ready their succs
                            on_terminal(name)
                # 2) complete+steal — one RPC flushes a worker's finished
                # batch AND steals its next one (Fig. 2 batch-then-drain);
                # a worker steals only while it holds fewer than steal_n
                # outstanding tasks; rotation keeps the order fair
                if n_alive == 1:
                    rotation = alive
                else:
                    start = rounds % n_alive
                    rotation = alive[start:] + alive[:start]
                for w in rotation:
                    if w in dead:
                        continue
                    batch = finished[w]
                    want_steal = (steal_ok
                                  and not done_flag[w]
                                  and outstanding[w] < steal_n
                                  and n_pending < pending_limit)
                    if not batch and not want_steal:
                        continue
                    got = complete_steal(w, batch,
                                         steal_n if want_steal else 0)
                    if batch:
                        finished[w] = []
                        progress = True
                    if not want_steal:
                        continue
                    if got == DONE:
                        # resident pre-stop: the server saying "all done"
                        # just means "idle right now" — more work may be
                        # submitted, so keep the worker in the rotation
                        if stopping:
                            done_flag[w] = True
                    elif got != EMPTY:
                        steals[w] += len(got)
                        accepted = []
                        for name, meta in got:
                            rec = running.get(name)
                            if (name in pending_names
                                    or (rec is not None
                                        and rec["worker"] not in dead)):
                                # duplicate steal after a lease-expiry
                                # requeue while a LIVE copy is still held
                                # (pending or in flight): the copy's
                                # Complete clears every stale assignment
                                # server-side, so just drop it.  A copy
                                # held only by a DEAD worker is accepted —
                                # its completion was discarded, so this
                                # re-steal is the only way forward.
                                continue
                            prior = results.get(name)
                            if prior is not None or name in terminal_seen:
                                # already terminal engine-side: a stale
                                # requeue duplicate with no live copy, or
                                # a pruned name a later dep re-declared as
                                # a server stub — report its terminal
                                # state instead of dropping it, so the
                                # server's join accounting (and any
                                # dependents) can move.  Never re-execute.
                                ok_prior = (prior.ok if prior is not None
                                            else name not in self._failed)
                                finished[w].append((name, ok_prior))
                                progress = True
                                continue
                            accepted.append((name, meta))
                        if not accepted:
                            continue
                        progress = True
                        # drain a batch inline ONLY when nothing in it (or
                        # already pending) carries a priority — otherwise a
                        # prio-0 item would run before a higher-priority
                        # one later in the same batch/heap
                        drain = fast_drain and not heap and all(
                            priority_of(name, meta) == 0.0
                            for name, meta in accepted)
                        if drain:
                            # with terminal accounting on, bookkeeping is
                            # batched: ONE lock hold (note_many) for the
                            # whole drained batch, amortizing the
                            # client-thread lock ping-pong over steal_n
                            notes = [] if note_terminal is not None \
                                else None
                            st = wstats[w]
                            for name, meta in accepted:
                                # steal order == seq order: complete rides
                                # on this worker's next CompleteSteal
                                emit4(STOLEN, name, w)
                                res = run_one(exec_fn, name, meta, w)
                                if res.crashed:
                                    # the rest of the batch is still
                                    # assigned server-side: Exit recycles
                                    # it with the in-flight task
                                    bury(w, announce=True, crash=True)
                                    break
                                if not res.ok:
                                    delay = retry_delay(name, res)
                                    if delay is not None:
                                        # the fast path never counted
                                        # this steal in outstanding: the
                                        # heap re-enqueue must
                                        st[1] += res.t_end - res.t_start
                                        outstanding[w] += 1
                                        schedule_retry(name, meta, w,
                                                       delay)
                                        continue
                                st[0] += 1
                                st[1] += res.t_end - res.t_start
                                if record_results:
                                    results[name] = res
                                finished[w].append((name, res.ok))
                                if notes is not None:
                                    notes.append((name, res.ok, res))
                                if res.ok:
                                    emit4(COMPLETED, name, w)
                                    if notes is None:
                                        on_terminal(name)
                                else:
                                    self.exec_failed += 1
                                    emit(FAILED, task=name, worker=w,
                                         error=res.error)
                            if notes:
                                note_many(notes)
                            continue
                        for name, meta in accepted:
                            emit4(STOLEN, name, w)
                            pending_names.add(name)
                            outstanding[w] += 1
                            seq += 1
                            heappush(heap, (
                                -priority_of(name, meta), seq,
                                {"name": name, "meta": meta, "worker": w,
                                 "slots": self._slots_of(name, meta)}))
                            n_pending += 1
                        try_launch = True
                # resident idle gate: a fully quiet round (no completions,
                # no steals served) arms the backoff until the epoch moves
                if resident and not stopping and not progress and steal_ok:
                    quiet_epoch = epoch0
                # 3) fault injection: worker deaths (between steal & launch,
                #    so a dying worker holds stolen-but-unstarted tasks)
                if faults is not None:
                    for w in alive:
                        if w in dead:
                            continue
                        if faults.should_die(w, steals[w]):
                            silent = faults.dies_silently(w)
                            # announced death: Exit recycles assignment;
                            # silent death: heartbeat-lease expiry recycles
                            bury(w, announce=not silent, silent=silent)
                # 4) launch: greedy highest-priority-first into free slots
                if heap and try_launch:
                    try_launch = False
                    held = []
                    while heap:
                        entry = heappop(heap)
                        it = entry[2]
                        name = it["name"]
                        if it["worker"] in dead:      # late scrub
                            pending_names.discard(name)
                            n_pending -= 1
                            continue
                        t_ready = it.get("t_ready")
                        if t_ready is not None \
                                and t_ready > time.perf_counter():
                            held.append(entry)    # retry backoff pending
                            backoff_wait = True
                            continue
                        if name in running:
                            # a dead worker's copy is still in flight;
                            # wait for it to drain before re-launching
                            held.append(entry)
                            continue
                        slots = min(it["slots"], capacity)
                        if slots > free:
                            held.append(entry)
                            continue
                        pending_names.discard(name)
                        n_pending -= 1
                        w = it["worker"]
                        if inline:
                            res = self._run_one(exec_fn, name, it["meta"], w)
                            if res.crashed:
                                # bury scrubs this worker's remaining heap
                                # entries; `held` is re-checked next pass
                                bury(w, announce=True, crash=True)
                                progress = True
                                continue
                            if not res.ok:
                                delay = retry_delay(name, res)
                                if delay is not None:
                                    # still held by w (outstanding not
                                    # yet decremented): re-enqueue only
                                    wstats[w][1] += res.t_end - res.t_start
                                    schedule_retry(name, it["meta"], w,
                                                   delay)
                                    progress = True
                                    continue
                            outstanding[w] -= 1
                            st = wstats[w]
                            st[0] += 1
                            st[1] += res.t_end - res.t_start
                            if not res.ok:
                                self.exec_failed += 1
                            if record_results:
                                results[name] = res
                            if note_terminal:
                                note_terminal(name, res.ok, res)
                            finished[w].append((name, res.ok))
                            emit(COMPLETED if res.ok else FAILED, task=name,
                                 worker=w, error=res.error)
                            if res.ok:
                                self._on_terminal(name)
                        else:
                            free -= slots
                            fut = pool.submit(self._run_one, exec_fn, name,
                                              it["meta"], w)
                            running[name] = {"worker": w, "fut": fut,
                                             "slots": slots,
                                             "meta": it["meta"]}
                        progress = True
                    for entry in held:
                        heappush(heap, entry)
                    if backoff_wait:
                        # a held backoff entry needs another launch pass
                        # once its deadline arrives, whatever else the
                        # round did
                        try_launch = True
                # 5) termination (batch mode, or resident after shutdown())
                if stopping and not running and not n_pending:
                    live = [w for w in alive if w not in dead]
                    if not live:
                        # every worker died: unless one of them saw the
                        # server's DONE first, work remains unserved —
                        # that is a stall, not a clean finish.  A resident
                        # pool counts its submitted universe instead (it
                        # may legitimately stop with zero workers).
                        if resident:
                            stalled = (self._inflight > 0
                                       or bool(self._mailbox))
                        else:
                            stalled = not any(done_flag.values())
                        break
                    if all(done_flag[w] for w in live) \
                            and not any(finished[w] for w in live):
                        break
                if progress:
                    idle_rounds = 0
                elif backoff_wait:
                    # retries waiting out their backoff are forward
                    # progress in waiting, not a stall
                    idle_rounds = 0
                    try_launch = True
                    time.sleep(self.poll)
                elif not running:
                    idle_rounds += 1
                    if idle_rounds >= self.max_idle_rounds and stopping:
                        stalled = True   # unresolvable (cycle / all leased)
                        break
                    time.sleep(self.poll)
                else:
                    time.sleep(self.poll)
        finally:
            if pool is not None:
                pool.shutdown(wait=True)
            journal = self.journal
            if journal is not None:
                # a clean exit is fully durable; an owned journal (built
                # from a path) is closed with the loop
                journal.sync()
                if self._owns_journal:
                    journal.close()
            if self._owns_backend:
                # in the finally so a mid-run RPC failure can't leak the
                # tree's sockets/threads; stats()/errors() below only
                # read in-process state and stay valid after close
                self.backend.close()
        # effective parallelism: the inline transports run tasks serially,
        # and the thread pool is sized by `capacity`, so overhead
        # accounting must not multiply wall time by phantom workers
        eff_workers = 1 if inline else min(peak_workers, self.capacity)
        return EngineReport(
            results=results, trace=self.tracer, workers=max(eff_workers, 1),
            pool_workers=max(peak_workers, 1),
            wall_s=time.perf_counter() - t_wall0,
            errors=self.backend.errors(), stalled=stalled,
            backend_stats=self.backend.stats())

    # ------------------------------------------------------- proc transport
    @property
    def comm_address(self) -> Optional[str]:
        """Where `python -m repro.core.engine.comm.worker --connect` dials
        (`tcp://host:port`) — None for in-process transports."""
        return getattr(self.backend, "address", None)

    def worker_pids(self) -> dict:
        """worker -> OS pid for every handshaken worker process
        (transport="proc"; empty for in-process transports)."""
        fn = getattr(self.backend, "worker_pids", None)
        return fn() if fn is not None else {}

    def wait_workers(self, n: Optional[int] = None,
                     timeout: float = 30.0) -> bool:
        """Block until `n` workers (default: the configured pool size)
        have completed their Hello handshake.  True once reached; in-
        process transports return True immediately (workers are the
        dispatch loop itself)."""
        fn = getattr(self.backend, "connected", None)
        if fn is None:
            return True
        want = self.workers if n is None else int(n)
        deadline = time.monotonic() + timeout
        while len(fn()) < want:
            if time.monotonic() >= deadline:
                return False
            time.sleep(0.01)
        return True

    def _run_proc(self, execute, pass_worker: bool) -> EngineReport:
        """Dispatch loop for `transport="proc"` — supervision, not
        execution.  Tasks run inside worker processes that speak the
        frame protocol straight to the backend's front door on its
        handler threads; this loop ingests submissions, drains the
        completion records the door queued, reconstructs their trace
        spans, and supervises liveness (membership commands, remote
        joins, crash/stale detection with zero-loss requeue)."""
        backend = self.backend
        resident = self.resident
        tracer = self.tracer
        emit = tracer.emit
        t_wall0 = time.perf_counter()
        # serialize the execute callback BEFORE spawning anything: an
        # unpicklable callback must fail fast, not hang a handshake
        backend.prepare(execute=execute, pass_worker=pass_worker,
                        steal_n=self.steal_n, resident=resident)
        alive = [f"w{i}" for i in range(self.workers)]
        dead: set[str] = set()
        self._dead_workers = dead
        wstats = self._wstats
        for w in alive:
            wstats.setdefault(w, [0, 0.0])
        self._live = len(alive)
        peak_workers = max(len(alive), 1)
        backend.start_pool(alive)
        results: dict[str, TaskResult] = {}
        record_results = self.keep_results or not resident
        note_terminal = (self._note_terminal
                         if resident or self.on_result is not None
                         or self.journal is not None else None)
        note_many = self._note_terminal_many
        terminal_seen = self._terminal if note_terminal else ()
        # liveness grace: a worker busy on a long task still heartbeats
        # (daemon thread), so staleness only means the PROCESS is gone or
        # wedged; locally-spawned processes are additionally poll()ed
        # and surface within one round of dying
        grace = max(3.0 * self.heartbeat_s, 1.0)
        stolen_at = backend.door.stolen_at
        stalled = False
        idle_rounds = 0
        # retry plumbing, proc flavor: the front door WITHHOLDS failures
        # this predicate approves (the task stays leased), queueing them
        # for drain_failed below — the policy decision runs here but the
        # completion-suppression must happen at the wire, before the
        # scheduler learns of the failure and poisons dependents
        retry_default = self.retry
        attempts = self._attempts
        retry_pending: list = []        # (t_ready, worker, task)

        def retry_policy_of(name: str):
            task = self.tasks.get(name)
            return (task.retry if task is not None
                    and task.retry is not None else retry_default)

        def retry_check(name: str, err) -> bool:
            # runs on door handler threads: GIL-grade dict reads only
            pol = retry_policy_of(name)
            return (pol is not None
                    and pol.should_retry(attempts.get(name, 0) + 1, err))

        backend.retry_check = retry_check

        def spawn_recompute(missing: str):
            """Ensure a recompute of a lost value is in flight: reuse the
            pending store-as alias when it has not itself terminated, else
            create a fresh one from the task's packed call.  -> the alias
            name, or None when recompute is impossible (no packed call) or
            the attempt budget is spent — callers fail/raise then."""
            with self._xfer_lock:
                alias = self._xfer_pending.get(missing)
                if alias is not None and alias not in terminal_seen \
                        and alias not in results:
                    return alias
                k = self._xfer_attempts.get(missing, 0) + 1
                task_m = self.tasks.get(missing)
                call = (task_m.meta.get("__call__")
                        if task_m is not None else None)
                if call is None or k > 3:
                    self._xfer_attempts[missing] = k   # mark exhausted:
                    return None                        # waiters stop too
                self._xfer_attempts[missing] = k
                alias = f"{missing}~r{k}"
                self._xfer_pending[missing] = alias
            backend.create(alias, deps=(), meta={
                "__call__": call, "__store_as__": missing})
            self.xfer_lost_total += 1
            return alias

        self._loop_live = True
        try:
            while True:
                progress = False
                stopping = not resident or self._stop
                if resident:
                    if self._abort:
                        break
                    if self._mailbox:
                        self._ingest_mailbox()
                        progress = True
                    if self._commands:
                        with self._cond:
                            cmds = list(self._commands)
                            self._commands.clear()
                        for cmd, w in cmds:
                            if cmd == "add":
                                if w in wstats and w not in dead:
                                    continue          # already live
                                dead.discard(w)
                                backend.door.exited.discard(w)
                                if w not in alive:
                                    alive.append(w)
                                wstats.setdefault(w, [0, 0.0])
                                backend.spawn(w)
                                self._live = len(alive) - len(dead)
                                peak_workers = max(peak_workers,
                                                   self._live)
                            elif cmd == "lose" and w in wstats \
                                    and w not in dead:
                                dead.add(w)
                                self.worker_deaths += 1
                                emit(WORKER_DEAD, worker=w, reason="lose")
                                backend.kill_worker(w)
                                backend.exit_worker(w)
                                self._live = len(alive) - len(dead)
                                progress = True
                                door = backend.door
                                for missing, loc in \
                                        list(door.locations.items()):
                                    if loc[0] != w \
                                            or missing in door.values:
                                        continue
                                    door.locations.pop(missing, None)
                                    if missing not in self.tasks:
                                        continue
                                    if spawn_recompute(missing) is not None:
                                        emit(REQUEUED, task=missing, n=1,
                                             via="xfer_lost")
                # remote joins: a CLI worker's Hello is add_worker-on-
                # connect (multi-host launch), and locally-spawned
                # workers land here too (their handshake confirms them)
                for w in backend.drain_joined():
                    if w in wstats and w not in dead:
                        continue
                    if w in dead:
                        dead.discard(w)
                    if w not in alive:
                        alive.append(w)
                    wstats.setdefault(w, [0, 0.0])
                    self._live = len(alive) - len(dead)
                    peak_workers = max(peak_workers, self._live)
                    progress = True
                # completion records queued by the front door
                recs = backend.drain_records()
                if recs:
                    progress = True
                    notes = [] if note_terminal is not None else None
                    for w, name, ok, err, dur, payload, nbytes, xfers \
                            in recs:
                        if xfers:
                            # dependency-value transfers this execution
                            # performed (peer fetches and hub fallbacks):
                            # every one is attributed, no sampling
                            for path, n, dt in xfers:
                                self._record_xfer(name, w, path, n, dt)
                        if name in terminal_seen or name in results:
                            # duplicate after a requeue: first one won
                            stolen_at.pop(name, None)
                            continue
                        value = None
                        if ok and payload is not None:
                            try:
                                value = loads(payload)
                            except Exception as e:  # noqa: BLE001
                                ok = False
                                err = ("result deserialization failed: "
                                       f"{e!r}")
                        elif ok and nbytes:
                            # the payload stayed in the producing worker's
                            # store: hand out a lazy handle — materialized
                            # hub-first/peer-second only when read
                            value = RemoteValue(name, nbytes,
                                                self._proc_fetch_value)
                        # reconstruct the run span from the worker's
                        # reported duration, clamped to the STOLEN stamp
                        # so report pairing never sees negative dispatch
                        t1 = tracer.clock()
                        t0 = t1 - dur
                        t_stolen = stolen_at.pop(name, None)
                        if t_stolen is not None and t0 < t_stolen:
                            t0 = t_stolen
                        tracer.emit_at(t0, RUN_START, task=name, worker=w)
                        tracer.emit_at(t1, RUN_END, task=name, worker=w)
                        st = wstats.setdefault(w, [0, 0.0])
                        st[0] += 1
                        st[1] += dur
                        if not ok:
                            self.exec_failed += 1
                        res = TaskResult(task=name, ok=ok, worker=w,
                                         t_start=t0, t_end=t1, value=value,
                                         error=err)
                        if record_results:
                            results[name] = res
                        emit(COMPLETED if ok else FAILED, task=name,
                             worker=w, error=err)
                        if notes is not None:
                            notes.append((name, ok, res))
                        elif ok:
                            self._on_terminal(name)
                    if notes:
                        note_many(notes)
                # lease requeues observed at the wire (an expired lease
                # reaped by another worker's steal)
                n_rq = backend.drain_requeued()
                if n_rq:
                    emit(REQUEUED, n=n_rq, via="lease")
                    if self.journal is not None:
                        self.journal.append_requeue(n_rq, "lease")
                    progress = True
                # completions the door WITHHELD because a dependency value
                # is unrecoverable (its producer was killed before the
                # value replicated): recompute the missing value under a
                # store-as alias, then Transfer-requeue the dependent —
                # the zero-loss contract for the peer-to-peer data plane
                for w, name, missing in backend.drain_lost():
                    progress = True
                    if name in terminal_seen or name in results:
                        # the dependent already completed elsewhere (a
                        # requeue duplicate): just clear the stale lease
                        backend.complete(w, name,
                                         ok=name not in self._failed)
                        continue
                    if missing in backend.door.values:
                        # the value resurfaced (a spill/exit-flush landed
                        # after the worker's fetch failed): plain requeue
                        backend.transfer(w, name, [])
                        continue
                    alias = spawn_recompute(missing)
                    if alias is None:
                        why = (f"dependency value {missing!r} lost "
                               "(producer died before replication); "
                               "recompute exhausted or no packed call")
                        backend.complete(w, name, ok=False)
                        self.exec_failed += 1
                        stolen_at.pop(name, None)
                        emit(FAILED, task=name, worker=w, error=why)
                        res = TaskResult(task=name, ok=False, worker=w,
                                         error=why)
                        if record_results:
                            results[name] = res
                        if note_terminal is not None:
                            note_terminal(name, False, res, why)
                        continue
                    emit(REQUEUED, task=name, n=1, via="xfer_lost")
                    backend.transfer(w, name, [alias])
                # transiently-failed completions the door withheld on
                # retry_check's word: charge the attempt and queue the
                # Transfer-requeue behind the policy's backoff
                for w, name, err in backend.drain_failed():
                    progress = True
                    if name in terminal_seen or name in results:
                        backend.complete(w, name,
                                         ok=name not in self._failed)
                        continue
                    pol = retry_policy_of(name)
                    attempt = attempts.get(name, 0) + 1
                    if pol is None or not pol.should_retry(attempt, err):
                        # the budget ran out between the wire check and
                        # this drain: fail for real
                        backend.complete(w, name, ok=False)
                        self.exec_failed += 1
                        stolen_at.pop(name, None)
                        emit(FAILED, task=name, worker=w, error=err)
                        res = TaskResult(task=name, ok=False, worker=w,
                                         error=err)
                        if record_results:
                            results[name] = res
                        if note_terminal is not None:
                            note_terminal(name, False, res, err)
                        continue
                    attempts[name] = attempt
                    delay = pol.delay_s(name, attempt)
                    self.retries_total += 1
                    emit(RETRIED, task=name, worker=w, attempt=attempt,
                         delay_s=delay)
                    retry_pending.append(
                        (time.perf_counter() + delay, w, name))
                if retry_pending:
                    now_r = time.perf_counter()
                    due = [e for e in retry_pending if e[0] <= now_r]
                    if due:
                        retry_pending = [e for e in retry_pending
                                         if e[0] > now_r]
                        for _t, w, name in due:
                            if w in dead:
                                # exit_worker already requeued the lease
                                continue
                            backend.transfer(w, name, [])
                        progress = True
                # engine-side readers (RemoteValue.get in a client
                # thread) asking for a lost value to be recomputed: all
                # backend.create calls stay on this thread
                if self._xfer_wanted:
                    with self._xfer_lock:
                        wanted = list(self._xfer_wanted)
                        self._xfer_wanted.clear()
                    door = backend.door
                    for missing in wanted:
                        if missing not in door.values \
                                and spawn_recompute(missing) is not None:
                            emit(REQUEUED, task=missing, n=1,
                                 via="xfer_lost")
                    progress = True
                # liveness: a SIGKILLed process surfaces as a crash
                # (WORKER_DEAD) and its in-flight work requeues via Exit
                for w, reason in backend.check_dead(grace):
                    if w in dead or w not in wstats:
                        continue
                    dead.add(w)
                    self.worker_deaths += 1
                    emit(WORKER_DEAD, worker=w, crash=True, reason=reason)
                    backend.exit_worker(w)
                    self._live = len(alive) - len(dead)
                    progress = True
                    # eager zero-loss: values whose ONLY copy lived in
                    # the dead worker's store are recomputed NOW, not
                    # when (if ever) a dependent trips over the hole —
                    # client-facing RemoteValues have no dependent task
                    door = backend.door
                    for missing, loc in list(door.locations.items()):
                        if loc[0] != w or missing in door.values \
                                or missing not in self.tasks:
                            continue   # alive elsewhere, replicated, or
                        if spawn_recompute(missing) is not None:  # alias
                            emit(REQUEUED, task=missing, n=1,
                                 via="xfer_lost")
                # termination
                if stopping and not backend.has_records():
                    if resident:
                        with self._cond:
                            if self._inflight <= 0 and not self._mailbox:
                                break
                    elif backend.all_done():
                        break
                    elif len(dead) >= len(alive):
                        stalled = True     # every worker died mid-batch
                        break
                if progress:
                    idle_rounds = 0
                else:
                    idle_rounds += 1
                    if idle_rounds >= self.max_idle_rounds and stopping \
                            and not resident:
                        # workers alive but nothing moving: only a true
                        # deadlock (nothing ready, nothing leased) is a
                        # stall — long-running tasks are just busy
                        st = backend.stats()
                        if not st.get("ready", 0) \
                                and not st.get("assigned", 0) \
                                and not backend.all_done():
                            stalled = True
                            break
                        idle_rounds = 0
                    time.sleep(self.poll)
        finally:
            self._loop_live = False
            backend.stop_pool()
            # the workers' exit flush has replicated every owned value to
            # the hub by now: materialize outstanding RemoteValue handles
            # while the door still exists (the handles are shared with
            # client futures, so get() caches for them too)
            for res in results.values():
                v = res.value
                if isinstance(v, RemoteValue):
                    try:
                        res.value = v.get()
                    except Exception:  # noqa: BLE001 — unrecoverable value
                        pass           # keep the handle; reads raise
            self._close_xfer_conns()
            journal = self.journal
            if journal is not None:
                journal.sync()
                if self._owns_journal:
                    journal.close()
            if self._owns_backend:
                self.backend.close()
        live_peak = max(peak_workers, 1)
        return EngineReport(
            results=results, trace=self.tracer, workers=live_peak,
            pool_workers=live_peak,
            wall_s=time.perf_counter() - t_wall0,
            errors=self.backend.errors(), stalled=stalled,
            backend_stats=self.backend.stats())

    # ----------------------------------------------- data plane (helpers)
    def _record_xfer(self, task: str, worker: Optional[str], path: str,
                     nbytes: int, dt: float):
        """Attribute one dependency-value transfer: an `xfer` trace event
        (never sampled — fetches are rare next to rpcs), the per-path
        running totals, and the obs metrics sink when wired."""
        self.tracer.emit(XFER, task=task, worker=worker, path=path,
                         n=int(nbytes), dt=float(dt))
        with self._xfer_lock:
            tot = self.xfer_totals.setdefault(path, [0, 0, 0.0])
            tot[0] += 1
            tot[1] += int(nbytes)
            tot[2] += float(dt)
        m = self.xfer_metrics
        if m is not None:
            m.observe(path, int(nbytes), float(dt))

    def _fetch_value_once(self, name: str):
        """One fetch attempt: the hub's value store first (a spill or
        exit-flush may have landed), then a direct dial of the producing
        worker's data listener.  -> (payload, path) or (None, None)."""
        door = self.backend.door
        payload = door.values.get(name)
        path = "hub"
        if payload is None:
            loc = door.locations.get(name)
            if loc is not None and loc[1]:
                addr = loc[1]
                resp = None
                try:
                    comm = self._xfer_conns.get(addr)
                    if comm is None:
                        comm = comm_core.connect(addr)
                        self._xfer_conns[addr] = comm
                    resp = comm.request(Fetch(task=name))
                except Exception:  # noqa: BLE001 — producer gone mid-dial
                    stale = self._xfer_conns.pop(addr, None)
                    if stale is not None:
                        try:
                            stale.close()
                        except Exception:  # noqa: BLE001
                            pass
                if isinstance(resp, ValueMsg):
                    payload = resp.payload
                    path = "peer"
            if payload is None:
                payload = door.values.get(name)  # a spill raced us in
        return (payload, path) if payload is not None else (None, None)

    def _proc_fetch_value(self, name: str):
        """Engine-side RemoteValue materializer, called from client
        threads (`Future.result()`, `gather`).  Cache-miss recovery: when
        the value is gone AND the dispatch loop is live AND the task has a
        packed call with attempt budget left, ask the loop to recompute it
        (`_xfer_wanted` — all backend.create calls stay on the dispatch
        thread) and poll until the store-as lands the value back on the
        hub.  Raises KeyError only when genuinely unrecoverable."""
        t0 = time.perf_counter()
        deadline = t0 + 30.0
        next_ask = t0
        while True:
            payload, path = self._fetch_value_once(name)
            if payload is not None:
                self._record_xfer(name, None, path, len(payload),
                                  time.perf_counter() - t0)
                return loads(payload)
            now = time.perf_counter()
            task = self.tasks.get(name)
            recomputable = (
                self._loop_live and now < deadline
                and task is not None
                and task.meta.get("__call__") is not None
                and self._xfer_attempts.get(name, 0) <= 3)
            if not recomputable:
                raise KeyError(
                    f"value for {name!r} is unrecoverable: not on the hub "
                    "and its producing worker cannot serve it")
            if now >= next_ask:   # re-ask ~1/s: idempotent while an alias
                with self._xfer_lock:          # is live, rolls to the next
                    self._xfer_wanted.add(name)  # attempt once one fails
                next_ask = now + 1.0
            time.sleep(0.02)

    def _close_xfer_conns(self):
        for comm in self._xfer_conns.values():
            try:
                comm.close()
            except Exception:  # noqa: BLE001
                pass
        self._xfer_conns.clear()

    # ------------------------------------------------------------ helpers
    def _priority_of(self, name: str, meta: dict) -> float:
        task = self.tasks.get(name)
        if task is not None:
            return task.priority
        return float(meta.get("priority", 0.0)) if meta else 0.0

    def _slots_of(self, name: str, meta: dict) -> int:
        task = self.tasks.get(name)
        if task is not None:
            return task.slots
        return int(meta.get("slots", 1)) if meta else 1
