"""The unified worker pool: one execution substrate for all three schedulers.

A single dispatch loop drives virtual workers against a scheduler backend
(`ServerBackend` / `ShardedBackend`), generalizing the paper's three
execution loops:

  * dwork  (§2.2) — workers Steal-n batches and Complete tasks; the loop
    IS the paper's Fig. 2 CLIENT-LOOP, with per-worker fault injection.
  * pmake  (§2.1) — tasks carry `slots` (nodes) and `priority` (EFT);
    the launch step is pmake's "greedy highest-priority-first onto free
    nodes", with `capacity` total slots.
  * mpi-list (§2.3) — each bulk step submits one task per rank; per-rank
    times (plus injected straggler jitter) feed the Gumbel sync-gap model.

Transports:
  * "inproc" — tasks run inline in the dispatch loop; fully deterministic
    (round-robin steal order, no threads, injectable clock) — the default
    for tests, fault injection, and pure-overhead measurement.
  * "thread" — a slot-bounded thread pool; real concurrency for workloads
    that block (pmake's popen'd scripts).

Every lifecycle transition is emitted to the `TraceRecorder`, from which
`tracing.OverheadReport` computes empirical per-task overhead and METG.
"""
from __future__ import annotations

import time
from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass, field
from typing import Callable, Optional

from repro.core.engine.backends import (DONE, EMPTY, ServerBackend,
                                        ShardedBackend)
from repro.core.engine.faults import FaultPlan
from repro.core.engine.model import (COMPLETED, CREATED, FAILED, READY,
                                     RUN_END, RUN_START, STOLEN, WORKER_DEAD,
                                     EngineTask, TaskResult, next_seq)
from repro.core.engine.tracing import OverheadReport, TraceRecorder


class _SyncFuture:
    """Immediately-done future: the inproc transport's result holder."""

    def __init__(self, value):
        self._value = value

    def done(self) -> bool:
        return True

    def result(self):
        return self._value


@dataclass
class EngineReport:
    results: dict                      # task -> TaskResult (last execution)
    trace: TraceRecorder
    workers: int
    wall_s: float
    errors: set = field(default_factory=set)
    stalled: bool = False
    backend_stats: dict = field(default_factory=dict)

    @property
    def completed(self) -> set:
        return {n for n, r in self.results.items() if r.ok}

    def overhead(self) -> OverheadReport:
        return self.trace.report(workers=self.workers)


class Engine:
    def __init__(self, *, workers: int = 1, capacity: Optional[int] = None,
                 transport: str = "inproc", steal_n: int = 1, shards: int = 1,
                 backend=None, tracer: Optional[TraceRecorder] = None,
                 faults: Optional[FaultPlan] = None, clock=None,
                 lease_timeout: Optional[float] = None, poll: float = 0.001,
                 max_idle_rounds: Optional[int] = None):
        if transport not in ("inproc", "thread"):
            raise ValueError(f"unknown transport {transport!r}")
        self.workers = max(int(workers), 0)
        self.capacity = capacity if capacity is not None else max(workers, 1)
        self.transport = transport
        self.steal_n = max(int(steal_n), 1)
        self.faults = faults
        self.poll = poll
        self.lease_timeout = lease_timeout
        self.tracer = tracer or TraceRecorder(clock=clock)
        if backend is None:
            if shards > 1:
                backend = ShardedBackend(shards=shards,
                                         lease_timeout=lease_timeout,
                                         clock=clock, tracer=self.tracer)
            else:
                backend = ServerBackend(lease_timeout=lease_timeout,
                                        clock=clock, tracer=self.tracer)
        elif getattr(backend, "tracer", None) is None:
            backend.tracer = self.tracer
        self.backend = backend
        # long enough for a heartbeat lease to expire while idling
        if max_idle_rounds is None:
            max_idle_rounds = 500
            if lease_timeout:
                max_idle_rounds = max(500, int(2 * lease_timeout / poll))
        self.max_idle_rounds = max_idle_rounds
        # engine-local task registry (fn/priority/slots + ready tracking)
        self.tasks: dict[str, EngineTask] = {}
        self._waiting: dict[str, set] = {}
        self._succs: dict[str, list] = {}

    # ------------------------------------------------------------- submit
    def submit(self, name: str, fn: Optional[Callable] = None, *,
               deps=(), meta: Optional[dict] = None, priority: float = 0.0,
               slots: int = 1) -> EngineTask:
        """Register a task.  Submit producers before dependents: the task
        server forward-declares an unknown dep as a READY stub and treats
        a later Create of the same name as a no-op (dwork §2.2 semantics),
        so a dependent submitted first would run before its producer."""
        task = EngineTask(name=name, fn=fn, deps=tuple(deps),
                          meta=dict(meta or {}), slots=max(int(slots), 1),
                          priority=priority)
        self.tasks[name] = task
        self.backend.create(name, deps=task.deps, meta=task.meta)
        self.tracer.emit(CREATED, task=name)
        if task.deps:
            self._waiting[name] = set(task.deps)
            for d in task.deps:
                self._succs.setdefault(d, []).append(name)
        else:
            self.tracer.emit(READY, task=name)
        return task

    def _on_terminal(self, name: str):
        for succ in self._succs.pop(name, []):
            w = self._waiting.get(succ)
            if w is None:
                continue
            w.discard(name)
            if not w:
                del self._waiting[succ]
                self.tracer.emit(READY, task=succ)

    # -------------------------------------------------------------- exec
    def _execute_registered(self, name: str, meta: dict):
        task = self.tasks.get(name)
        if task is None or task.fn is None:
            return (True, None)
        return (True, task.fn())

    def _run_one(self, exec_fn, name: str, meta: dict,
                 worker: str) -> TaskResult:
        self.tracer.emit(RUN_START, task=name, worker=worker)
        t0 = time.perf_counter()
        ok, value, err = True, None, None
        try:
            out = exec_fn(name, meta)
            if isinstance(out, tuple):
                ok, value = bool(out[0]), out[1]
            elif out is None:
                ok = True
            elif isinstance(out, bool):
                ok = out
            else:
                ok, value = True, out
        except Exception as e:                        # noqa: BLE001
            ok, err = False, repr(e)
        t1 = time.perf_counter()
        virtual = 0.0
        if self.faults is not None:
            virtual = self.faults.delay_s(name, worker)
            if self.faults.force_fail(name, worker):
                ok, err = False, err or "injected fault"
        self.tracer.emit(RUN_END, task=name, worker=worker,
                         virtual_s=virtual)
        return TaskResult(task=name, ok=ok, worker=worker, t_start=t0,
                          t_end=t1, value=value, error=err,
                          virtual_s=virtual)

    # --------------------------------------------------------------- run
    def run(self, execute: Optional[Callable] = None) -> EngineReport:
        """Run until every task reaches a terminal state (or all workers
        die / the pool stalls).  `execute(name, meta)` may return bool,
        (ok, value), or None (success); default runs the submitted `fn`."""
        exec_fn = execute or self._execute_registered
        t_wall0 = time.perf_counter()
        alive = [f"w{i}" for i in range(self.workers)]
        dead: set[str] = set()
        steals = {w: 0 for w in alive}
        done_flag = {w: False for w in alive}
        pending: list[dict] = []
        running: dict[str, dict] = {}
        shadows: dict[str, set] = {}   # task -> workers whose duplicate
        results: dict[str, TaskResult] = {}   # steal was suppressed
        free = self.capacity
        idle_rounds = 0
        stalled = False
        pending_limit = max(self.workers, 1) * self.steal_n + self.capacity
        pool = (ThreadPoolExecutor(max_workers=self.capacity)
                if self.transport == "thread" else None)
        rounds = 0
        try:
            while True:
                rounds += 1
                progress = False
                # 1) reap finished tasks
                for name in list(running):
                    rec = running[name]
                    if not rec["fut"].done():
                        continue
                    running.pop(name)
                    free += rec["slots"]
                    progress = True
                    if rec["worker"] in dead:
                        continue      # lost completion: requeued via Exit
                    res: TaskResult = rec["fut"].result()
                    results[name] = res
                    self.backend.complete(rec["worker"], name, ok=res.ok)
                    # a lease-expiry duplicate steal we suppressed left the
                    # task in the re-stealer's assigned set; an idempotent
                    # Complete on its behalf clears that server-side state
                    for sw in shadows.pop(name, ()):
                        if sw != rec["worker"]:
                            self.backend.complete(sw, name, ok=res.ok)
                    self.tracer.emit(COMPLETED if res.ok else FAILED,
                                     task=name, worker=rec["worker"],
                                     error=res.error)
                    if res.ok:      # failed tasks never ready their succs
                        self._on_terminal(name)
                # 2) steal — a worker steals only while it holds fewer than
                # steal_n outstanding tasks (the Fig. 2 client loop's
                # batch-then-drain rhythm); rotation keeps the order fair
                outstanding = {w: 0 for w in alive}
                for it in pending:
                    outstanding[it["worker"]] = \
                        outstanding.get(it["worker"], 0) + 1
                for rec in running.values():
                    outstanding[rec["worker"]] = \
                        outstanding.get(rec["worker"], 0) + 1
                start = rounds % max(len(alive), 1)
                for w in alive[start:] + alive[:start]:
                    if w in dead or done_flag[w]:
                        continue
                    if outstanding.get(w, 0) >= self.steal_n \
                            or len(pending) >= pending_limit:
                        continue
                    got = self.backend.steal(w, self.steal_n)
                    if got == DONE:
                        done_flag[w] = True
                    elif got != EMPTY:
                        steals[w] += len(got)
                        pending_names = {it["name"] for it in pending}
                        for name, meta in got:
                            rec = running.get(name)
                            if name in pending_names or (
                                    rec is not None
                                    and rec["worker"] not in dead):
                                # lease-expiry re-steal of a task a LIVE
                                # copy of this pool still holds: the first
                                # copy will complete (idempotent server-
                                # side); a second launch would leak slots
                                # and double-count events.  A copy held
                                # only by a DEAD worker is accepted — its
                                # completion will be discarded, so this
                                # re-steal is the task's only way forward.
                                shadows.setdefault(name, set()).add(w)
                                continue
                            pending_names.add(name)
                            self.tracer.emit(STOLEN, task=name, worker=w)
                            pending.append({
                                "name": name, "meta": meta, "worker": w,
                                "priority": self._priority_of(name, meta),
                                "slots": self._slots_of(name, meta),
                                "seq": next_seq()})
                        progress = True
                # 3) fault injection: worker deaths (between steal & launch,
                #    so a dying worker holds stolen-but-unstarted tasks)
                if self.faults is not None:
                    for w in alive:
                        if w in dead:
                            continue
                        if self.faults.should_die(w, steals[w]):
                            dead.add(w)
                            silent = self.faults.dies_silently(w)
                            self.tracer.emit(WORKER_DEAD, worker=w,
                                             silent=silent)
                            pending = [it for it in pending
                                       if it["worker"] != w]
                            if not silent:
                                # announced death: Exit recycles assignment
                                self.backend.exit_worker(w)
                            # silent death: heartbeat-lease expiry recycles
                            progress = True
                # 4) launch: greedy highest-priority-first into free slots
                if pending:
                    pending.sort(key=lambda it: (-it["priority"], it["seq"]))
                    held = []
                    for it in pending:
                        if it["worker"] in dead:
                            continue
                        if it["name"] in running:
                            # a dead worker's copy is still in flight;
                            # wait for it to drain before re-launching
                            held.append(it)
                            continue
                        slots = min(it["slots"], self.capacity)
                        if slots > free:
                            held.append(it)
                            continue
                        free -= slots
                        if pool is None:
                            fut = _SyncFuture(self._run_one(
                                exec_fn, it["name"], it["meta"],
                                it["worker"]))
                        else:
                            fut = pool.submit(self._run_one, exec_fn,
                                              it["name"], it["meta"],
                                              it["worker"])
                        running[it["name"]] = {"worker": it["worker"],
                                               "fut": fut, "slots": slots}
                        progress = True
                    pending = held
                # 5) termination
                live = [w for w in alive if w not in dead]
                if not running and not pending:
                    if not live or all(done_flag[w] for w in live):
                        break
                if progress:
                    idle_rounds = 0
                elif not running:
                    idle_rounds += 1
                    if idle_rounds >= self.max_idle_rounds:
                        stalled = True   # unresolvable (cycle / all leased)
                        break
                    time.sleep(self.poll)
                else:
                    time.sleep(self.poll)
        finally:
            if pool is not None:
                pool.shutdown(wait=True)
        # effective parallelism: the inproc transport runs tasks serially,
        # so overhead accounting must not multiply wall time by the pool size
        eff_workers = 1 if self.transport == "inproc" else self.workers
        return EngineReport(
            results=results, trace=self.tracer, workers=eff_workers,
            wall_s=time.perf_counter() - t_wall0,
            errors=self.backend.errors(), stalled=stalled,
            backend_stats=self.backend.stats())

    # ------------------------------------------------------------ helpers
    def _priority_of(self, name: str, meta: dict) -> float:
        task = self.tasks.get(name)
        if task is not None:
            return task.priority
        return float(meta.get("priority", 0.0)) if meta else 0.0

    def _slots_of(self, name: str, meta: dict) -> int:
        task = self.tasks.get(name)
        if task is not None:
            return task.slots
        return int(meta.get("slots", 1)) if meta else 1
