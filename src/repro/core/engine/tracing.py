"""Trace recorder + empirical overhead / METG / latency analysis.

The recorder is a thread-safe log of `TraceEvent`s stamped by an
injectable clock — append-only by default, or a bounded ring buffer
(`TraceRecorder(max_events=N)`) for long-lived resident sessions that
must not grow without bound.  Analysis turns an event stream into the paper's
quantities *measured from the running system* rather than modelled:

  * per-task overhead   — wall time not spent computing, per completed task
                          (the paper's "well-understood per-task overhead")
  * rpc_per_task_s      — scheduler round-trip time per task (dwork's 23 us
                          RTT analog, measured at the server boundary)
  * tasks_per_s         — dispatch throughput
  * empirical METG      — task duration at which measured overhead equals
                          compute (§3: eff = t / (t + overhead) = 50%)
  * request latency     — serving mode (`repro.core.serving`): per-request
                          enqueue -> complete latency with p50/p95/p99
                          percentiles plus admission queue-depth stats,
                          computed from the REQ_* / BATCH_FORMED events
                          (`LatencyReport`, attached to `OverheadReport`)

`crosscheck()` compares an empirical value against the analytic scaling
laws in `repro.core.metg` and reports whether they agree to within an
order of magnitude — the engine's validation loop for the models.
"""
from __future__ import annotations

import json
import threading
from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Optional

from repro.core.engine.model import (BATCH_FORMED, COMPLETED, FAILED,
                                     REQ_DONE, REQ_ENQUEUED, REQ_REJECTED,
                                     REQUEUED, RETRIED, RPC, RUN_END,
                                     RUN_START, STOLEN, XFER, TraceEvent,
                                     real_clock)
from repro.core.metg import same_order


def percentile(sorted_vals: list, q: float) -> float:
    """Linear-interpolated percentile of an ascending-sorted list
    (q in [0, 1]); 0.0 on empty input."""
    if not sorted_vals:
        return 0.0
    if len(sorted_vals) == 1:
        return float(sorted_vals[0])
    pos = q * (len(sorted_vals) - 1)
    lo = int(pos)
    hi = min(lo + 1, len(sorted_vals) - 1)
    frac = pos - lo
    return float(sorted_vals[lo] * (1.0 - frac) + sorted_vals[hi] * frac)


class TraceRecorder:
    def __init__(self, clock: Optional[Callable[[], float]] = None,
                 rpc_sample: int = 1, max_events: Optional[int] = None):
        self.clock = clock or real_clock
        # opt-in bounded memory for long-lived resident sessions: with
        # `max_events` the event log is a ring buffer — the newest
        # `max_events` events are kept and `self.dropped` counts the
        # evictions.  Analysis over a ring covers the retained window
        # only (events whose lifecycle partner was evicted pair as
        # incomplete and are skipped by the report pairing).
        self.max_events = max_events
        if max_events is not None:
            self.events: deque[TraceEvent] = deque(maxlen=max(max_events, 1))
        else:
            self.events: list[TraceEvent] = []
        self.n_emitted = 0
        self._lock = threading.Lock()
        # rpc sampling: record every k-th round-trip instead of all of
        # them.  Backends call `sample_rpc()` BEFORE timing a call; a
        # False return means "skip the perf_counter pair and the event
        # allocation entirely" — the unsampled calls are still counted
        # (`rpc_seen`) so `OverheadReport` can scale the totals back up.
        self.rpc_sample = max(int(rpc_sample), 1)
        self.rpc_seen = 0

    @property
    def dropped(self) -> int:
        """Events evicted by the ring buffer (0 when unbounded)."""
        return max(0, self.n_emitted - len(self.events))

    def sample_rpc(self) -> bool:
        """Should the next backend round-trip be timed + recorded?"""
        self.rpc_seen += 1
        return self.rpc_sample == 1 or self.rpc_seen % self.rpc_sample == 0

    def emit(self, event: str, task: Optional[str] = None,
             worker: Optional[str] = None, **extra):
        ev = TraceEvent(self.clock(), event, task, worker, extra)
        if self.max_events is None:
            # list.append is atomic under the GIL — no lock on the hot
            # path; readers still lock to snapshot a consistent view
            self.n_emitted += 1
            self.events.append(ev)
        else:
            # ring mode must lock: a bounded deque append also EVICTS, and
            # eviction during a reader's iteration raises.  Bounded mode
            # is opt-in, so the unbounded hot path stays lock-free.
            with self._lock:
                self.n_emitted += 1
                self.events.append(ev)
        return ev

    def emit_at(self, t: float, event: str, task: Optional[str] = None,
                worker: Optional[str] = None, **extra):
        """Emit with an explicit timestamp instead of stamping the clock:
        the proc transport reconstructs RUN_START/RUN_END spans
        engine-side from worker-reported durations, so the stamps must
        reflect when the task ran in the worker process, not when the
        record drained.  Events still append in call order (the report
        pairing walks list order, not timestamps)."""
        ev = TraceEvent(t, event, task, worker, extra)
        if self.max_events is None:
            self.n_emitted += 1
            self.events.append(ev)
        else:
            with self._lock:
                self.n_emitted += 1
                self.events.append(ev)
        return ev

    def emit4(self, event: str, task: str, worker: str):
        """No-extra fast emit for the 3-4 per-task lifecycle events on the
        dispatch hot path (skips kwargs packing)."""
        ev = TraceEvent(self.clock(), event, task, worker)
        if self.max_events is None:
            self.n_emitted += 1
            self.events.append(ev)
        else:
            with self._lock:
                self.n_emitted += 1
                self.events.append(ev)
        return ev

    # ------------------------------------------------------------ queries
    def of(self, event: str) -> list[TraceEvent]:
        with self._lock:
            return [e for e in self.events if e.event == event]

    def count(self, event: str) -> int:
        return len(self.of(event))

    def span_s(self) -> float:
        with self._lock:
            if not self.events:
                return 0.0
            ts = [e.t for e in self.events]
            return max(ts) - min(ts)

    def report(self, workers: int = 1) -> "OverheadReport":
        return OverheadReport.from_trace(self, workers=workers)

    def latency_report(self) -> "LatencyReport":
        return LatencyReport.from_trace(self)

    def to_chrome_trace(self, path: Optional[str] = None, *,
                        critical_path: Optional[list] = None) -> dict:
        """Export the event log as a Chrome Trace Event Format document
        (Perfetto / `chrome://tracing` loadable): one lane per worker
        with task spans, rpc and `hop:*` lanes, serving requests as
        async spans.  `critical_path` (a list of task names, e.g.
        `CriticalPathReport.path`) adds a dedicated lane plus flow
        arrows linking the path's executions.  Returns the document;
        with `path`, also writes it as JSON (conventional suffix
        `.trace.json`).  See `repro.core.obs.chrome_trace`."""
        from repro.core.obs.chrome_trace import to_chrome_trace
        return to_chrome_trace(self, path, critical_path=critical_path)

    # -------------------------------------------------------- persistence
    def save(self, path: str) -> int:
        """Write the event log as JSON Lines: one header object (recorder
        counters), then one `[t, event, task, worker, extra]` array per
        event.  The format round-trips through `TraceRecorder.load`, so a
        trace captured in one process can be analyzed offline
        (`python -m repro.core.obs.explain <path>`).  Returns the number
        of events written."""
        with self._lock:
            events = list(self.events)
        with open(path, "w") as f:
            json.dump({"format": "repro-trace", "version": 1,
                       "n_emitted": self.n_emitted,
                       "dropped": max(0, self.n_emitted - len(events)),
                       "rpc_seen": self.rpc_seen,
                       "rpc_sample": self.rpc_sample}, f)
            f.write("\n")
            for e in events:
                json.dump([e.t, e.event, e.task, e.worker,
                           e.extra if e.extra else None], f)
                f.write("\n")
        return len(events)

    @classmethod
    def load(cls, path: str) -> "TraceRecorder":
        """Rebuild a recorder from a `save()`d JSONL file (unbounded —
        the ring, if any, was applied at capture time; eviction counts
        are restored so reports stay honest about truncation)."""
        tr = cls()
        with open(path) as f:
            header = json.loads(f.readline())
            if header.get("format") != "repro-trace":
                raise ValueError(f"{path}: not a repro trace "
                                 "(missing JSONL header)")
            for line in f:
                if not line.strip():
                    continue
                t, event, task, worker, extra = json.loads(line)
                tr.events.append(TraceEvent(t, event, task, worker, extra))
        tr.n_emitted = int(header.get("n_emitted", len(tr.events)))
        tr.rpc_seen = int(header.get("rpc_seen", 0))
        tr.rpc_sample = max(int(header.get("rpc_sample", 1)), 1)
        return tr


@dataclass
class LatencyReport:
    """Per-request latency accounting for the serving layer, computed from
    the REQ_* / BATCH_FORMED event stream: enqueue -> complete latency
    percentiles (tail latency is the serving SLO, so p95/p99 matter more
    than the mean) plus admission queue-depth stats."""
    n_requests: int = 0              # requests that got a response
    n_incomplete: int = 0            # REQ_DONE with no usable latency
    n_failed: int = 0                # responses delivered with ok=False
    n_rejected: int = 0              # bounced by admission backpressure
    n_batches: int = 0               # engine tasks the requests rode on
    mean_batch: float = 0.0
    mean_s: float = 0.0
    p50_s: float = 0.0
    p95_s: float = 0.0
    p99_s: float = 0.0
    max_s: float = 0.0
    queue_depth_mean: float = 0.0    # sampled at every enqueue + dispatch
    queue_depth_max: int = 0
    batch_wait_mean_s: float = 0.0   # oldest request's age at coalesce time
    # windowed snapshots (Frontend.snapshot) stamp their window here;
    # whole-trace reports leave both at 0
    t_s: float = 0.0                 # snapshot time on the trace clock
    window_s: float = 0.0            # span the snapshot covers
    # per-tenant slices: tenant label -> LatencyReport (latency fields
    # only), present when any request carried a tenant= label
    by_tenant: Optional[dict] = None

    @classmethod
    def _tenant_slice(cls, lats: list, n_failed: int = 0,
                      n_rejected: int = 0) -> "LatencyReport":
        """A latency-only sub-report for one tenant's sorted latencies."""
        return cls(
            n_requests=len(lats),
            n_failed=n_failed,
            n_rejected=n_rejected,
            mean_s=(sum(lats) / len(lats)) if lats else 0.0,
            p50_s=percentile(lats, 0.50),
            p95_s=percentile(lats, 0.95),
            p99_s=percentile(lats, 0.99),
            max_s=lats[-1] if lats else 0.0,
        )

    @classmethod
    def from_trace(cls, trace: "TraceRecorder") -> "LatencyReport":
        lats: list[float] = []
        depths: list[int] = []
        n_failed = n_rejected = n_batches = n_incomplete = 0
        batched = 0
        wait_s = 0.0
        tenant_lats: dict = {}       # tenant -> [lats, n_failed, n_rejected]
        with trace._lock:
            events = list(trace.events)
        for e in events:
            ev = e.event
            if ev == REQ_DONE:
                lat = e.extra.get("latency_s")
                if lat is None:
                    # an unstamped completion (its lifecycle partner was
                    # evicted from the ring, or a foreign emitter): skip
                    # it — folding a 0.0 default into the population
                    # would drag p50/mean toward zero
                    n_incomplete += 1
                    continue
                lats.append(lat)
                ok = e.extra.get("ok", True)
                if not ok:
                    n_failed += 1
                tenant = e.extra.get("tenant")
                if tenant is not None:
                    row = tenant_lats.setdefault(tenant, [[], 0, 0])
                    row[0].append(lat)
                    if not ok:
                        row[1] += 1
            elif ev == REQ_ENQUEUED:
                depths.append(e.extra.get("depth", 0))
            elif ev == BATCH_FORMED:
                n_batches += 1
                batched += e.extra.get("size", 0)
                wait_s += e.extra.get("wait_s", 0.0)
                depths.append(e.extra.get("depth", 0))
            elif ev == REQ_REJECTED:
                n_rejected += 1
                tenant = e.extra.get("tenant")
                if tenant is not None:
                    tenant_lats.setdefault(tenant, [[], 0, 0])[2] += 1
        lats.sort()
        by_tenant = None
        if tenant_lats:
            by_tenant = {}
            for tenant, (tl, tf, tr) in sorted(tenant_lats.items()):
                tl.sort()
                by_tenant[tenant] = cls._tenant_slice(tl, tf, tr)
        return cls(
            by_tenant=by_tenant,
            n_requests=len(lats),
            n_incomplete=n_incomplete,
            n_failed=n_failed,
            n_rejected=n_rejected,
            n_batches=n_batches,
            mean_batch=(batched / n_batches) if n_batches else 0.0,
            mean_s=(sum(lats) / len(lats)) if lats else 0.0,
            p50_s=percentile(lats, 0.50),
            p95_s=percentile(lats, 0.95),
            p99_s=percentile(lats, 0.99),
            max_s=lats[-1] if lats else 0.0,
            queue_depth_mean=(sum(depths) / len(depths)) if depths else 0.0,
            queue_depth_max=max(depths, default=0),
            batch_wait_mean_s=(wait_s / n_batches) if n_batches else 0.0,
        )

    def summary(self) -> dict:
        return {
            "n_requests": self.n_requests, "n_failed": self.n_failed,
            "n_incomplete": self.n_incomplete,
            "n_rejected": self.n_rejected, "n_batches": self.n_batches,
            "mean_batch": round(self.mean_batch, 2),
            "latency_ms": {
                "mean": round(self.mean_s * 1e3, 3),
                "p50": round(self.p50_s * 1e3, 3),
                "p95": round(self.p95_s * 1e3, 3),
                "p99": round(self.p99_s * 1e3, 3),
                "max": round(self.max_s * 1e3, 3),
            },
            "queue_depth_mean": round(self.queue_depth_mean, 2),
            "queue_depth_max": self.queue_depth_max,
            "batch_wait_mean_ms": round(self.batch_wait_mean_s * 1e3, 3),
            **({"t_s": round(self.t_s, 3),
                "window_s": round(self.window_s, 3)}
               if self.window_s else {}),
            **({"tenants": {
                tenant: {
                    "n_requests": rep.n_requests,
                    "n_failed": rep.n_failed,
                    "n_rejected": rep.n_rejected,
                    "latency_ms": {
                        "mean": round(rep.mean_s * 1e3, 3),
                        "p50": round(rep.p50_s * 1e3, 3),
                        "p95": round(rep.p95_s * 1e3, 3),
                        "p99": round(rep.p99_s * 1e3, 3),
                        "max": round(rep.max_s * 1e3, 3),
                    },
                } for tenant, rep in self.by_tenant.items()}}
               if self.by_tenant else {}),
        }


@dataclass
class OverheadReport:
    """Empirical per-task overhead computed from an event stream."""
    n_tasks: int = 0                 # tasks that reached a terminal event
    n_failed: int = 0
    n_requeued: int = 0
    n_retried: int = 0               # transient failures re-enqueued
    workers: int = 1
    wall_s: float = 0.0
    compute_s: float = 0.0           # sum of real run durations
    virtual_s: float = 0.0           # injected straggler time (not walled)
    rpc_s: float = 0.0               # total scheduler round-trip time
    n_rpc: int = 0
    dispatch_s: float = 0.0          # total stolen -> run_start latency
    rpc_by_op: dict = field(default_factory=dict)  # op -> (count, total_s)
    # data plane (transport="proc"): dependency-value fetch accounting,
    # unsampled — every fetch emits exactly one XFER, no scale-up needed
    xfer_s: float = 0.0              # total fetch time, all paths
    n_xfer: int = 0
    xfer_bytes: int = 0
    xfer_by_path: dict = field(default_factory=dict)  # path -> (n, B, s)
    requests: Optional[LatencyReport] = None  # serving mode, else None
    # ring-buffer truncation accounting: a bounded TraceRecorder evicts
    # its oldest events, so a report over it covers the retained window
    # only — dropped > 0 says every count above under-reports
    n_emitted: int = 0               # events the recorder ever emitted
    dropped: int = 0                 # events evicted before this report
    # the source recorder, kept so `explain()` can run the post-hoc
    # critical-path analysis without re-plumbing; None for hand-built
    # reports (excluded from summary())
    trace: Optional[TraceRecorder] = None

    @classmethod
    def from_trace(cls, trace: TraceRecorder, workers: int = 1
                   ) -> "OverheadReport":
        # pair lifecycle events sequentially per task: a requeued task
        # re-executes and emits a second stolen/run_start/run_end triple,
        # so last-write-wins dicts would pair across executions and
        # produce negative durations
        compute = virtual = dispatch = 0.0
        open_start: dict = {}
        open_steal: dict = {}
        with trace._lock:
            events = list(trace.events)
        for e in events:
            if e.event == STOLEN:
                open_steal[e.task] = e.t
            elif e.event == RUN_START:
                open_start[e.task] = e.t
                t_stolen = open_steal.pop(e.task, None)
                if t_stolen is not None:
                    dispatch += e.t - t_stolen
            elif e.event == RUN_END:
                t_start = open_start.pop(e.task, None)
                if t_start is not None:
                    compute += e.t - t_start
                virtual += e.extra.get("virtual_s", 0.0)
        # rpc accounting: forwarding-tree hop events (op="hop:L<k>") are
        # nested inside the worker's end-to-end round-trip measurement, so
        # they go in the per-op breakdown (latency attribution) but NOT in
        # the rpc_s/n_rpc totals (that would double-count the tree)
        by_op: dict = {}
        rpc_s = 0.0
        n_rpc = 0
        for e in trace.of(RPC):
            op = e.extra.get("op", "?")
            dt = e.extra.get("dt", 0.0)
            cnt, tot = by_op.get(op, (0, 0.0))
            by_op[op] = (cnt + 1, tot + dt)
            if not op.startswith("hop:"):
                rpc_s += dt
                n_rpc += 1
        # sampled tracing: scale the recorded round-trips back up to the
        # true call count (rpc_seen counts every call, sampled or not)
        if trace.rpc_seen > n_rpc > 0:
            rpc_s *= trace.rpc_seen / n_rpc
            n_rpc = trace.rpc_seen
        # data-motion fold: per-path fetch totals (peer vs hub)
        xfer_by_path: dict = {}
        xfer_s = 0.0
        n_xfer = xfer_bytes = 0
        for e in trace.of(XFER):
            path = e.extra.get("path", "?")
            n = e.extra.get("n", 0)
            dt = e.extra.get("dt", 0.0)
            cnt, tb, ts = xfer_by_path.get(path, (0, 0, 0.0))
            xfer_by_path[path] = (cnt + 1, tb + n, ts + dt)
            n_xfer += 1
            xfer_bytes += n
            xfer_s += dt
        requeued = sum(e.extra.get("n", 1) for e in trace.of(REQUEUED))
        lat = LatencyReport.from_trace(trace)
        if lat.n_requests == 0 and lat.n_rejected == 0:
            lat = None                    # batch mode: no request stream
        return cls(
            trace=trace,
            requests=lat,
            n_tasks=trace.count(COMPLETED) + trace.count(FAILED),
            n_failed=trace.count(FAILED),
            n_requeued=requeued,
            n_retried=trace.count(RETRIED),
            workers=max(workers, 1),
            wall_s=trace.span_s(),
            compute_s=compute,
            virtual_s=virtual,
            rpc_s=rpc_s,
            n_rpc=n_rpc,
            dispatch_s=dispatch,
            rpc_by_op=by_op,
            xfer_s=xfer_s,
            n_xfer=n_xfer,
            xfer_bytes=xfer_bytes,
            xfer_by_path=xfer_by_path,
            n_emitted=trace.n_emitted,
            dropped=trace.dropped,
        )

    # ------------------------------------------------------------ derived
    @property
    def tasks_per_s(self) -> float:
        return self.n_tasks / self.wall_s if self.wall_s > 0 else 0.0

    @property
    def per_task_overhead_s(self) -> float:
        """Worker-seconds not spent computing, per terminal task.  With the
        serial in-proc transport (workers=1) this is exactly
        (wall - compute) / n: the scheduler's cost per task."""
        if self.n_tasks == 0:
            return 0.0
        idle = self.wall_s * self.workers - self.compute_s
        return max(idle, 0.0) / self.n_tasks

    @property
    def rpc_per_task_s(self) -> float:
        """Server-side handling time per terminal task (dwork RTT analog)."""
        return self.rpc_s / self.n_tasks if self.n_tasks else 0.0

    @property
    def queue_latency_per_task_s(self) -> float:
        """Mean stolen -> run_start latency.  NOTE: includes time waiting
        for a free slot (backlog), so it measures queue pressure, not pure
        scheduler cost — use `rpc_per_task_s` / `per_task_overhead_s` for
        overhead accounting."""
        return self.dispatch_s / self.n_tasks if self.n_tasks else 0.0

    def empirical_metg(self) -> float:
        """Task duration at which measured overhead = compute (50% eff)."""
        return self.per_task_overhead_s

    def explain(self, **kw) -> "object":
        """Post-hoc critical-path analysis over the source trace: *why*
        did this run take `wall_s` — which chain of tasks gated the
        makespan, and how much of it was scheduler time (dep-wait +
        queue + dispatch + notify) vs compute?  Returns a
        `repro.core.obs.critical_path.CriticalPathReport`; keyword
        arguments (`deps=`, `scheduler=`, `steal_n=`, ...) are forwarded
        to `CriticalPathReport.from_trace`.  Strictly an analysis pass —
        nothing here runs on the dispatch hot path."""
        if self.trace is None:
            raise ValueError("explain() needs the source trace; this "
                             "report was built without one")
        from repro.core.obs.critical_path import CriticalPathReport
        kw.setdefault("workers", self.workers)
        return CriticalPathReport.from_trace(self.trace, **kw)

    def summary(self) -> dict:
        out = {
            "n_tasks": self.n_tasks, "n_failed": self.n_failed,
            "n_requeued": self.n_requeued, "n_retried": self.n_retried,
            "workers": self.workers,
            "wall_s": round(self.wall_s, 6),
            "tasks_per_s": round(self.tasks_per_s, 1),
            "per_task_overhead_us": round(self.per_task_overhead_s * 1e6, 2),
            "rpc_per_task_us": round(self.rpc_per_task_s * 1e6, 2),
            "empirical_metg_s": self.empirical_metg(),
            "n_emitted": self.n_emitted,
            "dropped": self.dropped,
        }
        if self.n_xfer:
            out["xfer"] = {
                "n": self.n_xfer,
                "bytes": self.xfer_bytes,
                "total_s": round(self.xfer_s, 6),
                "by_path": {p: {"n": n, "bytes": b,
                                "total_s": round(t, 6)}
                            for p, (n, b, t)
                            in sorted(self.xfer_by_path.items())},
            }
        if self.requests is not None:
            out["requests"] = self.requests.summary()
        return out


def crosscheck(scheduler: str, empirical_s: float, analytic_s: float,
               factor: float = 10.0) -> dict:
    """Cross-check an empirical overhead/METG against the analytic law
    value from `repro.core.metg`.  `same_order` is True when the two agree
    to within `factor` (default: one order of magnitude)."""
    ratio = (empirical_s / analytic_s) if analytic_s > 0 else float("inf")
    return {
        "scheduler": scheduler,
        "empirical_s": empirical_s,
        "analytic_s": analytic_s,
        "ratio": ratio,
        "same_order": same_order(empirical_s, analytic_s, factor=factor),
    }
