"""Trace recorder + empirical overhead / METG analysis.

The recorder is an append-only, thread-safe list of `TraceEvent`s stamped
by an injectable clock.  Analysis turns an event stream into the paper's
quantities *measured from the running system* rather than modelled:

  * per-task overhead   — wall time not spent computing, per completed task
                          (the paper's "well-understood per-task overhead")
  * rpc_per_task_s      — scheduler round-trip time per task (dwork's 23 us
                          RTT analog, measured at the server boundary)
  * tasks_per_s         — dispatch throughput
  * empirical METG      — task duration at which measured overhead equals
                          compute (§3: eff = t / (t + overhead) = 50%)

`crosscheck()` compares an empirical value against the analytic scaling
laws in `repro.core.metg` and reports whether they agree to within an
order of magnitude — the engine's validation loop for the models.
"""
from __future__ import annotations

import threading
from dataclasses import dataclass, field
from typing import Callable, Optional

from repro.core.engine.model import (COMPLETED, FAILED, REQUEUED, RPC,
                                     RUN_END, RUN_START, STOLEN, TraceEvent,
                                     real_clock)
from repro.core.metg import same_order


class TraceRecorder:
    def __init__(self, clock: Optional[Callable[[], float]] = None,
                 rpc_sample: int = 1):
        self.clock = clock or real_clock
        self.events: list[TraceEvent] = []
        self._lock = threading.Lock()
        # rpc sampling: record every k-th round-trip instead of all of
        # them.  Backends call `sample_rpc()` BEFORE timing a call; a
        # False return means "skip the perf_counter pair and the event
        # allocation entirely" — the unsampled calls are still counted
        # (`rpc_seen`) so `OverheadReport` can scale the totals back up.
        self.rpc_sample = max(int(rpc_sample), 1)
        self.rpc_seen = 0

    def sample_rpc(self) -> bool:
        """Should the next backend round-trip be timed + recorded?"""
        self.rpc_seen += 1
        return self.rpc_sample == 1 or self.rpc_seen % self.rpc_sample == 0

    def emit(self, event: str, task: Optional[str] = None,
             worker: Optional[str] = None, **extra):
        ev = TraceEvent(self.clock(), event, task, worker, extra)
        # list.append is atomic under the GIL — no lock on the hot path;
        # readers still lock to snapshot a consistent view
        self.events.append(ev)
        return ev

    def emit4(self, event: str, task: str, worker: str):
        """No-extra fast emit for the 3-4 per-task lifecycle events on the
        dispatch hot path (skips kwargs packing)."""
        ev = TraceEvent(self.clock(), event, task, worker)
        self.events.append(ev)
        return ev

    # ------------------------------------------------------------ queries
    def of(self, event: str) -> list[TraceEvent]:
        with self._lock:
            return [e for e in self.events if e.event == event]

    def count(self, event: str) -> int:
        return len(self.of(event))

    def span_s(self) -> float:
        with self._lock:
            if not self.events:
                return 0.0
            ts = [e.t for e in self.events]
            return max(ts) - min(ts)

    def report(self, workers: int = 1) -> "OverheadReport":
        return OverheadReport.from_trace(self, workers=workers)


@dataclass
class OverheadReport:
    """Empirical per-task overhead computed from an event stream."""
    n_tasks: int = 0                 # tasks that reached a terminal event
    n_failed: int = 0
    n_requeued: int = 0
    workers: int = 1
    wall_s: float = 0.0
    compute_s: float = 0.0           # sum of real run durations
    virtual_s: float = 0.0           # injected straggler time (not walled)
    rpc_s: float = 0.0               # total scheduler round-trip time
    n_rpc: int = 0
    dispatch_s: float = 0.0          # total stolen -> run_start latency
    rpc_by_op: dict = field(default_factory=dict)  # op -> (count, total_s)

    @classmethod
    def from_trace(cls, trace: TraceRecorder, workers: int = 1
                   ) -> "OverheadReport":
        # pair lifecycle events sequentially per task: a requeued task
        # re-executes and emits a second stolen/run_start/run_end triple,
        # so last-write-wins dicts would pair across executions and
        # produce negative durations
        compute = virtual = dispatch = 0.0
        open_start: dict = {}
        open_steal: dict = {}
        with trace._lock:
            events = list(trace.events)
        for e in events:
            if e.event == STOLEN:
                open_steal[e.task] = e.t
            elif e.event == RUN_START:
                open_start[e.task] = e.t
                t_stolen = open_steal.pop(e.task, None)
                if t_stolen is not None:
                    dispatch += e.t - t_stolen
            elif e.event == RUN_END:
                t_start = open_start.pop(e.task, None)
                if t_start is not None:
                    compute += e.t - t_start
                virtual += e.extra.get("virtual_s", 0.0)
        # rpc accounting: forwarding-tree hop events (op="hop:L<k>") are
        # nested inside the worker's end-to-end round-trip measurement, so
        # they go in the per-op breakdown (latency attribution) but NOT in
        # the rpc_s/n_rpc totals (that would double-count the tree)
        by_op: dict = {}
        rpc_s = 0.0
        n_rpc = 0
        for e in trace.of(RPC):
            op = e.extra.get("op", "?")
            dt = e.extra.get("dt", 0.0)
            cnt, tot = by_op.get(op, (0, 0.0))
            by_op[op] = (cnt + 1, tot + dt)
            if not op.startswith("hop:"):
                rpc_s += dt
                n_rpc += 1
        # sampled tracing: scale the recorded round-trips back up to the
        # true call count (rpc_seen counts every call, sampled or not)
        if trace.rpc_seen > n_rpc > 0:
            rpc_s *= trace.rpc_seen / n_rpc
            n_rpc = trace.rpc_seen
        requeued = sum(e.extra.get("n", 1) for e in trace.of(REQUEUED))
        return cls(
            n_tasks=trace.count(COMPLETED) + trace.count(FAILED),
            n_failed=trace.count(FAILED),
            n_requeued=requeued,
            workers=max(workers, 1),
            wall_s=trace.span_s(),
            compute_s=compute,
            virtual_s=virtual,
            rpc_s=rpc_s,
            n_rpc=n_rpc,
            dispatch_s=dispatch,
            rpc_by_op=by_op,
        )

    # ------------------------------------------------------------ derived
    @property
    def tasks_per_s(self) -> float:
        return self.n_tasks / self.wall_s if self.wall_s > 0 else 0.0

    @property
    def per_task_overhead_s(self) -> float:
        """Worker-seconds not spent computing, per terminal task.  With the
        serial in-proc transport (workers=1) this is exactly
        (wall - compute) / n: the scheduler's cost per task."""
        if self.n_tasks == 0:
            return 0.0
        idle = self.wall_s * self.workers - self.compute_s
        return max(idle, 0.0) / self.n_tasks

    @property
    def rpc_per_task_s(self) -> float:
        """Server-side handling time per terminal task (dwork RTT analog)."""
        return self.rpc_s / self.n_tasks if self.n_tasks else 0.0

    @property
    def queue_latency_per_task_s(self) -> float:
        """Mean stolen -> run_start latency.  NOTE: includes time waiting
        for a free slot (backlog), so it measures queue pressure, not pure
        scheduler cost — use `rpc_per_task_s` / `per_task_overhead_s` for
        overhead accounting."""
        return self.dispatch_s / self.n_tasks if self.n_tasks else 0.0

    def empirical_metg(self) -> float:
        """Task duration at which measured overhead = compute (50% eff)."""
        return self.per_task_overhead_s

    def summary(self) -> dict:
        return {
            "n_tasks": self.n_tasks, "n_failed": self.n_failed,
            "n_requeued": self.n_requeued, "workers": self.workers,
            "wall_s": round(self.wall_s, 6),
            "tasks_per_s": round(self.tasks_per_s, 1),
            "per_task_overhead_us": round(self.per_task_overhead_s * 1e6, 2),
            "rpc_per_task_us": round(self.rpc_per_task_s * 1e6, 2),
            "empirical_metg_s": self.empirical_metg(),
        }


def crosscheck(scheduler: str, empirical_s: float, analytic_s: float,
               factor: float = 10.0) -> dict:
    """Cross-check an empirical overhead/METG against the analytic law
    value from `repro.core.metg`.  `same_order` is True when the two agree
    to within `factor` (default: one order of magnitude)."""
    ratio = (empirical_s / analytic_s) if analytic_s > 0 else float("inf")
    return {
        "scheduler": scheduler,
        "empirical_s": empirical_s,
        "analytic_s": analytic_s,
        "ratio": ratio,
        "same_order": same_order(empirical_s, analytic_s, factor=factor),
    }
