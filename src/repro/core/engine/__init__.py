"""Unified execution engine: one worker-pool substrate for all three
schedulers (Rogers 2021).

The paper's central claim is that pmake, dwork, and mpi-list "have the
same bottlenecks" and "well-understood per-task overhead".  This subsystem
makes that claim *measurable* in one place instead of three ad-hoc loops:

    model.py     Task / TaskResult / TraceEvent lifecycle data model
                 (created -> ready -> stolen -> running -> completed/
                  failed/requeued), mapped to the paper's Fig. 2 protocol
    backends.py  scheduler state adapters (dwork TaskServer, ShardedHub,
                 forwarding-tree TreeBackend) speaking the Table 2 verbs
                 incl. the batched CompleteSteal; every call timed as an
                 `rpc` event (tree hops as `op="hop:L<k>"`)
    executor.py  the worker pool: inproc / thread / tree / proc
                 transports, CompleteSteal piggybacking (complete+steal
                 in one RTT), Steal-n batching, sharded routing,
                 heap-scheduled slots/priority launch (pmake EFT)
    comm/        the transport registry: Connector/Listener pairs per
                 address scheme, TransportFamily per `transport=` name;
                 the proc family spawns worker PROCESSES speaking
                 Table-2 frames over TCP (Hello handshake, heartbeat
                 leases, cloudpickle at the boundary, multi-host join
                 via `python -m repro.core.engine.comm.worker`)
    faults.py    heartbeat leases, dead-worker requeue, seeded fault and
                 straggler injection (no wall-clock dependence in tests)
    journal.py   write-ahead journal + compacted checkpoints for the
                 Table-2 transitions; `Engine.recover(journal_dir)`
                 rebuilds a crashed session (docs/robustness.md)
    tracing.py   empirical per-task overhead + METG from event streams
                 (optionally rpc-sampled), cross-checked against the
                 analytic laws in core/metg.py

Scheduler adapters built on this substrate:
    dwork    `repro.core.dwork.pool.run_pool`  (TaskServer / ShardedHub)
    pmake    `repro.core.pmake.PMake.run`      (slots=nodes, EFT priority)
    mpi-list `repro.core.mpi_list.Context(..., engine_workers=...)`

Tuning `transport=` / `steal_n` against the METG laws (core/metg.py):

  * dwork's dispatch bound is METG(P) = rtt * P / (steal_n * shards)
    (§3, Table 4).  `steal_n` is the cheapest lever: it divides BOTH
    protocol directions now that completions piggyback on the next steal
    (`CompleteSteal`), at the cost of coarser work distribution — keep
    steal_n * task_duration well under the straggler horizon, and below
    the DAG's width / P so the tail of a batch can't serialize a level.
  * `transport="inproc"` measures pure scheduler cost (deterministic;
    use it for METG benchmarking and fault tests).  `transport="thread"`
    adds real concurrency for blocking tasks — use when task bodies hold
    the GIL < ~50% (popen'd scripts, I/O).  `transport="tree"` inserts a
    real forwarding tree (paper §4) in front of the hub: per-task rtt
    RISES by the per-hop relay cost (visible under `rpc_by_op` as
    `hop:L<k>`), but open connections at the hub drop from P to
    P/fanout^levels — pick it when connection count, not rtt, is the
    binding constraint, and size `tree_fanout` so each relay stays below
    ~fanout concurrent downstream frames per upstream round-trip.
    `transport="proc"` spawns real worker processes — the only family
    whose CPU-bound tasks scale with cores (the others serialize on the
    GIL) — at the highest per-task cost (fork + cloudpickle + socket
    rtt): callables must pickle (`SerializationError` at submit time
    otherwise), failures surface as error reprs, and a SIGKILLed
    worker's in-flight work requeues with zero loss via heartbeat
    leases.
  * `shards=N` multiplies dispatch rate by N for independent-task loads;
    cross-shard dependencies pay a proxy/notify round-trip, so shard
    only DAGs whose cut between shards is small (hash routing makes the
    cut ~ (1 - 1/N) of edges — prefer wide, shallow graphs).
  * `transport="tree", shards=N` COMPOSES the two levers (the paper's
    Summit-scale shape): the top-level tree node routes the Table 2
    verbs by task hash to per-shard servers (a ShardedHub behind the
    tree), so the connection bound AND the single-server dispatch bound
    fall together — `rpc_by_op` attributes relay levels as `hop:L<k>`
    and the apex shard fan-out as `hop:L1:s<j>`.

Rendered, example-driven versions of this guidance live in
docs/tuning.md (and the layer map in docs/architecture.md).
"""
from repro.core.engine.backends import (DONE, EMPTY, ServerBackend,
                                        ShardedBackend, TreeBackend)
from repro.core.engine.executor import Engine, EngineReport
from repro.core.engine.faults import FaultPlan
from repro.core.engine.journal import Journal, JournalState
from repro.core.engine.model import (BATCH_FORMED, CANCELLED, COMPLETED,
                                     CREATED, FAILED, READY, REQ_DONE,
                                     REQ_ENQUEUED, REQ_REJECTED, REQ_TIMEOUT,
                                     REQUEUED, RETRIED, RPC, RUN_END,
                                     RUN_START, STOLEN, WORKER_DEAD,
                                     EngineTask, ManualClock, RetryPolicy,
                                     TaskResult, TraceEvent, WorkerCrash)
from repro.core.engine.tracing import (LatencyReport, OverheadReport,
                                       TraceRecorder, crosscheck,
                                       percentile)

__all__ = [
    "Engine", "EngineReport", "EngineTask", "TaskResult", "TraceEvent",
    "TraceRecorder", "OverheadReport", "LatencyReport", "FaultPlan",
    "Journal", "JournalState", "RetryPolicy",
    "ManualClock", "WorkerCrash", "percentile",
    "ServerBackend", "ShardedBackend", "TreeBackend", "crosscheck",
    "DONE", "EMPTY",
    "CREATED", "READY", "STOLEN", "RUN_START", "RUN_END", "COMPLETED",
    "FAILED", "REQUEUED", "RETRIED", "CANCELLED", "WORKER_DEAD", "RPC",
    "REQ_ENQUEUED", "REQ_DONE", "REQ_REJECTED", "REQ_TIMEOUT",
    "BATCH_FORMED",
]
