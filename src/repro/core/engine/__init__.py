"""Unified execution engine: one worker-pool substrate for all three
schedulers (Rogers 2021).

The paper's central claim is that pmake, dwork, and mpi-list "have the
same bottlenecks" and "well-understood per-task overhead".  This subsystem
makes that claim *measurable* in one place instead of three ad-hoc loops:

    model.py     Task / TaskResult / TraceEvent lifecycle data model
                 (created -> ready -> stolen -> running -> completed/
                  failed/requeued), mapped to the paper's Fig. 2 protocol
    backends.py  scheduler state adapters (dwork TaskServer, ShardedHub)
                 speaking the Table 2 verbs; every call timed as an `rpc`
    executor.py  the worker pool: inproc + threaded transports, Steal-n
                 batching, sharded routing, slots/priority (pmake EFT)
    faults.py    heartbeat leases, dead-worker requeue, seeded fault and
                 straggler injection (no wall-clock dependence in tests)
    tracing.py   empirical per-task overhead + METG from event streams,
                 cross-checked against the analytic laws in core/metg.py

Scheduler adapters built on this substrate:
    dwork    `repro.core.dwork.pool.run_pool`  (TaskServer / ShardedHub)
    pmake    `repro.core.pmake.PMake.run`      (slots=nodes, EFT priority)
    mpi-list `repro.core.mpi_list.Context(..., engine_workers=...)`
"""
from repro.core.engine.backends import (DONE, EMPTY, ServerBackend,
                                        ShardedBackend)
from repro.core.engine.executor import Engine, EngineReport
from repro.core.engine.faults import FaultPlan
from repro.core.engine.model import (COMPLETED, CREATED, FAILED, READY,
                                     REQUEUED, RPC, RUN_END, RUN_START,
                                     STOLEN, WORKER_DEAD, EngineTask,
                                     ManualClock, TaskResult, TraceEvent)
from repro.core.engine.tracing import (OverheadReport, TraceRecorder,
                                       crosscheck)

__all__ = [
    "Engine", "EngineReport", "EngineTask", "TaskResult", "TraceEvent",
    "TraceRecorder", "OverheadReport", "FaultPlan", "ManualClock",
    "ServerBackend", "ShardedBackend", "crosscheck", "DONE", "EMPTY",
    "CREATED", "READY", "STOLEN", "RUN_START", "RUN_END", "COMPLETED",
    "FAILED", "REQUEUED", "WORKER_DEAD", "RPC",
]
