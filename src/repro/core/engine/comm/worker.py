"""Worker process entry point: the paper's Fig. 2 client loop over TCP.

    python -m repro.core.engine.comm.worker --connect HOST:PORT [--name W]

Spawned locally by `Engine(transport="proc")`, or run by hand on any
host that can reach the engine's front door (`engine.comm_address`) —
a remote worker joins the pool on connect (`add_worker` semantics: the
Hello handshake registers it, and the engine's supervision loop folds
it into the live set).

Loop shape (identical to `dwork.client.Client.run_loop`, plus the
process-boundary pieces): Hello handshake -> deserialize the shipped
execute callback (if any) -> CompleteSteal(finished, n=steal_n) ->
run each task -> repeat.  Per task: a `meta["__call__"]` payload wins
(a cloudpickled `(fn, args, kwargs)` — `Ref` arguments resolve through
the data plane below), else the shipped execute callback runs
`(name, meta[, worker])`.  Results serialize into the extended
CompleteSteal entry `[name, ok, {...}]`; a result that cannot pickle
reports ok=False with the SerializationError, never a hang.

The peer-to-peer data plane (`_DataPlane`): each worker owns a local
result store served by its own TCP data listener (advertised in Hello
as `data_addr`).  A result above the hub's `inline_bytes` threshold
stays HERE — the CompleteSteal entry carries only its byte count, and
the hub records the location.  A dependent's `Ref` then resolves
cache-first, then a hub Fetch; a `LocMsg` redirect dials the producing
worker's data listener directly (the hub is off the data path), falling
back to the hub when the producer is gone or evicted the value.  The
store is LRU-bounded by `spill_bytes`: evicted owned values are pushed
to the hub with `Spill` (so they outlive this worker), and a clean exit
flushes every still-unspilled owned value the same way.  A value
neither the producer nor the hub can serve is reported with the
`__xfer_lost__:` error prefix — the front door withholds that entry
and the engine recomputes the missing value (zero loss across SIGKILL).

A daemon thread heartbeats every `heartbeat_s` (the transport lock
makes it safe alongside the main loop).  Losing the connection — the
engine died or told us goodbye — exits the process: orphaned workers
reap themselves.  `WorkerCrash` raised by a task body hard-exits
(`os._exit`) to exercise real crash semantics end to end.
"""
from __future__ import annotations

import argparse
import os
import socket
import sys
import threading
import time
from collections import OrderedDict

from repro.core.dwork.api import (XFER_LOST_PREFIX, CompleteSteal, ExitResp,
                                  Fetch, Heartbeat, Hello, LocMsg, NotFound,
                                  Spill, TaskMsg, ValueMsg)
from repro.core.dwork.client import TCPTransport
from repro.core.engine.comm import core as comm_core
from repro.core.engine.comm.serialize import Ref, dumps, loads, loads_call
from repro.core.engine.model import WorkerCrash

CRASH_EXIT_CODE = 17


class _LostDep(Exception):
    """A dependency value is unrecoverable from both its producer and the
    hub (the producer died before replicating it): report the task with
    the `__xfer_lost__:` prefix so the engine recomputes the value."""

    def __init__(self, name: str):
        super().__init__(name)
        self.name = name


class _DataServer:
    """Frame handler for the worker's data listener: peers Fetch stored
    payloads straight from this worker (per-connection threads)."""

    def __init__(self, plane: "_DataPlane"):
        self.plane = plane

    def handle(self, msg):
        if isinstance(msg, Fetch):
            payload = self.plane.get_payload(msg.task)
            if payload is None:
                return NotFound()
            return ValueMsg(task=msg.task, payload=payload)
        return NotFound()


class _DataPlane:
    """Worker-local result store + the Ref resolution chain.

    `store` maps task -> [payload, owned, spilled]: `owned` marks values
    PRODUCED here (the hub points peers at us for them), and the LRU
    byte budget (`spill_bytes`) evicts oldest-first — owned unspilled
    victims are pushed to the hub with `Spill` first, so eviction never
    loses the only copy.  `objs` caches deserialized values for
    same-worker dependents (the fast path that skips every wire)."""

    def __init__(self, transport, *, listen_host: str = "127.0.0.1"):
        self.transport = transport          # control-plane link to the hub
        self.me = ""
        self.inline_bytes = 65536
        self.spill_bytes = 64 * 1024 * 1024
        self.lock = threading.Lock()
        self.store: OrderedDict = OrderedDict()  # task -> [payload, owned,
        self.stored_bytes = 0                    #          spilled]
        self.objs: dict = {}                # task -> deserialized value
        self.peers: dict = {}               # data_addr -> Comm
        try:
            self.listener = comm_core.listen(f"tcp://{listen_host}:0",
                                             _DataServer(self))
        except OSError:
            self.listener = None            # no data plane: hub-only mode

    @property
    def data_addr(self) -> str:
        return self.listener.address if self.listener is not None else ""

    # ------------------------------------------------------------- store
    def get_payload(self, name: str):
        with self.lock:
            ent = self.store.get(name)
            if ent is None:
                return None
            self.store.move_to_end(name)
            return ent[0]

    def cache_obj(self, name: str, value):
        with self.lock:
            self.objs[name] = value

    def put(self, name: str, payload: str, *, owned: bool, value=None,
            have_value: bool = False):
        """Insert a payload, then evict LRU entries past the byte budget
        (spilling owned unspilled victims to the hub — outside the lock,
        Spill is an RPC)."""
        victims = []
        with self.lock:
            if name in self.store:
                self.store.move_to_end(name)
            else:
                self.store[name] = [payload, owned, False]
                self.stored_bytes += len(payload)
            if have_value:
                self.objs[name] = value
            while self.stored_bytes > self.spill_bytes \
                    and len(self.store) > 1:
                old, (pl, own, spilled) = self.store.popitem(last=False)
                self.stored_bytes -= len(pl)
                self.objs.pop(old, None)
                if own and not spilled:
                    victims.append((old, pl))
        for old, pl in victims:
            try:
                self.transport.request(Spill(worker=self.me, task=old,
                                             payload=pl))
            except Exception:  # noqa: BLE001 — hub gone; heartbeat reaps us
                pass

    def flush_spills(self):
        """Clean-exit replication: push every owned, still-unspilled
        value to the hub so dependents (and result materialization)
        outlive this process."""
        with self.lock:
            todo = [(n, e[0]) for n, e in self.store.items()
                    if e[1] and not e[2]]
            for _, e in self.store.items():
                if e[1]:
                    e[2] = True
        for name, payload in todo:
            try:
                self.transport.request(Spill(worker=self.me, task=name,
                                             payload=payload))
            except Exception:  # noqa: BLE001 — already shutting down
                break

    # --------------------------------------------------------- resolution
    def resolve(self, obj, xfers: list):
        """Materialize a `Ref` argument: local caches, then a hub Fetch
        that either answers directly (ValueMsg) or redirects to the
        producing worker's data listener (LocMsg).  Every network fetch
        appends `[path, nbytes, seconds]` to `xfers` (ships in the
        CompleteSteal entry for engine-side attribution)."""
        if not isinstance(obj, Ref):
            return obj
        name = obj.name
        with self.lock:
            if name in self.objs:
                return self.objs[name]
            ent = self.store.get(name)
            payload = ent[0] if ent is not None else None
            if ent is not None:
                self.store.move_to_end(name)
        if payload is not None:
            val = loads(payload)
            self.cache_obj(name, val)
            return val
        t0 = time.perf_counter()
        resp = self.transport.request(Fetch(task=name))
        if isinstance(resp, ValueMsg):
            xfers.append(["hub", len(resp.payload),
                          time.perf_counter() - t0])
            val = loads(resp.payload)
            self.cache_obj(name, val)
            return val
        if isinstance(resp, LocMsg):
            val, ok = self._peer_fetch(name, resp, xfers)
            if ok:
                return val
            raise _LostDep(name)
        raise KeyError(f"dependency value {name!r} unavailable on the hub "
                       "(pruned before this task ran?)")

    def _peer_fetch(self, name: str, loc: LocMsg, xfers: list):
        """The redirect leg: dial the producer's data listener; on any
        failure (producer dead, value evicted) re-Fetch the hub ONCE —
        a Spill or exit flush may have landed meanwhile.  -> (value, ok);
        not-ok means the value is unrecoverable (recompute territory)."""
        resp = None
        if loc.addr:
            t0 = time.perf_counter()
            try:
                comm = self.peers.get(loc.addr)
                if comm is None:
                    comm = comm_core.connect(loc.addr)
                    self.peers[loc.addr] = comm
                resp = comm.request(Fetch(task=name))
            except Exception:  # noqa: BLE001 — producer gone mid-dial
                stale = self.peers.pop(loc.addr, None)
                if stale is not None:
                    try:
                        stale.close()
                    except Exception:  # noqa: BLE001
                        pass
                resp = None
            if isinstance(resp, ValueMsg):
                xfers.append(["peer", len(resp.payload),
                              time.perf_counter() - t0])
                val = loads(resp.payload)
                self.cache_obj(name, val)
                return val, True
        t0 = time.perf_counter()
        try:
            resp = self.transport.request(Fetch(task=name))
        except Exception:  # noqa: BLE001 — hub gone too
            return None, False
        if isinstance(resp, ValueMsg):
            xfers.append(["hub", len(resp.payload),
                          time.perf_counter() - t0])
            val = loads(resp.payload)
            self.cache_obj(name, val)
            return val, True
        return None, False

    def close(self):
        if self.listener is not None:
            try:
                self.listener.stop()
            except Exception:  # noqa: BLE001
                pass
        for comm in self.peers.values():
            try:
                comm.close()
            except Exception:  # noqa: BLE001
                pass
        self.peers.clear()


def _run_task(plane: _DataPlane, execute, pass_worker: bool,
              me: str, name: str, meta) -> list:
    """Execute one stolen task; -> the extended CompleteSteal entry
    [name, ok, info] where info carries "d" (duration), then either
    "v" (inlined value payload, at most inline_bytes) or "n" (payload
    bytes kept in the local store — the hub records the location), plus
    "e" (error), "x" (per-dependency fetch stats), and "as" (store-as
    alias, for engine-driven recompute of a lost value)."""
    t0 = time.perf_counter()
    ok, value, err = True, None, None
    xfers: list = []
    try:
        payload = (meta or {}).get("__call__")
        if payload is not None:
            fn, args, kwargs = loads_call(payload)
            args = tuple(plane.resolve(a, xfers) for a in args)
            kwargs = {k: plane.resolve(v, xfers)
                      for k, v in kwargs.items()}
            value = fn(*args, **kwargs)
        elif execute is not None:
            out = (execute(name, meta, me) if pass_worker
                   else execute(name, meta))
            if isinstance(out, tuple):
                ok, value = bool(out[0]), out[1]
            elif out is None:
                ok = True
            elif isinstance(out, bool):
                ok = out
            else:
                ok, value = True, out
        # neither a packed call nor an executor: a bare named task (the
        # engine's registered-fn convention) completes as a no-op
    except WorkerCrash:
        os._exit(CRASH_EXIT_CODE)     # a crash drill kills the real process
    except _LostDep as e:
        ok, err = False, XFER_LOST_PREFIX + e.name
    except BaseException as e:        # noqa: BLE001 — reported, not fatal
        ok, err = False, repr(e)
    dur = time.perf_counter() - t0
    info: dict = {"d": dur}
    if ok:
        # a None value still ships (and is kept fetchable): a dependent's
        # Ref resolution must distinguish "value is None" from "missing"
        try:
            payload = dumps(value, what=f"result of task {name!r}")
        except Exception as e:        # noqa: BLE001 — SerializationError
            ok = False
            err = repr(e)
        else:
            targets = [name]
            store_as = (meta or {}).get("__store_as__")
            if store_as:
                info["as"] = store_as
                targets.append(store_as)
            if len(payload) > plane.inline_bytes and plane.data_addr:
                info["n"] = len(payload)
                for t in targets:
                    plane.put(t, payload, owned=True, value=value,
                              have_value=True)
            else:
                info["v"] = payload
                for t in targets:
                    plane.cache_obj(t, value)
    if err is not None:
        info["e"] = err
    if xfers:
        info["x"] = xfers
    return [name, ok, info]


def run_worker(host: str, port: int, name: str = "", *,
               idle_sleep: float = 0.002) -> int:
    """Connect, handshake, and run the client loop until the engine says
    Exit (or the connection drops).  Returns tasks executed."""
    transport = TCPTransport(host, port)
    try:
        local_host = transport.sock.getsockname()[0]
    except OSError:
        local_host = "127.0.0.1"
    plane = _DataPlane(transport, listen_host=local_host)
    hello = transport.request(Hello(worker=name, pid=os.getpid(),
                                    host=socket.gethostname(),
                                    data_addr=plane.data_addr))
    me = hello.worker
    plane.me = me
    plane.inline_bytes = max(int(getattr(hello, "inline_bytes", 65536)), 0)
    plane.spill_bytes = max(int(getattr(hello, "spill_bytes",
                                        64 * 1024 * 1024)), 0)
    steal_n = max(int(hello.steal_n), 1)
    execute = loads(hello.execute) if hello.execute else None
    pass_worker = bool(hello.pass_worker)
    hb = max(float(hello.heartbeat_s or 0.5), 0.05)
    stop = threading.Event()

    def _beat():
        while not stop.wait(hb):
            try:
                transport.request(Heartbeat(worker=me))
            except Exception:  # noqa: BLE001 — engine gone: reap ourselves
                os._exit(0)

    threading.Thread(target=_beat, daemon=True,
                     name=f"heartbeat-{me}").start()

    finished: list = []
    done = 0
    while True:
        try:
            resp = transport.request(
                CompleteSteal(worker=me, done=finished, n=steal_n))
        except (ConnectionError, OSError):
            break                     # engine gone: orphan self-reaping
        finished = []
        if isinstance(resp, ExitResp):
            break
        if not isinstance(resp, TaskMsg):
            time.sleep(idle_sleep)
            continue
        for task_name, meta in resp.tasks:
            finished.append(_run_task(plane, execute, pass_worker,
                                      me, task_name, meta))
            done += 1
    stop.set()
    try:
        if finished:                  # flush a final batch (Exit raced it)
            transport.request(CompleteSteal(worker=me, done=finished, n=0))
        # replicate every locally-held owned value before the goodbye:
        # dependents and engine-side materialization outlive this process
        plane.flush_spills()
        plane.close()
        transport.close()
    except Exception:  # noqa: BLE001 — already shutting down
        pass
    return done


def main(argv=None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.core.engine.comm.worker",
        description="Join a listening repro engine as a worker process.")
    parser.add_argument("--connect", required=True, metavar="HOST:PORT",
                        help="the engine front door (Engine.comm_address)")
    parser.add_argument("--name", default="",
                        help="worker id (default: engine-assigned)")
    parser.add_argument("--idle-sleep", type=float, default=0.002,
                        help="sleep between empty steals (s)")
    args = parser.parse_args(argv)
    addr = args.connect
    if addr.startswith("tcp://"):
        addr = addr[len("tcp://"):]
    host, _, port = addr.rpartition(":")
    try:
        run_worker(host or "127.0.0.1", int(port), args.name,
                   idle_sleep=args.idle_sleep)
    except ConnectionError as e:
        print(f"worker: {e}", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
