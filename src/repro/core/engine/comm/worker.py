"""Worker process entry point: the paper's Fig. 2 client loop over TCP.

    python -m repro.core.engine.comm.worker --connect HOST:PORT [--name W]

Spawned locally by `Engine(transport="proc")`, or run by hand on any
host that can reach the engine's front door (`engine.comm_address`) —
a remote worker joins the pool on connect (`add_worker` semantics: the
Hello handshake registers it, and the engine's supervision loop folds
it into the live set).

Loop shape (identical to `dwork.client.Client.run_loop`, plus the
process-boundary pieces): Hello handshake -> deserialize the shipped
execute callback (if any) -> CompleteSteal(finished, n=steal_n) ->
run each task -> repeat.  Per task: a `meta["__call__"]` payload wins
(a cloudpickled `(fn, args, kwargs)` — `Ref` arguments resolve from the
local value cache or a Fetch round-trip), else the shipped execute
callback runs `(name, meta[, worker])`.  Results serialize into the
extended CompleteSteal entry `[name, ok, {"v","e","d"}]`; a result that
cannot pickle reports ok=False with the SerializationError, never a
hang.

A daemon thread heartbeats every `heartbeat_s` (the transport lock
makes it safe alongside the main loop).  Losing the connection — the
engine died or told us goodbye — exits the process: orphaned workers
reap themselves.  `WorkerCrash` raised by a task body hard-exits
(`os._exit`) to exercise real crash semantics end to end.
"""
from __future__ import annotations

import argparse
import os
import socket
import sys
import threading
import time

from repro.core.dwork.api import (CompleteSteal, ExitResp, Fetch, Heartbeat,
                                  Hello, TaskMsg, ValueMsg)
from repro.core.dwork.client import TCPTransport
from repro.core.engine.comm.serialize import (Ref, dumps, loads, loads_call)
from repro.core.engine.model import WorkerCrash

CRASH_EXIT_CODE = 17


def _resolve(transport, cache: dict, obj):
    """Materialize a `Ref` argument: local value cache first (tasks this
    worker completed), then a Fetch round-trip to the front door."""
    if not isinstance(obj, Ref):
        return obj
    name = obj.name
    if name in cache:
        return cache[name]
    resp = transport.request(Fetch(task=name))
    if not isinstance(resp, ValueMsg):
        raise KeyError(f"dependency value {name!r} unavailable on the hub "
                       "(pruned before this task ran?)")
    val = loads(resp.payload)
    cache[name] = val
    return val


def _run_task(transport, cache: dict, execute, pass_worker: bool,
              me: str, name: str, meta) -> list:
    """Execute one stolen task; -> the extended CompleteSteal entry
    [name, ok, {"v": value-payload, "e": error, "d": duration_s}]."""
    t0 = time.perf_counter()
    ok, value, err = True, None, None
    try:
        payload = (meta or {}).get("__call__")
        if payload is not None:
            fn, args, kwargs = loads_call(payload)
            args = tuple(_resolve(transport, cache, a) for a in args)
            kwargs = {k: _resolve(transport, cache, v)
                      for k, v in kwargs.items()}
            value = fn(*args, **kwargs)
        elif execute is not None:
            out = (execute(name, meta, me) if pass_worker
                   else execute(name, meta))
            if isinstance(out, tuple):
                ok, value = bool(out[0]), out[1]
            elif out is None:
                ok = True
            elif isinstance(out, bool):
                ok = out
            else:
                ok, value = True, out
        # neither a packed call nor an executor: a bare named task (the
        # engine's registered-fn convention) completes as a no-op
    except WorkerCrash:
        os._exit(CRASH_EXIT_CODE)     # a crash drill kills the real process
    except BaseException as e:        # noqa: BLE001 — reported, not fatal
        ok, err = False, repr(e)
    dur = time.perf_counter() - t0
    info: dict = {"d": dur}
    if ok:
        # a None value still ships (and is kept fetchable): a dependent's
        # Ref resolution must distinguish "value is None" from "missing"
        try:
            info["v"] = dumps(value, what=f"result of task {name!r}")
            cache[name] = value       # local dependents skip the Fetch
        except Exception as e:        # noqa: BLE001 — SerializationError
            ok = False
            err = repr(e)
    if err is not None:
        info["e"] = err
    return [name, ok, info]


def run_worker(host: str, port: int, name: str = "", *,
               idle_sleep: float = 0.002) -> int:
    """Connect, handshake, and run the client loop until the engine says
    Exit (or the connection drops).  Returns tasks executed."""
    transport = TCPTransport(host, port)
    hello = transport.request(Hello(worker=name, pid=os.getpid(),
                                    host=socket.gethostname()))
    me = hello.worker
    steal_n = max(int(hello.steal_n), 1)
    execute = loads(hello.execute) if hello.execute else None
    pass_worker = bool(hello.pass_worker)
    hb = max(float(hello.heartbeat_s or 0.5), 0.05)
    stop = threading.Event()

    def _beat():
        while not stop.wait(hb):
            try:
                transport.request(Heartbeat(worker=me))
            except Exception:  # noqa: BLE001 — engine gone: reap ourselves
                os._exit(0)

    threading.Thread(target=_beat, daemon=True,
                     name=f"heartbeat-{me}").start()

    cache: dict = {}
    finished: list = []
    done = 0
    while True:
        try:
            resp = transport.request(
                CompleteSteal(worker=me, done=finished, n=steal_n))
        except (ConnectionError, OSError):
            break                     # engine gone: orphan self-reaping
        finished = []
        if isinstance(resp, ExitResp):
            break
        if not isinstance(resp, TaskMsg):
            time.sleep(idle_sleep)
            continue
        for task_name, meta in resp.tasks:
            finished.append(_run_task(transport, cache, execute,
                                      pass_worker, me, task_name, meta))
            done += 1
    stop.set()
    try:
        if finished:                  # flush a final batch (Exit raced it)
            transport.request(CompleteSteal(worker=me, done=finished, n=0))
        transport.close()
    except Exception:  # noqa: BLE001 — already shutting down
        pass
    return done


def main(argv=None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.core.engine.comm.worker",
        description="Join a listening repro engine as a worker process.")
    parser.add_argument("--connect", required=True, metavar="HOST:PORT",
                        help="the engine front door (Engine.comm_address)")
    parser.add_argument("--name", default="",
                        help="worker id (default: engine-assigned)")
    parser.add_argument("--idle-sleep", type=float, default=0.002,
                        help="sleep between empty steals (s)")
    args = parser.parse_args(argv)
    addr = args.connect
    if addr.startswith("tcp://"):
        addr = addr[len("tcp://"):]
    host, _, port = addr.rpartition(":")
    try:
        run_worker(host or "127.0.0.1", int(port), args.name,
                   idle_sleep=args.idle_sleep)
    except ConnectionError as e:
        print(f"worker: {e}", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
