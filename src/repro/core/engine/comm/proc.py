"""`transport="proc"`: real worker processes over the Table-2 frame
protocol — the GIL-escaping transport.

Topology: the engine keeps its normal in-process scheduler state (a
`ServerBackend` or `ShardedBackend` — shards compose), and `ProcBackend`
puts a TCP **front door** in front of it.  Spawned worker processes (or
remote hosts running `python -m repro.core.engine.comm.worker
--connect host:port`) dial the front door and run the paper's Fig. 2
client loop against it: Hello handshake (worker id, steal_n, heartbeat
cadence, optional cloudpickled execute callback), then
CompleteSteal-driven batch-then-drain, with a daemon heartbeat thread
for liveness.

The front door is the translation layer between the process protocol
and the plain Table-2 verbs:

  * CompleteSteal `done` entries arrive EXTENDED — `[name, ok, {"v":
    value-payload, "e": error, "d": duration, "n": nbytes, "x": xfer
    stats, "as": store-as alias}]` — and are stripped to `(name, ok)`
    before reaching the TaskServer (which stays unchanged); the
    payloads/durations/xfer stats are queued as completion records for
    the engine's supervision loop (`Engine._run_proc`) to drain.
  * The data plane lives here too: a result above `inline_bytes` stays
    in its producing worker's local store — the entry carries "n"
    (byte count) instead of "v", and the door records the LOCATION
    (worker + its Hello-advertised data listener).  Fetch answers from
    the value store first, else redirects with a LocMsg; Spill accepts
    a worker's evicted/exit-flushed payload back into the value store.
    A `__xfer_lost__:`-prefixed failure (a dependency value neither its
    producer nor the hub could serve — the producer was SIGKILLed
    before replicating) is WITHHELD from the scheduler (the task stays
    leased) and queued on `lost` for the engine to recompute.
  * Hello / Heartbeat / Fetch / Spill are answered here (join
    registration, liveness touch, dependency-value serving) and never
    forwarded.
  * In resident mode a server-side "all done" (ExitResp) is converted
    to NotFound while the engine is not stopping, so workers idle-poll
    instead of exiting between submission waves.

Liveness is two-layered: locally-spawned processes are watched with
`Popen.poll()` (a SIGKILL surfaces within one supervision round), and
every worker — local or remote — is covered by heartbeat staleness.
Either way the engine announces `Exit` for the dead worker, which
recycles its in-flight assignment with zero loss (duplicate completions
after a requeue are deduplicated engine-side, exactly once per name).
"""
from __future__ import annotations

import atexit
import os
import subprocess
import sys
import threading
import time
from collections import deque
from typing import Callable, Optional

from repro.core.dwork.api import (XFER_LOST_PREFIX, CompleteSteal, ExitResp,
                                  Fetch, Heartbeat, Hello, HelloResp, LocMsg,
                                  NotFound, Spill, TaskMsg, ValueMsg)
from repro.core.engine.comm import core as comm_core
from repro.core.engine.comm.serialize import dumps
from repro.core.engine.model import RPC, STOLEN


class _FrontDoor:
    """The frame handler the TCP listener serves (per-connection
    threads).  Holds the worker directory (pids, heartbeats, joins,
    exits), the completed-value store for Fetch, and the completion
    record queue the engine supervision loop drains."""

    def __init__(self, backend: "ProcBackend"):
        self.backend = backend
        self.lock = threading.Lock()
        # (worker, task, ok, error, duration_s, value_payload, nbytes,
        #  xfers) records
        self.records: deque = deque()
        self.values: dict = {}           # task -> serialized value payload
        self.locations: dict = {}        # task -> (worker, data_addr, nbytes)
        self.data_addrs: dict = {}       # worker -> its data listener addr
        self.early_spills: dict = {}     # Spill that beat its CompleteSteal
        self.lost: deque = deque()       # (worker, task, missing-dep) queue
        self.failed_held: deque = deque()  # (worker, task, err) for retry
        self.pids: dict = {}             # worker -> os pid (0 if unknown)
        self.last_seen: dict = {}        # worker -> monotonic heartbeat
        self.joined: deque = deque()     # workers whose Hello arrived
        self.exited: set = set()         # workers told to exit (clean)
        self.stolen_at: dict = {}        # task -> STOLEN timestamp
        self.requeued = 0                # lease requeues seen at the wire
        self.stopping = False            # resident drain: let DONE through
        self._next_rid = 0               # auto ids for anonymous joins

    def handle(self, msg):
        if isinstance(msg, CompleteSteal):
            return self._complete_steal(msg)
        if isinstance(msg, Heartbeat):
            self.last_seen[msg.worker] = time.monotonic()
            return ExitResp()
        if isinstance(msg, Hello):
            return self._hello(msg)
        if isinstance(msg, Fetch):
            payload = self.values.get(msg.task)
            if payload is not None:
                return ValueMsg(task=msg.task, payload=payload)
            loc = self.locations.get(msg.task)
            if loc is not None:
                w, addr, nbytes = loc
                return LocMsg(task=msg.task, addr=addr, worker=w,
                              nbytes=nbytes)
            return NotFound()
        if isinstance(msg, Spill):
            with self.lock:
                if msg.task in self.locations or msg.task in self.values:
                    self.values.setdefault(msg.task, msg.payload)
                else:
                    # the eviction raced its own CompleteSteal: park the
                    # payload until the location registration consumes it
                    self.early_spills[msg.task] = msg.payload
            return ExitResp()
        # plain Table-2 traffic (multi-host Create, Stats, ...) passes
        # straight through to the scheduler state
        return self.backend.wire_handle(msg)

    def _hello(self, msg: Hello):
        b = self.backend
        w = msg.worker
        if not w:
            with self.lock:
                w = f"r{self._next_rid}"
                self._next_rid += 1
        now = time.monotonic()
        with self.lock:
            self.pids[w] = int(msg.pid or 0)
            self.last_seen[w] = now
            self.exited.discard(w)       # a rejoin under an old id
            self.joined.append(w)
            self.data_addrs[w] = msg.data_addr or ""
        return HelloResp(worker=w, steal_n=b.steal_n, resident=b.resident,
                         pass_worker=b.pass_worker,
                         heartbeat_s=b.heartbeat_s,
                         execute=b.execute_payload,
                         inline_bytes=b.inline_bytes,
                         spill_bytes=b.spill_bytes)

    def _complete_steal(self, msg: CompleteSteal):
        b = self.backend
        w = msg.worker
        self.last_seen[w] = time.monotonic()
        recs = []
        done = []
        lost = []
        held = []
        retry_check = b.retry_check
        for item in msg.done:
            name, ok = item[0], bool(item[1])
            info = item[2] if len(item) > 2 else {}
            err = info.get("e")
            if not ok and err and err.startswith(XFER_LOST_PREFIX):
                # a dependency value is unrecoverable worker-side: withhold
                # the entry (the task stays leased to `w`) and queue it for
                # the engine's recompute-then-Transfer path
                lost.append((w, name, err[len(XFER_LOST_PREFIX):]))
                continue
            if not ok and retry_check is not None \
                    and retry_check(name, err):
                # transient failure the engine's RetryPolicy will absorb:
                # withhold the completion the same way (the task stays
                # leased to `w`) — the engine Transfer-requeues it after
                # the policy's backoff instead of failing it for real
                held.append((w, name, err))
                continue
            done.append((name, ok))
            payload = info.get("v")
            nbytes = int(info.get("n") or 0)
            recs.append((w, name, ok, err, float(info.get("d") or 0.0),
                         payload, nbytes, info.get("x") or None))
            if ok:
                # register the value (or its location) BEFORE the scheduler
                # learns of the completion: a dependent stolen by another
                # worker must never miss a Fetch
                targets = [name]
                alias = info.get("as")
                if alias:
                    targets.append(alias)
                with self.lock:
                    for t in targets:
                        if payload is not None:
                            self.values.setdefault(t, payload)
                        elif nbytes:
                            early = self.early_spills.pop(t, None)
                            if early is not None:
                                self.values.setdefault(t, early)
                            self.locations[t] = (
                                w, self.data_addrs.get(w, ""), nbytes)
        if lost or held:
            with self.lock:
                self.lost.extend(lost)
                self.failed_held.extend(held)
        tracer = b.tracer
        sampled = tracer is not None and msg.n > 0 and tracer.sample_rpc()
        t0 = time.perf_counter() if sampled else 0.0
        # _rq_lock serializes requeue-counter delta reads across handler
        # threads AND the engine's own exit_worker calls, so a lease
        # requeue is attributed exactly once (and never double-counted
        # against an exit requeue the inner backend already recorded)
        with b._rq_lock:
            # a worker the engine already declared gone (crash/lose) gets
            # its completions applied — they really happened — but is
            # NEVER served new work: this handler thread may be the dead
            # worker's last in-flight request arriving after exit_worker
            # requeued its leases (checked under the same lock, so the
            # order is decided, not raced)
            gone = w in self.exited
            before = b.requeued_delta()
            resp = b.wire_handle(CompleteSteal(worker=w, done=done,
                                               n=0 if gone else msg.n))
            rq = b.requeued_delta() - before
        if sampled:
            dt = time.perf_counter() - t0
            tracer.emit(RPC, op="proc:complete_steal", dt=dt)
            m = b.metrics
            if m is not None:
                m.observe("proc:complete_steal", dt)
        if recs or rq:
            with self.lock:
                if recs:
                    self.records.extend(recs)
                self.requeued += rq
        if gone:
            return ExitResp()      # no polling conversion: die, worker
        if isinstance(resp, TaskMsg):
            if tracer is not None:
                stolen_at = self.stolen_at
                for name, _meta in resp.tasks:
                    ev = tracer.emit(STOLEN, task=name, worker=w)
                    stolen_at[name] = ev.t
            return resp
        if isinstance(resp, ExitResp) and msg.n > 0:
            if b.resident and not self.stopping:
                # "all done" while resident just means idle: more work
                # may be submitted, keep the worker polling
                return NotFound()
            self.exited.add(w)
        return resp


class ProcBackend:
    """Process-worker backend: delegates the scheduler protocol to an
    inner `ServerBackend` / `ShardedBackend` and serves the same state
    to worker processes through the front door's TCP listener.

    The engine drives the extra process-pool surface: `prepare()` (ships
    the execute callback — failing fast on an unpicklable one),
    `start_pool`/`spawn`/`kill_worker`/`stop_pool` (local process
    lifecycle, atexit-reaped so no orphans survive the interpreter), and
    the supervision taps `drain_records` / `drain_joined` /
    `drain_requeued` / `check_dead` / `all_done`."""

    def __init__(self, inner, *, host: str = "127.0.0.1", port: int = 0,
                 steal_n: int = 1, resident: bool = False,
                 heartbeat_s: float = 0.5, owns_inner: bool = True,
                 inline_bytes: int = 65536,
                 spill_bytes: int = 64 * 1024 * 1024):
        srv = getattr(inner, "server", None)
        hub = getattr(inner, "hub", None)
        if srv is None and hub is None:
            raise TypeError(
                "transport='proc' wraps a ServerBackend or ShardedBackend; "
                f"got {type(inner).__name__} (tree+proc do not compose — "
                "proc replaces the tree's connection-scaling role)")
        self.inner = inner
        self.owns_inner = owns_inner
        self._wire = srv.handle if srv is not None else hub.handle
        self.steal_n = max(int(steal_n), 1)
        self.resident = bool(resident)
        self.heartbeat_s = max(float(heartbeat_s), 0.05)
        self.inline_bytes = max(int(inline_bytes), 0)
        self.spill_bytes = max(int(spill_bytes), 0)
        self.pass_worker = False
        self.execute_payload: Optional[str] = None
        # engine-installed predicate `(task, err) -> bool`: True means the
        # engine's RetryPolicy will absorb this failure, so the door
        # withholds the completion (see drain_failed); None = no retry
        self.retry_check: Optional[Callable] = None
        self._rq_lock = threading.Lock()
        self.door = _FrontDoor(self)
        self.listener = comm_core.listen(f"tcp://{host}:{port}", self.door)
        self.procs: dict = {}            # worker -> subprocess.Popen
        self._closed = False
        atexit.register(self._kill_all)  # orphan reaping on interpreter exit

    # ------------------------------------------------------------ wire
    def wire_handle(self, msg):
        return self._wire(msg)

    def requeued_delta(self) -> int:
        return self.inner._requeued_total()

    @property
    def address(self) -> str:
        """What `--connect` dials: `tcp://host:port` of the front door."""
        return self.listener.address

    # ----------------------------------------------------- process pool
    def prepare(self, *, execute=None, pass_worker: bool = False,
                steal_n: Optional[int] = None,
                resident: Optional[bool] = None):
        """Stamp the run configuration the Hello handshake hands out.
        Serializing `execute` here fails fast (SerializationError) —
        before any process is spawned."""
        if steal_n is not None:
            self.steal_n = max(int(steal_n), 1)
        if resident is not None:
            self.resident = bool(resident)
        self.pass_worker = bool(pass_worker) and execute is not None
        self.execute_payload = (dumps(execute,
                                      what="the execute callback")
                                if execute is not None else None)

    def spawn(self, worker: str) -> subprocess.Popen:
        import repro

        env = dict(os.environ)
        src = str(os.path.dirname(os.path.dirname(
            os.path.abspath(repro.__file__))))
        pp = env.get("PYTHONPATH")
        env["PYTHONPATH"] = src + (os.pathsep + pp if pp else "")
        host, port = self.listener.host_port
        cmd = [sys.executable, "-m", "repro.core.engine.comm.worker",
               "--connect", f"{host}:{port}", "--name", worker]
        quiet = not os.environ.get("REPRO_PROC_DEBUG")
        proc = subprocess.Popen(
            cmd, env=env,
            stdout=subprocess.DEVNULL if quiet else None,
            stderr=subprocess.DEVNULL if quiet else None,
            start_new_session=True)
        self.procs[worker] = proc
        return proc

    def start_pool(self, workers):
        for w in workers:
            self.spawn(w)

    def kill_worker(self, worker: str):
        """Engine-announced removal (lose_worker): terminate the local
        process; mark it exited so liveness doesn't re-report it."""
        self.door.exited.add(worker)
        p = self.procs.pop(worker, None)
        if p is not None and p.poll() is None:
            p.terminate()

    def stop_pool(self, grace: float = 3.0):
        """Drain-stop every local worker: let the protocol's ExitResp
        reach them (stopping=True), then escalate terminate -> kill."""
        self.door.stopping = True
        deadline = time.monotonic() + grace
        for w, p in list(self.procs.items()):
            if p.poll() is not None:
                continue
            try:
                p.wait(timeout=max(deadline - time.monotonic(), 0.05))
            except subprocess.TimeoutExpired:
                p.terminate()
                try:
                    p.wait(timeout=1.0)
                except subprocess.TimeoutExpired:
                    p.kill()
                    try:
                        p.wait(timeout=1.0)
                    except subprocess.TimeoutExpired:
                        pass
        self.procs.clear()

    def _kill_all(self):
        # atexit safety net: a session that never reached stop_pool()
        # (crash, test abort) must not leave worker processes behind
        for p in list(self.procs.values()):
            if p.poll() is None:
                try:
                    p.kill()
                except OSError:
                    pass

    # -------------------------------------------------- supervision taps
    def connected(self) -> set:
        return set(self.door.pids)

    def worker_pids(self) -> dict:
        """worker -> OS pid, for every process that completed Hello."""
        return dict(self.door.pids)

    def has_records(self) -> bool:
        return bool(self.door.records)

    def drain_records(self) -> list:
        d = self.door
        if not d.records:
            return []
        with d.lock:
            out = list(d.records)
            d.records.clear()
        return out

    def drain_joined(self) -> list:
        d = self.door
        if not d.joined:
            return []
        with d.lock:
            out = list(d.joined)
            d.joined.clear()
        return out

    def drain_requeued(self) -> int:
        d = self.door
        if not d.requeued:
            return 0
        with d.lock:
            n = d.requeued
            d.requeued = 0
        return n

    def drain_lost(self) -> list:
        """-> [(worker, task, missing-dep), ...]: withheld completions
        whose dependency value is unrecoverable (the engine recomputes
        the missing value, then Transfer-requeues the dependent)."""
        d = self.door
        if not d.lost:
            return []
        with d.lock:
            out = list(d.lost)
            d.lost.clear()
        return out

    def drain_failed(self) -> list:
        """-> [(worker, task, err), ...]: failed completions the door
        withheld because `retry_check` approved them (the task is still
        leased to the worker) — the engine applies the policy's backoff
        and Transfer-requeues, or fails them for real."""
        d = self.door
        if not d.failed_held:
            return []
        with d.lock:
            out = list(d.failed_held)
            d.failed_held.clear()
        return out

    def check_dead(self, grace: float) -> list:
        """-> [(worker, reason)]: locally-spawned processes that exited
        without a clean protocol goodbye ("crash"), plus any worker —
        local or remote — whose heartbeat went stale past `grace`
        ("stale").  Each worker is reported at most once."""
        out = []
        door = self.door
        exited = door.exited
        for w, p in list(self.procs.items()):
            if p.poll() is None:
                continue
            del self.procs[w]
            if w in exited:
                continue                  # announced Exit, then exited
            door.last_seen.pop(w, None)
            out.append((w, "crash"))
        if grace > 0:
            now = time.monotonic()
            for w, seen in list(door.last_seen.items()):
                if w in exited or now - seen <= grace:
                    continue
                del door.last_seen[w]
                p = self.procs.pop(w, None)
                if p is not None and p.poll() is None:
                    p.kill()              # fence: wedged, not just slow
                out.append((w, "stale"))
        return out

    def all_done(self) -> bool:
        srv = getattr(self.inner, "server", None)
        if srv is not None:
            with srv.lock:
                return srv._all_done()
        for s in self.inner.hub.shards:
            with s.lock:
                if not s._all_done():
                    return False
        return True

    # ------------------------------------------- backend protocol (inner)
    def create(self, name, deps=(), meta=None):
        return self.inner.create(name, deps=deps, meta=meta)

    def create_many(self, tasks):
        return self.inner.create_many(tasks)

    def steal(self, worker, n=1):
        return self.inner.steal(worker, n)

    def complete(self, worker, name, ok=True):
        return self.inner.complete(worker, name, ok=ok)

    def complete_steal(self, worker, done, n=0):
        return self.inner.complete_steal(worker, done, n)

    def exit_worker(self, worker):
        # exited-marking and lease-requeue are one atomic step under
        # _rq_lock: a front-door handler thread carrying the worker's
        # LAST CompleteSteal serializes against this, so it either steals
        # before (and this requeue reclaims the lease) or observes the
        # worker as gone and is refused — a dead worker can never walk
        # away holding fresh leases (the zombie-steal race)
        with self._rq_lock:
            self.door.exited.add(worker)
            return self.inner.exit_worker(worker)

    def cancel(self, name):
        return self.inner.cancel(name)

    def transfer(self, worker, name, new_deps=()):
        return self.inner.transfer(worker, name, new_deps=new_deps)

    def prune_terminal(self, keep=()):
        n = self.inner.prune_terminal(keep=keep)
        door = self.door
        # mirror the prune into EVERY data-plane store — values,
        # locations, parked early spills — so a pruned session cannot
        # leak payload bytes (sharded inner reports counts, not names,
        # so prune conservatively by the same keep-set contract:
        # single-use names)
        keep = set(keep)
        with door.lock:
            for table in (door.values, door.locations, door.early_spills):
                if not table:
                    continue
                for name in [k for k in table if k not in keep]:
                    del table[name]
        return n

    def errors(self):
        return self.inner.errors()

    def ready_depth(self):
        return self.inner.ready_depth()

    def ready_depths(self):
        return self.inner.ready_depths()

    def stats(self):
        s = self.inner.stats()
        s["proc"] = {"listen": self.address, "workers": self.worker_pids()}
        return s

    def close(self):
        if self._closed:
            return
        self._closed = True
        self.stop_pool()
        self.listener.stop()
        try:
            atexit.unregister(self._kill_all)
        except Exception:  # noqa: BLE001 — interpreter tearing down
            pass
        if self.owns_inner:
            self.inner.close()

    # --------------------------------------- forwarded engine attributes
    @property
    def n_shards(self) -> int:
        return getattr(self.inner, "n_shards", 1)

    def _requeued_total(self) -> int:
        return self.inner._requeued_total()

    @property
    def tracer(self):
        return self.inner.tracer

    @tracer.setter
    def tracer(self, tracer):
        self.inner.tracer = tracer

    @property
    def metrics(self):
        return self.inner.metrics

    @metrics.setter
    def metrics(self, m):
        self.inner.metrics = m

    @property
    def journal(self):
        return self.inner.journal

    @journal.setter
    def journal(self, j):
        self.inner.journal = j
