"""Comm core: the connector/listener abstraction and the transport
registry (in the spirit of dask-distributed's comm layer).

Two registries live here:

  * **Address families** (`register_connector` / `register_listener`):
    `connect("tcp://host:port")` returns a `Comm` (request/response over
    the Table-2 frame protocol), `listen("tcp://host:0", handler)` binds
    a `Listener` that serves frames into any object with a `.handle(msg)`
    method.  The TCP family reuses the dwork frame machinery
    (`TCPServer` / `TCPTransport` — length-prefixed msgpack); "inproc://"
    is the zero-copy loopback.  New families (tls, uds, ...) plug in
    without touching the engine.

  * **Transport families** (`register_transport`): the engine-facing
    names — "inproc", "thread", "tree", "proc" — each owning a backend
    builder.  `Engine(transport=...)` resolves the name here, so the
    executor no longer hard-codes the backend if/else ladder and a new
    execution substrate is one `register_transport` call.

The split mirrors what the engine actually varies: HOW bytes move
(address family) vs WHO executes tasks (transport family).  "proc" is
the one family that uses both: its backend serves a TCP listener that
spawned worker processes (or remote hosts) dial back into.
"""
from __future__ import annotations

import threading
from dataclasses import dataclass
from typing import Callable

from repro.core.dwork.client import TCPServer, TCPTransport

# --------------------------------------------------------------- comms


class Comm:
    """One established channel speaking Table-2 verbs: `request(msg)`
    returns the decoded response message."""

    def request(self, msg):
        raise NotImplementedError

    def close(self):
        raise NotImplementedError


class Connector:
    """Dials an address of one family and returns a `Comm`."""

    scheme = ""

    def connect(self, location: str) -> Comm:
        raise NotImplementedError


class Listener:
    """Serves inbound comms of one family into `handler.handle(msg)`."""

    scheme = ""

    @property
    def address(self) -> str:
        raise NotImplementedError

    def stop(self):
        raise NotImplementedError


class TCPComm(Comm):
    """The dwork frame client as a Comm (locked socket, one in-flight
    request per comm)."""

    def __init__(self, host: str, port: int):
        self._t = TCPTransport(host, port)

    def request(self, msg):
        return self._t.request(msg)

    def close(self):
        self._t.close()


class TCPConnector(Connector):
    scheme = "tcp"

    def connect(self, location: str) -> TCPComm:
        host, _, port = location.rpartition(":")
        return TCPComm(host or "127.0.0.1", int(port))


class TCPListener(Listener):
    """The dwork threaded frame server bound to an arbitrary handler —
    `TCPServer` dispatches every decoded frame to `handler.handle(msg)`
    on a per-connection thread, exactly as it does for a TaskServer."""

    scheme = "tcp"

    def __init__(self, location: str, handler):
        host, _, port = location.rpartition(":")
        self._srv = TCPServer((host or "127.0.0.1", int(port or 0)), handler)
        self._srv.serve_background()

    @property
    def host_port(self) -> tuple:
        addr = self._srv.server_address
        return addr[0], addr[1]

    @property
    def address(self) -> str:
        host, port = self.host_port
        return f"tcp://{host}:{port}"

    def stop(self):
        self._srv.shutdown()
        self._srv.server_close()


class InProcComm(Comm):
    def __init__(self, handler):
        self._handler = handler

    def request(self, msg):
        return self._handler.handle(msg)

    def close(self):
        pass


class InProcListener(Listener):
    """Loopback listener: connectable by name within this process."""

    scheme = "inproc"

    def __init__(self, location: str, handler):
        self._name = location or f"anon-{id(handler):x}"
        with _INPROC_LOCK:
            _INPROC[self._name] = handler

    @property
    def address(self) -> str:
        return f"inproc://{self._name}"

    def stop(self):
        with _INPROC_LOCK:
            _INPROC.pop(self._name, None)


class InProcConnector(Connector):
    scheme = "inproc"

    def connect(self, location: str) -> InProcComm:
        with _INPROC_LOCK:
            handler = _INPROC.get(location)
        if handler is None:
            raise ConnectionError(f"no inproc listener named {location!r}")
        return InProcComm(handler)


_INPROC: dict = {}                  # name -> handler (loopback address table)
_INPROC_LOCK = threading.Lock()

_CONNECTORS: dict = {}
_LISTENERS: dict = {}


def register_connector(scheme: str, connector: Connector):
    _CONNECTORS[scheme] = connector


def register_listener(scheme: str, factory: Callable):
    _LISTENERS[scheme] = factory


def _split(address: str) -> tuple:
    scheme, sep, location = address.partition("://")
    if not sep:
        raise ValueError(f"address {address!r} has no scheme "
                         "(expected e.g. 'tcp://host:port')")
    return scheme, location


def connect(address: str) -> Comm:
    """Dial `address` ("tcp://host:port", "inproc://name")."""
    scheme, location = _split(address)
    conn = _CONNECTORS.get(scheme)
    if conn is None:
        raise ValueError(f"unknown address family {scheme!r}; "
                         f"registered: {sorted(_CONNECTORS)}")
    return conn.connect(location)


def listen(address: str, handler) -> Listener:
    """Bind a listener serving frames into `handler.handle(msg)`."""
    scheme, location = _split(address)
    factory = _LISTENERS.get(scheme)
    if factory is None:
        raise ValueError(f"unknown address family {scheme!r}; "
                         f"registered: {sorted(_LISTENERS)}")
    return factory(location, handler)


register_connector("tcp", TCPConnector())
register_listener("tcp", TCPListener)
register_connector("inproc", InProcConnector())
register_listener("inproc", InProcListener)


# ------------------------------------------------------ transport registry


@dataclass(frozen=True)
class TransportFamily:
    """One engine-facing transport: who executes, and how to build the
    scheduler backend for it.  `make_backend(**kw)` receives the full
    engine kwargs superset and picks what it needs."""

    name: str
    workers: str                    # "inline" | "threads" | "processes"
    description: str
    make_backend: Callable


_FAMILIES: dict = {}


def register_transport(family: TransportFamily):
    _FAMILIES[family.name] = family


def family(name: str) -> TransportFamily:
    fam = _FAMILIES.get(name)
    if fam is None:
        raise ValueError(f"unknown transport {name!r}; "
                         f"registered: {transport_names()}")
    return fam


def transport_names() -> tuple:
    return tuple(_FAMILIES)


def _make_local(*, shards=1, lease_timeout=None, clock=None, tracer=None,
                **_):
    from repro.core.engine.backends import ServerBackend, ShardedBackend

    if shards > 1:
        return ShardedBackend(shards=shards, lease_timeout=lease_timeout,
                              clock=clock, tracer=tracer)
    return ServerBackend(lease_timeout=lease_timeout, clock=clock,
                         tracer=tracer)


def _make_tree(*, workers=1, tree_fanout=4, tree_levels=1, shards=1,
               lease_timeout=None, clock=None, tracer=None, **_):
    from repro.core.engine.backends import TreeBackend

    return TreeBackend(workers=workers, fanout=tree_fanout,
                       levels=tree_levels, shards=shards,
                       lease_timeout=lease_timeout, clock=clock,
                       tracer=tracer)


def _make_proc(*, shards=1, lease_timeout=None, clock=None, tracer=None,
               steal_n=1, resident=False, proc_host="127.0.0.1",
               proc_port=0, heartbeat_s=0.5, inline_bytes=65536,
               spill_bytes=64 * 1024 * 1024, **_):
    from repro.core.engine.comm.proc import ProcBackend

    inner = _make_local(shards=shards, lease_timeout=lease_timeout,
                        clock=clock, tracer=tracer)
    return ProcBackend(inner, host=proc_host, port=proc_port,
                       steal_n=steal_n, resident=resident,
                       heartbeat_s=heartbeat_s, inline_bytes=inline_bytes,
                       spill_bytes=spill_bytes)


register_transport(TransportFamily(
    "inproc", "inline",
    "tasks run inline in the dispatch loop (deterministic; tests/METG)",
    _make_local))
register_transport(TransportFamily(
    "thread", "threads",
    "slot-bounded thread pool (blocking task bodies overlap)",
    _make_local))
register_transport(TransportFamily(
    "tree", "inline",
    "inline execution behind a real TCP forwarding tree (paper §4)",
    _make_tree))
register_transport(TransportFamily(
    "proc", "processes",
    "spawned worker processes over TCP frames (GIL-escaping parallelism)",
    _make_proc))
