"""Serialization at the process boundary (`transport="proc"`).

The in-process transports pass callables and values by reference; a
worker process needs them by value.  cloudpickle (pickle fallback)
carries lambdas, closures, and `__main__` functions; payloads are
base64-encoded to str so both wire codecs (msgpack and the JSON
fallback) ship them unchanged inside the Table-2 frames.

The one rule this module enforces: an unpicklable callable or argument
must fail LOUDLY at the submit boundary (`SerializationError`, naming
the task) — never opaquely inside a worker process.
"""
from __future__ import annotations

import base64
import pickle
import threading
from typing import Optional

try:
    import cloudpickle as _pickler
except Exception:  # pragma: no cover — cloudpickle ships with the env
    _pickler = pickle


class SerializationError(TypeError):
    """A callable / argument / result cannot cross the process boundary."""


class Ref:
    """Placeholder for a dependency's value in a serialized call: the
    worker resolves it from its local value cache, a peer fetch from the
    producing worker's data listener, or a Fetch round-trip to the hub
    before invoking the fn."""

    __slots__ = ("name",)

    def __init__(self, name: str):
        self.name = name

    def __repr__(self):
        return f"Ref({self.name!r})"


class RemoteValue:
    """A lazy handle for a result whose payload stayed in its producing
    worker's local store (the peer-to-peer data plane): the engine saw
    only the location, not the bytes.  `get()` materializes on first use
    — hub value store first, then a peer fetch from the producer — and
    caches; `Future.result()` calls it transparently, so code only sees
    a handle when it inspects a `TaskResult.value` directly.  Engine-
    side only: a RemoteValue never crosses the wire."""

    __slots__ = ("task", "nbytes", "_fetch", "_value", "_have", "_lock")

    def __init__(self, task: str, nbytes: int, fetch):
        self.task = task
        self.nbytes = int(nbytes)
        self._fetch = fetch              # engine's materializer (task)->val
        self._value = None
        self._have = False
        self._lock = threading.Lock()

    def get(self):
        """Materialize (and cache) the value; raises KeyError when the
        payload is unrecoverable (producer dead AND never replicated —
        the engine's recompute path prevents this for live sessions)."""
        with self._lock:
            if not self._have:
                self._value = self._fetch(self.task)
                self._have = True
                self._fetch = None       # drop the engine edge once cached
            return self._value

    @property
    def resolved(self) -> bool:
        return self._have

    def __repr__(self):
        state = "cached" if self._have else f"{self.nbytes}B remote"
        return f"RemoteValue({self.task!r}, {state})"


def dumps(obj, *, what: str = "object") -> str:
    """Pickle `obj` to a base64 str, or raise `SerializationError`
    describing `what` failed (and why) instead of a worker-side hang."""
    try:
        return base64.b64encode(_pickler.dumps(obj)).decode("ascii")
    except Exception as e:  # noqa: BLE001 — any pickling failure
        raise SerializationError(
            f"{what} cannot be serialized for transport='proc': {e!r}. "
            "Worker processes receive tasks by value (cloudpickle); "
            "closures over locks/sockets/files cannot cross the process "
            "boundary — pass plain data, or use an in-process transport."
        ) from e


def loads(payload: str):
    return _pickler.loads(base64.b64decode(payload.encode("ascii")))


def dumps_call(fn, args=(), kwargs=None, *, task: Optional[str] = None) -> str:
    """Serialize `(fn, args, kwargs)` for a worker process, naming the
    task in the error so a failed submit points at its cause."""
    label = f"task {task!r}" if task else "submitted call"
    fname = getattr(fn, "__name__", None)
    if fname and fname != "<lambda>":
        label += f" ({fname})"
    return dumps((fn, tuple(args), dict(kwargs or {})), what=label)


def loads_call(payload: str):
    """-> (fn, args, kwargs) as packed by `dumps_call`."""
    return loads(payload)
