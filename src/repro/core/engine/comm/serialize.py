"""Serialization at the process boundary (`transport="proc"`).

The in-process transports pass callables and values by reference; a
worker process needs them by value.  cloudpickle (pickle fallback)
carries lambdas, closures, and `__main__` functions; payloads are
base64-encoded to str so both wire codecs (msgpack and the JSON
fallback) ship them unchanged inside the Table-2 frames.

The one rule this module enforces: an unpicklable callable or argument
must fail LOUDLY at the submit boundary (`SerializationError`, naming
the task) — never opaquely inside a worker process.
"""
from __future__ import annotations

import base64
import pickle
from typing import Optional

try:
    import cloudpickle as _pickler
except Exception:  # pragma: no cover — cloudpickle ships with the env
    _pickler = pickle


class SerializationError(TypeError):
    """A callable / argument / result cannot cross the process boundary."""


class Ref:
    """Placeholder for a dependency's value in a serialized call: the
    worker resolves it from its local value cache or with a Fetch
    round-trip to the hub before invoking the fn."""

    __slots__ = ("name",)

    def __init__(self, name: str):
        self.name = name

    def __repr__(self):
        return f"Ref({self.name!r})"


def dumps(obj, *, what: str = "object") -> str:
    """Pickle `obj` to a base64 str, or raise `SerializationError`
    describing `what` failed (and why) instead of a worker-side hang."""
    try:
        return base64.b64encode(_pickler.dumps(obj)).decode("ascii")
    except Exception as e:  # noqa: BLE001 — any pickling failure
        raise SerializationError(
            f"{what} cannot be serialized for transport='proc': {e!r}. "
            "Worker processes receive tasks by value (cloudpickle); "
            "closures over locks/sockets/files cannot cross the process "
            "boundary — pass plain data, or use an in-process transport."
        ) from e


def loads(payload: str):
    return _pickler.loads(base64.b64decode(payload.encode("ascii")))


def dumps_call(fn, args=(), kwargs=None, *, task: Optional[str] = None) -> str:
    """Serialize `(fn, args, kwargs)` for a worker process, naming the
    task in the error so a failed submit points at its cause."""
    label = f"task {task!r}" if task else "submitted call"
    fname = getattr(fn, "__name__", None)
    if fname and fname != "<lambda>":
        label += f" ({fname})"
    return dumps((fn, tuple(args), dict(kwargs or {})), what=label)


def loads_call(payload: str):
    """-> (fn, args, kwargs) as packed by `dumps_call`."""
    return loads(payload)
