"""Comm subsystem: pluggable connectors/listeners + transport families.

`transport="proc"` lives here: real worker processes speaking the
Table-2 frame protocol over sockets (see `repro.core.engine.comm.proc`),
joinable from other hosts with

    python -m repro.core.engine.comm.worker --connect HOST:PORT
"""
from repro.core.engine.comm.core import (Comm, Connector, Listener,
                                         TransportFamily, connect, family,
                                         listen, register_connector,
                                         register_listener,
                                         register_transport,
                                         transport_names)
from repro.core.engine.comm.serialize import (Ref, SerializationError,
                                              dumps, dumps_call, loads,
                                              loads_call)

__all__ = [
    "Comm", "Connector", "Listener", "TransportFamily",
    "connect", "listen", "family", "transport_names",
    "register_connector", "register_listener", "register_transport",
    "Ref", "SerializationError", "dumps", "dumps_call", "loads",
    "loads_call",
]
