"""Write-ahead journal: durable Table-2 state transitions for the engine.

The unified engine keeps its task universe in memory (TaskServer /
ShardedHub tables); kill the process mid-campaign and every non-pmake
workload loses it.  This module makes the control plane durable the way
databases do — not by snapshotting the whole state on every change, but
by appending each state *transition* to an fsync-batched log and
compacting periodically:

    <dir>/checkpoint.json      compacted state (tmp file + atomic
                               os.replace — the same crash-safe publish
                               idiom as checkpoint/ckpt.py)
    <dir>/wal-<n>.jsonl        append-only segment of records since the
                               checkpoint (JSON lines; a torn final line
                               from a mid-write crash is tolerated)

Record shapes (compact JSON arrays, one per line):

    ["c",  name, [deps...], {meta}]    Create
    ["ok", name]                       Complete(ok=True)
    ["f",  name, error]                Complete(ok=False) / poison
    ["x",  name]                       Cancel
    ["rq", n, via]                     n tasks requeued (exit / lease)

`Journal.replay(dir)` folds checkpoint + segments into a `JournalState`;
`Engine.recover(journal_dir)` uses it to rebuild the task tables —
terminal names seed the exactly-once accounting (they never re-run,
never re-fire `on_result`) and every created-but-not-terminal task is
re-submitted ready, which re-marks leased-but-unfinished work from the
crashed run as stealable (the journal records no leases: an assignment
that never completed is work to redo, exactly like the dwork server's
save/load contract).

Durability granularity is the fsync batch (`sync_every` records, default
64): a crash loses at most the tail of unsynced records, which replays
as "not terminal" and re-runs — at-least-once execution, exactly-once
terminal accounting.  Appends are deduplicated by name (a terminal
record for an already-terminal name, or a duplicate create, writes
nothing), so recovery re-submission is idempotent and the log cannot
grow from replays.

Thread safety: one lock around append/sync/checkpoint.  The engine
journals from its dispatch thread (and `submit()` from client threads in
batch mode), so contention is the same short-hold pattern as the trace
ring.
"""
from __future__ import annotations

import json
import os
import threading
from dataclasses import dataclass, field
from pathlib import Path
from typing import Optional

CHECKPOINT = "checkpoint.json"
SEGMENT_FMT = "wal-{:06d}.jsonl"


@dataclass
class JournalState:
    """Folded journal contents: the recoverable control-plane state."""
    created: dict = field(default_factory=dict)   # name -> (deps, meta)
    completed: set = field(default_factory=set)
    failed: dict = field(default_factory=dict)    # name -> error
    cancelled: set = field(default_factory=set)
    requeues: int = 0
    torn_lines: int = 0          # undecodable tails skipped during replay

    def terminal(self) -> set:
        return self.completed | set(self.failed) | self.cancelled

    def pending(self) -> list:
        """(name, deps, meta) for every created-but-not-terminal task,
        in original creation order (producers before dependents — the
        order submissions arrived in)."""
        term = self.terminal()
        return [(n, deps, meta) for n, (deps, meta) in self.created.items()
                if n not in term]

    def summary(self) -> dict:
        return {
            "created": len(self.created), "completed": len(self.completed),
            "failed": len(self.failed), "cancelled": len(self.cancelled),
            "pending": len(self.pending()), "requeues": self.requeues,
            "torn_lines": self.torn_lines,
        }


def _apply(state: JournalState, rec: list):
    kind = rec[0]
    if kind == "c":
        state.created.setdefault(rec[1], (tuple(rec[2]), rec[3]))
    elif kind == "ok":
        state.completed.add(rec[1])
    elif kind == "f":
        state.failed.setdefault(rec[1], rec[2])
    elif kind == "x":
        state.cancelled.add(rec[1])
    elif kind == "rq":
        state.requeues += int(rec[1])
    # unknown kinds are skipped: a newer writer's records must not brick
    # an older reader's recovery


class Journal:
    """Append-side handle over one journal directory.

        j = Journal(dir)                      # creates or re-opens
        eng = Engine(resident=True, journal=j)

    Opening an existing directory replays it first (seeding the dedup
    state) and continues appending to the latest segment — the handle
    `Engine.recover` re-attaches after a crash.
    """

    def __init__(self, path, *, sync_every: int = 64,
                 checkpoint_every: int = 10000):
        self.dir = Path(path)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.sync_every = max(int(sync_every), 1)
        self.checkpoint_every = max(int(checkpoint_every), 0)
        self.bytes_written = 0        # total appended (obs counter)
        self.n_records = 0
        self.n_syncs = 0
        self.n_checkpoints = 0
        self._lock = threading.Lock()
        self._pending = 0             # appended since the last fsync
        self._since_ckpt = 0          # appended since the last checkpoint
        self._state = self.replay(self.dir)      # dedup + compaction state
        self._seg = self._latest_segment()
        self._fh = open(self.dir / SEGMENT_FMT.format(self._seg), "a",
                        encoding="utf-8")

    # ------------------------------------------------------------- append
    def append_create(self, name: str, deps=(), meta=None):
        with self._lock:
            if name in self._state.created:
                return                       # recovery re-submit: no-op
            deps = tuple(deps)
            meta = dict(meta or {})
            self._state.created[name] = (deps, meta)
            self._append(["c", name, list(deps), meta])

    def append_terminal(self, name: str, ok: bool,
                        error: Optional[str] = None):
        with self._lock:
            st = self._state
            if name in st.completed or name in st.failed \
                    or name in st.cancelled:
                return                       # terminal is exactly-once
            if ok:
                st.completed.add(name)
                self._append(["ok", name])
            else:
                st.failed[name] = error
                self._append(["f", name, error])

    def append_cancel(self, name: str):
        with self._lock:
            st = self._state
            if name in st.completed or name in st.failed \
                    or name in st.cancelled:
                return
            st.cancelled.add(name)
            self._append(["x", name])

    def append_requeue(self, n: int, via: str):
        with self._lock:
            self._state.requeues += int(n)
            self._append(["rq", int(n), via])

    def _append(self, rec: list):
        # caller holds the lock
        line = json.dumps(rec, separators=(",", ":")) + "\n"
        self._fh.write(line)
        self.bytes_written += len(line)
        self.n_records += 1
        self._pending += 1
        self._since_ckpt += 1
        if self.checkpoint_every and self._since_ckpt >= self.checkpoint_every:
            self._checkpoint_locked()
        elif self._pending >= self.sync_every:
            self._sync_locked()

    # ------------------------------------------------------------ durable
    def sync(self):
        """Flush + fsync everything appended so far (the engine calls
        this at drain/shutdown so a clean stop is fully durable)."""
        with self._lock:
            self._sync_locked()

    def _sync_locked(self):
        if self._pending == 0:
            return
        self._fh.flush()
        os.fsync(self._fh.fileno())
        self._pending = 0
        self.n_syncs += 1

    def checkpoint(self):
        """Compact: publish the folded state as checkpoint.json (tmp file
        + atomic rename), rotate to a fresh WAL segment, delete the
        superseded ones.  Terminal tasks keep only their name/error — the
        create records they accumulated are dropped, which is the
        compaction."""
        with self._lock:
            self._checkpoint_locked()

    def _checkpoint_locked(self):
        self._sync_locked()
        st = self._state
        next_seg = self._seg + 1
        doc = {
            "seg": next_seg,
            "created": [[n, list(deps), meta]
                        for n, (deps, meta) in st.created.items()
                        if n not in st.completed and n not in st.failed
                        and n not in st.cancelled],
            "completed": sorted(st.completed),
            "failed": dict(st.failed),
            "cancelled": sorted(st.cancelled),
            "requeues": st.requeues,
        }
        tmp = self.dir / (CHECKPOINT + ".tmp")
        with open(tmp, "w", encoding="utf-8") as fh:
            json.dump(doc, fh, separators=(",", ":"))
            fh.flush()
            os.fsync(fh.fileno())
        os.replace(tmp, self.dir / CHECKPOINT)   # atomic publish
        # compact in memory too: the dropped create records are exactly
        # the ones the published checkpoint no longer carries
        for n in list(st.created):
            if n in st.completed or n in st.failed or n in st.cancelled:
                del st.created[n]
        self._fh.close()
        old_seg, self._seg = self._seg, next_seg
        self._fh = open(self.dir / SEGMENT_FMT.format(next_seg), "a",
                        encoding="utf-8")
        for p in self.dir.glob("wal-*.jsonl"):
            try:
                if int(p.stem.split("-")[1]) <= old_seg:
                    p.unlink()
            except (ValueError, OSError):
                pass
        self._since_ckpt = 0
        self.n_checkpoints += 1

    def close(self):
        with self._lock:
            if self._fh.closed:
                return
            self._sync_locked()
            self._fh.close()

    # ------------------------------------------------------------- replay
    def _latest_segment(self) -> int:
        segs = []
        for p in self.dir.glob("wal-*.jsonl"):
            try:
                segs.append(int(p.stem.split("-")[1]))
            except ValueError:
                pass
        if segs:
            return max(segs)
        ckpt = self.dir / CHECKPOINT
        if ckpt.exists():
            try:
                return int(json.loads(ckpt.read_text()).get("seg", 0))
            except (ValueError, OSError):
                pass
        return 0

    @staticmethod
    def replay(path) -> JournalState:
        """Fold checkpoint + WAL segments into a `JournalState`.  Missing
        files mean an empty journal; an undecodable line (a torn tail
        from a mid-write crash) ends that segment's replay and is
        counted in `torn_lines`."""
        d = Path(path)
        state = JournalState()
        first_seg = 0
        ckpt = d / CHECKPOINT
        if ckpt.exists():
            doc = json.loads(ckpt.read_text())
            first_seg = int(doc.get("seg", 0))
            for n, deps, meta in doc.get("created", []):
                state.created[n] = (tuple(deps), meta)
            state.completed.update(doc.get("completed", []))
            state.failed.update(doc.get("failed", {}))
            state.cancelled.update(doc.get("cancelled", []))
            state.requeues = int(doc.get("requeues", 0))
        segs = []
        for p in d.glob("wal-*.jsonl"):
            try:
                n = int(p.stem.split("-")[1])
            except ValueError:
                continue
            if n >= first_seg:
                segs.append((n, p))
        for _, p in sorted(segs):
            with open(p, encoding="utf-8") as fh:
                for line in fh:
                    line = line.strip()
                    if not line:
                        continue
                    try:
                        rec = json.loads(line)
                    except ValueError:
                        state.torn_lines += 1
                        break        # a torn line ends the segment
                    _apply(state, rec)
        return state

    # ---------------------------------------------------------------- obs
    def stats(self) -> dict:
        with self._lock:
            return {
                "dir": str(self.dir), "segment": self._seg,
                "bytes_written": self.bytes_written,
                "n_records": self.n_records, "n_syncs": self.n_syncs,
                "n_checkpoints": self.n_checkpoints,
                **self._state.summary(),
            }

    def __repr__(self):
        return (f"Journal({str(self.dir)!r}, seg={self._seg}, "
                f"records={self.n_records}, bytes={self.bytes_written})")
