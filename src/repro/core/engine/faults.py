"""Deterministic fault and straggler injection for the engine.

All randomness is derived from a seed plus stable task/worker names, so a
given `FaultPlan` produces the same faults regardless of execution order or
wall clock — the property the fault-tolerance tests rely on.

Three fault families (mapped to the paper's failure modes):

  * worker death   — `kill_worker(w, after_steals=k)`: the worker dies once
                     it has stolen >= k tasks.  Announced deaths send
                     `Exit(worker)` (paper: node failure recycles its
                     assignment to the FRONT of the queue); silent deaths
                     send nothing and rely on heartbeat-lease expiry
                     (`TaskServer(lease_timeout=..., clock=ManualClock())`).
  * task failure   — `fail_task(name)` / `fail_rate(p)`: the task reports
                     Complete(ok=False) and poisons transitive successors.
                     `fail_first_k(k)` makes failures *transient*: only the
                     first k execution attempts of each affected task fail,
                     so a `RetryPolicy(max_attempts > k)` deterministically
                     recovers — the retry paths' test harness.
  * stragglers     — `stragglers(sigma)`: per-(task, worker) Gaussian
                     *virtual* delay, recorded in the trace but never slept.
                     Feeds the mpi-list Gumbel sync-gap model
                     (`METGModel.mpilist_metg(P, per_rank_sigma=sigma)`).
"""
from __future__ import annotations

import random
from typing import Optional


class FaultPlan:
    def __init__(self, seed: int = 0):
        self.seed = seed
        self._kills: dict[str, int] = {}       # worker -> after_steals
        self._silent: set[str] = set()
        self._fail: set[str] = set()
        self._fail_rate: float = 0.0
        self._sigma: float = 0.0
        self._first_k: int = 0                 # transient: fail attempts < k
        self._first_k_rate: float = 1.0
        self._first_k_tasks: Optional[set] = None

    # -------------------------------------------------------- configure
    def kill_worker(self, worker: str, after_steals: int = 1,
                    silent: bool = False) -> "FaultPlan":
        self._kills[worker] = after_steals
        if silent:
            self._silent.add(worker)
        return self

    def fail_task(self, name: str) -> "FaultPlan":
        self._fail.add(name)
        return self

    def fail_rate(self, p: float) -> "FaultPlan":
        self._fail_rate = p
        return self

    def fail_first_k(self, k: int, rate: float = 1.0,
                     tasks: Optional[list] = None) -> "FaultPlan":
        """Transient failures: each affected task's first `k` execution
        attempts fail, then it succeeds.  `rate` < 1 selects the affected
        subset by seeded draw (keyed by task name); `tasks` restricts
        injection to an explicit set.  Pairs with `RetryPolicy`: with
        `max_attempts > k` the workload deterministically completes, with
        `max_attempts <= k` the affected tasks deterministically poison."""
        self._first_k = int(k)
        self._first_k_rate = float(rate)
        self._first_k_tasks = set(tasks) if tasks is not None else None
        return self

    def stragglers(self, sigma: float) -> "FaultPlan":
        self._sigma = sigma
        return self

    # ------------------------------------------------------ engine hooks
    def _rng(self, *key) -> random.Random:
        return random.Random(f"{self.seed}:" + ":".join(map(str, key)))

    def should_die(self, worker: str, stolen_so_far: int) -> bool:
        k = self._kills.get(worker)
        return k is not None and stolen_so_far >= k

    def dies_silently(self, worker: str) -> bool:
        return worker in self._silent

    def force_fail(self, task: str, worker: Optional[str] = None,
                   attempt: int = 0) -> bool:
        """Should this execution of `task` fail?  `attempt` is how many
        times the task has already run (0 on first execution) — the
        engine's retry machinery threads it through so `fail_first_k`
        injection stops once a task has burned its transient budget."""
        if task in self._fail:
            return True
        if self._fail_rate > 0.0 \
                and self._rng("fail", task).random() < self._fail_rate:
            return True
        if self._first_k > 0 and attempt < self._first_k:
            if self._first_k_tasks is not None:
                return task in self._first_k_tasks
            if self._first_k_rate >= 1.0:
                return True
            return self._rng("first_k", task).random() < self._first_k_rate
        return False

    def delay_s(self, task: str, worker: Optional[str] = None) -> float:
        """Virtual straggler jitter for this task (seconds; may be
        negative — it's jitter about the mean, and only max-min gaps
        matter for the Gumbel sync-gap law).  Keyed by task name only, so
        the draw is independent of which worker runs it or in what
        order."""
        if self._sigma <= 0.0:
            return 0.0
        return self._rng("straggle", task).gauss(0.0, self._sigma)
