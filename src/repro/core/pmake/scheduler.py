"""pmake scheduler/executor: greedy highest-priority-first onto free nodes.

Scripts are generated as `rulename.n.sh` (set -e; cd dirname; setup;
script), executed with popen, logged to `rulename.n.log`.  {mpirun} expands
per the ambient batch scheduler (Slurm srun / LSF jsrun / local fallback),
as in the paper.  Completed outputs are trusted (file-sync restart);
non-zero exit poisons transitive successors.
"""
from __future__ import annotations

import os
import subprocess
import threading
import time
from pathlib import Path
from typing import Callable, Optional

from repro.core.pmake.graph import Task, build_graph
from repro.core.pmake.rules import parse_rules, parse_targets, staged_format


def detect_mpirun(resources) -> str:
    n = resources.ranks * resources.nrs
    if os.environ.get("SLURM_JOB_ID"):
        return f"srun -n {n}"
    if os.environ.get("LSB_JOBID"):
        return (f"jsrun -n {resources.nrs} -a {resources.ranks} "
                f"-c {resources.cpu} -g {resources.gpu}")
    return ""        # local: run the program directly


class PMake:
    def __init__(self, rules_text: str, targets_text: str, *, root: str = ".",
                 total_nodes: int = 1, poll: float = 0.02,
                 runner: Optional[Callable] = None):
        self.root = Path(root)
        self.rules = parse_rules(rules_text)
        self.targets = parse_targets(targets_text)
        self.tasks = build_graph(self.rules, self.targets, root=str(root))
        self.total_nodes = total_nodes
        self.poll = poll
        self.runner = runner          # override for tests/simulation
        self.log: list[dict] = []     # schedule trace
        self.errors: set[str] = set()

    # ------------------------------------------------------------------
    def render_script(self, task: Task) -> str:
        ctx = dict(task.ctx)
        ctx["mpirun"] = detect_mpirun(task.rule.resources)
        setup = staged_format(task.rule.setup, ctx)
        body = staged_format(task.rule.script, ctx)
        return (f"set -e\ncd {self.root / task.dirname}\n"
                f"{setup}\n{body}\n")

    def _run_task(self, task: Task) -> bool:
        sdir = self.root / task.dirname
        sdir.mkdir(parents=True, exist_ok=True)
        name = task.script_name()
        script_path = sdir / f"{name}.sh"
        log_path = sdir / f"{name}.log"
        script_path.write_text(self.render_script(task))
        if self.runner is not None:
            return bool(self.runner(task))
        with open(log_path, "w") as logf:
            proc = subprocess.Popen(["sh", str(script_path)], stdout=logf,
                                    stderr=subprocess.STDOUT)
            rc = proc.wait()
        if rc != 0:
            return False
        missing = [o for o in task.outputs
                   if not (sdir / o).exists()]
        if missing:
            raise FileNotFoundError(
                f"rule {task.rule.name} exited 0 but outputs missing: "
                f"{missing}")
        return True

    # ------------------------------------------------------------------
    def run(self) -> dict:
        """Greedy EFT loop; returns summary stats."""
        done: set[str] = set()
        running: dict[str, threading.Thread] = {}
        results: dict[str, bool] = {}
        free = self.total_nodes
        t0 = time.perf_counter()

        def outputs_exist(t: Task) -> bool:
            return all((self.root / t.dirname / o).exists() for o in t.outputs)

        # file-based restart: pre-complete tasks whose outputs exist
        for k, t in list(self.tasks.items()):
            if t.outputs and outputs_exist(t):
                done.add(k)

        def runnable():
            for k, t in self.tasks.items():
                if (k in done or k in running or k in self.errors
                        or not t.deps <= done):
                    continue
                if any(d in self.errors for d in t.deps):
                    continue
                yield t

        def poison(key: str):
            stack = [key]
            while stack:
                cur = stack.pop()
                if cur in self.errors:
                    continue
                self.errors.add(cur)
                stack.extend(self.tasks[cur].succs)

        while len(done) + len(self.errors & set(self.tasks)) < len(self.tasks):
            # launch as many as fit, highest priority first
            cands = sorted(runnable(), key=lambda t: -t.priority)
            for t in cands:
                need = min(t.rule.resources.nrs, self.total_nodes)
                if need > free:
                    continue
                free -= need

                def work(task=t, need=need):
                    ok = False
                    try:
                        ok = self._run_task(task)
                    finally:
                        results[task.key] = ok

                th = threading.Thread(target=work, daemon=True)
                running[t.key] = th
                self.log.append({"task": t.key, "event": "start",
                                 "t": time.perf_counter() - t0,
                                 "priority": t.priority, "nodes": need})
                th.start()
            # reap
            for k in list(running):
                if k in results:
                    running.pop(k).join()
                    free += min(self.tasks[k].rule.resources.nrs,
                                self.total_nodes)
                    if results[k]:
                        done.add(k)
                    else:
                        poison(k)
                    self.log.append({"task": k, "event": "done",
                                     "ok": results[k],
                                     "t": time.perf_counter() - t0})
            if not running and not any(True for _ in runnable()):
                break
            time.sleep(self.poll)

        return {"tasks": len(self.tasks), "done": len(done),
                "errors": len(self.errors),
                "wall_s": time.perf_counter() - t0}
