"""pmake scheduler/executor: greedy highest-priority-first onto free nodes.

Scripts are generated as `rulename.n.sh` (set -e; cd dirname; setup;
script), executed with popen, logged to `rulename.n.log`.  {mpirun} expands
per the ambient batch scheduler (Slurm srun / LSF jsrun / local fallback),
as in the paper.  Completed outputs are trusted (file-sync restart);
non-zero exit poisons transitive successors.

Execution runs on the unified engine pool (`repro.core.engine`): tasks
carry `slots` (= nrs nodes, clamped to the allocation) and the EFT
priority; the engine's launch step is exactly the paper's "greedy
highest-priority-first onto free nodes", and its trace provides the
empirical per-task launch overhead that the METG jsrun law models.
"""
from __future__ import annotations

import os
import subprocess
import time
from pathlib import Path
from typing import Callable, Optional

from repro.core.pmake.graph import Task, build_graph
from repro.core.pmake.rules import parse_rules, parse_targets, staged_format


def detect_mpirun(resources) -> str:
    n = resources.ranks * resources.nrs
    if os.environ.get("SLURM_JOB_ID"):
        return f"srun -n {n}"
    if os.environ.get("LSB_JOBID"):
        return (f"jsrun -n {resources.nrs} -a {resources.ranks} "
                f"-c {resources.cpu} -g {resources.gpu}")
    return ""        # local: run the program directly


class PMake:
    def __init__(self, rules_text: str, targets_text: str, *, root: str = ".",
                 total_nodes: int = 1, poll: float = 0.02,
                 runner: Optional[Callable] = None, transport: str = "thread",
                 tracer=None, faults=None):
        self.root = Path(root)
        self.rules = parse_rules(rules_text)
        self.targets = parse_targets(targets_text)
        self.tasks = build_graph(self.rules, self.targets, root=str(root))
        self.total_nodes = total_nodes
        self.poll = poll
        self.runner = runner          # override for tests/simulation
        self.transport = transport    # engine transport ("thread"/"inproc")
        self.tracer = tracer          # optional engine TraceRecorder
        self.faults = faults          # optional engine FaultPlan
        self.report = None            # EngineReport of the last run()
        self.futures = {}             # task key -> client Future (last run)
        self.log: list[dict] = []     # schedule trace
        self.errors: set[str] = set()

    # ------------------------------------------------------------------
    def render_script(self, task: Task) -> str:
        ctx = dict(task.ctx)
        ctx["mpirun"] = detect_mpirun(task.rule.resources)
        setup = staged_format(task.rule.setup, ctx)
        body = staged_format(task.rule.script, ctx)
        return (f"set -e\ncd {self.root / task.dirname}\n"
                f"{setup}\n{body}\n")

    def _run_task(self, task: Task) -> bool:
        sdir = self.root / task.dirname
        sdir.mkdir(parents=True, exist_ok=True)
        name = task.script_name()
        script_path = sdir / f"{name}.sh"
        log_path = sdir / f"{name}.log"
        script_path.write_text(self.render_script(task))
        if self.runner is not None:
            return bool(self.runner(task))
        with open(log_path, "w") as logf:
            proc = subprocess.Popen(["sh", str(script_path)], stdout=logf,
                                    stderr=subprocess.STDOUT)
            rc = proc.wait()
        if rc != 0:
            return False
        missing = [o for o in task.outputs
                   if not (sdir / o).exists()]
        if missing:
            raise FileNotFoundError(
                f"rule {task.rule.name} exited 0 but outputs missing: "
                f"{missing}")
        return True

    # ------------------------------------------------------------------
    def run(self) -> dict:
        """Greedy EFT run through the futures client (batch mode); returns
        summary stats.

        The engine's launch step (sort stolen tasks by priority, fill free
        slots) replaces the old popen polling loop; `slots` carries the
        clamped node count so node-limited allocations serialize exactly
        as before, and failures poison transitive successors server-side.
        This method is a shim over `repro.client.Client` — the same front
        door the dynamic futures API uses.
        """
        # lazy import: repro.client imports engine modules that import
        # pmake's siblings, so a module-scope import would cycle
        from repro.client import Client

        done: set[str] = set()
        t0 = time.perf_counter()

        def outputs_exist(t: Task) -> bool:
            return all((self.root / t.dirname / o).exists() for o in t.outputs)

        # file-based restart: pre-complete tasks whose outputs exist
        for k, t in list(self.tasks.items()):
            if t.outputs and outputs_exist(t):
                done.add(k)

        # steal window = the whole task set: the launch step then sorts
        # every ready task by EFT priority, reproducing the old loop's
        # global "greedy highest-priority-first onto free nodes" (a narrow
        # window would only prioritize within each stolen batch)
        client = Client(
            scheduler="pmake", workers=self.total_nodes,
            transport=self.transport, steal_n=max(4, len(self.tasks)),
            poll=self.poll, tracer=self.tracer, faults=self.faults,
            resident=False,
            executor=lambda name, meta: self._run_task(self.tasks[name]))
        # submit in dependency (topological) order: the task server
        # forward-declares unknown deps as READY stubs and ignores a later
        # duplicate Create, so a dependent submitted before its producer
        # would silently drop the producer's own dependency edges
        order, seen = [], set()
        for root_key in self.tasks:
            if root_key in seen:
                continue
            seen.add(root_key)
            stack = [(root_key, iter(sorted(self.tasks[root_key].deps)))]
            while stack:
                key, deps_it = stack[-1]
                for d in deps_it:
                    if d in self.tasks and d not in seen:
                        seen.add(d)
                        stack.append((d, iter(sorted(self.tasks[d].deps))))
                        break
                else:
                    order.append(key)
                    stack.pop()
        self.futures = {}
        for k in order:
            t = self.tasks[k]
            if k in done:
                continue
            self.futures[k] = client.submit_task(
                k, deps=[d for d in t.deps if d not in done],
                priority=t.priority,
                slots=min(t.rule.resources.nrs, self.total_nodes),
                meta={"rule": t.rule.name})
        try:
            report = client.run()
        finally:
            client.close()
        self.report = report

        for name, res in report.results.items():
            if res.ok:
                done.add(name)
        self.errors |= report.errors
        # legacy schedule trace: start/done records interleaved in
        # wall-clock order, as the old polling loop emitted them
        records = []
        for name, res in report.results.items():
            t = self.tasks[name]
            records.append({"task": name, "event": "start",
                            "t": res.t_start - t0, "priority": t.priority,
                            "nodes": min(t.rule.resources.nrs,
                                         self.total_nodes)})
            records.append({"task": name, "event": "done", "ok": res.ok,
                            "t": res.t_end - t0})
        self.log.extend(sorted(records, key=lambda r: r["t"]))

        return {"tasks": len(self.tasks), "done": len(done),
                "errors": len(self.errors),
                "wall_s": time.perf_counter() - t0}
