"""rules.yaml / targets.yaml parsing + template machinery (paper Fig. 1).

Substitution uses Python format() semantics, staged in the paper's order:
 i) target members (loop excluded), ii) loop variables, iii) rule members
 (script excluded), iv) the script (which also receives {mpirun}).
Unresolved keys survive each stage (SafeDict), so later stages can fill
them; literal braces must be escaped ({{ }}), as in the paper.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Any, Optional

import yaml


class SafeDict(dict):
    def __missing__(self, key):
        return "{" + key + "}"


def staged_format(text: str, ctx: dict) -> str:
    try:
        return text.format_map(SafeDict(ctx))
    except (IndexError, KeyError, ValueError):
        return text


@dataclass
class Resources:
    time: float = 10.0            # minutes
    nrs: int = 1                  # resource sets (~nodes)
    cpu: int = 1
    gpu: int = 0
    ranks: int = 1                # MPI ranks per resource set

    @property
    def node_hours(self) -> float:
        return self.time / 60.0 * self.nrs


@dataclass
class Rule:
    name: str
    resources: Resources
    inp: dict = field(default_factory=dict)     # key -> filename template
    out: dict = field(default_factory=dict)
    setup: str = ""
    script: str = ""
    loop: dict = field(default_factory=dict)    # var -> python iterable expr

    def template_var(self) -> Optional[str]:
        """The single allowed template variable, from the out section."""
        for t in self.out.values():
            m = re.findall(r"\{(\w+)(?:\[[^]]*\])?\}", t)
            for v in m:
                if v not in ("inp", "out", "mpirun"):
                    return v
        return None


@dataclass
class Target:
    name: str
    dirname: str = "."
    out: dict = field(default_factory=dict)
    tgt: dict = field(default_factory=dict)
    loop: dict = field(default_factory=dict)
    attrs: dict = field(default_factory=dict)   # arbitrary members


def parse_rules(text: str) -> dict[str, Rule]:
    raw = yaml.safe_load(text) or {}
    rules = {}
    for name, spec in raw.items():
        res = Resources(**(spec.get("resources") or {}))
        rules[name] = Rule(
            name=name, resources=res,
            inp=dict(spec.get("inp") or {}),
            out=dict(spec.get("out") or {}),
            setup=spec.get("setup", "") or "",
            script=spec.get("script", "") or "",
            loop=dict(spec.get("loop") or {}),
        )
    return rules


_RESERVED = {"dirname", "out", "tgt", "loop"}


def parse_targets(text: str) -> dict[str, Target]:
    raw = yaml.safe_load(text) or {}
    targets = {}
    for name, spec in raw.items():
        targets[name] = Target(
            name=name,
            dirname=spec.get("dirname", "."),
            out=dict(spec.get("out") or {}),
            tgt=dict(spec.get("tgt") or {}),
            loop=dict(spec.get("loop") or {}),
            attrs={k: v for k, v in spec.items() if k not in _RESERVED},
        )
    return targets


def expand_loop(loop: dict, ctx: dict) -> list[dict]:
    """loop: {var: "range(1,11)"} -> [{var: 1}, ..., {var: 10}] (cartesian)."""
    combos = [dict()]
    for var, expr in loop.items():
        if isinstance(expr, str):
            vals = list(eval(expr, {"range": range}, dict(ctx)))  # noqa: S307
        else:
            vals = list(expr)
        combos = [dict(c, **{var: v}) for c in combos for v in vals]
    return combos


def template_regex(template: str) -> re.Pattern:
    """Out-template -> regex extracting the template variable."""
    pat = ""
    for piece in re.split(r"(\{\w+\})", template):
        m = re.fullmatch(r"\{(\w+)\}", piece)
        if m:
            pat += f"(?P<{m.group(1)}>.+?)"
        else:
            pat += re.escape(piece)
    return re.compile("^" + pat + "$")


def match_output(rule: Rule, filename: str) -> Optional[dict]:
    """If `filename` matches one of the rule's out templates, return the
    extracted variable bindings (possibly empty)."""
    for t in rule.out.values():
        m = template_regex(t).match(filename)
        if m:
            return m.groupdict()
    return None
