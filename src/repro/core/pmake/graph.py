"""pmake task-graph construction: resolve desired outputs to producing
rules, recursing through inputs until files exist on disk (make semantics:
"stop searching when it finds all the files needed")."""
from __future__ import annotations

import os
from dataclasses import dataclass, field
from pathlib import Path

from repro.core.pmake.rules import (Rule, Target, expand_loop, match_output,
                                    staged_format)


@dataclass
class Task:
    key: str                       # "rule.var@dirname"
    rule: Rule
    dirname: str
    ctx: dict                      # fully staged substitution context
    inputs: list = field(default_factory=list)    # paths (relative dirname)
    outputs: list = field(default_factory=list)
    deps: set = field(default_factory=set)        # task keys
    succs: set = field(default_factory=set)
    priority: float = 0.0

    @property
    def node_hours(self) -> float:
        return self.rule.resources.node_hours

    def script_name(self) -> str:
        var = self.ctx.get("_var")
        return f"{self.rule.name}{'.' + str(var) if var is not None else ''}"


def _format_files(tmpl_dict: dict, ctx: dict) -> dict:
    return {k: staged_format(str(v), ctx) for k, v in tmpl_dict.items()}


def build_graph(rules: dict, targets: dict, root: str = ".") -> dict[str, Task]:
    """Returns task key -> Task with deps/succs wired."""
    tasks: dict[str, Task] = {}
    producers: dict[tuple, str] = {}   # (dirname, filename) -> task key

    def want(filename: str, target: Target, t_ctx: dict):
        """Ensure a task exists that produces `filename` in target.dirname.
        Returns the producing task key, or None if the file pre-exists."""
        path = Path(root) / target.dirname / filename
        key = (target.dirname, filename)
        if key in producers:
            return producers[key]
        if path.exists():
            return None
        for rule in rules.values():
            binding = match_output(rule, filename)
            if binding is None:
                continue
            # paper's substitution order: target attrs, loop vars, rule attrs
            ctx = dict(target.attrs)
            ctx.update(t_ctx)
            ctx.update(binding)
            var = rule.template_var()
            ctx["_var"] = binding.get(var) if var else None
            inp = _format_files(rule.inp, ctx)
            for combo in expand_loop(rule.loop, ctx):
                ctx.update(combo)
            out = _format_files(rule.out, ctx)
            ctx["inp"] = inp
            ctx["out"] = out
            tkey = f"{rule.name}.{ctx['_var']}@{target.dirname}" \
                if ctx["_var"] is not None else f"{rule.name}@{target.dirname}"
            if tkey in tasks:
                producers[key] = tkey
                return tkey
            task = Task(key=tkey, rule=rule, dirname=target.dirname, ctx=ctx,
                        inputs=list(inp.values()), outputs=list(out.values()))
            tasks[tkey] = task
            for o in out.values():
                producers[(target.dirname, o)] = tkey
            # recurse into inputs
            for f in inp.values():
                dep = want(f, target, t_ctx)
                if dep is not None:
                    task.deps.add(dep)
            return tkey
        raise FileNotFoundError(
            f"no rule produces {filename!r} (target {target.name}) and the "
            f"file does not exist at {path}")

    for target in targets.values():
        base_ctx = dict(target.attrs)
        for f in _format_files(target.out, base_ctx).values():
            want(f, target, base_ctx)
        for combo in expand_loop(target.loop, base_ctx):
            ctx = dict(base_ctx, **combo)
            for f in _format_files(target.tgt, ctx).values():
                want(f, target, ctx)

    for t in tasks.values():
        for d in t.deps:
            tasks[d].succs.add(t.key)
    assign_priorities(tasks)
    return tasks


def assign_priorities(tasks: dict[str, Task]):
    """EFT priority (paper §2.1): total node-hours consumed by a task and
    all its transitive successors, computed leaf-to-root."""
    memo: dict[str, float] = {}

    def closure_hours(key: str, depth=0) -> float:
        if key in memo:
            return memo[key]
        if depth > len(tasks) + 1:
            raise ValueError("cycle in pmake task graph")
        t = tasks[key]
        # transitive successor set (not sum-of-subtrees: avoid double count)
        seen: set = set()
        stack = list(t.succs)
        while stack:
            s = stack.pop()
            if s in seen:
                continue
            seen.add(s)
            stack.extend(tasks[s].succs)
        memo[key] = t.node_hours + sum(tasks[s].node_hours for s in seen)
        return memo[key]

    for k, t in tasks.items():
        t.priority = closure_hours(k)
