"""pmake: parallel make-like, file-based workflow scheduler (Rogers 2021, §2.1).

Every task corresponds to output files; rules describe how to build outputs
from inputs.  A single managing process reads `rules.yaml` + `targets.yaml`,
builds the task DAG, assigns an earliest-finish-time priority (total
node-hours of a task plus its transitive successors), and greedily pushes
the highest-priority runnable task onto free nodes via popen'd shell
scripts (`rulename.n.sh` -> `rulename.n.log`).  Existing outputs are never
rebuilt (file-based restart => campaign-level fault tolerance).
"""
from repro.core.pmake.rules import Rule, Target, parse_rules, parse_targets
from repro.core.pmake.graph import Task, build_graph
from repro.core.pmake.scheduler import PMake

__all__ = ["Rule", "Target", "parse_rules", "parse_targets", "Task",
           "build_graph", "PMake"]
