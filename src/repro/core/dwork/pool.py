"""dwork engine adapter: dispatch a TaskServer / ShardedHub through the
unified worker pool.

`run_pool(server, execute, workers=4)` replaces hand-rolled
`Client.run_loop` driver code: the engine's pool speaks the same Steal /
Complete / Exit protocol (Fig. 2) against the given server, with Steal-n
batching, per-worker fault injection, and a lifecycle trace from which
empirical per-task overhead and METG are computed
(`report.overhead().summary()`).

Since the futures redesign this is a thin shim over the batch mode of
`repro.client.Client` — the same front door the dynamic futures API
uses — kept for its task-universe-on-a-server calling convention.
"""
from __future__ import annotations

from typing import Callable, Optional


def run_pool(server, execute: Optional[Callable] = None, *,
             workers: int = 4, steal_n: int = 1, transport: str = "inproc",
             tracer=None, faults=None, clock=None, poll: float = 0.001,
             tree_fanout: int = 4, tree_levels: int = 1, **engine_kw):
    """Run every task on `server` to a terminal state through the engine
    pool.  `server` is a `TaskServer` or a `ShardedHub`;
    `execute(name, meta)` returns bool | (ok, value) | None (success).
    With `transport="tree"` every worker RPC crosses a forwarding tree
    (`tree_fanout` workers per leaf Forwarder, `tree_levels` relay
    layers) in front of the server.  Returns the `EngineReport` (results,
    trace, errors, backend stats)."""
    # lazy import: repro.client imports engine modules that import dwork
    # submodules, so importing at module scope would create a cycle
    from repro.client import Client

    client = Client(scheduler="dwork", workers=workers, steal_n=steal_n,
                    transport=transport, server=server, executor=execute,
                    resident=False, tracer=tracer, faults=faults,
                    clock=clock, poll=poll, tree_fanout=tree_fanout,
                    tree_levels=tree_levels, **engine_kw)
    try:
        return client.run()
    finally:
        client.close()
