"""dwork engine adapter: dispatch a TaskServer / ShardedHub through the
unified worker pool.

`run_pool(server, execute, workers=4)` replaces hand-rolled
`Client.run_loop` driver code: the engine's pool speaks the same Steal /
Complete / Exit protocol (Fig. 2) against the given server, with Steal-n
batching, per-worker fault injection, and a lifecycle trace from which
empirical per-task overhead and METG are computed
(`report.overhead().summary()`).
"""
from __future__ import annotations

from typing import Callable, Optional


def run_pool(server, execute: Optional[Callable] = None, *,
             workers: int = 4, steal_n: int = 1, transport: str = "inproc",
             tracer=None, faults=None, clock=None, poll: float = 0.001,
             tree_fanout: int = 4, tree_levels: int = 1, **engine_kw):
    """Run every task on `server` to a terminal state through the engine
    pool.  `server` is a `TaskServer` or a `ShardedHub`;
    `execute(name, meta)` returns bool | (ok, value) | None (success).
    With `transport="tree"` every worker RPC crosses a forwarding tree
    (`tree_fanout` workers per leaf Forwarder, `tree_levels` relay
    layers) in front of the server.  Returns the `EngineReport` (results,
    trace, errors, backend stats)."""
    # lazy import: repro.core.engine.backends imports dwork submodules,
    # so importing at module scope would create a package-level cycle
    from repro.core.dwork.sharded import ShardedHub
    from repro.core.engine.backends import (ServerBackend, ShardedBackend,
                                            TreeBackend)
    from repro.core.engine.executor import Engine

    if isinstance(server, ShardedHub):
        if transport == "tree":
            raise ValueError("tree transport forwards to a single hub; "
                             "pass a TaskServer")
        backend = ShardedBackend(hub=server, tracer=tracer)
        lease = server.shards[0].lease_timeout if server.shards else None
    elif transport == "tree":
        # the Forwarders capture the tracer at construction, so it must
        # exist BEFORE the tree is built or hop events are silently lost
        from repro.core.engine.tracing import TraceRecorder
        tracer = tracer or TraceRecorder(clock=clock)
        backend = TreeBackend(server=server, workers=workers,
                              fanout=tree_fanout, levels=tree_levels,
                              tracer=tracer)
        lease = server.lease_timeout
    else:
        backend = ServerBackend(server=server, tracer=tracer)
        lease = server.lease_timeout
    # propagate the server's heartbeat lease so the engine's idle budget
    # outlives lease expiry (a silently-dead worker's tasks must be
    # reaped, not abandoned as a premature stall)
    engine_kw.setdefault("lease_timeout", lease)
    eng = Engine(workers=workers, transport=transport, steal_n=steal_n,
                 backend=backend, tracer=tracer, faults=faults, clock=clock,
                 poll=poll, **engine_kw)
    try:
        return eng.run(execute)
    finally:
        if transport == "tree":
            backend.close()     # run_pool owns the tree's sockets/threads
