"""dwork wire API (paper Table 2).

Queries:  Create(task, deps) | Steal(worker, n) | Complete(worker, task)
          | CompleteSteal(worker, done, n) | Transfer(worker, task, new_deps)
          | Exit(worker) | Cancel(task)
Responses: TaskMsg(tasks) | NotFound | ExitResp

`CompleteSteal` is the Fig. 2 batch-then-drain rhythm collapsed into one
round-trip: a worker reports every task it finished since its last call
and (optionally) steals its next batch in the same message, so `steal_n`
amortizes both directions of the protocol.  With `n=0` it degenerates to
a batched Complete.

Workers are strings; tasks are (name, meta-dict) — the protobuf analog.
Serialization is msgpack (JSON fallback) with a one-byte tag.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

try:
    import msgpack

    def _dumps(obj) -> bytes:
        return msgpack.packb(obj, use_bin_type=True)

    def _loads(b: bytes):
        return msgpack.unpackb(b, raw=False)
except Exception:  # pragma: no cover - msgpack is installed offline
    import json

    def _dumps(obj) -> bytes:
        return json.dumps(obj).encode()

    def _loads(b: bytes):
        return json.loads(b.decode())


@dataclass
class Create:
    task: str
    deps: list = field(default_factory=list)
    meta: dict = field(default_factory=dict)
    hold: bool = False        # +1 join count, released via Release (sharding)


@dataclass
class Release:
    task: str


@dataclass
class Steal:
    worker: str
    n: int = 1                      # paper §5: "Steal n" batching


@dataclass
class Complete:
    worker: str
    task: str
    ok: bool = True


@dataclass
class CompleteSteal:
    """Piggyback a batch of completions onto the next steal (one RTT for
    both protocol directions).  `done` is [(task, ok), ...]; `n=0` means
    complete-only (the response is ExitResp, not a steal result)."""
    worker: str
    done: list = field(default_factory=list)
    n: int = 0


@dataclass
class Transfer:
    """Replace a running task back into the queue with NEW dependencies
    (paper: dynamic task graphs; cycles via Transfer are the documented
    user-error deadlock)."""
    worker: str
    task: str
    new_deps: list = field(default_factory=list)


@dataclass
class Cancel:
    """Withdraw a task that no worker holds yet (framework extension for
    the futures client).  Succeeds only while the task is unleased and
    non-terminal; the server then poisons it like a failure so transitive
    successors can never run.  Response: ExitResp on success, NotFound if
    the task is already stolen/terminal/unknown."""
    task: str


@dataclass
class Exit:
    worker: str


@dataclass
class TaskMsg:
    tasks: list                     # [(name, meta), ...]


@dataclass
class NotFound:
    pass


@dataclass
class ExitResp:
    pass


@dataclass
class Stats:
    pass


# ------------------------------------------------- proc-transport verbs
# (`repro.core.engine.comm`): spoken between a worker PROCESS and the
# engine's front door, never by the TaskServer itself — the front door
# strips them (and the extended CompleteSteal `done` entries, which may
# carry a third per-task element {"v": value, "e": error, "d": duration,
# "n": nbytes, "x": xfer stats, "as": store-as alias}) down to the plain
# Table-2 protocol before forwarding.  Results larger than the inline
# threshold stay in the producing worker's local store ("n" instead of
# "v"); the hub tracks their LOCATION and answers Fetch with a LocMsg
# redirect so dependents pull peer-to-peer.  Spill pushes an evicted (or
# exit-flushed) value back to the hub so it survives the producer.

# error prefix a worker uses to report a dependency value it could not
# obtain from either its producer or the hub (producer SIGKILLed before
# replication): the front door intercepts these instead of failing the
# task, and the engine recomputes the missing value
XFER_LOST_PREFIX = "__xfer_lost__:"


@dataclass
class Hello:
    """Worker-process handshake.  An empty `worker` asks the engine to
    assign an id (multi-host join).  `data_addr` advertises the worker's
    peer-fetch listener (`tcp://host:port`; empty = no data plane)."""
    worker: str = ""
    pid: int = 0
    host: str = ""
    data_addr: str = ""


@dataclass
class HelloResp:
    """Handshake reply: the worker's id plus its run configuration —
    steal batch size, heartbeat cadence, data-plane thresholds
    (`inline_bytes`: results at most this many payload bytes inline into
    CompleteSteal; `spill_bytes`: the worker-local store's LRU byte
    budget), and (optionally) the engine's execute callback as a
    cloudpickle payload."""
    worker: str = ""
    steal_n: int = 1
    resident: bool = False
    pass_worker: bool = False
    heartbeat_s: float = 0.5
    execute: Optional[str] = None
    inline_bytes: int = 65536
    spill_bytes: int = 67108864


@dataclass
class Heartbeat:
    """Liveness beacon (response: ExitResp).  A worker whose heartbeats
    go stale past the engine's grace window is declared crashed and its
    in-flight work requeues."""
    worker: str


@dataclass
class Fetch:
    """Ask for a completed task's serialized value (dependency values a
    worker doesn't hold locally).  Response: ValueMsg | NotFound."""
    task: str


@dataclass
class ValueMsg:
    task: str
    payload: str = ""


@dataclass
class LocMsg:
    """Fetch redirect: the hub doesn't hold the value, but knows the
    worker that does — dial `addr` (a worker's data listener) and Fetch
    there.  `nbytes` is the serialized payload size (attribution)."""
    task: str
    addr: str = ""
    worker: str = ""
    nbytes: int = 0


@dataclass
class Spill:
    """Push a locally-stored result's payload to the hub: LRU eviction
    under the worker's byte budget, or the exit flush that replicates
    every still-unspilled owned value before a clean goodbye.  Response:
    ExitResp (accepted) | NotFound (the hub no longer tracks the task —
    pruned; the payload is dropped)."""
    worker: str
    task: str
    payload: str = ""


_TAGS = {"Create": Create, "Steal": Steal, "Complete": Complete,
         "CompleteSteal": CompleteSteal, "Transfer": Transfer, "Exit": Exit,
         "TaskMsg": TaskMsg, "NotFound": NotFound, "ExitResp": ExitResp,
         "Stats": Stats, "Release": Release, "Cancel": Cancel,
         "Hello": Hello, "HelloResp": HelloResp, "Heartbeat": Heartbeat,
         "Fetch": Fetch, "ValueMsg": ValueMsg, "LocMsg": LocMsg,
         "Spill": Spill}


def encode(msg) -> bytes:
    return _dumps([type(msg).__name__, msg.__dict__])


def decode(b: bytes):
    tag, kw = _loads(b)
    if tag == "StatsResp":
        return kw
    return _TAGS[tag](**kw)


def encode_stats(d: dict) -> bytes:
    return _dumps(["StatsResp", d])
