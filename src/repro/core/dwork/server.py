"""dhub: the dwork task server (paper §2.2 + Fig. 2 pseudocode).

State (exactly two tables + derived runtime info, per the paper):
  joins:  task -> [join_counter, successor list]
  meta:   task -> metadata dict
Derived: ready deque (FIFO steals / LIFO re-inserts), assigned map,
completed set, error set (failed tasks poison their transitive successors).

Fault tolerance: `Exit(worker)` recycles that worker's assigned tasks to
the FRONT of the queue; an optional lease timeout re-queues tasks held too
long (straggler mitigation — framework extension, marked as such).
Persistence: save()/load() round-trips the two tables; ready state is
reconstructed on load (paper: "generated from these tables on startup").
"""
from __future__ import annotations

import json
import threading
import time
from collections import deque
from pathlib import Path
from typing import Callable, Optional

from repro.core.dwork.api import (Cancel, Complete, CompleteSteal, Create,
                                  Exit, ExitResp, NotFound, Release, Stats,
                                  Steal, TaskMsg, Transfer)


class TaskServer:
    def __init__(self, *, lease_timeout: Optional[float] = None,
                 clock: Optional[Callable[[], float]] = None):
        self.joins: dict[str, list] = {}      # task -> [join_count, [succ]]
        self.meta: dict[str, dict] = {}
        self.ready: deque[str] = deque()
        self.assigned: dict[str, set] = {}    # worker -> {task}
        self.lease: dict[str, float] = {}     # task -> steal time
        self.requeued_tasks: set[str] = set()  # may have duplicate holders
        self.completed: set[str] = set()
        self.errors: set[str] = set()
        self.lease_timeout = lease_timeout
        # injectable heartbeat clock: the engine's fault layer passes a
        # ManualClock so lease expiry is deterministic in tests
        self.clock = clock or time.monotonic
        self.lock = threading.Lock()
        self.counters = {"created": 0, "stolen": 0, "completed": 0,
                         "requeued": 0, "errors": 0}
        # tasks that reached completed OR errors, counted once: keeps
        # _all_done() O(1) — a resident engine probes it on every empty
        # steal, and a full joins-table scan there is O(history)
        self._n_terminal = 0
        # when set (by ShardedHub) _poison appends newly-poisoned names
        # here, so cross-shard propagation is an incremental worklist
        # instead of an O(error-history) rescan per failure
        self._new_errors: Optional[list] = None

    # ------------------------------------------------------------------ API
    def handle(self, msg):
        with self.lock:
            if isinstance(msg, CompleteSteal):
                return self._complete_steal(msg)
            if isinstance(msg, Steal):
                return self._steal(msg)
            if isinstance(msg, Complete):
                return self._complete(msg)
            if isinstance(msg, Create):
                return self._create(msg)
            if isinstance(msg, Transfer):
                return self._transfer(msg)
            if isinstance(msg, Exit):
                return self._exit(msg)
            if isinstance(msg, Cancel):
                return self._cancel(msg)
            if isinstance(msg, Release):
                return self._release(msg)
            if isinstance(msg, Stats):
                return self.stats()
            raise TypeError(f"unknown message {msg!r}")

    def create_bulk(self, tasks: list):
        """Local driver API (not a wire verb): apply a batch of Creates —
        [(name, deps, meta), ...] — under ONE lock hold.  The resident
        engine's mailbox ingest calls this once per round instead of
        paying the handle() ladder and a lock acquisition per task."""
        with self.lock:
            for name, deps, meta in tasks:
                self._create(Create(task=name, deps=list(deps),
                                    meta=dict(meta or {})))

    def _create(self, msg: Create):
        if msg.task in self.joins:
            return NotFound()                 # duplicate create is a no-op
        if any(d in self.errors for d in msg.deps):
            # a dependency already failed: poison at create time — wiring
            # it up as a live dep would leave a join count that no
            # Complete can ever release (the server poisons successors at
            # failure time, so a dependent created later would dangle)
            self.joins[msg.task] = [0, []]
            self.meta[msg.task] = dict(msg.meta)
            self.counters["created"] += 1
            self._poison(msg.task)
            return ExitResp()
        live_deps = [d for d in msg.deps if d not in self.completed]
        # hold: delegation-as-assignment (paper §6) — an extra join count
        # released by a remote database/worker via Release
        self.joins[msg.task] = [len(live_deps) + (1 if msg.hold else 0), []]
        self.meta[msg.task] = dict(msg.meta)
        for d in live_deps:
            if d not in self.joins:           # forward-declared dependency
                self.joins[d] = [0, []]
                self.meta.setdefault(d, {})
                self.ready.append(d)
            self.joins[d][1].append(msg.task)
        if not live_deps and not msg.hold:
            self.ready.append(msg.task)       # FIFO tail
        self.counters["created"] += 1
        return ExitResp()

    def _steal(self, msg: Steal):
        self._reap_leases()
        out = []
        while self.ready and len(out) < max(1, msg.n):
            t = self.ready.popleft()          # FIFO: oldest ready first
            if t in self.errors or t in self.completed:
                # completed: a stale ready entry left by a late Complete
                # after a lease-timeout requeue — must not be re-executed
                continue
            self.assigned.setdefault(msg.worker, set()).add(t)
            self.lease[t] = self.clock()
            out.append((t, self.meta.get(t, {})))
        if out:
            self.counters["stolen"] += len(out)
            return TaskMsg(tasks=out)
        if self._all_done():
            return ExitResp()                 # paper: respond 'Exit'
        return NotFound()

    def _complete(self, msg: Complete):
        self._finish(msg.worker, msg.task, msg.ok)
        return ExitResp()

    def _finish(self, worker: str, t: str, ok: bool):
        self.assigned.get(worker, set()).discard(t)
        self.lease.pop(t, None)
        if t in self.requeued_tasks:
            # the task was requeued (lease expiry / Exit) so it may have
            # been re-stolen: a terminal task's assignment is stale
            # wherever it lives — clear every holder (exactly-once
            # terminal).  Never-requeued tasks (the hot path) have
            # exactly one holder and skip the all-workers scan.
            self.requeued_tasks.discard(t)
            for held in self.assigned.values():
                held.discard(t)
        if t in self.completed:
            return                            # exactly-once: idempotent
        if not ok:
            self._poison(t)
            return
        self.completed.add(t)
        self.counters["completed"] += 1
        if t not in self.errors:
            self._n_terminal += 1
        for succ in self.joins.get(t, [0, []])[1]:
            j = self.joins.get(succ)
            if j is None:
                # successor pruned while this dep was still live (it was
                # already terminal — poisoned dep-waiting): nothing left
                # to notify
                continue
            j[0] -= 1
            if j[0] == 0 and succ not in self.completed:
                self.ready.append(succ)

    def _complete_steal(self, msg: CompleteSteal):
        """Fig. 2 batch-then-drain in one round-trip: apply the finished
        batch, then serve the next steal — all under one lock hold."""
        for t, ok in msg.done:
            self._finish(msg.worker, t, ok)
        if msg.n <= 0:
            return ExitResp()                 # complete-only
        return self._steal(Steal(worker=msg.worker, n=msg.n))

    def _transfer(self, msg: Transfer):
        """Move a task back from worker to manager, adding dependencies.
        Re-inserted tasks go to the FRONT (work-stealing deque, §2.2)."""
        t = msg.task
        self.assigned.get(msg.worker, set()).discard(t)
        self.lease.pop(t, None)
        live = [d for d in msg.new_deps if d not in self.completed]
        self.joins.setdefault(t, [0, []])
        self.joins[t][0] += len(live)
        for d in live:
            if d not in self.joins:
                self.joins[d] = [0, []]
                self.meta.setdefault(d, {})
                self.ready.append(d)
            self.joins[d][1].append(t)
        if self.joins[t][0] == 0:
            self.ready.appendleft(t)          # LIFO head
        return ExitResp()

    def _exit(self, msg: Exit):
        """Node failure/abort: recycle the worker's assigned tasks."""
        for t in sorted(self.assigned.pop(msg.worker, set())):
            self.lease.pop(t, None)
            self.ready.appendleft(t)
            self.requeued_tasks.add(t)
            self.counters["requeued"] += 1
        return ExitResp()

    def _cancel(self, msg: Cancel):
        """Withdraw a task no worker holds (futures-client cancel): succeeds
        only while the task is unleased and non-terminal, then poisons it
        like a failure so transitive successors can never run.  A task
        already stolen (leased), terminal, or unknown returns NotFound —
        the cancel loses the race and the caller must treat the task as
        live.  Serialized against Steal by the server lock, so a task is
        never both cancelled and handed to a worker."""
        self._reap_leases()
        t = msg.task
        if (t not in self.joins or t in self.completed or t in self.errors
                or t in self.lease or t in self.requeued_tasks):
            # requeued_tasks: a lease-expired requeue may STILL be
            # executing on its straggler worker — "cancelled" must mean
            # "never runs", so a possibly-running task is not cancellable
            return NotFound()
        try:
            self.ready.remove(t)          # may be dep-waiting, not ready
        except ValueError:
            pass
        self._poison(t)
        return ExitResp()

    def _release(self, msg: Release):
        j = self.joins.get(msg.task)
        if j is None or msg.task in self.completed:
            return NotFound()
        j[0] -= 1
        if j[0] == 0:
            self.ready.append(msg.task)
        return ExitResp()

    # ------------------------------------------------------------- helpers
    def _poison(self, t: str):
        """Failed task: mark it and all transitive successors as errors."""
        stack = [t]
        while stack:
            cur = stack.pop()
            if cur in self.errors:
                continue
            if cur not in self.joins and cur != t:
                # a pruned ghost in a live successor list: already
                # terminal before it was pruned — re-adding it to errors
                # would inflate _n_terminal past the live table
                continue
            self.errors.add(cur)
            if self._new_errors is not None:
                self._new_errors.append(cur)
            self.counters["errors"] += 1
            if cur not in self.completed:
                self._n_terminal += 1
            stack.extend(self.joins.get(cur, [0, []])[1])

    def _reap_leases(self):
        if self.lease_timeout is None:
            return
        now = self.clock()
        expired = [t for t, ts in self.lease.items()
                   if now - ts > self.lease_timeout]
        for t in expired:
            for w, ts in self.assigned.items():
                ts.discard(t)
            self.lease.pop(t, None)
            self.ready.appendleft(t)
            self.requeued_tasks.add(t)
            self.counters["requeued"] += 1

    def _all_done(self) -> bool:
        return self._n_terminal >= len(self.joins)

    def prune_terminal(self, keep=()) -> list:
        """Bounded-state hook for long-lived resident services: drop the
        history-table entries (joins/meta/completed/errors) of tasks that
        reached a terminal state, returning the pruned names (callers
        holding per-name side tables — the sharded hub's home map —
        delete exactly those keys).  Names in `keep` are retained (the
        engine passes deps of submissions still in its mailbox).

        Contract: only call when no FUTURE Create will name a pruned task
        as a dependency — a pruned completed task would be re-declared as
        a READY stub (and a pruned failed one would no longer poison its
        new dependents).  Single-use task names (the futures client, the
        serving frontend) satisfy this by construction.  Tasks with a
        stale ready entry or a stale holder (requeue races) are kept so a
        late duplicate can still be recognized as terminal."""
        with self.lock:
            ready_set = set(self.ready)
            held: set = set()
            for ts in self.assigned.values():
                held |= ts
            # names whose cross-shard poison is still in the propagation
            # worklist must survive: pruning an errored __notify__ before
            # _propagate_poison reads its meta would orphan the
            # dependent's held proxy forever
            pending_poison = set(self._new_errors or ())
            pruned: list = []
            for t in list(self.completed) + list(self.errors):
                if t in ready_set or t in held or t in self.requeued_tasks \
                        or t in keep or t in pending_poison:
                    continue
                if self.joins.pop(t, None) is None:
                    continue                  # already pruned (both sets)
                self.meta.pop(t, None)
                self.completed.discard(t)
                self.errors.discard(t)
                self.lease.pop(t, None)
                pruned.append(t)
            self._n_terminal -= len(pruned)
            return pruned

    def stats(self) -> dict:
        return {
            "tasks": len(self.joins), "ready": len(self.ready),
            "assigned": sum(len(s) for s in self.assigned.values()),
            "completed": len(self.completed), "errors": len(self.errors),
            **self.counters,
        }

    # --------------------------------------------------------- persistence
    def save(self, path: str):
        state = {"joins": {k: [v[0], v[1]] for k, v in self.joins.items()},
                 "meta": self.meta,
                 "completed": sorted(self.completed),
                 "errors": sorted(self.errors)}
        Path(path).write_text(json.dumps(state))

    @classmethod
    def load(cls, path: str, **kw) -> "TaskServer":
        state = json.loads(Path(path).read_text())
        srv = cls(**kw)
        srv.joins = {k: [v[0], list(v[1])] for k, v in state["joins"].items()}
        srv.meta = state["meta"]
        srv.completed = set(state["completed"])
        srv.errors = set(state["errors"])
        srv._n_terminal = len(srv.completed | srv.errors)
        # reconstruct ready: join==0, not completed/errored (assigned tasks
        # from the previous run are implicitly requeued — crash tolerance)
        for t, (j, _succ) in srv.joins.items():
            if j == 0 and t not in srv.completed and t not in srv.errors:
                srv.ready.append(t)
        return srv
