"""Sharded dwork hub — the paper's §6 expansion item 4: "shared
responsibility for handing out tasks, sharded between multiple servers",
using the observation that "delegating a task to another task database is
logically the same as assigning it to a worker".

`ShardedHub` fronts N independent TaskServers:
  * Create: tasks hash to a home shard; cross-shard dependencies are
    mediated by proxy tasks — the home shard of a dependency gets a
    `__notify__` successor that completes the dependent's local proxy on
    its shard (the delegation-as-assignment trick).
  * Steal: workers have an affinity shard (locality); on NotFound they
    steal from the busiest other shard (work stealing across shards).
  * METG effect: dispatch rate multiplies by the shard count
    (METGModel.dwork_metg(..., shards=N)).

Relay boundary (`handle()` + `sender`): the hub can be mounted BEHIND
the §4 forwarding tree.  `handle(msg)` accepts the Table-2 verbs exactly
as they arrive over a wire — no shard annotations — and routes them by
the home map (Complete/CompleteSteal), task hash (Create), or worker
affinity (Steal); all verbs it accepts round-trip through the msgpack
wire encoding, so prune/cancel/poison behavior survives serialization.
Every per-shard verb the hub issues goes through `_send`, which a
mounted hub redirects over a real per-shard link (`ShardLinks` installs
itself as `sender` — one timed `hop:L<k>:s<j>` rpc event per shard
round-trip).  Batched `CompleteSteal` verbs whose finished-batch and
steal-target shards differ are SPLIT per home shard, and the
steal-target shard's group is MERGED onto the steal frame so that shard
still sees one round-trip (Fig. 2 batch-then-drain, per shard).

Control plane: `_propagate_poison` and `prune_terminal` read shard state
in-process (worklists + meta under the shard locks) — they are hub
maintenance, not wire verbs, and stay correct whether or not the data
plane crosses a relay.
"""
from __future__ import annotations

import threading
from typing import Callable, Optional

from repro.core.dwork.api import (Cancel, Complete, CompleteSteal, Create,
                                  Exit, ExitResp, NotFound, Release, Stats,
                                  Steal, TaskMsg, Transfer)
from repro.core.dwork.server import TaskServer


class ShardedHub:
    def __init__(self, n_shards: int = 2, *, lease_timeout: Optional[float] = None,
                 clock=None):
        self.shards = [TaskServer(lease_timeout=lease_timeout, clock=clock)
                       for _ in range(n_shards)]
        for s in self.shards:
            s._new_errors = []     # arm the cross-shard poison worklist
        self.home: dict[str, int] = {}
        self.lock = threading.Lock()
        # data-plane indirection: None = in-process shard handle; a hub
        # behind the tree gets a ShardLinks sender so every per-shard
        # verb crosses the per-shard wire (and is hop-timed)
        self.sender: Optional[Callable] = None

    def _send(self, shard: int, msg):
        """Deliver one Table-2 verb to shard `shard` — in-process by
        default, over the installed per-shard link when mounted behind a
        relay (TreeBackend installs `sender`)."""
        if self.sender is None:
            return self.shards[shard].handle(msg)
        return self.sender(shard, msg)

    def _shard_of(self, task: str) -> int:
        with self.lock:
            if task not in self.home:
                self.home[task] = hash(task) % len(self.shards)
            return self.home[task]

    @staticmethod
    def _affinity(worker: str) -> Optional[int]:
        """Shard affinity from the engine's worker naming (w<i>)."""
        tail = worker.rsplit("w", 1)[-1]
        return int(tail) if tail.isdigit() else None

    def _steal_order(self, affinity: Optional[int]) -> list:
        """Affinity shard first (locality), else busiest-first (cross-
        shard work stealing) — the shared probe order for steals."""
        order = list(range(len(self.shards)))
        if affinity is not None:
            order.sort(key=lambda i: 0 if i == affinity % len(self.shards)
                       else 1)
        else:
            order.sort(key=lambda i: -len(self.shards[i].ready))
        return order

    # ---------------------------------------------------- relay boundary
    def handle(self, msg):
        """Wire-boundary entry point: the Table-2 verbs as they arrive
        over a relay (no shard annotations).  Routing: CompleteSteal and
        Complete by the authoritative home map, Create by task hash,
        Steal by worker affinity.  Responses are the plain protocol
        responses (TaskMsg / NotFound / ExitResp / stats dict), so a
        `ShardRouter` can encode them straight back downstream."""
        if isinstance(msg, CompleteSteal):
            resp, _ = self.complete_steal(msg.worker,
                                          self._route_done(msg.done),
                                          n=msg.n,
                                          affinity=self._affinity(msg.worker))
            return resp
        if isinstance(msg, Steal):
            resp, _ = self.steal(msg.worker, n=msg.n,
                                 affinity=self._affinity(msg.worker))
            return resp
        if isinstance(msg, Complete):
            shard = self.home.get(msg.task)
            if shard is None:
                return NotFound()             # unknown / pruned name
            return self.complete(msg.worker, msg.task, shard, ok=msg.ok)
        if isinstance(msg, Create):
            self.create(msg.task, deps=msg.deps, meta=msg.meta)
            return ExitResp()
        if isinstance(msg, Exit):
            self.exit_worker(msg.worker)
            return ExitResp()
        if isinstance(msg, Cancel):
            return ExitResp() if self.cancel(msg.task) else NotFound()
        if isinstance(msg, Transfer):
            return self.transfer(msg.worker, msg.task,
                                 new_deps=msg.new_deps)
        if isinstance(msg, Stats):
            return self.stats()
        raise TypeError(f"unroutable message {msg!r}")

    def _route_done(self, done) -> list:
        """[(task, ok)] -> [(task, ok, home shard)], dropping names the
        home map no longer knows (a late duplicate for a pruned task —
        never guess a shard)."""
        routed = []
        for name, ok in done:
            shard = self.home.get(name)
            if shard is not None:
                routed.append((name, ok, shard))
        return routed

    # ------------------------------------------------------------------
    def create(self, task: str, deps=(), meta=None):
        s = self._shard_of(task)
        local, remote = [], []
        for d in deps:
            (local if self._shard_of(d) == s else remote).append(d)
        # remote deps: a HELD proxy per remote dep lives on the HOME shard
        # ("delegation is logically the same as assigning to a worker" —
        # the remote shard holds the proxy's extra join count and Releases
        # it via its __notify__ successor when the dependency completes)
        proxy_deps = list(local)
        for d in remote:
            proxy = f"__proxy__{d}__for__{task}"
            self._send(s, Create(task=proxy, deps=[], meta={}, hold=True))
            proxy_deps.append(proxy)
            ds = self._shard_of(d)
            self._send(ds, Create(
                task=f"__notify__{proxy}", deps=[d],
                meta={"notify_shard": s, "proxy": proxy}))
        self._send(s, Create(task=task, deps=proxy_deps,
                             meta=dict(meta or {})))
        if remote:
            # a remote dep that ALREADY failed poisons its __notify__ at
            # create time; drain the worklist so the held proxy (and the
            # dependent) fail now instead of dangling
            self._propagate_poison()

    def steal(self, worker: str, n: int = 1, affinity: Optional[int] = None,
              merged=None):
        """Serve one steal, probing shards in `_steal_order`.  `merged`
        is an optional (shard, [(task, ok), ...]) finished batch that
        must ride the steal frame to that shard (the CompleteSteal
        merge): it is forced to the front of the probe order so the
        completions are applied even if another shard could serve the
        steal first."""
        order = self._steal_order(affinity)
        if merged is not None:
            order.sort(key=lambda i: 0 if i == merged[0] else 1)  # stable
        all_exit = True
        for i in order:
            if merged is not None and merged[0] == i:
                r = self._send(i, CompleteSteal(worker=f"{worker}@{i}",
                                                done=merged[1], n=n))
                merged = None
            else:
                r = self._send(i, Steal(worker=f"{worker}@{i}", n=n))
            if isinstance(r, TaskMsg):
                served = []
                for name, meta in r.tasks:
                    if name.startswith("__notify__"):
                        # bookkeeping: Release the held proxy on the
                        # dependent's home shard, retire the notify
                        self._send(meta["notify_shard"],
                                   Release(task=meta["proxy"]))
                        self._send(i, Complete(
                            worker=f"{worker}@{i}", task=name))
                    elif name.startswith("__proxy__"):
                        # structural: released proxies auto-complete, which
                        # unblocks their dependents' join counters
                        self._send(i, Complete(
                            worker=f"{worker}@{i}", task=name))
                    else:
                        served.append((name, meta))
                if served:
                    return TaskMsg(tasks=served), i
                return self.steal(worker, n, affinity)   # retry after notify
            if isinstance(r, NotFound):
                all_exit = False
        return (ExitResp() if all_exit else NotFound()), -1

    def complete(self, worker: str, task: str, shard: int, ok: bool = True):
        resp = self._send(shard, Complete(worker=f"{worker}@{shard}",
                                          task=task, ok=ok))
        if not ok:
            self._propagate_poison()   # cross-shard dependents must fail
        return resp

    def complete_steal(self, worker: str, done, n: int = 0,
                       affinity: Optional[int] = None):
        """The batched CompleteSteal verb generalized over shards: `done`
        is [(task, ok, shard), ...] — completions are grouped per home
        shard, and the group homed on the steal-target shard rides the
        steal frame itself (split per shard, merge with the steal), so
        the common single-shard batch stays ONE per-shard round-trip.
        Groups with failures are applied before the steal (their poison
        must propagate before more work is handed out).  Returns
        (response, shard) like `steal`."""
        by_shard: dict[int, list] = {}
        any_failed = False
        for name, ok, shard in done:
            by_shard.setdefault(shard, []).append((name, ok))
            any_failed = any_failed or not ok
        merged = None
        if n > 0 and not any_failed and by_shard:
            first = self._steal_order(affinity)[0]
            if first in by_shard:
                merged = (first, by_shard.pop(first))
        for shard, batch in by_shard.items():
            self._send(shard, CompleteSteal(worker=f"{worker}@{shard}",
                                            done=batch, n=0))
        if any_failed:
            self._propagate_poison()   # cross-shard dependents must fail
        if n <= 0:
            return ExitResp(), -1
        return self.steal(worker, n=n, affinity=affinity, merged=merged)

    def transfer(self, worker: str, task: str, new_deps=()):
        """Transfer generalized over shards: replace a leased task back
        into its HOME shard's queue with new dependencies.  Cross-shard
        new deps get the same held-proxy + `__notify__` mediation as
        `create` (a dependency must exist before the Transfer lands —
        `_transfer` forward-declares unknown local names as ready stubs,
        which would shadow the real task)."""
        with self.lock:
            s = self.home.get(task)
        if s is None:
            return NotFound()              # unknown / pruned name
        local, remote = [], []
        for d in new_deps:
            (local if self._shard_of(d) == s else remote).append(d)
        proxy_deps = list(local)
        for d in remote:
            proxy = f"__proxy__{d}__for__{task}"
            self._send(s, Create(task=proxy, deps=[], meta={}, hold=True))
            proxy_deps.append(proxy)
            ds = self._shard_of(d)
            self._send(ds, Create(
                task=f"__notify__{proxy}", deps=[d],
                meta={"notify_shard": s, "proxy": proxy}))
        resp = self._send(s, Transfer(worker=f"{worker}@{s}", task=task,
                                      new_deps=proxy_deps))
        if remote:
            self._propagate_poison()
        return resp

    def exit_worker(self, worker: str):
        """Node failure: recycle the worker's assignment on every shard
        (workers steal under per-shard aliases `worker@shard`)."""
        for i in range(len(self.shards)):
            self._send(i, Exit(worker=f"{worker}@{i}"))

    def cancel(self, task: str) -> bool:
        """Cancel on the task's home shard (unleased + non-terminal only),
        then propagate the poison across shards: a cross-shard dependent
        must observe the cancel as a failed dependency, not wait forever
        on a Release its poisoned __notify__ helper can no longer send."""
        with self.lock:
            s = self.home.get(task)
        if s is None:
            return False
        if not isinstance(self._send(s, Cancel(task=task)), ExitResp):
            return False
        self._propagate_poison()
        return True

    def _propagate_poison(self):
        """Cross-shard failure propagation: poisoning a task also poisons
        its `__notify__` helpers, which then can never Release the
        dependent's HELD proxy on its home shard — so the dependent would
        dangle forever, neither run nor fail.  Poison the proxy instead
        (the dependent must never run once its dependency failed).
        Incremental: only names poisoned since the last call are
        examined (each shard's `_new_errors` worklist), looping until
        the cascade across shards quiesces.  Control plane: reads shard
        state in-process under the shard locks (not a wire verb)."""
        while True:
            metas = []
            for shard in self.shards:
                with shard.lock:
                    if not shard._new_errors:
                        continue
                    for t in shard._new_errors:
                        if t.startswith("__notify__"):
                            metas.append(dict(shard.meta.get(t) or {}))
                    shard._new_errors.clear()
            if not metas:
                return
            for meta in metas:
                ns, proxy = meta.get("notify_shard"), meta.get("proxy")
                if ns is None or proxy is None:
                    continue
                target = self.shards[ns]
                with target.lock:
                    if (proxy in target.errors
                            or proxy in target.completed):
                        continue
                    target._poison(proxy)

    def prune_terminal(self, keep=()) -> int:
        """Per-shard terminal-entry pruning plus home-map cleanup (same
        single-use-names contract as `TaskServer.prune_terminal`) —
        O(pruned), not O(live+history): only the pruned names are
        deleted from the home map."""
        pruned = 0
        for s in self.shards:
            names = s.prune_terminal(keep=keep)
            pruned += len(names)
            if names:
                with self.lock:
                    for t in names:
                        self.home.pop(t, None)
        return pruned

    # one definition of the cross-shard aggregates, shared by every
    # backend fronting this hub (in-process or behind the tree)
    def user_errors(self) -> set:
        """Failed USER tasks across shards — the `__proxy__`/`__notify__`
        bookkeeping names are the hub's own, never surfaced."""
        return {t for s in self.shards for t in s.errors
                if not t.startswith("__")}

    def ready_depth(self) -> int:
        return sum(len(s.ready) for s in self.shards)

    def requeued_total(self) -> int:
        return sum(s.counters["requeued"] for s in self.shards)

    def stats(self) -> dict:
        per = [s.stats() for s in self.shards]
        return {"shards": per,
                "completed": sum(p["completed"] for p in per),
                "user_completed": sum(
                    p["completed"] for p in per) - sum(
                        1 for t in self.home if t.startswith("__")),
                }

    def run_to_completion(self, execute, workers: int = 2,
                          max_rounds: int = 100000) -> int:
        """Simple driver: round-robin workers until global Exit."""
        done = 0
        rounds = 0
        while rounds < max_rounds:
            rounds += 1
            progress = False
            exits = 0
            for w in range(workers):
                r, shard = self.steal(f"w{w}", affinity=w)
                if isinstance(r, TaskMsg):
                    progress = True
                    for name, meta in r.tasks:
                        ok = execute(name, meta)
                        self.complete(f"w{w}", name, shard, ok=ok)
                        done += 1
                elif isinstance(r, ExitResp):
                    exits += 1
            if exits == workers:
                return done
            if not progress:
                continue
        return done
