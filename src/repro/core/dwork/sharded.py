"""Sharded dwork hub — the paper's §6 expansion item 4: "shared
responsibility for handing out tasks, sharded between multiple servers",
using the observation that "delegating a task to another task database is
logically the same as assigning it to a worker".

`ShardedHub` fronts N independent TaskServers:
  * Create: tasks hash to a home shard; cross-shard dependencies are
    mediated by proxy tasks — the home shard of a dependency gets a
    `__notify__` successor that completes the dependent's local proxy on
    its shard (the delegation-as-assignment trick).
  * Steal: workers have an affinity shard (locality); on NotFound they
    steal from the busiest other shard (work stealing across shards).
  * METG effect: dispatch rate multiplies by the shard count
    (METGModel.dwork_metg(..., shards=N)).
"""
from __future__ import annotations

import threading
from typing import Optional

from repro.core.dwork.api import (Cancel, Complete, CompleteSteal, Create,
                                  Exit, ExitResp, NotFound, Release, Steal,
                                  TaskMsg)
from repro.core.dwork.server import TaskServer


class ShardedHub:
    def __init__(self, n_shards: int = 2, *, lease_timeout: Optional[float] = None,
                 clock=None):
        self.shards = [TaskServer(lease_timeout=lease_timeout, clock=clock)
                       for _ in range(n_shards)]
        for s in self.shards:
            s._new_errors = []     # arm the cross-shard poison worklist
        self.home: dict[str, int] = {}
        self.lock = threading.Lock()

    def _shard_of(self, task: str) -> int:
        with self.lock:
            if task not in self.home:
                self.home[task] = hash(task) % len(self.shards)
            return self.home[task]

    # ------------------------------------------------------------------
    def create(self, task: str, deps=(), meta=None):
        s = self._shard_of(task)
        local, remote = [], []
        for d in deps:
            (local if self._shard_of(d) == s else remote).append(d)
        # remote deps: a HELD proxy per remote dep lives on the HOME shard
        # ("delegation is logically the same as assigning to a worker" —
        # the remote shard holds the proxy's extra join count and Releases
        # it via its __notify__ successor when the dependency completes)
        proxy_deps = list(local)
        for d in remote:
            proxy = f"__proxy__{d}__for__{task}"
            self.shards[s].handle(Create(task=proxy, deps=[], meta={},
                                         hold=True))
            proxy_deps.append(proxy)
            ds = self._shard_of(d)
            self.shards[ds].handle(Create(
                task=f"__notify__{proxy}", deps=[d],
                meta={"notify_shard": s, "proxy": proxy}))
        self.shards[s].handle(Create(task=task, deps=proxy_deps,
                                     meta=dict(meta or {})))
        if remote:
            # a remote dep that ALREADY failed poisons its __notify__ at
            # create time; drain the worklist so the held proxy (and the
            # dependent) fail now instead of dangling
            self._propagate_poison()

    def steal(self, worker: str, n: int = 1, affinity: Optional[int] = None):
        order = list(range(len(self.shards)))
        if affinity is not None:
            order.sort(key=lambda i: 0 if i == affinity % len(self.shards)
                       else 1)
        else:
            order.sort(key=lambda i: -len(self.shards[i].ready))
        all_exit = True
        for i in order:
            r = self.shards[i].handle(Steal(worker=f"{worker}@{i}", n=n))
            if isinstance(r, TaskMsg):
                served = []
                for name, meta in r.tasks:
                    if name.startswith("__notify__"):
                        # bookkeeping: Release the held proxy on the
                        # dependent's home shard, retire the notify
                        self.shards[meta["notify_shard"]].handle(
                            Release(task=meta["proxy"]))
                        self.shards[i].handle(Complete(
                            worker=f"{worker}@{i}", task=name))
                    elif name.startswith("__proxy__"):
                        # structural: released proxies auto-complete, which
                        # unblocks their dependents' join counters
                        self.shards[i].handle(Complete(
                            worker=f"{worker}@{i}", task=name))
                    else:
                        served.append((name, meta))
                if served:
                    return TaskMsg(tasks=served), i
                return self.steal(worker, n, affinity)   # retry after notify
            if isinstance(r, NotFound):
                all_exit = False
        return (ExitResp() if all_exit else NotFound()), -1

    def complete(self, worker: str, task: str, shard: int, ok: bool = True):
        resp = self.shards[shard].handle(Complete(worker=f"{worker}@{shard}",
                                                  task=task, ok=ok))
        if not ok:
            self._propagate_poison()   # cross-shard dependents must fail
        return resp

    def complete_steal(self, worker: str, done, n: int = 0,
                       affinity: Optional[int] = None):
        """The batched CompleteSteal verb generalized over shards: `done`
        is [(task, ok, shard), ...] — completions are grouped per serving
        shard and applied first, then the next steal is served.  Returns
        (response, shard) like `steal`."""
        by_shard: dict[int, list] = {}
        any_failed = False
        for name, ok, shard in done:
            by_shard.setdefault(shard, []).append((name, ok))
            any_failed = any_failed or not ok
        for shard, batch in by_shard.items():
            self.shards[shard].handle(
                CompleteSteal(worker=f"{worker}@{shard}", done=batch, n=0))
        if any_failed:
            self._propagate_poison()   # cross-shard dependents must fail
        if n <= 0:
            return ExitResp(), -1
        return self.steal(worker, n=n, affinity=affinity)

    def exit_worker(self, worker: str):
        """Node failure: recycle the worker's assignment on every shard
        (workers steal under per-shard aliases `worker@shard`)."""
        for i, s in enumerate(self.shards):
            s.handle(Exit(worker=f"{worker}@{i}"))

    def cancel(self, task: str) -> bool:
        """Cancel on the task's home shard (unleased + non-terminal only),
        then propagate the poison across shards: a cross-shard dependent
        must observe the cancel as a failed dependency, not wait forever
        on a Release its poisoned __notify__ helper can no longer send."""
        with self.lock:
            s = self.home.get(task)
        if s is None:
            return False
        if not isinstance(self.shards[s].handle(Cancel(task=task)),
                          ExitResp):
            return False
        self._propagate_poison()
        return True

    def _propagate_poison(self):
        """Cross-shard failure propagation: poisoning a task also poisons
        its `__notify__` helpers, which then can never Release the
        dependent's HELD proxy on its home shard — so the dependent would
        dangle forever, neither run nor fail.  Poison the proxy instead
        (the dependent must never run once its dependency failed).
        Incremental: only names poisoned since the last call are
        examined (each shard's `_new_errors` worklist), looping until
        the cascade across shards quiesces."""
        while True:
            metas = []
            for shard in self.shards:
                with shard.lock:
                    if not shard._new_errors:
                        continue
                    for t in shard._new_errors:
                        if t.startswith("__notify__"):
                            metas.append(dict(shard.meta.get(t) or {}))
                    shard._new_errors.clear()
            if not metas:
                return
            for meta in metas:
                ns, proxy = meta.get("notify_shard"), meta.get("proxy")
                if ns is None or proxy is None:
                    continue
                target = self.shards[ns]
                with target.lock:
                    if (proxy in target.errors
                            or proxy in target.completed):
                        continue
                    target._poison(proxy)

    def prune_terminal(self, keep=()) -> int:
        """Per-shard terminal-entry pruning plus home-map cleanup (same
        single-use-names contract as `TaskServer.prune_terminal`) —
        O(pruned), not O(live+history): only the pruned names are
        deleted from the home map."""
        pruned = 0
        for s in self.shards:
            names = s.prune_terminal(keep=keep)
            pruned += len(names)
            if names:
                with self.lock:
                    for t in names:
                        self.home.pop(t, None)
        return pruned

    def stats(self) -> dict:
        per = [s.stats() for s in self.shards]
        return {"shards": per,
                "completed": sum(p["completed"] for p in per),
                "user_completed": sum(
                    p["completed"] for p in per) - sum(
                        1 for t in self.home if t.startswith("__")),
                }

    def run_to_completion(self, execute, workers: int = 2,
                          max_rounds: int = 100000) -> int:
        """Simple driver: round-robin workers until global Exit."""
        done = 0
        rounds = 0
        while rounds < max_rounds:
            rounds += 1
            progress = False
            exits = 0
            for w in range(workers):
                r, shard = self.steal(f"w{w}", affinity=w)
                if isinstance(r, TaskMsg):
                    progress = True
                    for name, meta in r.tasks:
                        ok = execute(name, meta)
                        self.complete(f"w{w}", name, shard, ok=ok)
                        done += 1
                elif isinstance(r, ExitResp):
                    exits += 1
            if exits == workers:
                return done
            if not progress:
                continue
        return done
