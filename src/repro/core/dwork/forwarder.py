"""Message-forwarding tree (paper §4: 2-level rack-leader tree on Summit).

A Forwarder accepts downstream dwork connections and relays every frame
over ONE shared upstream connection — constant open connections per rack
leader, no per-worker TCP setup at the hub.  Chaining forwarders builds
deeper trees for larger machines (`Engine(transport="tree")` assembles
one automatically).

Relaying is pipelined: a downstream handler enqueues its frame and waits
on its own reply slot while other handlers keep sending, so frames from
different downstream connections overlap on the upstream link instead of
serializing one round-trip at a time.  Request/response matching uses the
upstream connection's FIFO ordering as the tag: replies are handed back
in the order frames were sent (the upstream hub serves one connection's
frames in order, so this is exact).  That machinery lives in
`UpstreamLink` so a node with SEVERAL upstreams can reuse it per link.

`ShardRouter` is the sharded apex (paper §6 expansion item 4 composed
with the §4 tree): instead of blind frame relay it DECODES each frame
and routes the Table-2 verbs by task hash to per-shard upstream
`TaskServer`s through a `ShardedHub`'s routing logic — the hub behind
the tree.  Batched `CompleteSteal` verbs whose finished-batch and
steal-target shards differ are split per home shard and the steal-target
group is merged back onto the steal frame (one round-trip for that
shard).  Every per-shard round-trip is timed as an `rpc` event
`op="hop:L<k>:s<j>"` so `OverheadReport.rpc_by_op` attributes the shard
fan-out the same way plain forwarder hops are attributed per level.

Failure behavior: an upstream error wakes every waiting handler, closes
the downstream connections (both directions — no half-open relays), and
is surfaced on `Forwarder.upstream_error` instead of being swallowed.
"""
from __future__ import annotations

import socket
import socketserver
import threading
import time
from collections import deque

from repro.core.dwork.api import decode, encode, encode_stats
from repro.core.dwork.client import _recv_frame, _send_frame


class _Reply:
    """One-shot reply slot a downstream handler waits on."""

    __slots__ = ("event", "frame")

    def __init__(self):
        self.event = threading.Event()
        self.frame = None

    def set(self, frame):
        self.frame = frame
        self.event.set()


class UpstreamLink:
    """One shared, pipelined upstream connection: thread-safe frame
    round-trips with FIFO request/response matching.  The send lock is
    held only while writing, never across the upstream round-trip, so
    frames from many downstream handlers overlap on the wire."""

    def __init__(self, upstream, *, reply_timeout: float = 60.0):
        self.upstream = upstream
        self.error: str | None = None
        self.relayed = 0                      # frames sent upstream
        self.reply_timeout = reply_timeout    # per-request wait, seconds
        self._sock = None                     # lazily-opened shared link
        self._send_lock = threading.Lock()    # orders sends + FIFO tags
        self._pending: deque[_Reply] = deque()
        self._pending_lock = threading.Lock()
        self._reader: threading.Thread | None = None

    def _ensure(self):
        if self._sock is None:
            sock = socket.create_connection(self.upstream)
            sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
            self._sock = sock
            self._reader = threading.Thread(target=self._read_upstream,
                                            daemon=True)
            self._reader.start()
        return self._sock

    def relay(self, frame: bytes) -> bytes:
        """Send one frame upstream, return its response."""
        reply = _Reply()
        with self._send_lock:
            if self.error is not None:
                raise ConnectionError(self.error)
            # local snapshot: the reader thread may null self._sock on an
            # upstream error mid-send; sendall on the closed local socket
            # raises OSError (handled), never AttributeError
            sock = self._ensure()
            with self._pending_lock:
                self._pending.append(reply)
            try:
                _send_frame(sock, frame)
            except OSError as e:
                self.fail(repr(e))
                raise ConnectionError(self.error) from e
            self.relayed += 1
            if self.error is not None:
                # the reader failed while we were sending: our slot may
                # have been appended after fail() drained the FIFO, so
                # nobody would ever wake us — fail fast instead
                with self._pending_lock:
                    try:
                        self._pending.remove(reply)
                    except ValueError:
                        pass
                raise ConnectionError(self.error)
        if not reply.event.wait(timeout=self.reply_timeout):
            # transient stall: abandon THIS request only.  The slot stays
            # in the FIFO (a late response is absorbed by it, keeping
            # request/response matching aligned) and the shared link
            # survives for every other downstream client.
            raise ConnectionError("upstream response timed out")
        if reply.frame is None:
            raise ConnectionError(self.error or "upstream closed")
        return reply.frame

    def _read_upstream(self):
        sock = self._sock
        try:
            while True:
                resp = _recv_frame(sock)
                if resp is None:
                    raise ConnectionError("upstream closed")
                with self._pending_lock:
                    reply = self._pending.popleft()
                reply.set(resp)
        except Exception as e:                # noqa: BLE001
            self.fail(repr(e))

    def fail(self, error: str):
        """Surface an upstream failure: record it, wake every waiter with
        an empty reply, and close the shared link."""
        if self.error is None:
            self.error = error
        with self._pending_lock:
            waiters, self._pending = list(self._pending), deque()
        for reply in waiters:
            reply.set(None)
        sock, self._sock = self._sock, None
        if sock is not None:
            try:
                sock.close()
            except OSError:
                pass


class _RelayHandler(socketserver.BaseRequestHandler):
    """Shared downstream frame loop for every tree node: the server's
    `relay(frame)` does the node's work (blind relay for `Forwarder`,
    hash routing for `ShardRouter`)."""

    def handle(self):
        try:
            while True:
                frame = _recv_frame(self.request)
                if frame is None:
                    return                    # downstream closed cleanly
                resp = self.server.relay(frame)
                _send_frame(self.request, resp)
        except ConnectionError:
            # upstream died (or an abrupt downstream disconnect raced a
            # send): close our side so the client sees the failure now
            # instead of hanging on a half-open relay
            pass
        finally:
            try:
                self.request.shutdown(socket.SHUT_RDWR)
            except OSError:
                pass
            self.request.close()


class _TreeNode(socketserver.ThreadingTCPServer):
    """Common TCP shell of a tree node (Forwarder / ShardRouter)."""

    allow_reuse_address = True
    daemon_threads = True

    def __init__(self, addr):
        super().__init__(addr, _RelayHandler)

    def serve_background(self) -> threading.Thread:
        th = threading.Thread(target=self.serve_forever, daemon=True)
        th.start()
        return th


class Forwarder(_TreeNode):
    def __init__(self, addr, upstream, *, tracer=None, label: str = "fwd"):
        super().__init__(addr)
        self.upstream = upstream
        self.link = UpstreamLink(upstream)
        self.tracer = tracer                  # emits one `rpc` per hop
        self.label = label

    # link state surfaced under the names the rest of the repo uses
    @property
    def upstream_error(self) -> str | None:
        return self.link.error

    @property
    def relayed(self) -> int:
        return self.link.relayed

    @property
    def reply_timeout(self) -> float:
        return self.link.reply_timeout

    @reply_timeout.setter
    def reply_timeout(self, value: float):
        self.link.reply_timeout = value

    # ------------------------------------------------------------- relay
    def relay(self, frame: bytes) -> bytes:
        """Send one frame upstream, return its response.  Thread-safe and
        pipelined (see `UpstreamLink.relay`)."""
        t0 = time.perf_counter()
        resp = self.link.relay(frame)
        if self.tracer is not None:
            self.tracer.emit("rpc", op=f"hop:{self.label}",
                             dt=time.perf_counter() - t0)
        return resp

    # ------------------------------------------------------------ control
    def close(self):
        self.shutdown()
        self.link.fail("forwarder closed")
        self.server_close()


class ShardLinks:
    """The per-shard upstream links of a hub mounted behind the tree: one
    pipelined `UpstreamLink` per shard TaskServer, shared by every
    top-level `ShardRouter` (links are thread-safe).  Installed as a
    `ShardedHub.sender`, so every per-shard Table-2 verb the hub issues
    crosses a real wire and is timed as an `rpc` event
    `op="hop:<label>:s<shard>"` — the shard fan-out attribution."""

    def __init__(self, addrs, *, tracer=None, label: str = "L1"):
        self.links = [UpstreamLink(a) for a in addrs]
        self.tracer = tracer
        self.label = label

    def __call__(self, shard: int, msg):
        t0 = time.perf_counter()
        resp = decode(self.links[shard].relay(encode(msg)))
        if self.tracer is not None:
            self.tracer.emit("rpc", op=f"hop:{self.label}:s{shard}",
                             dt=time.perf_counter() - t0)
        return resp

    @property
    def error(self) -> str | None:
        return next((ln.error for ln in self.links
                     if ln.error is not None), None)

    def close(self):
        for ln in self.links:
            ln.fail("shard links closed")


class ShardRouter(_TreeNode):
    """The top-level tree node when the hub is sharded: decodes each
    frame arriving from the tree (or the boss link) and routes the
    Table-2 verbs by task hash to the per-shard upstream TaskServers,
    via `ShardedHub.handle` — affinity steals, cross-shard dependency
    `__notify__` mediation, `CompleteSteal` split/merge, and poison
    propagation all happen here, at the apex, exactly once per tree.

    Several routers (a wide level-1 layer) may front the SAME hub: the
    routing state (home map) and the per-shard links are shared and
    thread-safe, so any router can serve any downstream frame."""

    def __init__(self, addr, hub, *, tracer=None, label: str = "L1"):
        super().__init__(addr)
        self.hub = hub
        self.tracer = tracer        # parity with Forwarder (tree retuning);
        self.label = label          # per-shard hops are emitted by the
        self.relayed = 0            # hub's ShardLinks sender, not here
        self._count_lock = threading.Lock()

    @property
    def upstream_error(self) -> str | None:
        sender = getattr(self.hub, "sender", None)
        return getattr(sender, "error", None)

    def relay(self, frame: bytes) -> bytes:
        """The router's version of a relay: decode, hash-route through
        the hub, re-encode.  Handler threads run this concurrently, so
        the frame counter increments under a lock (the Forwarder's
        counter is ordered by its send lock)."""
        resp = self.hub.handle(decode(frame))
        with self._count_lock:
            self.relayed += 1
        if isinstance(resp, dict):
            return encode_stats(resp)
        return encode(resp)

    def close(self):
        self.shutdown()
        self.server_close()
