"""Message-forwarding tree (paper §4: 2-level rack-leader tree on Summit).

A Forwarder accepts downstream dwork connections and relays every frame to
a single upstream connection — maintaining constant open connections per
rack and avoiding per-worker TCP setup at the hub.  Chaining forwarders
builds deeper trees for larger machines.
"""
from __future__ import annotations

import socket
import socketserver
import struct
import threading

from repro.core.dwork.client import _recv_frame, _send_frame


class _RelayHandler(socketserver.BaseRequestHandler):
    def handle(self):
        up = socket.create_connection(self.server.upstream)
        up.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        try:
            while True:
                frame = _recv_frame(self.request)
                if frame is None:
                    return
                with self.server.up_lock:
                    _send_frame(up, frame)
                    resp = _recv_frame(up)
                if resp is None:
                    return
                _send_frame(self.request, resp)
        finally:
            up.close()


class Forwarder(socketserver.ThreadingTCPServer):
    allow_reuse_address = True
    daemon_threads = True

    def __init__(self, addr, upstream):
        super().__init__(addr, _RelayHandler)
        self.upstream = upstream
        self.up_lock = threading.Lock()

    def serve_background(self) -> threading.Thread:
        th = threading.Thread(target=self.serve_forever, daemon=True)
        th.start()
        return th
