"""Message-forwarding tree (paper §4: 2-level rack-leader tree on Summit).

A Forwarder accepts downstream dwork connections and relays every frame
over ONE shared upstream connection — constant open connections per rack
leader, no per-worker TCP setup at the hub.  Chaining forwarders builds
deeper trees for larger machines (`Engine(transport="tree")` assembles
one automatically).

Relaying is pipelined: a downstream handler enqueues its frame and waits
on its own reply slot while other handlers keep sending, so frames from
different downstream connections overlap on the upstream link instead of
serializing one round-trip at a time.  Request/response matching uses the
upstream connection's FIFO ordering as the tag: replies are handed back
in the order frames were sent (the upstream hub serves one connection's
frames in order, so this is exact).

Failure behavior: an upstream error wakes every waiting handler, closes
the downstream connections (both directions — no half-open relays), and
is surfaced on `Forwarder.upstream_error` instead of being swallowed.
"""
from __future__ import annotations

import socket
import socketserver
import threading
import time
from collections import deque

from repro.core.dwork.client import _recv_frame, _send_frame


class _Reply:
    """One-shot reply slot a downstream handler waits on."""

    __slots__ = ("event", "frame")

    def __init__(self):
        self.event = threading.Event()
        self.frame = None

    def set(self, frame):
        self.frame = frame
        self.event.set()


class _RelayHandler(socketserver.BaseRequestHandler):
    def handle(self):
        try:
            while True:
                frame = _recv_frame(self.request)
                if frame is None:
                    return                    # downstream closed cleanly
                resp = self.server.relay(frame)
                _send_frame(self.request, resp)
        except ConnectionError:
            # upstream died (or an abrupt downstream disconnect raced a
            # send): close our side so the client sees the failure now
            # instead of hanging on a half-open relay
            pass
        finally:
            try:
                self.request.shutdown(socket.SHUT_RDWR)
            except OSError:
                pass
            self.request.close()


class Forwarder(socketserver.ThreadingTCPServer):
    allow_reuse_address = True
    daemon_threads = True

    def __init__(self, addr, upstream, *, tracer=None, label: str = "fwd"):
        super().__init__(addr, _RelayHandler)
        self.upstream = upstream
        self.tracer = tracer                  # emits one `rpc` per hop
        self.label = label
        self.upstream_error: str | None = None
        self.relayed = 0                      # frames relayed upstream
        self.reply_timeout = 60.0             # per-request wait, seconds
        self._up_sock = None                  # lazily-opened shared link
        self._send_lock = threading.Lock()    # orders sends + FIFO tags
        self._pending: deque[_Reply] = deque()
        self._pending_lock = threading.Lock()
        self._reader: threading.Thread | None = None

    # ------------------------------------------------------------- relay
    def _ensure_upstream(self):
        if self._up_sock is None:
            sock = socket.create_connection(self.upstream)
            sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
            self._up_sock = sock
            self._reader = threading.Thread(target=self._read_upstream,
                                            daemon=True)
            self._reader.start()
        return self._up_sock

    def relay(self, frame: bytes) -> bytes:
        """Send one frame upstream, return its response.  Thread-safe and
        pipelined: the send lock is held only while writing, never across
        the upstream round-trip."""
        reply = _Reply()
        t0 = time.perf_counter()
        with self._send_lock:
            if self.upstream_error is not None:
                raise ConnectionError(self.upstream_error)
            # local snapshot: the reader thread may null self._up_sock on
            # an upstream error mid-send; sendall on the closed local
            # socket raises OSError (handled), never AttributeError
            sock = self._ensure_upstream()
            with self._pending_lock:
                self._pending.append(reply)
            try:
                _send_frame(sock, frame)
            except OSError as e:
                self._fail(repr(e))
                raise ConnectionError(self.upstream_error) from e
            self.relayed += 1
            if self.upstream_error is not None:
                # the reader failed while we were sending: our slot may
                # have been appended after _fail drained the FIFO, so
                # nobody would ever wake us — fail fast instead
                with self._pending_lock:
                    try:
                        self._pending.remove(reply)
                    except ValueError:
                        pass
                raise ConnectionError(self.upstream_error)
        if not reply.event.wait(timeout=self.reply_timeout):
            # transient stall: abandon THIS request only.  The slot stays
            # in the FIFO (a late response is absorbed by it, keeping
            # request/response matching aligned) and the shared link
            # survives for every other downstream client.
            raise ConnectionError("upstream response timed out")
        if reply.frame is None:
            raise ConnectionError(self.upstream_error or "upstream closed")
        if self.tracer is not None:
            self.tracer.emit("rpc", op=f"hop:{self.label}",
                             dt=time.perf_counter() - t0)
        return reply.frame

    def _read_upstream(self):
        sock = self._up_sock
        try:
            while True:
                resp = _recv_frame(sock)
                if resp is None:
                    raise ConnectionError("upstream closed")
                with self._pending_lock:
                    reply = self._pending.popleft()
                reply.set(resp)
        except Exception as e:                # noqa: BLE001
            self._fail(repr(e))

    def _fail(self, error: str):
        """Surface an upstream failure: record it, wake every waiter with
        an empty reply, and close the shared link (both directions die —
        handlers propagate by closing their downstream sockets)."""
        if self.upstream_error is None:
            self.upstream_error = error
        with self._pending_lock:
            waiters, self._pending = list(self._pending), deque()
        for reply in waiters:
            reply.set(None)
        sock, self._up_sock = self._up_sock, None
        if sock is not None:
            try:
                sock.close()
            except OSError:
                pass

    # ------------------------------------------------------------ control
    def serve_background(self) -> threading.Thread:
        th = threading.Thread(target=self.serve_forever, daemon=True)
        th.start()
        return th

    def close(self):
        self.shutdown()
        self._fail("forwarder closed")
        self.server_close()
