"""Overlapping client — paper Fig. 2 caption: "Production client code would
use an assembly-line pattern to overlap these 4 steps", and §5: "This
waiting time can be hidden by overlapping computation and communication,
which I have implemented in the client."

`OverlapClient.run_loop` keeps one Steal in flight while the current task
executes (double-buffering), so per-task dispatch latency is hidden as long
as execution time >= round-trip time — exactly the paper's mechanism for
pushing the effective METG down to the server dispatch-rate bound.
"""
from __future__ import annotations

import queue
import threading
from typing import Callable

from repro.core.dwork.api import ExitResp, NotFound, TaskMsg
from repro.core.dwork.client import Client


class OverlapClient(Client):
    def run_loop(self, execute: Callable[[str, dict], bool], *,
                 steal_n: int = 1, idle_sleep: float = 0.001,
                 max_idle: int = 1000):
        import time as _time
        prefetched: queue.Queue = queue.Queue(maxsize=1)
        stop = threading.Event()

        def fetcher():
            idle = 0
            while not stop.is_set():
                resp = self.steal(n=steal_n)
                if isinstance(resp, ExitResp):
                    prefetched.put(None)
                    return
                if isinstance(resp, NotFound):
                    idle += 1
                    if idle > max_idle:
                        prefetched.put(None)
                        return
                    _time.sleep(idle_sleep)
                    continue
                idle = 0
                prefetched.put(resp)          # blocks: one batch in flight

        th = threading.Thread(target=fetcher, daemon=True)
        th.start()
        done = 0
        try:
            while True:
                resp = prefetched.get()
                if resp is None:
                    return done
                assert isinstance(resp, TaskMsg)
                for name, meta in resp.tasks:
                    try:
                        ok = execute(name, meta)
                    except Exception:
                        ok = False
                    self.complete(name, ok=ok)
                    done += 1
        finally:
            stop.set()
