"""dwork transports + worker client loop.

InProcTransport measures pure scheduler overhead (the paper's 23 us RTT
analog); TCPTransport is the ZeroMQ stand-in: length-prefixed msgpack over
a threaded socket server.
"""
from __future__ import annotations

import socket
import socketserver
import struct
import threading
from typing import Callable, Optional

from repro.core.dwork.api import (Complete, CompleteSteal, Create, Exit,
                                  ExitResp, NotFound, Stats, Steal, TaskMsg,
                                  Transfer, decode, encode, encode_stats)
from repro.core.dwork.server import TaskServer


class InProcTransport:
    def __init__(self, server: TaskServer):
        self.server = server

    def request(self, msg):
        return self.server.handle(msg)

    def close(self):
        pass


def _send_frame(sock, data: bytes):
    sock.sendall(struct.pack(">I", len(data)) + data)


def _recv_frame(sock) -> Optional[bytes]:
    hdr = b""
    while len(hdr) < 4:
        chunk = sock.recv(4 - len(hdr))
        if not chunk:
            return None
        hdr += chunk
    (n,) = struct.unpack(">I", hdr)
    buf = b""
    while len(buf) < n:
        chunk = sock.recv(min(65536, n - len(buf)))
        if not chunk:
            return None
        buf += chunk
    return buf


class _Handler(socketserver.BaseRequestHandler):
    def handle(self):
        while True:
            frame = _recv_frame(self.request)
            if frame is None:
                return
            msg = decode(frame)
            resp = self.server.task_server.handle(msg)
            if isinstance(resp, dict):
                _send_frame(self.request, encode_stats(resp))
            else:
                _send_frame(self.request, encode(resp))


class TCPServer(socketserver.ThreadingTCPServer):
    allow_reuse_address = True
    daemon_threads = True

    def __init__(self, addr, task_server: TaskServer):
        super().__init__(addr, _Handler)
        self.task_server = task_server

    def serve_background(self) -> threading.Thread:
        th = threading.Thread(target=self.serve_forever, daemon=True)
        th.start()
        return th


class TCPTransport:
    def __init__(self, host: str, port: int):
        self.sock = socket.create_connection((host, port))
        self.sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        self.lock = threading.Lock()

    def request(self, msg):
        with self.lock:
            _send_frame(self.sock, encode(msg))
            frame = _recv_frame(self.sock)
        if frame is None:
            raise ConnectionError("dhub connection closed")
        return decode(frame)

    def close(self):
        self.sock.close()


class Client:
    """Worker-side API wrapper + the paper's client loop (Fig. 2)."""

    def __init__(self, transport, worker: str):
        self.t = transport
        self.worker = worker

    def create(self, task: str, deps=(), meta=None):
        return self.t.request(Create(task=task, deps=list(deps),
                                     meta=dict(meta or {})))

    def steal(self, n: int = 1):
        return self.t.request(Steal(worker=self.worker, n=n))

    def complete(self, task: str, ok: bool = True):
        return self.t.request(Complete(worker=self.worker, task=task, ok=ok))

    def complete_steal(self, done, n: int = 0):
        """Report a batch of finished tasks and steal the next batch in the
        same round-trip (`done` is [(task, ok), ...]; n=0 completes only)."""
        return self.t.request(CompleteSteal(worker=self.worker,
                                            done=list(done), n=n))

    def transfer(self, task: str, new_deps):
        return self.t.request(Transfer(worker=self.worker, task=task,
                                       new_deps=list(new_deps)))

    def exit(self):
        return self.t.request(Exit(worker=self.worker))

    def stats(self) -> dict:
        return self.t.request(Stats())

    def run_loop(self, execute: Callable[[str, dict], bool], *,
                 steal_n: int = 1, idle_sleep: float = 0.001,
                 max_idle: int = 1000):
        """CLIENT-LOOP from Fig. 2: steal -> execute -> complete, until the
        server responds Exit.  `execute` returns success; failures are
        reported (error poisoning on the server).  The finished batch rides
        on the next steal (`CompleteSteal`), so each loop iteration costs
        one round-trip regardless of `steal_n`."""
        import time as _time
        idle = 0
        done = 0
        finished: list = []
        while True:
            resp = self.complete_steal(finished, n=steal_n)
            finished = []
            if isinstance(resp, ExitResp):
                return done
            if isinstance(resp, NotFound):
                idle += 1
                if idle > max_idle:
                    return done
                _time.sleep(idle_sleep)
                continue
            idle = 0
            assert isinstance(resp, TaskMsg)
            for name, meta in resp.tasks:
                try:
                    ok = execute(name, meta)
                except Exception:
                    ok = False
                finished.append((name, ok))
                done += 1
