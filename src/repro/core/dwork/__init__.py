"""dwork: client/server bag-of-tasks with dependencies (Rogers 2021, §2.2).

Minimal API (paper Table 2): Create / Steal / Complete / Transfer / Exit,
plus the paper's two scalability extensions: `Steal n` batching and a
message-forwarding tree (rack leaders).  The server keeps exactly two
tables — join counters + successors, and task metadata — and a double-ended
ready queue (FIFO for steals, LIFO for re-inserted tasks).

The paper's ZeroMQ+protobuf+TKRZW stack is adapted to an offline-friendly
equivalent: length-prefixed msgpack over TCP, plus an in-proc transport for
overhead benchmarks, and file persistence with ready-state reconstruction.
"""
from repro.core.dwork.api import (Complete, CompleteSteal, Create, Exit,
                                  ExitResp, NotFound, Steal, TaskMsg,
                                  Transfer)
from repro.core.dwork.server import TaskServer
from repro.core.dwork.client import Client, InProcTransport, TCPTransport
from repro.core.dwork.forwarder import Forwarder
from repro.core.dwork.pool import run_pool

__all__ = ["Create", "Steal", "Complete", "CompleteSteal", "Transfer",
           "Exit", "TaskMsg", "NotFound", "ExitResp", "TaskServer", "Client",
           "InProcTransport", "TCPTransport", "Forwarder", "run_pool"]
