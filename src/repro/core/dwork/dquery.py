"""dquery — command-line dwork client (paper §2.2: "a command-line tool
(dquery) as an example client that can interact with the API from shell
scripts").

    python -m repro.core.dwork.dquery --host H --port P serve        # dhub
    python -m repro.core.dwork.dquery --host H --port P create T [-d DEP]...
    python -m repro.core.dwork.dquery ... steal [-n N] [--worker W]
    python -m repro.core.dwork.dquery ... complete T [--fail]
    python -m repro.core.dwork.dquery ... transfer T -d NEWDEP...
    python -m repro.core.dwork.dquery ... exit-worker --worker W
    python -m repro.core.dwork.dquery ... stats
"""
from __future__ import annotations

import argparse
import json
import sys

from repro.core.dwork.api import ExitResp, NotFound, TaskMsg
from repro.core.dwork.client import Client, TCPServer, TCPTransport
from repro.core.dwork.server import TaskServer


def main(argv=None):
    ap = argparse.ArgumentParser(prog="dquery")
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=7781)
    ap.add_argument("--worker", default="dquery")
    ap.add_argument("--db", default="", help="persistence file (serve)")
    sub = ap.add_subparsers(dest="cmd", required=True)
    sub.add_parser("serve")
    c = sub.add_parser("create")
    c.add_argument("task")
    c.add_argument("-d", "--dep", action="append", default=[])
    st = sub.add_parser("steal")
    st.add_argument("-n", type=int, default=1)
    co = sub.add_parser("complete")
    co.add_argument("task")
    co.add_argument("--fail", action="store_true")
    tr = sub.add_parser("transfer")
    tr.add_argument("task")
    tr.add_argument("-d", "--dep", action="append", default=[])
    sub.add_parser("exit-worker")
    sub.add_parser("stats")
    args = ap.parse_args(argv)

    if args.cmd == "serve":
        import pathlib
        srv = (TaskServer.load(args.db)
               if args.db and pathlib.Path(args.db).exists() else TaskServer())
        tcp = TCPServer((args.host, args.port), srv)
        print(f"dhub listening on {tcp.server_address}", flush=True)
        try:
            tcp.serve_forever()
        except KeyboardInterrupt:
            if args.db:
                srv.save(args.db)
                print(f"state saved to {args.db}")
        return 0

    cl = Client(TCPTransport(args.host, args.port), args.worker)
    if args.cmd == "create":
        cl.create(args.task, deps=args.dep)
        print("ok")
    elif args.cmd == "steal":
        r = cl.steal(n=args.n)
        if isinstance(r, TaskMsg):
            for name, meta in r.tasks:
                print(name if not meta else f"{name}\t{json.dumps(meta)}")
        elif isinstance(r, NotFound):
            print("NOTFOUND")
            return 3
        elif isinstance(r, ExitResp):
            print("EXIT")
            return 4
    elif args.cmd == "complete":
        cl.complete(args.task, ok=not args.fail)
        print("ok")
    elif args.cmd == "transfer":
        cl.transfer(args.task, args.dep)
        print("ok")
    elif args.cmd == "exit-worker":
        cl.exit()
        print("ok")
    elif args.cmd == "stats":
        print(json.dumps(cl.stats(), indent=1))
    return 0


if __name__ == "__main__":
    sys.exit(main())
