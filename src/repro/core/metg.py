"""Minimum Effective Task Granularity models (Rogers 2021, §3-§6).

METG = task duration at which scheduling overhead equals compute time.
Each scheduler archetype follows a different scaling law:

  pmake    METG(P) = jsrun(P) + alloc           jsrun ~ a + b*log(P)
  dwork    METG(P) = rtt * P                    single-server dispatch bound
           (mitigations: Steal-n batching  -> rtt*P/n;
            forwarding tree adds hop latency but removes connection limits;
            sharded servers -> rtt*P/shards)
  mpi-list METG(P) = straggler gap = E[max-min] of per-rank runtimes
           ~ sigma * sqrt(2 ln P) (Gumbel / extreme-value law, ref [31])

Paper-measured constants (Summit, Table 4) are kept as defaults so the
benchmarks can validate our reproduction against the paper's own numbers.
"""
from __future__ import annotations

import math
from dataclasses import dataclass

# paper Table 4 (seconds)
PAPER_JSRUN = {6: 0.987, 60: 1.783, 864: 2.336, 6912: 3.823}
PAPER_ALLOC = 1.81
PAPER_DWORK_RTT = 23e-6
PAPER_MPILIST_SYNC = {6: 0.09, 60: 0.17, 864: 0.33, 6912: 0.47}
# paper §4: METG at 864 ranks (seconds)
PAPER_METG_864 = {"mpi-list": 0.3e-3, "dwork": 25e-3, "pmake": 4.5}


def fit_log(points: dict) -> tuple[float, float]:
    """Least-squares fit y = a + b*ln(x)."""
    xs = [math.log(x) for x in points]
    ys = list(points.values())
    n = len(xs)
    mx, my = sum(xs) / n, sum(ys) / n
    b = sum((x - mx) * (y - my) for x, y in zip(xs, ys)) / \
        sum((x - mx) ** 2 for x in xs)
    return my - b * mx, b


@dataclass
class METGModel:
    jsrun_a: float = 0.0
    jsrun_b: float = 0.0
    alloc: float = PAPER_ALLOC
    dwork_rtt: float = PAPER_DWORK_RTT
    sync_a: float = 0.0
    sync_b: float = 0.0

    @classmethod
    def from_paper(cls) -> "METGModel":
        ja, jb = fit_log(PAPER_JSRUN)
        sa, sb = fit_log({p: v for p, v in PAPER_MPILIST_SYNC.items()})
        return cls(jsrun_a=ja, jsrun_b=jb, sync_a=sa, sync_b=sb)

    @classmethod
    def from_measured(cls, *, launch_s: float = 0.0, alloc_s: float = 0.0,
                      rtt_s: float = PAPER_DWORK_RTT) -> "METGModel":
        """Instantiate the scaling laws with constants measured on the
        running system (engine trace / benchmarks) instead of the paper's
        Summit numbers: launch_s -> flat jsrun cost, rtt_s -> dwork
        dispatch RTT.  Used by `engine.tracing.crosscheck` to validate the
        law *shapes* against empirical event streams."""
        return cls(jsrun_a=launch_s, jsrun_b=0.0, alloc=alloc_s,
                   dwork_rtt=rtt_s)

    # -- scaling laws ------------------------------------------------------
    def jsrun_time(self, ranks: int) -> float:
        return self.jsrun_a + self.jsrun_b * math.log(max(ranks, 1))

    def pmake_metg(self, ranks: int) -> float:
        """Launch cost is unhideable per task (paper §4)."""
        return self.jsrun_time(ranks) + self.alloc

    def dwork_metg(self, ranks: int, *, steal_n: int = 1,
                   shards: int = 1) -> float:
        """Single server must serve every rank per task interval."""
        return self.dwork_rtt * ranks / (max(steal_n, 1) * max(shards, 1))

    def mpilist_metg(self, ranks: int, *, per_rank_sigma: float = 0.0) -> float:
        """Straggler gap; with a measured sigma use the Gumbel law, else the
        paper's fitted sync-latency curve."""
        if per_rank_sigma > 0.0:
            return per_rank_sigma * math.sqrt(2.0 * math.log(max(ranks, 2)))
        return max(self.sync_a + self.sync_b * math.log(max(ranks, 1)), 0.0) \
            * 1e-3  # paper's sync column is dominated by per-1024-task cost

    def metg(self, scheduler: str, ranks: int, **kw) -> float:
        return {"pmake": self.pmake_metg, "dwork": self.dwork_metg,
                "mpi-list": self.mpilist_metg}[scheduler](ranks, **kw)


def same_order(a: float, b: float, factor: float = 10.0) -> bool:
    """True when two positive quantities agree to within `factor` (default:
    one order of magnitude) — the engine's empirical-vs-analytic check."""
    if a <= 0.0 or b <= 0.0:
        return False
    return max(a, b) / min(a, b) <= factor


def efficiency(task_time: float, metg: float) -> float:
    """Fraction of wall time spent computing when per-task overhead equals
    the METG-implied overhead: eff = t / (t + overhead)."""
    return task_time / (task_time + metg)


def pick_batch_size(scheduler: str, ranks: int, per_task_s: float,
                    target_eff: float = 0.9, model: METGModel = None,
                    shards: int = 1) -> int:
    """METG-aware batching (framework feature): how many requests/steps to
    bundle per task so scheduling overhead stays below (1-target_eff).
    `shards` divides dwork's dispatch bound (a sharded hub — alone or
    behind the forwarding tree — multiplies dispatch rate), so a sharded
    deployment needs proportionally smaller batches for the same
    efficiency target; the other scheduler laws ignore it."""
    m = model or METGModel.from_paper()
    kw = {"shards": max(int(shards), 1)} if scheduler == "dwork" else {}
    overhead = m.metg(scheduler, ranks, **kw)
    # t*n / (t*n + overhead) >= eff  =>  n >= overhead*eff / (t*(1-eff))
    n = overhead * target_eff / (per_task_s * (1.0 - target_eff))
    return max(1, math.ceil(n))
