"""`python -m repro.core.obs.top` — a curses-free text dashboard that
polls a `StatsServer`'s `/stats` endpoint and redraws in place.

    python -m repro.core.obs.top --url http://127.0.0.1:8787
    python -m repro.core.obs.top --url ... --once      # single snapshot

Pure stdlib (urllib + ANSI clear), so it runs anywhere the repo does;
`render()` is importable for tests and for embedding the same view in
other tools.
"""
from __future__ import annotations

import argparse
import json
import sys
import time
import urllib.request
from typing import Optional


def fetch(url: str, timeout: float = 5.0) -> dict:
    """GET <url>/stats and decode the JSON payload."""
    with urllib.request.urlopen(url.rstrip("/") + "/stats",
                                timeout=timeout) as resp:
        return json.loads(resp.read().decode())


def render(stats: dict) -> str:
    """One screenful of dashboard text for a `/stats` payload."""
    eng = stats.get("engine") or {}
    rates = stats.get("rates") or {}
    trace = eng.get("trace") or {}
    rate = rates.get("tasks_per_s")
    window = rates.get("window_s")
    lines = [
        "repro engine — live stats",
        (f"  tasks/s {rate if rate is not None else '—':>10}"
         f"   window {window if window is not None else '—'}s"
         f"   done {eng.get('tasks_done', 0)}"
         f"   failed {eng.get('tasks_failed', 0)}"
         f"   workers {eng.get('live_workers', 0)}"
         f" (deaths {eng.get('worker_deaths', 0)})"),
        (f"  ready depth {eng.get('ready_depth', 0)}"
         f"   per-shard {eng.get('shard_ready_depth', [])}"
         f"   retried {eng.get('tasks_retried', 0)}"
         f"   journal {eng.get('journal_bytes', 0)}B"
         f"   trace emitted {trace.get('n_emitted', 0)}"
         f" dropped {trace.get('dropped', 0)}"),
        "",
    ]
    workers = stats.get("workers") or {}
    # pid/rss columns only when the rows carry them (transport="proc")
    with_pids = any(row.get("pid") for row in workers.values())
    header = f"  {'WORKER':<12}{'DONE':>10}{'BUSY_S':>12}{'BUSY%':>8}"
    if with_pids:
        header += f"{'PID':>8}{'RSS_MB':>9}"
    lines.append(header + "  STATE")
    for w, row in workers.items():
        frac = row.get("busy_frac")
        busy_pct = f"{frac * 100:7.1f}%" if frac is not None else "      —"
        line = (f"  {w:<12}{row.get('done', 0):>10}"
                f"{row.get('busy_s', 0.0):>12.3f}{busy_pct}")
        if with_pids:
            pid = row.get("pid")
            rss = row.get("rss_bytes")
            line += f"{pid if pid else '—':>8}"
            line += (f"{rss / 1e6:>9.1f}" if rss else f"{'—':>9}")
        lines.append(line + "  "
                     + ("live" if row.get("alive", True) else "DEAD"))
    cp = stats.get("critical_path") or {}
    if cp.get("skipped"):
        lines.append("")
        lines.append(f"  critical path: {cp['skipped']}")
    elif cp.get("path"):
        bd = cp.get("breakdown_s") or {}
        conc = cp.get("concurrency") or {}
        ideal = conc.get("ideal_metg")
        eff = conc.get("efficiency")
        lines.append("")
        lines.append(
            f"  critical path: {cp.get('n_tasks_on_path', 0)} of"
            f" {cp.get('n_tasks', 0)} tasks gate"
            f" {cp.get('makespan_s', 0.0):.3f}s"
            f"  sched {cp.get('sched_frac', 0.0) * 100:.1f}%"
            f" (dep-wait {bd.get('dep_wait', 0)}s"
            f" queue {bd.get('queue', 0)}s"
            f" dispatch {bd.get('dispatch', 0)}s"
            f" notify {bd.get('notify', 0)}s)")
        lines.append(
            f"   concurrency mean {conc.get('mean', 0)}"
            f" peak {conc.get('peak', 0)}"
            f" of {cp.get('workers', 0)} workers"
            + (f"  METG ideal ~{ideal}" if ideal is not None else "")
            + (f"  efficiency {eff * 100:.0f}%" if eff is not None else "")
            + f"  idle {cp.get('idle_s', 0)}s")
        ends = " -> ".join(str(t) for t in cp["path"][-3:])
        lines.append(f"   tail: {ends}")
        for s in cp.get("stragglers") or []:
            mark = "  << ON PATH" if s.get("on_path") else ""
            lines.append(f"   straggler {s['task']}"
                         f" {s['run_s']}s x{s['ratio']}"
                         f" on {s['worker']}{mark}")
    for i, rep in enumerate(stats.get("serving") or []):
        lat = rep.get("latency_ms") or {}
        lines.append("")
        lines.append(
            f"  serving[{i}]: {rep.get('n_requests', 0)} req"
            f"  p50 {lat.get('p50', 0)}ms p95 {lat.get('p95', 0)}ms"
            f" p99 {lat.get('p99', 0)}ms"
            f"  rejected {rep.get('n_rejected', 0)}"
            f"  mean batch {rep.get('mean_batch', 0)}"
            f"  queue depth {rep.get('queue_depth_mean', 0)}")
        for tenant, trep in sorted((rep.get("tenants") or {}).items()):
            tlat = trep.get("latency_ms") or {}
            lines.append(
                f"    tenant {tenant}: {trep.get('n_requests', 0)} req"
                f"  p50 {tlat.get('p50', 0)}ms"
                f" p95 {tlat.get('p95', 0)}ms"
                f" p99 {tlat.get('p99', 0)}ms"
                f"  failed {trep.get('n_failed', 0)}"
                f"  rejected {trep.get('n_rejected', 0)}")
    return "\n".join(lines)


def main(argv: Optional[list] = None) -> int:
    p = argparse.ArgumentParser(
        prog="python -m repro.core.obs.top",
        description="text dashboard over a repro StatsServer")
    p.add_argument("--url", default="http://127.0.0.1:8787",
                   help="stats server base URL (default %(default)s)")
    p.add_argument("--interval", type=float, default=2.0,
                   help="refresh period in seconds (default %(default)s)")
    p.add_argument("--once", action="store_true",
                   help="print one snapshot and exit")
    args = p.parse_args(argv)
    while True:
        try:
            stats = fetch(args.url)
        except OSError as e:
            print(f"fetch {args.url}/stats failed: {e}", file=sys.stderr)
            return 1
        out = render(stats)
        if args.once:
            print(out)
            return 0
        # ANSI clear + home: redraw in place without curses
        print("\x1b[2J\x1b[H" + out, flush=True)
        time.sleep(args.interval)


if __name__ == "__main__":
    sys.exit(main())
