"""Critical-path analysis: explain *why* a run missed maximum parallelism.

`OverheadReport` answers the paper's aggregate question (was per-task
scheduling overhead below the METG?); this module answers the per-run
one: which chain of tasks gated the makespan, and how much of that
chain was scheduler time vs compute?  It reconstructs the causal span
graph from the `TraceRecorder` lifecycle events plus the engine's
dependency table, decomposing each task's span into the Fig.-2 protocol
stages:

    dep-wait -> ready-queue -> steal/dispatch -> run -> complete-notify

with earlier run episodes (requeues after a worker death, `RetryPolicy`
re-executions) reported as wasted sub-spans.  The longest weighted path
through the completed DAG — chosen backward from the last terminal task
via each task's latest-finishing dependency — is the critical path: the
one chain whose stage times telescope *exactly* to the measured
makespan, so the decomposition is an attribution, not an estimate.

Beyond the path itself the report carries the run-shape diagnostics
that explain a parallelism gap:

  * a concurrency-vs-time profile (how many tasks were actually running)
    with mean/peak, compared against the pool size and the ideal
    parallelism implied by the METG laws in `repro.core.metg` at the
    observed mean task duration and scheduler RTT;
  * idle gaps — spans inside the makespan window where *nothing* ran;
  * straggler detection — tasks whose run time dwarfs the median, and
    whether they sit on the critical path;
  * the per-op rpc cost fold from the same events `rpc_by_op` uses.

Everything here is strictly post-hoc: the analyzer only ever reads a
snapshot of the event log, never touching the dispatch loop
(`benchmarks/engine_overhead.py --check` holds that budget).  Entry
points: `CriticalPathReport.from_trace` / `.from_engine`,
`OverheadReport.explain()`, the `/stats` `critical_path` section, and
the `python -m repro.core.obs.explain <trace>` CLI.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Optional

from repro.core.engine.model import (COMPLETED, CREATED, FAILED, READY,
                                     RETRIED, RPC, RUN_END, RUN_START,
                                     STOLEN, XFER)
from repro.core.metg import METGModel

# the Fig.-2 stage names, in causal order; every per-task decomposition
# and every report total uses these keys
SEGMENTS = ("dep_wait", "queue", "dispatch", "run", "notify")


class _Span:
    """Per-task stamp accumulator for one pass over the event log."""

    __slots__ = ("created", "ready", "steals", "runs", "open_run",
                 "terminal", "ok", "worker", "deps", "retries",
                 "xfer_s", "n_xfer", "xfer_bytes")

    def __init__(self):
        self.created = None       # first CREATED t
        self.ready = None         # first READY t
        self.steals = []          # every STOLEN t (requeues repeat)
        self.runs = []            # (t0, t1, worker) per execution episode
        self.open_run = None      # sequential RUN_START/RUN_END pairing
        self.terminal = None      # LAST COMPLETED/FAILED t
        self.ok = True
        self.worker = None
        self.deps = None          # from the CREATED event, if stamped
        self.retries = 0
        self.xfer_s = 0.0         # data-plane fetch time of THIS task's
        self.n_xfer = 0           #   value (attributed to the producer)
        self.xfer_bytes = 0


def _collect(events) -> tuple[dict, dict, dict, float]:
    """One pass: task -> _Span, rpc per-op fold, xfer per-path fold,
    trace epoch."""
    spans: dict[str, _Span] = {}
    rpc_by_op: dict = {}
    xfer_by_path: dict = {}           # path -> [n, bytes, seconds]
    t_first = None

    def span(name) -> _Span:
        s = spans.get(name)
        if s is None:
            s = spans[name] = _Span()
        return s

    for e in events:
        if t_first is None:
            t_first = e.t
        ev = e.event
        if ev == RUN_START:
            span(e.task).open_run = e.t
        elif ev == RUN_END:
            s = span(e.task)
            if s.open_run is not None:
                s.runs.append((s.open_run, e.t, e.worker))
                s.open_run = None
        elif ev == STOLEN:
            span(e.task).steals.append(e.t)
        elif ev == CREATED:
            s = span(e.task)
            if s.created is None:
                s.created = e.t
                deps = e.extra.get("deps")
                if deps:
                    s.deps = tuple(deps)
        elif ev == READY:
            s = span(e.task)
            if s.ready is None:
                s.ready = e.t
        elif ev in (COMPLETED, FAILED):
            s = span(e.task)
            s.terminal = e.t              # last wins: resurrected stubs
            s.ok = ev == COMPLETED
            if e.worker is not None:
                s.worker = e.worker
        elif ev == RETRIED:
            span(e.task).retries += 1
        elif ev == RPC:
            op = e.extra.get("op", "?")
            dt = e.extra.get("dt", 0.0)
            cnt, tot = rpc_by_op.get(op, (0, 0.0))
            rpc_by_op[op] = (cnt + 1, tot + dt)
        elif ev == XFER:
            # data-plane fetch of e.task's value (peer or hub path) —
            # folded onto the PRODUCER's span and the per-path totals
            n = e.extra.get("n", 0)
            dt = e.extra.get("dt", 0.0)
            s = span(e.task)
            s.n_xfer += 1
            s.xfer_bytes += n
            s.xfer_s += dt
            ent = xfer_by_path.get(e.extra.get("path", "?"))
            if ent is None:
                ent = xfer_by_path[e.extra.get("path", "?")] = [0, 0, 0.0]
            ent[0] += 1
            ent[1] += n
            ent[2] += dt
    return spans, rpc_by_op, xfer_by_path, (t_first or 0.0)


def _arrive_t(s: _Span) -> Optional[float]:
    """Earliest stamp a task's causal span can anchor on (CREATED is
    absent for pre-created server universes and ring-evicted heads)."""
    for t in (s.created, s.ready,
              s.steals[0] if s.steals else None,
              s.runs[0][0] if s.runs else None):
        if t is not None:
            return t
    return s.terminal


def _segments_of(s: _Span, t_arrive: float) -> dict:
    """Decompose [t_arrive, terminal] into the five protocol stages using
    the FINAL execution episode (earlier episodes are wasted work).  The
    checkpoints are prefix-max clamped, so the stage durations are
    non-negative and telescope exactly to `terminal - t_arrive`."""
    run = s.runs[-1] if s.runs else None
    if run is not None:
        t0, t1 = run[0], run[1]
        steal = None
        for t in reversed(s.steals):
            if t <= t0:
                steal = t
                break
        if steal is None and s.steals:
            steal = s.steals[-1]
        raw = (s.ready, steal, t0, t1, s.terminal)
    else:
        # never ran: poisoned / cancelled / fail-fast — the whole span is
        # dep-wait (it waited on a producer that failed it)
        raw = (s.terminal, s.terminal, s.terminal, s.terminal, s.terminal)
    cps = [t_arrive]
    for t in raw:
        prev = cps[-1]
        cps.append(prev if t is None else max(t, prev))
    return {name: cps[i + 1] - cps[i] for i, name in enumerate(SEGMENTS)}


@dataclass
class CriticalPathReport:
    """The causal explanation of one run's makespan.  Build it with
    `from_trace` / `from_engine` (or `OverheadReport.explain()`); read
    it with `summary()`, render it with `repro.core.obs.explain.render`,
    overlay it on a timeline with
    `trace.to_chrome_trace(path, critical_path=report.path)`."""
    path: list = field(default_factory=list)        # task names, in order
    segments: list = field(default_factory=list)    # per path task dicts
    makespan_s: float = 0.0          # path-start arrive -> last terminal
    wall_s: float = 0.0              # full trace span (>= makespan_s)
    t_start: float = 0.0             # path start, relative to trace epoch
    n_tasks: int = 0                 # tasks that reached terminal
    workers: int = 1                 # pool size the run was configured for
    # makespan decomposition over the path (sums to makespan_s):
    dep_wait_s: float = 0.0
    queue_s: float = 0.0
    dispatch_s: float = 0.0
    run_s: float = 0.0               # compute-attributable
    notify_s: float = 0.0
    wasted_s: float = 0.0            # earlier run episodes on the path
    # concurrency-vs-time:
    concurrency_mean: float = 0.0
    concurrency_peak: int = 0
    profile: list = field(default_factory=list)     # (t_rel, n_running)
    idle_s: float = 0.0              # makespan time with nothing running
    idle_gaps: list = field(default_factory=list)   # longest (t_rel, dur)
    # stragglers:
    stragglers: list = field(default_factory=list)
    straggler_factor: float = 4.0
    run_median_s: float = 0.0
    # METG-law comparison:
    scheduler: Optional[str] = None
    metg_ideal_workers: Optional[float] = None
    parallel_efficiency: Optional[float] = None
    # rpc fold (same exclusion rules as OverheadReport):
    rpc_s: float = 0.0
    n_rpc: int = 0
    rtt_mean_s: float = 0.0
    rpc_by_op: dict = field(default_factory=dict)
    # data motion (peer-to-peer data plane, transport="proc"):
    xfer_s: float = 0.0              # total fetch time, all tasks
    n_xfer: int = 0
    xfer_bytes: int = 0
    xfer_by_path: dict = field(default_factory=dict)  # path -> (n, B, s)
    path_xfer_s: float = 0.0         # fetch time of critical-path values
    # truncation honesty:
    n_emitted: int = 0
    dropped: int = 0

    # ------------------------------------------------------------ derived
    @property
    def compute_s(self) -> float:
        """Compute-attributable share of the makespan (path run time)."""
        return self.run_s

    @property
    def sched_s(self) -> float:
        """Scheduler-attributable share of the makespan: everything on
        the path that is not the final run episodes."""
        return (self.dep_wait_s + self.queue_s + self.dispatch_s
                + self.notify_s)

    @property
    def sched_frac(self) -> float:
        return self.sched_s / self.makespan_s if self.makespan_s > 0 else 0.0

    @property
    def xfer_verdict(self) -> Optional[str]:
        """Was the run gated by moving bytes or by scheduling them?
        None when the data plane never fetched anything (inline-only
        runs, in-process transports).  "transfer-bound" when the fetch
        time of critical-path values exceeds the path's scheduler share
        (dispatch + queue + notify) — shrinking rpc latency then cannot
        help as much as moving fewer bytes (bigger inline threshold,
        better placement); "dispatch-bound" otherwise."""
        if self.n_xfer == 0:
            return None
        sched_non_wait = self.queue_s + self.dispatch_s + self.notify_s
        return ("transfer-bound" if self.path_xfer_s > sched_non_wait
                else "dispatch-bound")

    # --------------------------------------------------------- construction
    @classmethod
    def from_trace(cls, trace, *, deps: Optional[dict] = None,
                   workers: int = 1, scheduler: Optional[str] = None,
                   steal_n: int = 1, shards: int = 1,
                   model: Optional[METGModel] = None,
                   straggler_factor: float = 4.0,
                   profile_points: int = 240) -> "CriticalPathReport":
        """Analyze a `TraceRecorder`.  `deps` maps task -> iterable of
        dependency names (e.g. `engine.dep_table()`); without it the
        analyzer uses the `deps` stamped on CREATED events — identical
        for any trace the engine produced.  `scheduler` ("dwork" /
        "pmake" / "mpi-list", default dwork) selects the METG law for
        the ideal-parallelism comparison."""
        with trace._lock:
            events = list(trace.events)
        rep = cls.from_events(
            events, deps=deps, workers=workers, scheduler=scheduler,
            steal_n=steal_n, shards=shards, model=model,
            straggler_factor=straggler_factor,
            profile_points=profile_points)
        rep.n_emitted = trace.n_emitted
        rep.dropped = trace.dropped
        # sampled tracing: scale recorded round-trips up to the true count
        if trace.rpc_seen > rep.n_rpc > 0:
            rep.rpc_s *= trace.rpc_seen / rep.n_rpc
            rep.n_rpc = trace.rpc_seen
        return rep

    @classmethod
    def from_engine(cls, engine, **kw) -> "CriticalPathReport":
        """Analyze a live (or finished) engine: its tracer joined with
        its dependency table and pool shape.  Monitoring-grade reads
        only — never blocks the dispatch loop."""
        kw.setdefault("deps", engine.dep_table())
        kw.setdefault("workers", max(engine.live_workers(), 1))
        kw.setdefault("steal_n", getattr(engine, "steal_n", 1))
        kw.setdefault("shards", getattr(engine, "shards", 1))
        return cls.from_trace(engine.tracer, **kw)

    @classmethod
    def from_events(cls, events: list, *, deps: Optional[dict] = None,
                    workers: int = 1, scheduler: Optional[str] = None,
                    steal_n: int = 1, shards: int = 1,
                    model: Optional[METGModel] = None,
                    straggler_factor: float = 4.0,
                    profile_points: int = 240) -> "CriticalPathReport":
        spans, rpc_by_op, xfer_by_path, t_epoch = _collect(events)
        term = {n: s for n, s in spans.items() if s.terminal is not None}
        rep = cls(workers=max(int(workers), 1), scheduler=scheduler,
                  straggler_factor=straggler_factor, n_tasks=len(term))
        # rpc fold (hop:* stays in the breakdown, out of the totals)
        rep.rpc_by_op = {op: (cnt, tot)
                         for op, (cnt, tot) in sorted(rpc_by_op.items())}
        for op, (cnt, tot) in rpc_by_op.items():
            if not op.startswith("hop:"):
                rep.rpc_s += tot
                rep.n_rpc += cnt
        rep.rtt_mean_s = rep.rpc_s / rep.n_rpc if rep.n_rpc else 0.0
        # data-motion fold (unsampled: every fetch emits exactly one XFER)
        rep.xfer_by_path = {p: (n, b, round(t, 6))
                            for p, (n, b, t) in sorted(xfer_by_path.items())}
        for n, b, t in xfer_by_path.values():
            rep.n_xfer += n
            rep.xfer_bytes += b
            rep.xfer_s += t
        if events:
            ts = [e.t for e in events]
            rep.wall_s = max(ts) - min(ts)
        if not term:
            return rep

        def dep_names(name: str):
            if deps is not None:
                return deps.get(name) or ()
            s = spans.get(name)
            return s.deps or () if s is not None else ()

        # ---- longest path: walk back from the last terminal task via the
        # latest-finishing dependency, extending only while the chosen
        # edge was binding (the dep finished after this task existed —
        # a dep that completed before the dependent was even created
        # gated nothing)
        end = max(term, key=lambda n: term[n].terminal)
        path = [end]
        seen = {end}
        cur = end
        while True:
            cands = [d for d in dep_names(cur)
                     if d in term and d not in seen]
            if not cands:
                break
            best = max(cands, key=lambda d: term[d].terminal)
            t_cur = _arrive_t(spans[cur])
            if t_cur is not None and term[best].terminal < t_cur:
                break
            path.append(best)
            seen.add(best)
            cur = best
        path.reverse()
        rep.path = path

        # ---- stage decomposition: each path task's span starts where the
        # previous one finished (the chain is causal and the engine stamps
        # READY after the producer's COMPLETED, so checkpoints are
        # monotone) — the sum telescopes exactly to the makespan
        t_start = _arrive_t(spans[path[0]])
        t_end = term[end].terminal
        rep.t_start = t_start - t_epoch
        rep.makespan_s = max(t_end - t_start, 0.0)
        prev_t = t_start
        for name in path:
            s = spans[name]
            seg = _segments_of(s, prev_t)
            wasted = sum(t1 - t0 for t0, t1, _ in s.runs[:-1])
            row = {"task": name, "worker": s.worker,
                   "t_s": round(prev_t - t_epoch, 6),
                   "n_runs": len(s.runs), "retries": s.retries,
                   **{f"{k}_s": round(v, 6) for k, v in seg.items()}}
            if s.n_xfer:
                # data motion: time dependents spent fetching THIS value
                row["xfer_s"] = round(s.xfer_s, 6)
                row["xfer_bytes"] = s.xfer_bytes
                rep.path_xfer_s += s.xfer_s
            if wasted:
                row["wasted_s"] = round(wasted, 6)
                row["episodes"] = [
                    {"t_s": round(t0 - t_epoch, 6),
                     "run_s": round(t1 - t0, 6), "worker": w}
                    for t0, t1, w in s.runs[:-1]]
            rep.segments.append(row)
            rep.dep_wait_s += seg["dep_wait"]
            rep.queue_s += seg["queue"]
            rep.dispatch_s += seg["dispatch"]
            rep.run_s += seg["run"]
            rep.notify_s += seg["notify"]
            rep.wasted_s += wasted
            prev_t = s.terminal

        # ---- concurrency-vs-time over EVERY run episode (wasted work
        # occupied a worker too), swept inside the makespan window
        marks = []
        total_run = 0.0
        finals = []
        for name, s in term.items():
            for t0, t1, _w in s.runs:
                a, b = max(t0, t_start), min(t1, t_end)
                if b > a:
                    marks.append((a, 1))
                    marks.append((b, -1))
                    total_run += b - a
            if s.runs:
                finals.append((name, s.runs[-1]))
        marks.sort()
        profile = []                    # (t, level) changepoints
        level = 0
        idle_gaps = []                  # (t_gap_start, dur)
        t_idle_from = t_start
        for t, d in marks:
            if level == 0 and d > 0 and t > t_idle_from:
                idle_gaps.append((t_idle_from, t - t_idle_from))
            level += d
            if d < 0 and level == 0:
                t_idle_from = t
            if profile and profile[-1][0] == t:
                profile[-1] = (t, level)
            else:
                profile.append((t, level))
        if level == 0 and t_end > t_idle_from:
            idle_gaps.append((t_idle_from, t_end - t_idle_from))
        rep.idle_s = sum(d for _, d in idle_gaps)
        idle_gaps.sort(key=lambda g: -g[1])
        rep.idle_gaps = [(round(t - t_epoch, 6), round(d, 6))
                         for t, d in idle_gaps[:5]]
        rep.concurrency_peak = max((lv for _, lv in profile), default=0)
        if rep.makespan_s > 0:
            rep.concurrency_mean = total_run / rep.makespan_s
        if len(profile) > profile_points:
            step = len(profile) / profile_points
            profile = [profile[int(i * step)]
                       for i in range(profile_points)]
        rep.profile = [(round(t - t_epoch, 6), lv) for t, lv in profile]

        # ---- stragglers: final-episode run times vs the median
        durs = sorted(t1 - t0 for _, (t0, t1, _w) in finals)
        if durs:
            rep.run_median_s = durs[len(durs) // 2]
        med = rep.run_median_s
        on_path = set(path)
        if med > 0:
            out = [(name, t1 - t0, w) for name, (t0, t1, w) in finals
                   if (t1 - t0) >= straggler_factor * med]
            out.sort(key=lambda r: -r[1])
            rep.stragglers = [
                {"task": name, "worker": w, "run_s": round(d, 6),
                 "ratio": round(d / med, 2), "on_path": name in on_path}
                for name, d, w in out[:5]]

        # ---- METG-law ideal parallelism at the observed task granularity
        mean_task_s = (sum(durs) / len(durs)) if durs else 0.0
        rep.metg_ideal_workers = _ideal_workers(
            scheduler or "dwork", mean_task_s, rep.rtt_mean_s,
            steal_n=steal_n, shards=shards, model=model)
        cap = rep.workers
        if rep.metg_ideal_workers is not None:
            cap = min(cap, rep.metg_ideal_workers)
        if cap and cap > 0:
            rep.parallel_efficiency = min(
                rep.concurrency_mean / cap, 1.0)
        return rep

    # ------------------------------------------------------------- output
    def summary(self, max_tasks: Optional[int] = None) -> dict:
        """JSON-able digest (the `/stats` `critical_path` section).  With
        `max_tasks`, the per-task segment rows are capped to the LAST
        `max_tasks` path entries (the end of the path is where the run
        finished — usually the interesting part)."""
        segs = self.segments
        path = self.path
        truncated = False
        if max_tasks is not None and len(segs) > max_tasks:
            segs = segs[-max_tasks:]
            path = path[-max_tasks:]
            truncated = True
        out = {
            "n_tasks": self.n_tasks,
            "n_tasks_on_path": len(self.path),
            "makespan_s": round(self.makespan_s, 6),
            "wall_s": round(self.wall_s, 6),
            "workers": self.workers,
            "compute_s": round(self.compute_s, 6),
            "sched_s": round(self.sched_s, 6),
            "sched_frac": round(self.sched_frac, 4),
            "breakdown_s": {
                "dep_wait": round(self.dep_wait_s, 6),
                "queue": round(self.queue_s, 6),
                "dispatch": round(self.dispatch_s, 6),
                "run": round(self.run_s, 6),
                "notify": round(self.notify_s, 6),
            },
            "wasted_s": round(self.wasted_s, 6),
            "concurrency": {
                "mean": round(self.concurrency_mean, 3),
                "peak": self.concurrency_peak,
                "ideal_metg": (round(self.metg_ideal_workers, 1)
                               if self.metg_ideal_workers is not None
                               else None),
                "efficiency": (round(self.parallel_efficiency, 4)
                               if self.parallel_efficiency is not None
                               else None),
            },
            "idle_s": round(self.idle_s, 6),
            "idle_gaps": self.idle_gaps,
            "stragglers": self.stragglers,
            "rpc": {"n": self.n_rpc, "total_s": round(self.rpc_s, 6),
                    "rtt_mean_us": round(self.rtt_mean_s * 1e6, 2)},
            "data_motion": {
                "n_xfer": self.n_xfer,
                "bytes": self.xfer_bytes,
                "total_s": round(self.xfer_s, 6),
                "path_s": round(self.path_xfer_s, 6),
                "by_path": {p: {"n": n, "bytes": b, "total_s": t}
                            for p, (n, b, t) in self.xfer_by_path.items()},
                "verdict": self.xfer_verdict,
            },
            "path": path,
            "segments": segs,
        }
        if truncated:
            out["path_truncated"] = True
        if self.dropped:
            out["n_emitted"] = self.n_emitted
            out["dropped"] = self.dropped
        return out


def _ideal_workers(scheduler: str, task_s: float, rtt_s: float, *,
                   steal_n: int = 1, shards: int = 1,
                   model: Optional[METGModel] = None) -> Optional[float]:
    """Invert the METG law: the parallelism P at which per-task
    scheduling overhead would equal the observed mean task duration
    (50% efficiency) — running wider than this cannot help, so it is the
    ceiling the concurrency profile should be compared against.  None
    when the law cannot be inverted from what was measured."""
    if task_s <= 0.0:
        return None
    m = model
    if scheduler == "dwork":
        # METG(P) = rtt * P / (steal_n * shards)  =>  P*
        rtt = rtt_s if rtt_s > 0 else (m.dwork_rtt if m is not None
                                       else None)
        if not rtt:
            return None
        return task_s * max(steal_n, 1) * max(shards, 1) / rtt
    if m is None:
        m = METGModel.from_paper()
    if scheduler == "pmake":
        # METG(P) = a + b ln P + alloc  =>  P* = exp((t - alloc - a) / b)
        if m.jsrun_b <= 0:
            return None
        x = (task_s - m.alloc - m.jsrun_a) / m.jsrun_b
        return math.exp(min(x, 50.0)) if x > 0 else 1.0
    if scheduler in ("mpi-list", "mpi_list"):
        # sync gap a + b ln P (ms) = t  =>  P* on the fitted curve
        if m.sync_b <= 0:
            return None
        x = (task_s * 1e3 - m.sync_a) / m.sync_b
        return math.exp(min(x, 50.0)) if x > 0 else 1.0
    return None
