"""Live observability for the unified engine, serving layer, and client.

Three pieces, designed to compose (see `docs/observability.md`):

  * `metrics`      — lock-cheap Counter/Gauge/Histogram primitives and
                     the `MetricsRegistry` (JSON dump + Prometheus text)
  * `instrument`   — wires a registry onto a live Engine / Client /
                     Frontend: callback instruments over state the hot
                     loop maintains anyway, plus sampled rpc and
                     per-request latency histograms
  * `server`       — `StatsServer`: `/stats`, `/health`, `/metrics`
                     over stdlib `http.server`;
    `top`          — `python -m repro.core.obs.top` text dashboard
  * `chrome_trace` — `to_chrome_trace`: the `TraceRecorder` event log
                     as a Perfetto-loadable timeline (also available as
                     `TraceRecorder.to_chrome_trace(path)`), with an
                     optional critical-path lane + flow arrows
  * `critical_path` — `CriticalPathReport`: post-hoc causal analysis of
                     a run (makespan decomposition, concurrency vs the
                     METG-law ideal, idle gaps, stragglers);
    `explain`      — `python -m repro.core.obs.explain <trace>` CLI and
                     the text renderer over it

The one-call front door is `Client.stats_server()`; everything here
also works piecemeal on a bare `Engine`.
"""
from repro.core.obs.chrome_trace import to_chrome_trace
from repro.core.obs.critical_path import CriticalPathReport
from repro.core.obs.instrument import (RPC_BUCKETS, RpcMetrics,
                                       ServingMetrics, instrument)
from repro.core.obs.metrics import (LATENCY_BUCKETS, Counter, Gauge,
                                    Histogram, MetricsRegistry)
from repro.core.obs.server import StatsServer

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry",
    "LATENCY_BUCKETS", "RPC_BUCKETS",
    "RpcMetrics", "ServingMetrics", "instrument",
    "StatsServer", "to_chrome_trace", "CriticalPathReport",
]
