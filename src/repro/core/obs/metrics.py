"""Lock-cheap metrics primitives: counters, gauges, histograms, and the
registry that owns them.

Design rules (the instrumentation-overhead budget depends on them):

  * **Single-writer updates are unlocked.**  `Counter.inc` /
    `Histogram.observe` mutate plain slots under the GIL with no lock —
    each hot-path metric has exactly one writer thread (the dispatch
    loop, one frontend coalescer, one client thread), so unlocked
    updates are exact there.  The rare multi-writer metric tolerates an
    occasionally-lost increment: monitoring reads are approximate by
    nature, and a lock on the hot path is the one cost this subsystem
    must not impose.
  * **Callback instruments cost nothing until scraped.**  A counter or
    gauge built with `fn=` reads an existing engine/frontend attribute
    (live worker count, ready depth, terminal totals) at dump time —
    the hot loop maintains those values anyway, so attaching metrics
    adds zero instructions to it.
  * **Histograms have fixed bucket boundaries** chosen at creation
    (default: a µs-to-10 s latency ladder), so `observe` is one C
    `bisect` + one list-slot increment, and the Prometheus exposition
    needs no per-scrape aggregation.

Registry creation (`counter()`/`gauge()`/`histogram()`) is
get-or-create keyed by (name, labels) and IS locked — it happens once
per metric, not per update.  `dump()` returns a JSON-able snapshot;
`prometheus()` renders the text exposition format (version 0.0.4).
"""
from __future__ import annotations

import threading
from bisect import bisect_left
from typing import Callable, Optional

# µs .. 10 s: wide enough for in-proc rpc (~1 µs) and batched model
# inference (~seconds) on one ladder, small enough to bisect cheaply
LATENCY_BUCKETS = (
    1e-6, 2.5e-6, 5e-6, 1e-5, 2.5e-5, 5e-5, 1e-4, 2.5e-4, 5e-4,
    1e-3, 2.5e-3, 5e-3, 1e-2, 2.5e-2, 5e-2, 0.1, 0.25, 0.5,
    1.0, 2.5, 5.0, 10.0,
)


def _fmt(v: float) -> str:
    """Prometheus sample-value formatting (ints stay ints)."""
    if isinstance(v, bool):
        return "1" if v else "0"
    if isinstance(v, int):
        return str(v)
    if v != v:                    # NaN
        return "NaN"
    if v in (float("inf"), float("-inf")):
        return "+Inf" if v > 0 else "-Inf"
    return repr(float(v))


def _escape(v: str) -> str:
    return (str(v).replace("\\", r"\\").replace('"', r'\"')
            .replace("\n", r"\n"))


class Counter:
    """Monotonically-increasing count.  `fn=` makes it a callback
    counter: the value is read from an existing attribute at scrape
    time and `inc()` is forbidden (the owner already counts)."""

    kind = "counter"
    __slots__ = ("name", "help", "labels", "_value", "_fn")

    def __init__(self, name: str, help: str = "", labels: Optional[dict] = None,
                 fn: Optional[Callable[[], float]] = None):
        self.name = name
        self.help = help
        self.labels = dict(labels or {})
        self._value = 0
        self._fn = fn

    def inc(self, n=1):
        if self._fn is not None:
            raise RuntimeError(f"{self.name} is a callback counter")
        self._value += n

    @property
    def value(self):
        if self._fn is not None:
            try:
                return self._fn()
            except Exception:    # noqa: BLE001 — monitoring must never
                return 0         # take the observed system down
        return self._value


class Gauge:
    """A value that can go up and down; `fn=` for callback gauges."""

    kind = "gauge"
    __slots__ = ("name", "help", "labels", "_value", "_fn")

    def __init__(self, name: str, help: str = "", labels: Optional[dict] = None,
                 fn: Optional[Callable[[], float]] = None):
        self.name = name
        self.help = help
        self.labels = dict(labels or {})
        self._value = 0.0
        self._fn = fn

    def set(self, v):
        self._value = v

    def inc(self, n=1):
        self._value += n

    def dec(self, n=1):
        self._value -= n

    @property
    def value(self):
        if self._fn is not None:
            try:
                return self._fn()
            except Exception:    # noqa: BLE001
                return 0
        return self._value


class Histogram:
    """Fixed-boundary histogram: `buckets` are ascending upper bounds in
    the observed unit (seconds for latencies); counts[i] is the number of
    observations <= buckets[i], with one extra overflow slot (+Inf)."""

    kind = "histogram"
    __slots__ = ("name", "help", "labels", "buckets", "counts",
                 "sum", "count")

    def __init__(self, name: str, help: str = "", labels: Optional[dict] = None,
                 buckets=LATENCY_BUCKETS):
        self.name = name
        self.help = help
        self.labels = dict(labels or {})
        self.buckets = tuple(sorted(buckets))
        if not self.buckets:
            raise ValueError("histogram needs at least one bucket bound")
        self.counts = [0] * (len(self.buckets) + 1)
        self.sum = 0.0
        self.count = 0

    def observe(self, v: float):
        self.counts[bisect_left(self.buckets, v)] += 1
        self.sum += v
        self.count += 1

    def quantile(self, q: float) -> float:
        """Bucket-interpolated quantile estimate (0 on empty)."""
        total = self.count
        if total <= 0:
            return 0.0
        target = q * total
        cum = 0
        lo = 0.0
        for i, c in enumerate(self.counts):
            hi = self.buckets[i] if i < len(self.buckets) else self.buckets[-1]
            if cum + c >= target:
                if c == 0 or i >= len(self.buckets):
                    return hi
                frac = (target - cum) / c
                return lo + (hi - lo) * frac
            cum += c
            lo = hi
        return self.buckets[-1]

    def snapshot(self) -> dict:
        counts = list(self.counts)            # one pass, consistent-ish
        out, cum = {}, 0
        for bound, c in zip(self.buckets, counts):
            cum += c
            out[_fmt(bound)] = cum
        out["+Inf"] = cum + counts[-1]
        return {"count": self.count, "sum": self.sum, "buckets": out}


class MetricsRegistry:
    """Owns every metric of one observed system.  Get-or-create accessors
    are keyed by (name, labels); asking for an existing key returns the
    same instance (so hot-path callers can cache it), and a kind
    mismatch raises."""

    def __init__(self):
        self._metrics: dict = {}
        self._lock = threading.Lock()

    # ------------------------------------------------------------ create
    def _get(self, cls, name: str, help: str, labels, **kw):
        key = (name, tuple(sorted((labels or {}).items())))
        with self._lock:
            m = self._metrics.get(key)
            if m is None:
                m = cls(name, help=help, labels=labels, **kw)
                self._metrics[key] = m
            elif not isinstance(m, cls):
                raise TypeError(f"metric {name!r} already registered as "
                                f"{m.kind}")
            return m

    def counter(self, name: str, help: str = "", *,
                labels: Optional[dict] = None,
                fn: Optional[Callable] = None) -> Counter:
        return self._get(Counter, name, help, labels, fn=fn)

    def gauge(self, name: str, help: str = "", *,
              labels: Optional[dict] = None,
              fn: Optional[Callable] = None) -> Gauge:
        return self._get(Gauge, name, help, labels, fn=fn)

    def histogram(self, name: str, help: str = "", *,
                  labels: Optional[dict] = None,
                  buckets=LATENCY_BUCKETS) -> Histogram:
        return self._get(Histogram, name, help, labels, buckets=buckets)

    # -------------------------------------------------------------- read
    def _items(self) -> list:
        with self._lock:
            return list(self._metrics.values())

    @staticmethod
    def _key(m) -> str:
        if not m.labels:
            return m.name
        inner = ",".join(f'{k}="{_escape(v)}"'
                         for k, v in sorted(m.labels.items()))
        return f"{m.name}{{{inner}}}"

    def dump(self) -> dict:
        """JSON-able snapshot: {'counters': {...}, 'gauges': {...},
        'histograms': {...}} keyed by the label-qualified metric name."""
        out = {"counters": {}, "gauges": {}, "histograms": {}}
        for m in self._items():
            key = self._key(m)
            if m.kind == "histogram":
                out["histograms"][key] = m.snapshot()
            else:
                out[m.kind + "s"][key] = m.value
        return out

    def prometheus(self) -> str:
        """Text exposition (format version 0.0.4): # HELP / # TYPE once
        per metric family, then one sample line per labelset (histograms
        expand to cumulative _bucket{le=} series plus _sum/_count)."""
        lines: list[str] = []
        seen_family: set = set()
        for m in sorted(self._items(),
                        key=lambda m: (m.name, sorted(m.labels.items()))):
            if m.name not in seen_family:
                seen_family.add(m.name)
                if m.help:
                    lines.append(f"# HELP {m.name} {m.help}")
                lines.append(f"# TYPE {m.name} {m.kind}")
            base = sorted(m.labels.items())
            if m.kind == "histogram":
                snap = m.snapshot()
                for le, cum in snap["buckets"].items():
                    lbl = ",".join(f'{k}="{_escape(v)}"'
                                   for k, v in base + [("le", le)])
                    lines.append(f"{m.name}_bucket{{{lbl}}} {cum}")
                suffix = ("{" + ",".join(f'{k}="{_escape(v)}"'
                                         for k, v in base) + "}"
                          if base else "")
                lines.append(f"{m.name}_sum{suffix} {_fmt(snap['sum'])}")
                lines.append(f"{m.name}_count{suffix} {snap['count']}")
            else:
                suffix = ("{" + ",".join(f'{k}="{_escape(v)}"'
                                         for k, v in base) + "}"
                          if base else "")
                lines.append(f"{m.name}{suffix} {_fmt(m.value)}")
        return "\n".join(lines) + "\n"
