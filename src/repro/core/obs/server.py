"""Streaming stats endpoint: a stdlib `http.server` thread over a live
`MetricsRegistry` + engine, so a resident service is observable without
stopping it (ROADMAP item 5's status/worker-monitor shape).

Endpoints:

  * `GET /stats`   — JSON: the registry dump, windowed rates (tasks/s
    and per-worker busy fraction over the interval since the previous
    scrape), per-worker and per-shard tables, trace counters, a
    `critical_path` section (the post-hoc analyzer's digest — why the
    run-so-far took as long as it did; skipped with a reason once the
    retained trace exceeds `explain_max_events`), and the latest
    windowed `LatencyReport` per serving frontend (with per-tenant
    slices when requests carry `tenant=` labels)
  * `GET /health`  — JSON liveness: `ok` is false once the resident
    dispatch loop has died
  * `GET /metrics` — Prometheus text exposition (format 0.0.4)

Rates are scrape-windowed: each `/stats` diffs the cumulative
done/busy tables against the previous scrape (baseline taken at
`start()`), so the scraper's own interval is the averaging window —
the standard pull-model convention, and it needs no background sampler
thread of its own.  All reads are monitoring-grade: unlocked engine
tables read under the GIL, never blocking the dispatch loop.

`Client.stats_server()` builds the registry (via `obs.instrument`) and
one of these in a single call.
"""
from __future__ import annotations

import json
import threading
import time
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Optional


class _HTTPServer(ThreadingHTTPServer):
    daemon_threads = True
    allow_reuse_address = True
    owner: "StatsServer"


class _Handler(BaseHTTPRequestHandler):
    server_version = "repro-obs/1"

    def do_GET(self):  # noqa: N802 — http.server API
        owner = self.server.owner
        path = self.path.split("?", 1)[0]
        try:
            if path == "/stats":
                body = json.dumps(owner.stats(), default=str).encode()
                ctype = "application/json"
            elif path == "/health":
                body = json.dumps(owner.health()).encode()
                ctype = "application/json"
            elif path == "/metrics":
                body = owner.registry.prometheus().encode()
                ctype = "text/plain; version=0.0.4; charset=utf-8"
            else:
                self.send_error(404, "unknown endpoint "
                                     "(try /stats, /health, /metrics)")
                return
        except Exception as e:   # noqa: BLE001 — a scrape failure is the
            self.send_error(500, repr(e))   # scraper's problem, never the
            return                          # observed system's
        self.send_response(200)
        self.send_header("Content-Type", ctype)
        self.send_header("Content-Length", str(len(body)))
        self.end_headers()
        self.wfile.write(body)

    def log_message(self, *args):     # silence per-request stderr lines
        pass


class StatsServer:
    """Serve `/stats`, `/health`, `/metrics` for one registry + engine.

        srv = StatsServer(reg, engine=engine).start()
        urllib.request.urlopen(srv.url + "/stats")

    `port=0` (default) binds an ephemeral port, published as
    `srv.port` / `srv.url` after `start()`.  Pass `client=` to follow
    its engine AND any frontends it attaches later via `serve()`.
    """

    def __init__(self, registry, *, client=None, engine=None,
                 frontends: Optional[list] = None,
                 host: str = "127.0.0.1", port: int = 0,
                 explain_max_events: int = 200_000):
        self.registry = registry
        self._client = client
        self._engine = engine if engine is not None else (
            client.engine if client is not None else None)
        self._frontends = frontends
        # critical-path scrape budget: the analyzer is post-hoc (one
        # pass over a snapshot of the event log), but a scrape must stay
        # cheap — above this many retained events the section reports
        # "skipped" instead of analyzing.  0 disables the section.
        self.explain_max_events = explain_max_events
        self.host = host
        self.port = port
        self._httpd: Optional[_HTTPServer] = None
        self._thread: Optional[threading.Thread] = None
        self._lock = threading.Lock()
        self._last = None          # (t_mono, done_total, {w: busy_s})

    # ----------------------------------------------------------- lifecycle
    def start(self) -> "StatsServer":
        if self._httpd is not None:
            return self
        httpd = _HTTPServer((self.host, self.port), _Handler)
        httpd.owner = self
        self.port = httpd.server_address[1]
        if self._engine is not None:
            # baseline so the FIRST scrape already has a rate window
            wstats = self._engine.worker_stats()
            self._last = (time.monotonic(),
                          self._engine.tasks_done_total(),
                          {w: s["busy_s"] for w, s in wstats.items()})
        self._httpd = httpd
        self._thread = threading.Thread(target=httpd.serve_forever,
                                        name="obs-stats", daemon=True)
        self._thread.start()
        return self

    def stop(self):
        httpd, self._httpd = self._httpd, None
        if httpd is None:
            return
        httpd.shutdown()
        httpd.server_close()
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    @property
    def url(self) -> str:
        return f"http://{self.host}:{self.port}"

    def __enter__(self) -> "StatsServer":
        return self.start()

    def __exit__(self, *exc):
        self.stop()

    # ------------------------------------------------------------ payloads
    def _live_frontends(self) -> list:
        if self._frontends is not None:
            return list(self._frontends)
        if self._client is not None:
            return list(self._client._frontends)
        return []

    def stats(self) -> dict:
        eng = self._engine
        pids: dict = {}
        if eng is not None:
            # proc transport: register RSS gauges for workers that joined
            # after instrumentation, BEFORE the registry dump below so
            # the same scrape already exposes them
            pids = eng.worker_pids()
            if pids:
                from repro.core.obs.instrument import instrument_worker_rss
                instrument_worker_rss(self.registry, eng)
        payload: dict = {"metrics": self.registry.dump()}
        if eng is not None:
            now = time.monotonic()
            wstats = eng.worker_stats()
            done_total = eng.tasks_done_total()
            busy_now = {w: s["busy_s"] for w, s in wstats.items()}
            with self._lock:
                last = self._last
                self._last = (now, done_total, busy_now)
            window = rate = None
            lbusy: dict = {}
            if last is not None:
                lt, ldone, lbusy = last
                window = max(now - lt, 1e-9)
                rate = max(done_total - ldone, 0) / window
            if pids:
                from repro.core.obs.instrument import _pid_rss
            workers = {}
            for w, s in wstats.items():
                row = {"done": s["done"],
                       "busy_s": round(s["busy_s"], 6),
                       "alive": s["alive"]}
                if window is not None:
                    frac = (s["busy_s"] - lbusy.get(w, 0.0)) / window
                    row["busy_frac"] = round(min(max(frac, 0.0), 1.0), 4)
                pid = pids.get(w)
                if pid:
                    row["pid"] = pid
                    row["rss_bytes"] = _pid_rss(pid)
                workers[w] = row
            tracer = eng.tracer
            journal = eng.journal
            payload["engine"] = {
                "live_workers": eng.live_workers(),
                "worker_deaths": eng.worker_deaths,
                "tasks_done": done_total,
                "tasks_failed": eng.exec_failed,
                "tasks_retried": eng.retries_total,
                "journal_bytes": (journal.bytes_written
                                  if journal is not None else 0),
                "ready_depth": eng.backend.ready_depth(),
                "shard_ready_depth": eng.backend.ready_depths(),
                "trace": {"n_emitted": tracer.n_emitted,
                          "dropped": tracer.dropped,
                          "rpc_seen": tracer.rpc_seen},
            }
            totals = getattr(eng, "xfer_totals", None)
            if totals is not None:
                with eng._xfer_lock:
                    snap = {p: list(v) for p, v in totals.items()}
                payload["engine"]["xfer"] = {
                    "lost": eng.xfer_lost_total,
                    "by_path": {p: {"n": n, "bytes": b,
                                    "total_s": round(t, 6)}
                                for p, (n, b, t) in sorted(snap.items())},
                }
            payload["rates"] = {
                "tasks_per_s": (round(rate, 3)
                                if rate is not None else None),
                "window_s": (round(window, 3)
                             if window is not None else None),
            }
            payload["workers"] = workers
            n_events = len(tracer.events)
            if self.explain_max_events and n_events:
                if n_events <= self.explain_max_events:
                    try:
                        from repro.core.obs.critical_path import \
                            CriticalPathReport
                        cp = CriticalPathReport.from_engine(eng)
                        payload["critical_path"] = cp.summary(max_tasks=16)
                    except Exception:   # noqa: BLE001 — diagnosis must
                        pass            # never fail the scrape
                else:
                    payload["critical_path"] = {
                        "skipped": f"trace too large ({n_events} events "
                                   f"> explain_max_events="
                                   f"{self.explain_max_events})"}
        serving = []
        for fe in self._live_frontends():
            # a running periodic monitor owns the window; otherwise the
            # scrape itself is the window (snapshot() arms monitoring,
            # so the priming scrape returns an empty first window)
            if fe._snap_thread is not None and fe.snapshots:
                rep = fe.snapshots[-1]
            else:
                rep = fe.snapshot()
            serving.append(rep.summary())
        payload["serving"] = serving
        return payload

    def health(self) -> dict:
        eng = self._engine
        if eng is None:
            return {"ok": True}
        loop_dead = eng._loop_error is not None
        return {
            "ok": not loop_dead,
            "resident": eng.resident,
            "loop_running": eng.started if eng.resident else False,
            "live_workers": eng.live_workers(),
        }
