"""`python -m repro.core.obs.explain <trace>` — explain a recorded run.

Loads a trace saved with `TraceRecorder.save()` (the JSONL format
`examples/obs_demo.py --trace-log` and `Client.report().trace.save()`
produce), runs the critical-path analyzer, and prints the explanation:
the makespan decomposition, concurrency vs the METG-law ideal, idle
gaps, stragglers, and the per-stage table for every task on the path.

    python -m repro.core.obs.explain run.jsonl
    python -m repro.core.obs.explain run.jsonl --json       # raw summary
    python -m repro.core.obs.explain run.jsonl --chrome out.trace.json

`render()` is importable: the same text view for any
`CriticalPathReport`, whatever built it.
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Optional

from repro.core.engine.tracing import TraceRecorder
from repro.core.obs.critical_path import CriticalPathReport


def _ms(s: float) -> str:
    return f"{s * 1e3:.3f}ms"


def render(rep: CriticalPathReport, *, max_tasks: int = 20) -> str:
    """Human-readable explanation of a `CriticalPathReport`."""
    if not rep.path:
        return ("no completed tasks in the trace — nothing to explain "
                f"(events emitted: {rep.n_emitted}, dropped: {rep.dropped})")
    mk = rep.makespan_s
    pct = (lambda x: f"{100.0 * x / mk:.1f}%") if mk > 0 else (lambda x: "—")
    lines = [
        f"critical path: {len(rep.path)} of {rep.n_tasks} tasks gate the "
        f"{_ms(mk)} makespan (trace span {_ms(rep.wall_s)})",
        f"  compute   {_ms(rep.compute_s):>12}  {pct(rep.compute_s):>7}"
        "   (critical-path run time)",
        f"  scheduler {_ms(rep.sched_s):>12}  {pct(rep.sched_s):>7}"
        f"   dep-wait {_ms(rep.dep_wait_s)}"
        f" | queue {_ms(rep.queue_s)}"
        f" | dispatch {_ms(rep.dispatch_s)}"
        f" | notify {_ms(rep.notify_s)}",
    ]
    if rep.wasted_s > 0:
        lines.append(f"  wasted    {_ms(rep.wasted_s):>12}"
                     "           (requeued/retried episodes on the path)")
    ideal = rep.metg_ideal_workers
    conc = (f"  concurrency: mean {rep.concurrency_mean:.2f}, "
            f"peak {rep.concurrency_peak}, pool {rep.workers}")
    if ideal is not None:
        conc += f", METG-law ideal ~{ideal:.1f}"
    if rep.parallel_efficiency is not None:
        conc += f"  ->  parallel efficiency {rep.parallel_efficiency:.0%}"
    lines.append(conc)
    if rep.idle_s > 0:
        gaps = ", ".join(f"{_ms(d)} @ t={t:.3f}s"
                         for t, d in rep.idle_gaps[:3])
        lines.append(f"  idle gaps: {_ms(rep.idle_s)} total ({pct(rep.idle_s)}"
                     f" of makespan) — longest: {gaps}")
    if rep.n_rpc:
        lines.append(f"  rpc: {rep.n_rpc} round-trips, "
                     f"{_ms(rep.rpc_s)} total, "
                     f"mean rtt {rep.rtt_mean_s * 1e6:.1f}us")
        tops = sorted(rep.rpc_by_op.items(), key=lambda kv: -kv[1][1])[:4]
        lines.append("       by op: " + "  ".join(
            f"{op} x{cnt} {_ms(tot)}" for op, (cnt, tot) in tops))
    if rep.n_xfer:
        by = "  ".join(f"{p} x{n} {b / 1024:.0f}KiB {_ms(t)}"
                       for p, (n, b, t) in rep.xfer_by_path.items())
        lines.append(f"  data motion: {rep.n_xfer} fetches, "
                     f"{rep.xfer_bytes / 1024:.0f}KiB, "
                     f"{_ms(rep.xfer_s)} total "
                     f"({_ms(rep.path_xfer_s)} on the path) — {by}")
        lines.append(f"       verdict: the run was {rep.xfer_verdict} "
                     + ("(moving bytes gated the path more than "
                        "scheduling did)" if rep.xfer_verdict
                        == "transfer-bound" else
                        "(scheduling gated the path more than moving "
                        "bytes did)"))
    for s in rep.stragglers:
        mark = "  << ON THE CRITICAL PATH" if s["on_path"] else ""
        lines.append(f"  straggler: {s['task']} ran {_ms(s['run_s'])} "
                     f"({s['ratio']}x the median) on {s['worker']}{mark}")
    lines.append("")
    lines.append(f"  {'#':>3} {'task':<28}{'worker':<8}"
                 f"{'dep-wait':>10}{'queue':>10}{'dispatch':>10}"
                 f"{'run':>10}{'notify':>10}{'xfer':>10}  notes")
    segs = rep.segments
    skipped = 0
    if len(segs) > max_tasks:
        skipped = len(segs) - max_tasks
        segs = segs[-max_tasks:]
    base = skipped
    if skipped:
        lines.append(f"  ... {skipped} earlier path tasks elided ...")
    for i, row in enumerate(segs):
        notes = []
        if row["n_runs"] > 1:
            notes.append(f"{row['n_runs']} runs "
                         f"(wasted {_ms(row.get('wasted_s', 0.0))})")
        if row["retries"]:
            notes.append(f"{row['retries']} retries")
        if row.get("xfer_bytes"):
            notes.append(f"{row['xfer_bytes'] / 1024:.0f}KiB fetched")
        xfer = _ms(row["xfer_s"]) if "xfer_s" in row else "—"
        lines.append(
            f"  {base + i + 1:>3} {str(row['task'])[:27]:<28}"
            f"{str(row['worker'] or '—')[:7]:<8}"
            f"{_ms(row['dep_wait_s']):>10}{_ms(row['queue_s']):>10}"
            f"{_ms(row['dispatch_s']):>10}{_ms(row['run_s']):>10}"
            f"{_ms(row['notify_s']):>10}{xfer:>10}  {', '.join(notes)}")
    return "\n".join(lines)


def main(argv: Optional[list] = None) -> int:
    p = argparse.ArgumentParser(
        prog="python -m repro.core.obs.explain",
        description="critical-path explanation of a saved engine trace")
    p.add_argument("trace", help="JSONL trace file (TraceRecorder.save)")
    p.add_argument("--workers", type=int, default=1,
                   help="pool size the run used (default %(default)s)")
    p.add_argument("--scheduler", default="dwork",
                   choices=("dwork", "pmake", "mpi-list"),
                   help="METG law for the ideal-parallelism comparison")
    p.add_argument("--steal-n", type=int, default=1)
    p.add_argument("--shards", type=int, default=1)
    p.add_argument("--json", action="store_true",
                   help="print the raw summary() JSON instead of text")
    p.add_argument("--max-tasks", type=int, default=20,
                   help="path rows to print (default %(default)s)")
    p.add_argument("--chrome", metavar="PATH",
                   help="also export a Chrome trace with the critical "
                        "path highlighted (flow arrows + lane)")
    args = p.parse_args(argv)
    trace = TraceRecorder.load(args.trace)
    rep = CriticalPathReport.from_trace(
        trace, workers=args.workers, scheduler=args.scheduler,
        steal_n=args.steal_n, shards=args.shards)
    if args.chrome:
        trace.to_chrome_trace(args.chrome, critical_path=rep.path)
    if args.json:
        print(json.dumps(rep.summary(max_tasks=args.max_tasks), indent=2))
    else:
        print(render(rep, max_tasks=args.max_tasks))
    return 0


if __name__ == "__main__":
    sys.exit(main())
