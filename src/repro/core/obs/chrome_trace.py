"""Chrome-trace export: render a `TraceRecorder` event log as a
Trace Event Format document that Perfetto / `chrome://tracing` loads
directly — the Fig. 2 protocol as a timeline you can scrub.

Lane layout (one `tid` per lane, stable across exports):

  * one lane per worker, in pool order (`w0`, `w1`, ...): each task
    execution is an `X` (complete) span from RUN_START to RUN_END,
    with worker deaths and failures as instant markers on the lane
  * an `rpc` lane: every sampled scheduler round-trip as a span ending
    at its emit time (rpc events are stamped on completion with `dt`)
  * one lane per `hop:*` op (`hop:L1`, `hop:L1:s0`, ...): the
    forwarding-tree / per-shard hops nested under the worker's
    end-to-end round-trip, now visibly so
  * a `requests` lane: serving requests as async `b`/`e` pairs keyed by
    request name (overlapping freely), with batch formations and
    rejections as instants.  A REQ_DONE whose enqueue partner was
    evicted from the ring buffer gets its begin synthesized at
    `t - latency_s`, clamped at the trace epoch (a request older than
    the retained window must not render at a negative timestamp); one
    without `latency_s` at all is skipped.
  * with `critical_path=` (a list of task names, e.g.
    `CriticalPathReport.path`): a dedicated `critical path` lane at the
    top repeating the path's final executions in order, plus flow
    arrows (`s`/`f` pairs) linking each path task's run to the next
    across the worker lanes — the makespan chain, scrubbed visually.

`to_chrome_trace(trace, path=None, critical_path=None)` returns the
document as a dict and, with `path`, writes it as JSON (the
conventional suffix is `.trace.json`).  `TraceRecorder.to_chrome_trace`
forwards here.
"""
from __future__ import annotations

import json
from typing import Optional

from repro.core.engine.model import (BATCH_FORMED, CANCELLED, FAILED,
                                     REQ_DONE, REQ_ENQUEUED, REQ_REJECTED,
                                     REQUEUED, RPC, RUN_END, RUN_START,
                                     WORKER_DEAD)

PID = 1


def _worker_key(w: str):
    """Natural sort for w<i> names so lanes appear in pool order."""
    if isinstance(w, str) and w[:1] == "w" and w[1:].isdigit():
        return (0, int(w[1:]), w)
    return (1, 0, str(w))


def to_chrome_trace(trace, path: Optional[str] = None, *,
                    critical_path: Optional[list] = None) -> dict:
    with trace._lock:
        events = list(trace.events)
    t0 = min((e.t for e in events), default=0.0)

    def us(t: float) -> float:
        return (t - t0) * 1e6

    cp = list(critical_path or ())
    cp_set = set(cp)
    cp_runs: dict = {}           # path task -> last (ts, dur, worker) run
    spans: list = []             # events carrying a symbolic lane key
    open_start: dict = {}        # task -> t (sequential pairing, as in
    req_open: set = set()        #          OverheadReport.from_trace)
    workers: set = set()
    hop_lanes: set = set()
    other_lanes: set = set()
    for e in events:
        ev = e.event
        if ev == RUN_START:
            open_start[e.task] = e.t
        elif ev == RUN_END:
            ts = open_start.pop(e.task, None)
            if ts is not None and e.worker is not None:
                workers.add(e.worker)
                spans.append((("w", e.worker), {
                    "ph": "X", "name": e.task, "cat": "task",
                    "ts": us(ts), "dur": max(us(e.t) - us(ts), 0.0)}))
                if e.task in cp_set:
                    # last execution wins: that is the one the critical
                    # path's decomposition attributes
                    cp_runs[e.task] = (us(ts),
                                       max(us(e.t) - us(ts), 0.0),
                                       e.worker)
        elif ev == RPC:
            op = e.extra.get("op", "?")
            dt = e.extra.get("dt", 0.0)
            if op.startswith("hop:"):
                lane = ("hop", op)
                hop_lanes.add(op)
            else:
                lane = ("rpc",)
                other_lanes.add("rpc")
            rec = {"ph": "X", "name": op, "cat": "rpc",
                   "ts": us(e.t - dt), "dur": dt * 1e6}
            if "n" in e.extra:
                rec["args"] = {"n": e.extra["n"]}
            spans.append((lane, rec))
        elif ev == REQ_ENQUEUED:
            req_open.add(e.task)
            other_lanes.add("requests")
            spans.append((("requests",), {
                "ph": "b", "cat": "request", "id": str(e.task),
                "name": "request", "ts": us(e.t),
                "args": {"depth": e.extra.get("depth", 0)}}))
        elif ev == REQ_DONE:
            lat = e.extra.get("latency_s")
            if lat is None:
                continue          # partner evicted AND unstamped: no span
            other_lanes.add("requests")
            if e.task not in req_open:
                # enqueue evicted from the ring: synthesize the begin,
                # clamped at the trace epoch — a request enqueued before
                # the retained window began must not render at a
                # negative timestamp (Perfetto misplaces the span)
                spans.append((("requests",), {
                    "ph": "b", "cat": "request", "id": str(e.task),
                    "name": "request", "ts": max(us(e.t - lat), 0.0)}))
            else:
                req_open.discard(e.task)
            spans.append((("requests",), {
                "ph": "e", "cat": "request", "id": str(e.task),
                "name": "request", "ts": us(e.t),
                "args": {"ok": e.extra.get("ok", True),
                         "latency_ms": round(lat * 1e3, 3)}}))
        elif ev == BATCH_FORMED:
            other_lanes.add("requests")
            spans.append((("requests",), {
                "ph": "i", "s": "t", "name": "batch", "cat": "serving",
                "ts": us(e.t),
                "args": {"size": e.extra.get("size", 0),
                         "depth": e.extra.get("depth", 0)}}))
        elif ev == REQ_REJECTED:
            other_lanes.add("requests")
            spans.append((("requests",), {
                "ph": "i", "s": "t", "name": "rejected", "cat": "serving",
                "ts": us(e.t),
                "args": {"depth": e.extra.get("depth", 0)}}))
        elif ev == WORKER_DEAD and e.worker is not None:
            workers.add(e.worker)
            spans.append((("w", e.worker), {
                "ph": "i", "s": "t", "name": "worker-dead", "cat": "fault",
                "ts": us(e.t), "args": dict(e.extra)}))
        elif ev == FAILED and e.worker is not None:
            workers.add(e.worker)
            spans.append((("w", e.worker), {
                "ph": "i", "s": "t", "name": f"fail:{e.task}",
                "cat": "fault", "ts": us(e.t),
                "args": {"error": e.extra.get("error")}}))
        elif ev in (REQUEUED, CANCELLED):
            other_lanes.add("scheduler")
            spans.append((("scheduler",), {
                "ph": "i", "s": "t",
                "name": ("requeue" if ev == REQUEUED
                         else f"cancel:{e.task}"),
                "cat": "scheduler", "ts": us(e.t),
                "args": dict(e.extra)}))

    # critical-path overlay: a dedicated lane repeating the path's final
    # executions in order, plus s/f flow arrows stitching consecutive
    # path tasks together across the worker lanes
    cp_drawn = [t for t in cp if t in cp_runs]
    for i, task in enumerate(cp_drawn):
        ts, dur, w = cp_runs[task]
        spans.append((("critical",), {
            "ph": "X", "name": task, "cat": "critical_path",
            "ts": ts, "dur": dur, "args": {"order": i, "worker": w}}))
    for i in range(len(cp_drawn) - 1):
        a, b = cp_drawn[i], cp_drawn[i + 1]
        ts_a, dur_a, w_a = cp_runs[a]
        ts_b, _dur_b, w_b = cp_runs[b]
        flow = {"id": i + 1, "name": "critical-path",
                "cat": "critical_path"}
        spans.append((("w", w_a), {
            **flow, "ph": "s", "ts": ts_a + dur_a}))
        spans.append((("w", w_b), {
            **flow, "ph": "f", "bp": "e", "ts": max(ts_b, ts_a + dur_a)}))

    # lane order: critical path on top, workers in pool order, then rpc,
    # hops, scheduler, requests — matched by thread_sort_index below
    lanes: list = []
    if cp_drawn:
        lanes.append(("critical",))
    lanes.extend(("w", w) for w in sorted(workers, key=_worker_key))
    if "rpc" in other_lanes:
        lanes.append(("rpc",))
    lanes.extend(("hop", op) for op in sorted(hop_lanes))
    if "scheduler" in other_lanes:
        lanes.append(("scheduler",))
    if "requests" in other_lanes:
        lanes.append(("requests",))
    tid_of = {lane: i + 1 for i, lane in enumerate(lanes)}

    out: list = [{"ph": "M", "pid": PID, "tid": 0, "name": "process_name",
                  "args": {"name": "repro engine"}}]
    for lane, tid in tid_of.items():
        label = lane[1] if lane[0] in ("w", "hop") else (
            "critical path" if lane[0] == "critical" else lane[0])
        out.append({"ph": "M", "pid": PID, "tid": tid,
                    "name": "thread_name", "args": {"name": label}})
        out.append({"ph": "M", "pid": PID, "tid": tid,
                    "name": "thread_sort_index", "args": {"sort_index": tid}})
    for lane, rec in spans:
        rec["pid"] = PID
        rec["tid"] = tid_of[lane]
        out.append(rec)

    doc = {"traceEvents": out, "displayTimeUnit": "ms"}
    if path is not None:
        with open(path, "w") as f:
            json.dump(doc, f)
    return doc
