"""Wiring: attach a `MetricsRegistry` to a live engine / client /
frontend.

Two attachment styles, matched to the hot-path budget:

  * **Callback instruments** (the default): counters and gauges built
    with `fn=` read values the engine and frontend maintain anyway —
    per-worker done/busy tables, ready depth, live workers, requeue and
    crash counts, admission counters.  Attaching them adds literally
    nothing to the dispatch loop; the cost is paid at scrape time.
  * **Push histograms** for the two latency streams that have no
    always-on accumulator: scheduler rpc round-trips (observed at the
    backend's already-sampled timing sites, so `rpc_sample=` thins the
    metric exactly like the trace) and per-request serving latency
    (observed in `Frontend._resolve`).

`instrument(registry, engine=... | client=... | frontend=...)` is
idempotent per target and returns the registry, so it chains:

    reg = instrument(MetricsRegistry(), client=client)

`Client.stats_server()` calls this for you and serves the result over
HTTP (`repro.core.obs.server`).
"""
from __future__ import annotations

import os
from typing import Optional

from repro.core.obs.metrics import LATENCY_BUCKETS, MetricsRegistry

# rpc round-trips live in the µs..ms decades; the tail of the default
# ladder would waste half the buckets on impossible multi-second rpcs
RPC_BUCKETS = tuple(b for b in LATENCY_BUCKETS if b <= 0.25)

_PAGE_SIZE = 4096
try:
    _PAGE_SIZE = os.sysconf("SC_PAGE_SIZE")
except (ValueError, OSError, AttributeError):  # pragma: no cover
    pass


def _pid_rss(pid: int) -> int:
    """Resident set size of `pid` in bytes via /proc/<pid>/statm (Linux;
    0 when the pid is gone or the platform has no procfs) — monitoring
    never fails the scrape."""
    if not pid:
        return 0
    try:
        with open(f"/proc/{pid}/statm", "rb") as f:
            return int(f.read().split()[1]) * _PAGE_SIZE
    except (OSError, IndexError, ValueError):
        return 0


def instrument_worker_rss(reg: MetricsRegistry, engine) -> None:
    """Per-process memory gauges for `transport="proc"`: one
    `repro_worker_rss_bytes{worker=}` callback gauge per handshaken
    worker process.  Idempotent (get-or-create) and pid-chasing: the
    callback re-reads the worker's CURRENT pid at scrape time, so a
    respawned worker reports its new process.  No-op for in-process
    transports (no pids to read)."""
    for w in engine.worker_pids():
        reg.gauge(
            "repro_worker_rss_bytes",
            "Worker process resident set size (transport=proc)",
            labels={"worker": w},
            fn=lambda e=engine, w=w: _pid_rss(e.worker_pids().get(w, 0)))


class RpcMetrics:
    """Per-op rpc latency histograms, cached so the backend's sampled
    timing site pays one dict hit + one observe per recorded call."""

    __slots__ = ("_registry", "_by_op")

    def __init__(self, registry: MetricsRegistry):
        self._registry = registry
        self._by_op: dict = {}

    def observe(self, op: str, dt: float):
        # the cache maps op -> BOUND Histogram.observe: the hot call is
        # one dict hit + one call, no attribute chase
        ob = self._by_op.get(op)
        if ob is None:
            h = self._registry.histogram(
                "repro_rpc_latency_seconds",
                "Scheduler round-trip latency per protocol verb "
                "(worker-side end-to-end, sampled like the trace)",
                labels={"op": op}, buckets=RPC_BUCKETS)
            ob = self._by_op[op] = h.observe
        ob(dt)


class XferMetrics:
    """Data-plane transfer metrics (transport="proc"): per-path latency
    histograms plus byte/count totals, fed by the engine's unsampled
    xfer attribution (`Engine._record_xfer`).  Bound observers are
    cached per path — there are only two ("peer"/"hub"), so the hot
    call is one dict hit."""

    __slots__ = ("_registry", "_by_path")

    def __init__(self, registry: MetricsRegistry):
        self._registry = registry
        self._by_path: dict = {}

    def observe(self, path: str, nbytes: int, dt: float):
        ent = self._by_path.get(path)
        if ent is None:
            lbl = {"path": path}
            h = self._registry.histogram(
                "repro_xfer_latency_seconds",
                "Dependency-value transfer latency per path "
                "(peer = producer's data listener, hub = front door)",
                labels=lbl, buckets=RPC_BUCKETS)
            b = self._registry.counter(
                "repro_xfer_bytes_total",
                "Serialized bytes moved per transfer path", labels=lbl)
            c = self._registry.counter(
                "repro_xfer_total",
                "Dependency-value transfers per path", labels=lbl)
            ent = self._by_path[path] = (h.observe, b.inc, c.inc)
        ent[0](dt)
        ent[1](nbytes)
        ent[2]()


class ServingMetrics:
    """Push-side serving metrics: the per-request latency histogram
    observed at response delivery (everything else about the frontend is
    readable from its own counters via callbacks).  Requests submitted
    with `tenant=` additionally land in a tenant-labelled histogram,
    cached per tenant so the delivery path pays one dict hit extra."""

    __slots__ = ("latency", "_failed", "_registry", "_index", "_by_tenant")

    def __init__(self, registry: MetricsRegistry, index: int = 0):
        lbl = {"frontend": str(index)}
        self._registry = registry
        self._index = index
        self._by_tenant: dict = {}     # tenant -> bound Histogram.observe
        self.latency = registry.histogram(
            "repro_request_latency_seconds",
            "Serving enqueue -> response latency", labels=lbl)
        self._failed = registry.counter(
            "repro_requests_failed_total",
            "Responses delivered with ok=False", labels=lbl)

    def observe_request(self, latency_s: float, ok: bool,
                        tenant: Optional[str] = None):
        self.latency.observe(latency_s)
        if not ok:
            self._failed.inc()
        if tenant is None:
            return
        ob = self._by_tenant.get(tenant)
        if ob is None:
            h = self._registry.histogram(
                "repro_request_latency_seconds",
                "Serving enqueue -> response latency",
                labels={"frontend": str(self._index), "tenant": tenant})
            ob = self._by_tenant[tenant] = h.observe
        ob(latency_s)


def _instrument_engine(reg: MetricsRegistry, engine) -> None:
    backend = engine.backend
    if getattr(backend, "metrics", None) is None:
        backend.metrics = RpcMetrics(reg)
    if getattr(engine, "xfer_metrics", None) is None:
        # data-plane attribution sink (populated only under
        # transport="proc"; zero-cost otherwise — nothing observes)
        engine.xfer_metrics = XferMetrics(reg)
    reg.gauge("repro_live_workers", "Workers currently alive",
              fn=engine.live_workers)
    reg.counter("repro_worker_deaths_total",
                "Workers killed (crash, injected fault, or lose_worker)",
                fn=lambda: engine.worker_deaths)
    reg.counter("repro_tasks_completed_total",
                "Tasks that finished ok on a worker",
                fn=lambda: engine.tasks_done_total() - engine.exec_failed)
    reg.counter("repro_tasks_failed_total",
                "Task executions that raised / returned not-ok",
                fn=lambda: engine.exec_failed)
    reg.counter("repro_requeued_total",
                "Tasks recycled by Exit or lease expiry",
                fn=backend._requeued_total)
    reg.counter("repro_task_retries_total",
                "Transient task failures re-enqueued by RetryPolicy",
                fn=lambda: engine.retries_total)
    reg.counter("repro_journal_bytes_total",
                "Bytes appended to the write-ahead journal",
                fn=lambda: (engine.journal.bytes_written
                            if engine.journal is not None else 0))
    reg.gauge("repro_ready_depth", "Tasks ready to steal, all shards",
              fn=backend.ready_depth)
    for i in range(getattr(backend, "n_shards", 1)):
        reg.gauge("repro_shard_ready_depth",
                  "Tasks ready to steal on one shard",
                  labels={"shard": str(i)},
                  fn=lambda b=backend, i=i: b.ready_depths()[i])
    tracer = engine.tracer
    reg.counter("repro_trace_events_total", "Trace events emitted",
                fn=lambda: tracer.n_emitted)
    reg.counter("repro_trace_dropped_total",
                "Trace events evicted by the ring buffer",
                fn=lambda: tracer.dropped)
    # proc transport: per-worker-process RSS (workers that join later are
    # folded in by the StatsServer at scrape time via the same call)
    instrument_worker_rss(reg, engine)


def _instrument_frontend(reg: MetricsRegistry, fe, index: int = 0) -> None:
    if getattr(fe, "metrics", None) is None:
        fe.metrics = ServingMetrics(reg, index=index)
    lbl = {"frontend": str(index)}
    reg.counter("repro_requests_accepted_total",
                "Requests admitted to the serving queue", labels=lbl,
                fn=lambda: fe.accepted)
    reg.counter("repro_requests_rejected_total",
                "Requests bounced by admission backpressure", labels=lbl,
                fn=lambda: fe.rejected)
    reg.counter("repro_requests_timeout_total",
                "Requests withdrawn after queueing past their deadline",
                labels=lbl, fn=lambda: fe.timeouts)
    reg.counter("repro_batches_total",
                "Engine tasks the requests were coalesced into",
                labels=lbl, fn=lambda: fe.batches)
    reg.gauge("repro_serving_queue_depth", "Requests waiting to batch",
              labels=lbl, fn=lambda: len(fe._queue))
    reg.gauge("repro_serving_target_batch", "Current METG batch target",
              labels=lbl, fn=fe.target_batch)


def _instrument_client(reg: MetricsRegistry, client) -> None:
    client._metrics = reg            # Client.serve() instruments later fes
    _instrument_engine(reg, client.engine)
    for i, fe in enumerate(client._frontends):
        _instrument_frontend(reg, fe, index=i)
    reg.counter("repro_futures_submitted_total", "Futures submitted",
                fn=lambda: client._submitted)
    reg.counter("repro_futures_resolved_total",
                "Futures that reached a terminal state",
                fn=lambda: client._futures_resolved)
    reg.gauge("repro_futures_pending", "Futures awaiting resolution",
              fn=lambda: len(client._futures))


def instrument(registry: Optional[MetricsRegistry] = None, *,
               engine=None, client=None, frontend=None,
               frontend_index: int = 0) -> MetricsRegistry:
    """Attach live metrics to the given target(s); builds a fresh
    registry when none is passed.  Safe to call more than once — the
    registry's get-or-create semantics make re-instrumentation a no-op."""
    reg = registry if registry is not None else MetricsRegistry()
    if client is not None:
        _instrument_client(reg, client)
    if engine is not None:
        _instrument_engine(reg, engine)
    if frontend is not None:
        _instrument_frontend(reg, frontend, index=frontend_index)
    return reg
