"""Continuous-serving subsystem: live traffic on the unified engine.

The paper's schedulers are batch-oriented — submit a task universe, drain
it.  This package is the service-mode layer over the same substrate (the
gap Balsam / pilot-job systems fill over batch launchers): a resident
`Engine` (`resident=True`, `start()/submit()/drain()/shutdown()`) keeps
the dispatch loop running open-ended, and a `Frontend` turns a stream of
*requests* into METG-sized engine *tasks*:

    client -> Frontend.submit(payload)        bounded admission queue
                    |                          (block / reject backpressure)
              coalesce into a batch            size = pick_batch_size(...)
                    |                          OR max_wait_s deadline hit
              Engine task (resident pool)      steal/complete, faults,
                    |                          leases, tracing — unchanged
              ServeRequest.wait() -> value     REQ_* events -> LatencyReport

Everything the engine guarantees for tasks holds for requests: a worker
death mid-stream requeues the in-flight batch (announced Exit or
heartbeat-lease expiry) and the requests ride the re-execution — zero
loss, at-most-once response delivery (`ServeRequest` resolves once).

Tuning `batch`/`max_wait_s` against the METG laws (`core/metg.py`),
mirroring the engine docstring's `steal_n`/`transport` guidance:

  * The batch target is the serving analog of Steal-n: dwork's dispatch
    bound METG(P) = rtt * P means a batch must carry at least
    `pick_batch_size(P, t_req)` requests for scheduling overhead to stay
    under (1 - target_eff) of compute.  The frontend re-evaluates this
    every dispatch from the LIVE worker count (`engine.live_workers()` —
    deaths shrink P, elastic growth raises it) and an EWMA of observed
    per-request time measured on the trace clock, so granularity tracks
    the running system, not a config constant.
  * `max_wait_s` is the latency guard: a deadline dispatch sends a
    partial batch so a trickle of traffic is never starved waiting for a
    full one.  Keep it well under your latency SLO minus one batch
    service time; raising it trades p50 latency for throughput (bigger
    batches), and past the point where batches already hit the METG
    target it buys nothing.
  * `max_queue` bounds memory and wait time: by Little's law a full
    queue adds ~max_queue * t_req / P to tail latency, so size it to the
    worst p99 you are willing to serve and let backpressure
    (`policy="block"` to push back on the client, `"reject"` to fail
    fast) shed the rest.

Latency accounting lives in the engine trace: `REQ_ENQUEUED` /
`BATCH_FORMED` / `REQ_DONE` / `REQ_REJECTED` events feed
`engine.tracing.LatencyReport` (p50/p95/p99 enqueue->complete latency,
queue-depth stats), attached to `OverheadReport.requests` so one report
covers both the paper's overhead quantities and the serving SLOs.
"""
from repro.core.serving.frontend import (AdmissionFull, Frontend,
                                         ServeRequest)

__all__ = ["Frontend", "ServeRequest", "AdmissionFull"]
