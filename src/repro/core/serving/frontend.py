"""Admission control + METG-aware dynamic batching for the resident engine.

`Frontend` owns the request side of the serving subsystem: a bounded
admission queue with backpressure, a coalescer that packs requests into
engine tasks sized by the METG granularity laws (adapting to the live
worker count and observed per-request time), and a max-wait deadline so
tail latency is bounded even when traffic trickles.  See the package
docstring for the tuning guidance.
"""
from __future__ import annotations

import threading
from collections import deque
from typing import Callable, Optional

from repro.core.engine.model import (BATCH_FORMED, REQ_DONE, REQ_ENQUEUED,
                                     REQ_REJECTED, WorkerCrash, next_seq)
from repro.core.metg import METGModel, pick_batch_size


class AdmissionFull(RuntimeError):
    """The admission queue is full (reject policy) or stayed full past the
    submit timeout (block policy) — the client should back off."""


class ServeRequest:
    """One in-flight request: resolved exactly once (re-executions after a
    worker death hit the already-set guard), waitable from any thread."""

    __slots__ = ("name", "payload", "meta", "t_enqueue", "t_done",
                 "value", "ok", "error", "_event")

    def __init__(self, name: str, payload, meta: Optional[dict],
                 t_enqueue: float):
        self.name = name
        self.payload = payload
        self.meta = meta or {}
        self.t_enqueue = t_enqueue
        self.t_done = 0.0
        self.value = None
        self.ok = False
        self.error: Optional[str] = None
        self._event = threading.Event()

    def wait(self, timeout: Optional[float] = None) -> bool:
        """True once a response is delivered; False on timeout."""
        return self._event.wait(timeout)

    @property
    def done(self) -> bool:
        return self._event.is_set()

    @property
    def latency_s(self) -> float:
        """Enqueue -> response latency on the engine's trace clock."""
        return (self.t_done - self.t_enqueue) if self.done else 0.0

    def __repr__(self):
        state = ("ok" if self.ok else f"err={self.error!r}") if self.done \
            else "pending"
        return f"ServeRequest({self.name}, {state})"


class Frontend:
    """Enqueue requests, coalesce them into METG-sized engine tasks.

    `execute_batch(payloads)` runs on an engine worker and returns a list
    of per-request values (same order/length), a single value broadcast to
    the batch, or None.  Raising marks every request in the batch failed;
    raising `WorkerCrash` instead kills the worker and the batch is
    requeued, not failed (fault drills).

    A batch is dispatched when the queue reaches the current METG target
    (`pick_batch_size` at the live worker count and the observed
    per-request EWMA) or when the oldest queued request has waited
    `max_wait_s`, whichever comes first.
    """

    def __init__(self, engine, execute_batch: Callable, *,
                 max_queue: int = 256, max_batch: int = 64,
                 max_wait_s: float = 0.005, target_eff: float = 0.9,
                 per_request_s0: float = 1e-3, scheduler: str = "dwork",
                 model: Optional[METGModel] = None, policy: str = "block"):
        if policy not in ("block", "reject"):
            raise ValueError(f"unknown backpressure policy {policy!r}")
        if not engine.resident:
            raise ValueError("Frontend requires Engine(resident=True)")
        self.engine = engine
        self.execute_batch = execute_batch
        self.max_queue = max(int(max_queue), 1)
        self.max_batch = max(int(max_batch), 1)
        self.max_wait_s = max_wait_s
        self.target_eff = target_eff
        self.scheduler = scheduler
        self.model = model or METGModel.from_paper()
        self.policy = policy
        self._per_req_s = max(per_request_s0, 1e-9)  # observed-time EWMA
        self._ewma_alpha = 0.2
        self._queue: deque[ServeRequest] = deque()
        self._cond = threading.Condition()
        self._closing = False
        self._force_flush = False
        self.accepted = 0
        self.rejected = 0
        self.batches = 0
        self._thread: Optional[threading.Thread] = None

    # ----------------------------------------------------------- lifecycle
    def start(self) -> "Frontend":
        """Start the coalescer (and the engine's resident loop if the
        caller hasn't already)."""
        if self._thread is not None:
            raise RuntimeError("frontend already started")
        if not self.engine.started:
            self.engine.start()
        self._closing = False
        self._thread = threading.Thread(target=self._coalesce_loop,
                                        name="serving-frontend", daemon=True)
        self._thread.start()
        return self

    def close(self, *, drain: bool = True,
              timeout: Optional[float] = None) -> bool:
        """Stop admitting, flush the queue as final batches, and (with
        `drain=True`) wait for every dispatched batch to finish.  Does NOT
        shut the engine down — that is the engine owner's call."""
        with self._cond:
            self._closing = True
            self._cond.notify_all()
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if drain:
            return self.engine.drain(timeout)
        return True

    # ------------------------------------------------------------- client
    def submit(self, payload, *, meta: Optional[dict] = None,
               timeout: Optional[float] = None) -> ServeRequest:
        """Admit one request.  With a full queue: `policy="reject"` raises
        `AdmissionFull` immediately; `policy="block"` waits for space up
        to `timeout` seconds (None = forever) and then raises."""
        tracer = self.engine.tracer
        with self._cond:
            if self._closing:
                raise RuntimeError("frontend is closed")
            if len(self._queue) >= self.max_queue:
                blocked = (self.policy == "block"
                           and (timeout is None or timeout > 0)
                           and self._cond.wait_for(
                               lambda: (len(self._queue) < self.max_queue
                                        or self._closing), timeout))
                if not blocked or self._closing:
                    self.rejected += 1
                    tracer.emit(REQ_REJECTED, depth=len(self._queue),
                                policy=self.policy)
                    raise AdmissionFull(
                        f"admission queue full ({self.max_queue})")
            # next_seq(): engine task names are single-use forever, so
            # request/batch names must be unique across every frontend
            # that ever shares an engine (or a task server)
            req = ServeRequest(f"__req{next_seq()}", payload, meta,
                               t_enqueue=tracer.clock())
            self._queue.append(req)
            self.accepted += 1
            tracer.emit(REQ_ENQUEUED, task=req.name,
                        depth=len(self._queue))
            self._cond.notify_all()
        return req

    def flush(self):
        """Dispatch whatever is queued right now without waiting for the
        batch target or deadline (deterministic tests, graceful drains)."""
        with self._cond:
            self._force_flush = True
            self._cond.notify_all()

    # ------------------------------------------------------------ batching
    def target_batch(self) -> int:
        """Current METG-aware batch target: the granularity at which
        scheduling overhead stays under (1 - target_eff) of compute, for
        the LIVE worker count and the observed per-request time."""
        live = max(self.engine.live_workers(), 1)
        n = pick_batch_size(self.scheduler, live, self._per_req_s,
                            target_eff=self.target_eff, model=self.model)
        return max(1, min(n, self.max_batch))

    def _coalesce_loop(self):
        clock = self.engine.tracer.clock
        while True:
            with self._cond:
                while True:
                    if self._closing:
                        break
                    n = len(self._queue)
                    target = self.target_batch()
                    if n >= target:
                        break
                    if n and self._force_flush:
                        break
                    wait = None
                    if n:
                        age = clock() - self._queue[0].t_enqueue
                        if age >= self.max_wait_s:
                            break
                        # under a ManualClock `age` may never advance;
                        # the floor keeps the wait finite either way
                        wait = max(self.max_wait_s - age, 1e-4)
                    self._cond.wait(wait)
                self._force_flush = False
                if not self._queue:
                    if self._closing:
                        return
                    continue
                take = min(len(self._queue), max(self.target_batch(), 1))
                batch = [self._queue.popleft() for _ in range(take)]
                depth_after = len(self._queue)
                self._cond.notify_all()      # space freed: wake submitters
            try:
                self._dispatch(batch, depth_after)
            except Exception as e:            # noqa: BLE001
                # a dispatch failure (engine shut down under us, backend
                # error) must never strand waiters — fail the batch loudly
                err = repr(e)
                for r in batch:
                    self._resolve(r, ok=False, error=err)

    def _dispatch(self, batch: list, depth_after: int):
        tracer = self.engine.tracer
        self.batches += 1
        name = f"__batch{next_seq()}"
        now = tracer.clock()
        tracer.emit(BATCH_FORMED, task=name, size=len(batch),
                    wait_s=now - batch[0].t_enqueue,
                    target=self.target_batch(), depth=depth_after)
        reqs = tuple(batch)
        self.engine.submit(name, fn=lambda: self._run_batch(reqs))

    def _run_batch(self, reqs: tuple):
        clock = self.engine.tracer.clock
        t0 = clock()
        try:
            values = self.execute_batch([r.payload for r in reqs])
        except WorkerCrash:
            raise          # worker dies; the engine requeues the batch
        except Exception as e:                        # noqa: BLE001
            err = repr(e)
            for r in reqs:
                self._resolve(r, ok=False, error=err)
            raise          # the batch task is marked failed, consistently
        dt = clock() - t0
        a = self._ewma_alpha
        self._per_req_s = ((1 - a) * self._per_req_s
                           + a * max(dt / len(reqs), 1e-9))
        if isinstance(values, (list, tuple)) and len(values) == len(reqs):
            for r, v in zip(reqs, values):
                self._resolve(r, ok=True, value=v)
        else:
            for r in reqs:
                self._resolve(r, ok=True, value=values)
        return True

    def _resolve(self, req: ServeRequest, *, ok: bool, value=None,
                 error: Optional[str] = None):
        if req._event.is_set():
            return             # re-execution after a requeue: deliver once
        tracer = self.engine.tracer
        req.value = value
        req.ok = ok
        req.error = error
        req.t_done = tracer.clock()
        tracer.emit(REQ_DONE, task=req.name, worker=None,
                    latency_s=req.t_done - req.t_enqueue, ok=ok)
        req._event.set()

    # ---------------------------------------------------------------- obs
    def stats(self) -> dict:
        with self._cond:
            depth = len(self._queue)
        return {
            "accepted": self.accepted, "rejected": self.rejected,
            "batches": self.batches, "queue_depth": depth,
            "target_batch": self.target_batch(),
            "per_request_ewma_s": self._per_req_s,
            "live_workers": self.engine.live_workers(),
            "engine_ready_depth": self.engine.backend.ready_depth(),
        }
