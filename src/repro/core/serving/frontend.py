"""Admission control + METG-aware dynamic batching for the resident engine.

`Frontend` owns the request side of the serving subsystem: a bounded
admission queue with backpressure, a coalescer that packs requests into
engine tasks sized by the METG granularity laws (adapting to the live
worker count and observed per-request time), and a max-wait deadline so
tail latency is bounded even when traffic trickles.  See the package
docstring for the tuning guidance.

Monitoring (`snapshot()` / `start_snapshots(interval_s)`): the frontend
keeps a small windowed accumulator of per-request latencies and queue
depths, independent of the engine trace, so a long-lived resident
service can emit periodic `LatencyReport`s (p50/p95/p99 for the window
since the previous snapshot) with bounded state — no trace scan, no
trace retention requirement.  Snapshots land in the bounded
`Frontend.snapshots` deque and optionally a callback.
"""
from __future__ import annotations

import threading
from collections import deque
from typing import Callable, Optional

from repro.core.engine.model import (BATCH_FORMED, REQ_DONE, REQ_ENQUEUED,
                                     REQ_REJECTED, REQ_TIMEOUT, WorkerCrash,
                                     next_seq)
from repro.core.engine.tracing import LatencyReport, percentile
from repro.core.metg import METGModel, pick_batch_size


class AdmissionFull(RuntimeError):
    """The admission queue is full (reject policy) or stayed full past the
    submit timeout (block policy) — the client should back off."""


class ServeRequest:
    """One in-flight request: resolved exactly once (re-executions after a
    worker death hit the already-set guard), waitable from any thread."""

    __slots__ = ("name", "payload", "meta", "tenant", "t_enqueue", "t_done",
                 "value", "ok", "error", "deadline", "timed_out", "_event")

    def __init__(self, name: str, payload, meta: Optional[dict],
                 t_enqueue: float, deadline: Optional[float] = None,
                 tenant: Optional[str] = None):
        self.name = name
        self.payload = payload
        self.meta = meta or {}
        self.tenant = tenant
        self.t_enqueue = t_enqueue
        self.t_done = 0.0
        self.value = None
        self.ok = False
        self.error: Optional[str] = None
        self.deadline = deadline       # absolute trace-clock dispatch cutoff
        self.timed_out = False         # expired in the queue, never ran
        self._event = threading.Event()

    def wait(self, timeout: Optional[float] = None) -> bool:
        """True once a response is delivered; False on timeout."""
        return self._event.wait(timeout)

    @property
    def done(self) -> bool:
        return self._event.is_set()

    @property
    def latency_s(self) -> float:
        """Enqueue -> response latency on the engine's trace clock."""
        return (self.t_done - self.t_enqueue) if self.done else 0.0

    def __repr__(self):
        state = ("ok" if self.ok else f"err={self.error!r}") if self.done \
            else "pending"
        return f"ServeRequest({self.name}, {state})"


class Frontend:
    """Enqueue requests, coalesce them into METG-sized engine tasks.

    `execute_batch(payloads)` runs on an engine worker and returns a list
    of per-request values (same order/length), a single value broadcast to
    the batch, or None.  Raising marks every request in the batch failed;
    raising `WorkerCrash` instead kills the worker and the batch is
    requeued, not failed (fault drills).

    A batch is dispatched when the queue reaches the current METG target
    (`pick_batch_size` at the live worker count and the observed
    per-request EWMA) or when the oldest queued request has waited
    `max_wait_s`, whichever comes first.
    """

    def __init__(self, engine, execute_batch: Callable, *,
                 max_queue: int = 256, max_batch: int = 64,
                 max_wait_s: float = 0.005, target_eff: float = 0.9,
                 per_request_s0: float = 1e-3, scheduler: str = "dwork",
                 model: Optional[METGModel] = None, policy: str = "block",
                 snapshot_interval_s: Optional[float] = None,
                 snapshot_keep: int = 120,
                 on_snapshot: Optional[Callable] = None):
        if policy not in ("block", "reject"):
            raise ValueError(f"unknown backpressure policy {policy!r}")
        if not engine.resident:
            raise ValueError("Frontend requires Engine(resident=True)")
        self.engine = engine
        self.execute_batch = execute_batch
        self.max_queue = max(int(max_queue), 1)
        self.max_batch = max(int(max_batch), 1)
        self.max_wait_s = max_wait_s
        self.target_eff = target_eff
        self.scheduler = scheduler
        self.model = model or METGModel.from_paper()
        self.policy = policy
        self._per_req_s = max(per_request_s0, 1e-9)  # observed-time EWMA
        self._ewma_alpha = 0.2
        self._queue: deque[ServeRequest] = deque()
        self._cond = threading.Condition()
        self._closing = False
        self._force_flush = False
        self.accepted = 0
        self.rejected = 0
        self.timeouts = 0              # queued past their deadline
        self._n_deadlines = 0          # queued requests carrying a deadline
        self.batches = 0
        # optional serving-metrics sink (repro.core.obs.ServingMetrics):
        # observed at response delivery, beside the REQ_DONE emit
        self.metrics = None
        self._thread: Optional[threading.Thread] = None
        # ---------------------------------------- monitoring snapshots
        # windowed accumulator, reset on every snapshot(): bounded by the
        # traffic of one window, never by service lifetime.  Accumulation
        # only runs while monitoring is ARMED (ctor interval,
        # start_snapshots(), or a priming snapshot() call) — a frontend
        # nobody ever snapshots must not grow these lists forever.
        self._monitoring = snapshot_interval_s is not None
        self.snapshot_interval_s = snapshot_interval_s
        self.on_snapshot = on_snapshot
        self.snapshots: deque[LatencyReport] = deque(
            maxlen=max(int(snapshot_keep), 1))
        self._snap_lock = threading.Lock()
        self._snap_t0 = engine.tracer.clock()
        self._w_lats: list[float] = []
        self._w_failed = 0
        self._w_rejected = 0
        # tenant -> [lats, n_failed, n_rejected]: the per-tenant slice of
        # the same window, populated only for requests that carry tenant=
        self._w_tenants: dict = {}
        self._w_batches = 0
        self._w_batched = 0
        self._w_wait_s = 0.0
        self._w_depths: list[int] = []
        self._snap_stop = threading.Event()
        self._snap_thread: Optional[threading.Thread] = None

    # ----------------------------------------------------------- lifecycle
    def start(self) -> "Frontend":
        """Start the coalescer (and the engine's resident loop if the
        caller hasn't already)."""
        if self._thread is not None:
            raise RuntimeError("frontend already started")
        if not self.engine.started:
            self.engine.start()
        self._closing = False
        self._thread = threading.Thread(target=self._coalesce_loop,
                                        name="serving-frontend", daemon=True)
        self._thread.start()
        if self.snapshot_interval_s is not None:
            self.start_snapshots(self.snapshot_interval_s)
        return self

    def close(self, *, drain: bool = True,
              timeout: Optional[float] = None) -> bool:
        """Stop admitting, flush the queue as final batches, and (with
        `drain=True`) wait for every dispatched batch to finish.  Does NOT
        shut the engine down — that is the engine owner's call."""
        monitoring = self._monitoring
        self.stop_snapshots(final=False)
        with self._cond:
            self._closing = True
            self._cond.notify_all()
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        ok = self.engine.drain(timeout) if drain else True
        if monitoring:
            # the tail window: requests that resolved during the flush +
            # drain above must reach the monitor too, so the final
            # snapshot is taken AFTER the drain, not before it
            self.snapshot()
        return ok

    # ------------------------------------------------------------- client
    def submit(self, payload, *, meta: Optional[dict] = None,
               timeout: Optional[float] = None,
               tenant: Optional[str] = None) -> ServeRequest:
        """Admit one request.  With a full queue: `policy="reject"` raises
        `AdmissionFull` immediately; `policy="block"` waits for space up
        to `timeout` seconds (None = forever) and then raises.

        `timeout` is also the request's QUEUE DEADLINE: once admitted, a
        request still undispatched `timeout` seconds after its enqueue is
        withdrawn and resolved with `ok=False`, `timed_out=True`, and a
        `TimeoutError` repr in `error` (plus a `REQ_TIMEOUT` trace
        event) — overload sheds the oldest deadline work instead of
        serving unboundedly stale responses.  A dispatched request always
        runs to completion; the deadline only covers queue wait.

        `tenant` labels the request for per-tenant observability: the
        label rides the REQ_* trace events, the windowed snapshots
        (`LatencyReport.by_tenant`, visible in `/stats`), and the
        `repro_request_latency_seconds{tenant=...}` histogram when a
        metrics registry is attached.  Purely observational — admission
        and batching never look at it."""
        tracer = self.engine.tracer
        with self._cond:
            if self._closing:
                raise RuntimeError("frontend is closed")
            if len(self._queue) >= self.max_queue:
                blocked = (self.policy == "block"
                           and (timeout is None or timeout > 0)
                           and self._cond.wait_for(
                               lambda: (len(self._queue) < self.max_queue
                                        or self._closing), timeout))
                if not blocked or self._closing:
                    self.rejected += 1
                    if tenant is None:
                        tracer.emit(REQ_REJECTED, depth=len(self._queue),
                                    policy=self.policy)
                    else:
                        tracer.emit(REQ_REJECTED, depth=len(self._queue),
                                    policy=self.policy, tenant=tenant)
                    if self._monitoring:
                        with self._snap_lock:
                            self._w_rejected += 1
                            if tenant is not None:
                                self._w_tenant(tenant)[2] += 1
                    raise AdmissionFull(
                        f"admission queue full ({self.max_queue})")
            # next_seq(): engine task names are single-use forever, so
            # request/batch names must be unique across every frontend
            # that ever shares an engine (or a task server)
            t_enq = tracer.clock()
            req = ServeRequest(
                f"__req{next_seq()}", payload, meta, t_enqueue=t_enq,
                deadline=(t_enq + timeout) if timeout is not None else None,
                tenant=tenant)
            self._queue.append(req)
            if req.deadline is not None:
                self._n_deadlines += 1
            self.accepted += 1
            depth = len(self._queue)
            if tenant is None:
                tracer.emit(REQ_ENQUEUED, task=req.name, depth=depth)
            else:
                tracer.emit(REQ_ENQUEUED, task=req.name, depth=depth,
                            tenant=tenant)
            self._cond.notify_all()
        if self._monitoring:
            with self._snap_lock:
                self._w_depths.append(depth)
        return req

    def flush(self):
        """Dispatch whatever is queued right now without waiting for the
        batch target or deadline (deterministic tests, graceful drains)."""
        with self._cond:
            self._force_flush = True
            self._cond.notify_all()

    # ------------------------------------------------------------ batching
    def target_batch(self) -> int:
        """Current METG-aware batch target: the granularity at which
        scheduling overhead stays under (1 - target_eff) of compute, for
        the LIVE worker count, the observed per-request time, and the
        engine's shard count (a sharded hub — alone or behind the tree —
        divides the dispatch bound, so batches can shrink)."""
        live = max(self.engine.live_workers(), 1)
        n = pick_batch_size(self.scheduler, live, self._per_req_s,
                            target_eff=self.target_eff, model=self.model,
                            shards=getattr(self.engine, "shards", 1))
        return max(1, min(n, self.max_batch))

    def _coalesce_loop(self):
        clock = self.engine.tracer.clock
        while True:
            with self._cond:
                while True:
                    if self._n_deadlines:
                        self._expire_overdue(clock())
                    if self._closing:
                        break
                    n = len(self._queue)
                    target = self.target_batch()
                    if n >= target:
                        break
                    if n and self._force_flush:
                        break
                    wait = None
                    if n:
                        age = clock() - self._queue[0].t_enqueue
                        if age >= self.max_wait_s:
                            break
                        # under a ManualClock `age` may never advance;
                        # the floor keeps the wait finite either way
                        wait = max(self.max_wait_s - age, 1e-4)
                    if self._n_deadlines:
                        # wake at the earliest queue deadline too, so an
                        # expiry is detected promptly even when the batch
                        # deadline is far off
                        earliest = min(r.deadline for r in self._queue
                                       if r.deadline is not None)
                        dl = max(earliest - clock(), 1e-4)
                        wait = dl if wait is None else min(wait, dl)
                    self._cond.wait(wait)
                self._force_flush = False
                if not self._queue:
                    if self._closing:
                        return
                    continue
                take = min(len(self._queue), max(self.target_batch(), 1))
                batch = [self._queue.popleft() for _ in range(take)]
                if self._n_deadlines:
                    self._n_deadlines -= sum(1 for r in batch
                                             if r.deadline is not None)
                depth_after = len(self._queue)
                self._cond.notify_all()      # space freed: wake submitters
            try:
                self._dispatch(batch, depth_after)
            except Exception as e:            # noqa: BLE001
                # a dispatch failure (engine shut down under us, backend
                # error) must never strand waiters — fail the batch loudly
                err = repr(e)
                for r in batch:
                    self._resolve(r, ok=False, error=err)

    def _expire_overdue(self, now: float):
        """Withdraw every queued request past its deadline and resolve it
        as timed out (caller holds `self._cond`)."""
        expired = [r for r in self._queue
                   if r.deadline is not None and now >= r.deadline]
        if not expired:
            return
        dead = set(map(id, expired))
        self._queue = deque(r for r in self._queue if id(r) not in dead)
        self._n_deadlines -= len(expired)
        self.timeouts += len(expired)
        tracer = self.engine.tracer
        for r in expired:
            r.timed_out = True
            tracer.emit(REQ_TIMEOUT, task=r.name,
                        waited_s=now - r.t_enqueue)
            self._resolve(r, ok=False, error=repr(TimeoutError(
                f"{r.name}: queued past its deadline")))
        self._cond.notify_all()          # space freed: wake submitters

    def _dispatch(self, batch: list, depth_after: int):
        tracer = self.engine.tracer
        self.batches += 1
        name = f"__batch{next_seq()}"
        now = tracer.clock()
        wait_s = now - batch[0].t_enqueue
        tracer.emit(BATCH_FORMED, task=name, size=len(batch),
                    wait_s=wait_s, target=self.target_batch(),
                    depth=depth_after)
        if self._monitoring:
            with self._snap_lock:
                self._w_batches += 1
                self._w_batched += len(batch)
                self._w_wait_s += wait_s
                self._w_depths.append(depth_after)
        reqs = tuple(batch)
        self.engine.submit(name, fn=lambda: self._run_batch(reqs))

    def _run_batch(self, reqs: tuple):
        clock = self.engine.tracer.clock
        t0 = clock()
        try:
            values = self.execute_batch([r.payload for r in reqs])
        except WorkerCrash:
            raise          # worker dies; the engine requeues the batch
        except Exception as e:                        # noqa: BLE001
            err = repr(e)
            for r in reqs:
                self._resolve(r, ok=False, error=err)
            raise          # the batch task is marked failed, consistently
        dt = clock() - t0
        a = self._ewma_alpha
        self._per_req_s = ((1 - a) * self._per_req_s
                           + a * max(dt / len(reqs), 1e-9))
        if isinstance(values, (list, tuple)) and len(values) == len(reqs):
            for r, v in zip(reqs, values):
                self._resolve(r, ok=True, value=v)
        else:
            for r in reqs:
                self._resolve(r, ok=True, value=values)
        return True

    def _resolve(self, req: ServeRequest, *, ok: bool, value=None,
                 error: Optional[str] = None):
        if req._event.is_set():
            return             # re-execution after a requeue: deliver once
        tracer = self.engine.tracer
        req.value = value
        req.ok = ok
        req.error = error
        req.t_done = tracer.clock()
        latency_s = req.t_done - req.t_enqueue
        if req.tenant is None:
            tracer.emit(REQ_DONE, task=req.name, worker=None,
                        latency_s=latency_s, ok=ok)
        else:
            tracer.emit(REQ_DONE, task=req.name, worker=None,
                        latency_s=latency_s, ok=ok, tenant=req.tenant)
        m = self.metrics
        if m is not None:
            m.observe_request(latency_s, ok, tenant=req.tenant)
        if self._monitoring:
            with self._snap_lock:
                self._w_lats.append(latency_s)
                if not ok:
                    self._w_failed += 1
                if req.tenant is not None:
                    slot = self._w_tenant(req.tenant)
                    slot[0].append(latency_s)
                    if not ok:
                        slot[1] += 1
        req._event.set()

    def _w_tenant(self, tenant: str) -> list:
        """The window accumulator slot for one tenant: [lats, failed,
        rejected] (caller holds `self._snap_lock`)."""
        slot = self._w_tenants.get(tenant)
        if slot is None:
            slot = self._w_tenants[tenant] = [[], 0, 0]
        return slot

    # ---------------------------------------------------------- snapshots
    def snapshot(self) -> LatencyReport:
        """One windowed `LatencyReport` covering the requests resolved
        since the previous snapshot (or since monitoring was armed),
        appended to the bounded `self.snapshots` deque.  State is bounded
        by one window's traffic, not service lifetime — monitoring for
        long-lived resident services that run with `max_trace_events=`
        ring buffers (or no trace retention at all).

        Monitoring arms on the ctor's `snapshot_interval_s`, on
        `start_snapshots()`, or on the FIRST call here — that priming
        call returns an empty window (nothing was accumulating before),
        and every later window is complete."""
        clock = self.engine.tracer.clock
        self._monitoring = True
        with self._snap_lock:
            lats = self._w_lats
            depths = self._w_depths
            n_failed, self._w_failed = self._w_failed, 0
            n_rejected, self._w_rejected = self._w_rejected, 0
            n_batches, self._w_batches = self._w_batches, 0
            batched, self._w_batched = self._w_batched, 0
            wait_s, self._w_wait_s = self._w_wait_s, 0.0
            tenants, self._w_tenants = self._w_tenants, {}
            self._w_lats = []
            self._w_depths = []
            t1 = clock()
            t0, self._snap_t0 = self._snap_t0, t1
        lats.sort()
        by_tenant = None
        if tenants:
            by_tenant = {}
            for tenant, (tlats, tfailed, trejected) in sorted(
                    tenants.items()):
                tlats.sort()
                by_tenant[tenant] = LatencyReport._tenant_slice(
                    tlats, n_failed=tfailed, n_rejected=trejected)
        rep = LatencyReport(
            n_requests=len(lats),
            n_failed=n_failed,
            n_rejected=n_rejected,
            n_batches=n_batches,
            mean_batch=(batched / n_batches) if n_batches else 0.0,
            mean_s=(sum(lats) / len(lats)) if lats else 0.0,
            p50_s=percentile(lats, 0.50),
            p95_s=percentile(lats, 0.95),
            p99_s=percentile(lats, 0.99),
            max_s=lats[-1] if lats else 0.0,
            queue_depth_mean=(sum(depths) / len(depths)) if depths else 0.0,
            queue_depth_max=max(depths, default=0),
            batch_wait_mean_s=(wait_s / n_batches) if n_batches else 0.0,
            t_s=t1,
            window_s=max(t1 - t0, 0.0),
            by_tenant=by_tenant,
        )
        self.snapshots.append(rep)
        if self.on_snapshot is not None:
            try:
                self.on_snapshot(rep)
            except Exception:    # noqa: BLE001 — monitoring must never
                pass             # take the serving path down
        return rep

    def start_snapshots(self, interval_s: float) -> "Frontend":
        """Spawn the periodic monitor: every `interval_s` a windowed
        snapshot() lands in `self.snapshots` (and `on_snapshot`, if
        set).  Idempotent; stopped by `stop_snapshots()` / `close()`."""
        if self._snap_thread is not None:
            return self
        self._monitoring = True
        self.snapshot_interval_s = interval_s
        self._snap_stop.clear()

        def _loop():
            while not self._snap_stop.wait(self.snapshot_interval_s):
                self.snapshot()

        self._snap_thread = threading.Thread(
            target=_loop, name="serving-snapshots", daemon=True)
        self._snap_thread.start()
        return self

    def stop_snapshots(self, *, final: bool = True):
        """Stop the periodic monitor; with `final=True` (default) take
        one last snapshot so the tail window is not lost."""
        th, self._snap_thread = self._snap_thread, None
        if th is None:
            return
        self._snap_stop.set()
        th.join()
        if final:
            self.snapshot()

    # ---------------------------------------------------------------- obs
    def stats(self) -> dict:
        with self._cond:
            depth = len(self._queue)
        return {
            "accepted": self.accepted, "rejected": self.rejected,
            "timeouts": self.timeouts,
            "batches": self.batches, "queue_depth": depth,
            "target_batch": self.target_batch(),
            "per_request_ewma_s": self._per_req_s,
            "live_workers": self.engine.live_workers(),
            "engine_ready_depth": self.engine.backend.ready_depth(),
        }
