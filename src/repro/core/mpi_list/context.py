"""In-process mpi-list: Context + DFM with the paper's exact partition law.

The rank loop is sequential (one process), but every operation is expressed
rank-locally — the same code shape as the mpi4py original — and the
partition invariant (contiguous ascending blocks, paper §2.3) is enforced
and property-tested.
"""
from __future__ import annotations

import time
from typing import Any, Callable, Iterable, Optional


def partition_bounds(N: int, P: int, p: int) -> tuple[int, int]:
    """Start/end of rank p's block: start = p*(N//P) + min(p, N%P)."""
    start = p * (N // P) + min(p, N % P)
    length = N // P + (1 if p < N % P else 0)
    return start, start + length


class Context:
    """Communicator stand-in. `procs` ranks, rank-local jitter optional
    (straggler modelling for the METG benchmark)."""

    def __init__(self, procs: int = 1, *, jitter: Optional[Callable[[int], float]] = None):
        self.procs = procs
        self.rank = 0                   # in-proc: we "are" every rank in turn
        self.jitter = jitter
        self.sync_time = 0.0            # accumulated straggler gap (modelled)

    # -- constructors ------------------------------------------------------
    def iterates(self, N: int) -> "DFM":
        parts = []
        for p in range(self.procs):
            s, e = partition_bounds(N, self.procs, p)
            parts.append(list(range(s, e)))
        return DFM(self, parts)

    def scatter(self, xs: list) -> "DFM":
        N = len(xs)
        parts = []
        for p in range(self.procs):
            s, e = partition_bounds(N, self.procs, p)
            parts.append(list(xs[s:e]))
        return DFM(self, parts)

    # -- BSP sync point (straggler accounting) -----------------------------
    def _sync(self, per_rank_times: Optional[list] = None):
        if per_rank_times:
            self.sync_time += max(per_rank_times) - min(per_rank_times)


class DFM:
    """Distributed free monoid: list of per-rank blocks."""

    def __init__(self, C: Context, parts: list):
        assert len(parts) == C.procs
        self.C = C
        self.parts = parts

    # -- embarrassingly parallel ops (no sync) ------------------------------
    def map(self, f: Callable) -> "DFM":
        return self._timed(lambda blk: [f(x) for x in blk])

    def flatMap(self, f: Callable) -> "DFM":
        return self._timed(lambda blk: [y for x in blk for y in f(x)])

    def filter(self, pred: Callable) -> "DFM":
        return self._timed(lambda blk: [x for x in blk if pred(x)])

    def _timed(self, g: Callable) -> "DFM":
        out, times = [], []
        for p, blk in enumerate(self.parts):
            t0 = time.perf_counter()
            out.append(g(blk))
            dt = time.perf_counter() - t0
            if self.C.jitter is not None:
                dt += self.C.jitter(p)
            times.append(dt)
        self.C._sync(times)
        return DFM(self.C, out)

    # -- reductions (sync) ---------------------------------------------------
    def len(self) -> int:
        return sum(len(b) for b in self.parts)

    def reduce(self, f: Callable, zero: Any) -> Any:
        acc = zero
        for blk in self.parts:
            for x in blk:
                acc = f(acc, x)
        return acc

    def scan(self, f: Callable, zero: Any) -> "DFM":
        """Inclusive prefix scan over the global list order."""
        out, acc = [], zero
        for blk in self.parts:
            cur = []
            for x in blk:
                acc = f(acc, x)
                cur.append(acc)
            out.append(cur)
        return DFM(self.C, out)

    def collect(self) -> list:
        return [x for blk in self.parts for x in blk]

    def head(self, n: int = 10) -> list:
        return self.collect()[:n]

    # -- data movement -------------------------------------------------------
    def repartition(self, len_f: Callable, split_f: Callable,
                    concat_f: Callable) -> "DFM":
        """Re-balance treating each element as a container of records
        (paper: len / subdivide / combine functions).  The result is one
        combined element per rank, with records split by the partition law."""
        records = []
        for blk in self.parts:
            for x in blk:
                n = len_f(x)
                records.extend(split_f(x, n))   # one chunk per record
        N = len(records)
        parts = []
        for p in range(self.C.procs):
            s, e = partition_bounds(N, self.C.procs, p)
            parts.append([concat_f(records[s:e])] if e > s else [])
        return DFM(self.C, parts)

    def group(self, dest_f: Callable, combine_f: Callable) -> "DFM":
        """dest_f: element -> {dest_index: [records]}; records are shipped to
        `dest_index` (mod procs) and combined per destination."""
        P = self.C.procs
        inbox: dict[int, list] = {}
        for blk in self.parts:
            for x in blk:
                for dest, recs in dest_f(x).items():
                    inbox.setdefault(dest % P, []).extend(recs)
        parts = []
        for p in range(P):
            parts.append([combine_f(p, inbox[p])] if p in inbox else [])
        return DFM(self.C, parts)

    # -- invariants (property-tested) ---------------------------------------
    def check_partition_law(self):
        """Blocks must be contiguous ascending when elements are ints."""
        flat = self.collect()
        sizes = [len(b) for b in self.parts]
        N, P = sum(sizes), self.C.procs
        for p in range(P):
            s, e = partition_bounds(N, P, p)
            assert sizes[p] == e - s, (p, sizes[p], e - s)
        return flat
