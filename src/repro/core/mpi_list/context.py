"""In-process mpi-list: Context + DFM with the paper's exact partition law.

The rank loop is sequential (one process), but every operation is expressed
rank-locally — the same code shape as the mpi4py original — and the
partition invariant (contiguous ascending blocks, paper §2.3) is enforced
and property-tested.

Engine-backed multi-rank mode: `Context(P, engine_workers=W)` dispatches
the map-family bulk steps (map / flatMap / filter — the `_timed` path)
as tasks on the unified engine pool (`repro.core.engine`), one task per
rank per superstep — the BSP analog of the paper's Fig. 2 dispatch.
Reductions and data movement (reduce / scan / repartition / group) stay
in-process.  Seeded straggler injection (`straggler_sigma`) adds
deterministic virtual jitter to per-rank times; the accumulated max-min
sync gaps feed the Gumbel extreme-value law
`METGModel.mpilist_metg(P, per_rank_sigma=sigma)` via
`Context.straggler_crosscheck()`.
"""
from __future__ import annotations

import time
from typing import Any, Callable, Iterable, Optional


def partition_bounds(N: int, P: int, p: int) -> tuple[int, int]:
    """Start/end of rank p's block: start = p*(N//P) + min(p, N%P)."""
    start = p * (N // P) + min(p, N % P)
    length = N // P + (1 if p < N % P else 0)
    return start, start + length


class Context:
    """Communicator stand-in. `procs` ranks, rank-local jitter optional
    (straggler modelling for the METG benchmark).

    With `engine_workers` (or `straggler_sigma` > 0) set, bulk operations
    run through the unified engine pool — one task per rank per superstep —
    and per-step sync gaps are recorded in `self.gaps`/`self.rank_times`.
    """

    def __init__(self, procs: int = 1, *,
                 jitter: Optional[Callable[[int], float]] = None,
                 engine_workers: Optional[int] = None,
                 straggler_sigma: float = 0.0, seed: int = 0):
        self.procs = procs
        self.rank = 0                   # in-proc: we "are" every rank in turn
        self.jitter = jitter
        self.sync_time = 0.0            # accumulated straggler gap (modelled)
        self.engine_workers = engine_workers
        self.straggler_sigma = straggler_sigma
        self.seed = seed
        self.engine_enabled = engine_workers is not None or straggler_sigma > 0
        self.step = 0                   # superstep counter (engine mode)
        self.gaps: list[float] = []     # per-step max-min rank-time gap
        self.rank_times: list[list[float]] = []
        # injected-jitter-only gaps: exactly reproducible for a fixed seed
        # (real per-rank times always carry wall-clock noise)
        self.virtual_gaps: list[float] = []

    # -- constructors ------------------------------------------------------
    def iterates(self, N: int) -> "DFM":
        parts = []
        for p in range(self.procs):
            s, e = partition_bounds(N, self.procs, p)
            parts.append(list(range(s, e)))
        return DFM(self, parts)

    def scatter(self, xs: list) -> "DFM":
        N = len(xs)
        parts = []
        for p in range(self.procs):
            s, e = partition_bounds(N, self.procs, p)
            parts.append(list(xs[s:e]))
        return DFM(self, parts)

    # -- BSP sync point (straggler accounting) -----------------------------
    def _sync(self, per_rank_times: Optional[list] = None):
        if per_rank_times:
            self.sync_time += max(per_rank_times) - min(per_rank_times)

    # -- engine-backed superstep (one task per rank) -----------------------
    def _engine_step(self, parts: list, g: Callable) -> list:
        """Dispatch one bulk operation through the futures client (batch
        mode, one future per rank): rank p's block becomes task
        `rank{p}.step{s}`; per-rank times (real + any injected virtual
        straggler jitter) are recorded and synced.  A shim over
        `repro.client.Client`, same as the other front doors."""
        from repro.client import Client
        from repro.core.engine.faults import FaultPlan

        faults = None
        if self.straggler_sigma > 0:
            faults = FaultPlan(seed=self.seed * 1_000_003 + self.step)
            faults.stragglers(self.straggler_sigma)
        workers = self.engine_workers or min(self.procs, 8)
        client = Client(scheduler="mpi_list", workers=max(workers, 1),
                        transport="inproc",
                        steal_n=max(1, self.procs // max(workers, 1)),
                        faults=faults, resident=False)
        futs = [client.submit(g, blk, key=f"rank{p}.step{self.step}")
                for p, blk in enumerate(parts)]
        try:
            client.run()
        finally:
            client.close()
        out, times, virtuals = [], [], []
        for p, fut in enumerate(futs):
            err = fut.exception()
            if err is not None:
                raise RuntimeError(f"mpi-list rank {p} failed: {err!r}")
            res = fut.task_result
            if res is None:
                raise RuntimeError(f"mpi-list rank {p} failed: lost task")
            out.append(fut.result())
            dt = res.duration_s
            if self.jitter is not None:
                dt += self.jitter(p)
            times.append(dt)
            virtuals.append(res.virtual_s)
        self.step += 1
        self.rank_times.append(times)
        self.gaps.append(max(times) - min(times))
        self.virtual_gaps.append(max(virtuals) - min(virtuals))
        self._sync(times)
        return out

    def straggler_crosscheck(self, factor: float = 10.0) -> dict:
        """Empirical mean sync gap vs the Gumbel law sigma*sqrt(2 ln P)
        (paper §3, ref [31]) evaluated at the injected sigma."""
        from repro.core.engine.tracing import crosscheck
        from repro.core.metg import METGModel

        if not self.gaps:
            raise ValueError("no engine-mode supersteps recorded")
        if self.straggler_sigma <= 0.0:
            raise ValueError(
                "straggler_crosscheck needs injected jitter "
                "(straggler_sigma > 0); with sigma=0 the model side would "
                "fall back to the paper's Summit-fitted sync curve, which "
                "says nothing about this run")
        emp = sum(self.gaps) / len(self.gaps)
        ana = METGModel.from_paper().mpilist_metg(
            self.procs, per_rank_sigma=self.straggler_sigma)
        return crosscheck("mpi-list", emp, ana, factor=factor)


class DFM:
    """Distributed free monoid: list of per-rank blocks."""

    def __init__(self, C: Context, parts: list):
        assert len(parts) == C.procs
        self.C = C
        self.parts = parts

    # -- embarrassingly parallel ops (no sync) ------------------------------
    def map(self, f: Callable) -> "DFM":
        return self._timed(lambda blk: [f(x) for x in blk])

    def flatMap(self, f: Callable) -> "DFM":
        return self._timed(lambda blk: [y for x in blk for y in f(x)])

    def filter(self, pred: Callable) -> "DFM":
        return self._timed(lambda blk: [x for x in blk if pred(x)])

    def _timed(self, g: Callable) -> "DFM":
        if self.C.engine_enabled:
            return DFM(self.C, self.C._engine_step(self.parts, g))
        out, times = [], []
        for p, blk in enumerate(self.parts):
            t0 = time.perf_counter()
            out.append(g(blk))
            dt = time.perf_counter() - t0
            if self.C.jitter is not None:
                dt += self.C.jitter(p)
            times.append(dt)
        self.C._sync(times)
        return DFM(self.C, out)

    # -- reductions (sync) ---------------------------------------------------
    def len(self) -> int:
        return sum(len(b) for b in self.parts)

    def reduce(self, f: Callable, zero: Any) -> Any:
        acc = zero
        for blk in self.parts:
            for x in blk:
                acc = f(acc, x)
        return acc

    def scan(self, f: Callable, zero: Any) -> "DFM":
        """Inclusive prefix scan over the global list order."""
        out, acc = [], zero
        for blk in self.parts:
            cur = []
            for x in blk:
                acc = f(acc, x)
                cur.append(acc)
            out.append(cur)
        return DFM(self.C, out)

    def collect(self) -> list:
        return [x for blk in self.parts for x in blk]

    def head(self, n: int = 10) -> list:
        return self.collect()[:n]

    # -- data movement -------------------------------------------------------
    def repartition(self, len_f: Callable, split_f: Callable,
                    concat_f: Callable) -> "DFM":
        """Re-balance treating each element as a container of records
        (paper: len / subdivide / combine functions).  The result is one
        combined element per rank, with records split by the partition law."""
        records = []
        for blk in self.parts:
            for x in blk:
                n = len_f(x)
                records.extend(split_f(x, n))   # one chunk per record
        N = len(records)
        parts = []
        for p in range(self.C.procs):
            s, e = partition_bounds(N, self.C.procs, p)
            parts.append([concat_f(records[s:e])] if e > s else [])
        return DFM(self.C, parts)

    def group(self, dest_f: Callable, combine_f: Callable) -> "DFM":
        """dest_f: element -> {dest_index: [records]}; records are shipped to
        `dest_index` (mod procs) and combined per destination."""
        P = self.C.procs
        inbox: dict[int, list] = {}
        for blk in self.parts:
            for x in blk:
                for dest, recs in dest_f(x).items():
                    inbox.setdefault(dest % P, []).extend(recs)
        parts = []
        for p in range(P):
            parts.append([combine_f(p, inbox[p])] if p in inbox else [])
        return DFM(self.C, parts)

    # -- invariants (property-tested) ---------------------------------------
    def check_partition_law(self):
        """Blocks must be contiguous ascending when elements are ints."""
        flat = self.collect()
        sizes = [len(b) for b in self.parts]
        N, P = sum(sizes), self.C.procs
        for p in range(P):
            s, e = partition_bounds(N, P, p)
            assert sizes[p] == e - s, (p, sizes[p], e - s)
        return flat
