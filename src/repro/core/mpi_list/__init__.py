"""mpi-list: bulk-synchronous distributed lists (Rogers 2021, §2.3).

A `Context` holds the communicator; a `DFM` (distributed free monoid) is an
ordered global list with a contiguous ascending block per rank:
rank p of P stores the subsequence starting at ``p*(N//P) + min(p, N%P)``.

Three backends:
  * in-process rank simulation (`Context(n_ranks)`) — semantics-exact SPMD,
    used by the data pipeline, tests, and METG benchmarks;
  * engine-backed multi-rank mode (`Context(n_ranks, engine_workers=W)`) —
    the map-family bulk steps (map / flatMap / filter) dispatch one task
    per rank on the unified engine pool (`repro.core.engine`), with
    seeded straggler injection feeding the Gumbel sync-gap law
    (`Context.straggler_crosscheck`); reductions and data movement
    (reduce / scan / repartition / group) stay in-process;
  * mesh bridge (`repro.core.mpi_list.mesh_ops`) — the same bulk ops lowered
    onto a jax mesh data axis (map -> sharded elementwise, reduce -> psum,
    scan -> associative prefix, repartition/group -> all-to-all), which is
    how the DFM concept becomes the framework's data-parallel inner loop.
"""
from repro.core.mpi_list.context import DFM, Context, partition_bounds

__all__ = ["Context", "DFM", "partition_bounds"]
