"""DFM mesh bridge: the mpi-list bulk operations lowered onto a jax mesh.

A "mesh DFM" is a pytree of arrays whose leading dim is the global list
index, sharded over the mesh `data` axis with the paper's contiguous-block
partition (NamedSharding produces exactly that layout).  The mpi-list ops
map onto jax-native constructs:

    map         -> jit(vmap(f))        (elementwise over the sharded dim)
    reduce      -> jit(sum/monoid)     (psum via sharding propagation)
    scan        -> lax.associative_scan (cross-shard prefix handled by XLA)
    repartition -> resharding to the balanced partition (all-to-all-ish)
    group       -> fixed-size bucket exchange (sort + reshard)

This is the sense in which the framework's data-parallel inner loop *is*
mpi-list: `train_step` = dfm.map(grad) . dfm.reduce(+).
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P


def data_sharding(mesh, ndim: int):
    axes = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
    return NamedSharding(mesh, P(axes, *([None] * (ndim - 1))))


def iterates(mesh, N: int) -> jax.Array:
    x = jnp.arange(N)
    return jax.device_put(x, data_sharding(mesh, 1))


def scatter(mesh, x) -> jax.Array:
    x = jnp.asarray(x)
    return jax.device_put(x, data_sharding(mesh, x.ndim))


def dfm_map(mesh, f: Callable, dfm, *, donate: bool = False):
    out_fn = jax.jit(jax.vmap(f), donate_argnums=(0,) if donate else ())
    return out_fn(dfm)


def dfm_reduce(mesh, f_monoid: Callable, dfm):
    """Tree-reduction over the global list with an associative monoid
    (cross-shard combine becomes a psum-like collective via GSPMD)."""
    def pairwise(v):
        n = v.shape[0]
        if n == 1:
            return v[0]
        if n % 2:
            return f_monoid(pairwise(v[:-1]), v[-1])
        return pairwise(f_monoid(v[0::2], v[1::2]))
    return jax.jit(lambda x: jax.tree_util.tree_map(pairwise, x))(dfm)


def dfm_sum(mesh, dfm):
    return jax.jit(lambda x: jax.tree_util.tree_map(
        lambda v: jnp.sum(v, axis=0), x))(dfm)


def dfm_scan(mesh, f_assoc: Callable, dfm):
    """Inclusive prefix scan (cross-shard prefix exchange handled by XLA)."""
    return jax.jit(lambda x: jax.tree_util.tree_map(
        lambda v: jax.lax.associative_scan(f_assoc, v, axis=0), x))(dfm)


def repartition(mesh, dfm):
    """Rebalance to the canonical contiguous-block partition."""
    return jax.tree_util.tree_map(
        lambda v: jax.device_put(v, data_sharding(mesh, v.ndim)), dfm)


def group(mesh, dest: jax.Array, dfm):
    """Move row i to bucket dest[i] (stable within bucket): sort-by-key then
    rebalance — the all-to-all exchange pattern of mpi-list.group."""
    order = jnp.argsort(dest, stable=True)
    out = jax.tree_util.tree_map(lambda v: jnp.take(v, order, axis=0), dfm)
    return repartition(mesh, out)


def collect(dfm):
    return jax.tree_util.tree_map(
        lambda v: jax.device_get(v), dfm)
