"""repro.core — the paper's contribution: three workflow schedulers + METG.

  pmake    file-based push scheduler with EFT priority   (paper §2.1)
  dwork    client/server bag-of-tasks with dependencies   (paper §2.2)
  mpi_list bulk-synchronous distributed lists (DFM)       (paper §2.3)
  metg     minimum-effective-task-granularity scaling laws (§3-§6)
"""
