"""Sharded train step: loss -> grads (optionally microbatched) -> AdamW.

Under pjit, the gradient all-reduce over (pod, data) is implicit in the
sharding propagation; microbatching turns it into per-microbatch psums that
XLA can overlap with the next microbatch's compute.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.models.common import softmax_xent
from repro.optim.adamw import OptState, adamw_update, init_opt


def make_loss_fn(model):
    cfg = model.cfg

    def loss_fn(params, batch):
        logits, aux = model.forward(params, batch)
        loss = softmax_xent(logits, batch["labels"], cfg.vocab_size)
        return loss + aux, {"xent": loss, "moe_aux": aux}

    return loss_fn


def make_train_step(model, rc):
    """Returns train_step(params, opt_state, batch) -> (params, opt, metrics)."""
    loss_fn = make_loss_fn(model)
    n_mb = rc.microbatches
    acc_dtype = jnp.bfloat16 if rc.grad_compress == "bf16" else jnp.float32

    def train_step(params, opt_state: OptState, batch):
        if n_mb == 1:
            (loss, aux), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                params, batch)
        else:
            def split_b(x, axis=0):
                b = x.shape[axis]
                new = x.shape[:axis] + (n_mb, b // n_mb) + x.shape[axis + 1:]
                return jnp.moveaxis(x.reshape(new), axis, 0)

            # mrope_positions carries batch on axis 1 ((3, B, S))
            mbs = {k: split_b(v, 1 if k == "mrope_positions" else 0)
                   for k, v in batch.items()}

            def mb_step(carry, mb):
                g_acc, l_acc = carry
                (l, _), g = jax.value_and_grad(loss_fn, has_aux=True)(params, mb)
                g_acc = jax.tree_util.tree_map(
                    lambda a, b: a + b.astype(acc_dtype), g_acc, g)
                return (g_acc, l_acc + l), None

            g0 = jax.tree_util.tree_map(
                lambda x: jnp.zeros(x.shape, acc_dtype), params)
            (grads, loss), _ = jax.lax.scan(mb_step, (g0, 0.0), mbs)
            grads = jax.tree_util.tree_map(lambda g: g / n_mb, grads)
            loss = loss / n_mb
            aux = {"xent": loss, "moe_aux": jnp.zeros((), jnp.float32)}

        params, opt_state, opt_metrics = adamw_update(grads, opt_state,
                                                      params, rc)
        metrics = {"loss": loss, **aux, **opt_metrics}
        return params, opt_state, metrics

    return train_step


def init_train_state(model, rc, key):
    params = model.init(key)
    return params, init_opt(params, rc)
