"""Serving steps: prefill (fill cache, emit first token logits) and decode
(one token per sequence against the cache).  Sampling is greedy-argmax for
determinism; the dwork serving loop batches requests into these steps."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def make_prefill_step(model):
    cfg = model.cfg

    def prefill_step(params, batch):
        logits, cache, _aux = model.forward(params, batch, mode="prefill")
        next_tok = jnp.argmax(logits[..., :cfg.vocab_size], axis=-1)
        return next_tok.astype(jnp.int32), cache

    return prefill_step


def make_decode_step(model):
    cfg = model.cfg

    def serve_step(params, tokens, positions, cache):
        logits, cache = model.decode_step(params, tokens, positions, cache)
        next_tok = jnp.argmax(logits[..., :cfg.vocab_size], axis=-1)
        return next_tok.astype(jnp.int32), cache

    return serve_step


def greedy_generate(model, params, batch, max_new: int, cache_len: int):
    """Small-scale example driver: prefill then greedy-decode max_new tokens."""
    prefill = jax.jit(make_prefill_step(model))
    decode = jax.jit(make_decode_step(model))
    B, S = batch["tokens"].shape
    if model.cfg.family in ("ssm", "hybrid"):
        # recurrent state: run prefill token-by-token via decode for exactness
        cache = model.init_cache(B, cache_len)
        tok = batch["tokens"][:, 0]
        for t in range(S):
            tok, cache = decode(params, batch["tokens"][:, t],
                                jnp.full((B,), t, jnp.int32), cache)
    else:
        tok, small_cache = prefill(params, batch)
        cache = model.init_cache(B, cache_len)

        def splice(big, small):
            difs = [i for i, (a, b) in enumerate(zip(big.shape, small.shape))
                    if a != b]
            if not difs:
                return small.astype(big.dtype)
            ax = difs[0]
            idx = tuple(slice(None) if i != ax else slice(0, small.shape[ax])
                        for i in range(big.ndim))
            return big.at[idx].set(small.astype(big.dtype))

        cache = jax.tree_util.tree_map(splice, cache, small_cache)
    out = [tok]
    for t in range(S, S + max_new - 1):
        tok, cache = decode(params, tok, jnp.full((B,), t, jnp.int32), cache)
        out.append(tok)
    return jnp.stack(out, axis=1)
