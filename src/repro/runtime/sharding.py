"""Sharding rules: DP over (pod, data), TP/EP over model, ZeRO-1 over data.

Parameter specs are derived from leaf names (stable across stacked /
unstacked layouts); activations are guided by `shard_hint` logical rules.
"""
from __future__ import annotations

import re
from typing import Optional

import jax
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

# leaf-name classes --------------------------------------------------------

# output-feature sharded (last dim -> model)
_ROW = {"wq", "wk", "wv", "wg", "wr", "w1", "w3", "ws1", "ws3", "in_proj",
        "ck", "router", "wdkv", "wuk", "wuv", "conv_w", "conv_b", "cr",
        "bq", "bk", "bv", "norm"}
# input-feature sharded (dim -2 -> model)
_COL = {"wo", "w2", "ws2", "out", "out_proj", "cv"}
# per-head vectors (dim holding H -> model)
_HEAD_VEC = {"A_log", "dt_bias", "D_skip"}
_HEAD_MAT = {"u"}
_REPLICATED = {"ln1", "ln2", "pn1", "pn2", "final_norm", "kv_norm", "ln0",
               "ln_x", "ln_out", "s", "b", "maa_x", "maa", "maa_w1", "maa_w2",
               "w0", "maa_k", "maa_r", "mamba_ln"}


def batch_axes(mesh) -> tuple:
    names = mesh.axis_names
    return ("pod", "data") if "pod" in names else ("data",)


def effective_batch_axes(mesh, global_batch: int):
    """Largest batch-sharding axis set that divides the global batch
    (long-context B=1 shards nothing on the batch dim)."""
    ba = batch_axes(mesh)
    while ba:
        size = int(np.prod([mesh.shape[a] for a in ba]))
        if global_batch % size == 0 and global_batch >= size:
            return ba
        ba = ba[1:]
    return None


def logical_rules(mesh, *, global_batch: int = 0,
                  seq_shard_kv: bool = False,
                  shard_params_2d: bool = False) -> dict:
    """Logical activation axis -> mesh axes, consumed by shard_hint."""
    ba = (effective_batch_axes(mesh, global_batch) if global_batch
          else batch_axes(mesh))
    return {
        "batch": ba,
        "heads": "model",
        "model_ff": "model",
        "vocab": "model",
        "expert": "model",
        # 2D-weight serving: the data axis holds weight shards, so token
        # groups stay unsharded there (they are tiny at decode batch sizes)
        "moe_groups": None if shard_params_2d else ba,
        # expert-FFN hidden dim: follows the 2D weight sharding so expert
        # matmuls stay local (GSPMD would otherwise all-gather the weights)
        "moe_ff": "data" if shard_params_2d else None,
        "kv_seq": tuple(mesh.axis_names) if seq_shard_kv else "model",
    }


def _leaf_name(path) -> str:
    for entry in reversed(path):
        if isinstance(entry, jax.tree_util.DictKey):
            return str(entry.key)
        if isinstance(entry, jax.tree_util.GetAttrKey):
            return str(entry.name)
    return ""


def _path_names(path) -> list:
    return [str(e.key) for e in path if isinstance(e, jax.tree_util.DictKey)]


def param_pspec(path, leaf, n_experts: Optional[int] = None) -> P:
    """PartitionSpec for one parameter leaf."""
    name = _leaf_name(path)
    names = _path_names(path)
    nd = len(leaf.shape)
    none = (None,) * nd

    if name == "embed":
        return P("model", None)
    if name == "head":
        return P(None, "model")
    if name == "adapters":
        return P(None, None, "model")
    # rwkv decay loras w1/w2 live under "tm" and are tiny -> replicate
    if "tm" in names and name in ("w1", "w2"):
        return P(*none)
    # MoE expert-stacked weights: (L, E, D, F) or (E, D, F)
    if name in ("w1", "w2", "w3") and "mlp" in names and nd >= 3:
        if n_experts is not None and leaf.shape[nd - 3] == n_experts:
            spec = [None] * nd
            spec[nd - 3] = "model"
            return P(*spec)
    if name in _ROW:
        spec = [None] * nd
        spec[-1] = "model"
        return P(*spec)
    if name in _COL and nd >= 2:
        spec = [None] * nd
        spec[-2] = "model"
        return P(*spec)
    if name in _HEAD_VEC:
        spec = [None] * nd
        spec[-1] = "model"
        return P(*spec)
    if name in _HEAD_MAT and nd >= 2:
        spec = [None] * nd
        spec[-2] = "model"
        return P(*spec)
    return P(*none)


def param_specs(abstract_params, cfg) -> dict:
    """Same-structure pytree of PartitionSpecs."""
    n_experts = cfg.moe.n_experts if cfg.moe is not None else None
    return jax.tree_util.tree_map_with_path(
        lambda p, x: param_pspec(p, x, n_experts), abstract_params)


def zero1_spec(spec: P, shape, data_size: int, axis: str = "data") -> P:
    """Additionally shard an optimizer-state tensor over the data axis, on
    the first unsharded dim divisible by the data-axis size."""
    entries = list(spec) + [None] * (len(shape) - len(spec))
    for i, (e, s) in enumerate(zip(entries, shape)):
        if e is None and s % data_size == 0 and s >= data_size:
            entries[i] = axis
            return P(*entries)
    return P(*entries)


def param_specs_2d(pspecs, abstract_params, mesh, *,
                   min_elems: int = 1 << 26) -> dict:
    """Serving-time 2D weight sharding: additionally spread the DOMINANT
    parameter tensors (expert stacks, embeddings, LM heads) over the data
    axis — without this, a 480B MoE's expert weights are replicated 16x
    across the data axis (~117 GB/device).  Dense projection weights stay
    1D (their data-axis gathers/psums cost more than they save)."""
    data_size = int(np.prod([mesh.shape[a] for a in batch_axes(mesh)]))

    def upd(path, sp, x):
        name = _leaf_name(path)
        names = _path_names(path)
        is_expert = "mlp" in names and len(x.shape) >= 3 and name in (
            "w1", "w2", "w3")
        if not (is_expert or name in ("embed", "head")):
            return sp
        if int(np.prod(x.shape)) < min_elems:
            return sp
        return zero1_spec(sp, x.shape, data_size)

    return jax.tree_util.tree_map_with_path(upd, pspecs, abstract_params)


def opt_state_specs(pspecs, abstract_params, mesh, zero1: bool) -> dict:
    if not zero1:
        return pspecs
    data_size = int(np.prod([mesh.shape[a] for a in batch_axes(mesh)]))
    return jax.tree_util.tree_map(
        lambda sp, x: zero1_spec(sp, x.shape, data_size), pspecs,
        abstract_params)


# ---------------------------------------------------------------------------
# Batch / cache specs
# ---------------------------------------------------------------------------


def batch_specs(cfg, shape_cfg, mesh) -> dict:
    ba = effective_batch_axes(mesh, shape_cfg.global_batch)
    sp: dict = {}
    if shape_cfg.mode == "train":
        sp["tokens"] = P(ba, None)
        sp["labels"] = P(ba, None)
    elif shape_cfg.mode == "prefill":
        sp["tokens"] = P(ba, None)
    else:
        sp["tokens"] = P(ba)
        sp["positions"] = P(ba)
    if cfg.mrope and shape_cfg.mode != "decode":
        sp["mrope_positions"] = P(None, ba, None)
    if cfg.family == "audio":
        sp["encoder_frames"] = P(ba, None, None)
    return sp


def cache_specs(cfg, abstract_cache, mesh, *, global_batch: int,
                seq_shard_kv: bool = False):
    """PartitionSpec pytree matching a model's decode cache.

    KV caches are SEQUENCE-sharded on the model axis (flash-decoding style:
    universal divisibility, softmax stats reduce with tiny psums) with the
    batch on the data axes; `seq_shard_kv` (long-context, batch too small
    to shard) spreads the sequence over every mesh axis instead.
    SSM / conv / token-shift states: batch on data, channels on model.
    """
    ba = effective_batch_axes(mesh, global_batch)
    if seq_shard_kv:
        seq_ax = tuple(a for a in mesh.axis_names)
        bax = None
    else:
        seq_ax = "model"
        bax = ba

    def leaf_spec(path, x):
        names = _path_names(path)
        nd = len(x.shape)
        if cfg.family == "ssm":
            # rwkv: S (L,B,H,hd,hd) | tm_x/cm_x (L,B,D)
            if nd == 5:
                return P(None, ba, "model", None, None)
            return P(None, ba, None)
        if "memory" in names:               # whisper encoder memory (B,F,D)
            return P(ba, None, None)
        if "mamba" in names or "ssm" in names or "conv" in names:
            # (L,B,H,hd,N) or (L,B,W-1,convch)
            if nd == 5:
                return P(None, ba, "model", None, None)
            return P(None, ba, None, "model")
        if cfg.mla is not None:
            if nd == 4:                      # (L,B,T,r)
                return P(None, bax, seq_ax, None)
            if nd == 3:                      # unstacked (B,T,r)
                return P(bax, seq_ax, None)
        if nd == 5:                          # (L,B,T,G,hd)
            return P(None, bax, seq_ax, None, None)
        if nd == 4:                          # unstacked (B,T,G,hd)
            return P(bax, seq_ax, None, None)
        return P(*(None,) * nd)

    return jax.tree_util.tree_map_with_path(leaf_spec, abstract_cache)


def to_named(mesh, spec_tree):
    return jax.tree_util.tree_map(lambda s: NamedSharding(mesh, s), spec_tree,
                                  is_leaf=lambda s: isinstance(s, P))
