"""Elastic worker pool: dwork as the framework's fault-tolerance layer.

Training work-shards / inference request batches are dwork tasks; workers
Steal/Complete; a dead worker's Exit (or lease expiry — straggler
mitigation) recycles its tasks.  On membership change the pool invokes a
`remesh` callback so the runtime can re-lower the step for the new device
count (elastic scaling) and resume from the latest checkpoint.

METG-aware batching (paper §5, automated): steal_n is sized so per-steal
work stays above the dwork METG for the current worker count.
"""
from __future__ import annotations

import threading
import time
from typing import Callable, Optional

from repro.core.dwork import Client, InProcTransport, TaskServer
from repro.core.dwork.api import ExitResp, NotFound, TaskMsg
from repro.core.metg import METGModel, pick_batch_size


class ElasticPool:
    def __init__(self, *, lease_timeout: float = 30.0,
                 remesh: Optional[Callable[[int], None]] = None,
                 per_task_s: float = 1.0):
        self.server = TaskServer(lease_timeout=lease_timeout)
        self.remesh = remesh
        self.per_task_s = per_task_s
        self.metg = METGModel.from_paper()
        self.workers: dict[str, threading.Thread] = {}
        self._lock = threading.Lock()
        self.completed: list = []

    # ------------------------------------------------------------------
    def submit(self, name: str, deps=(), meta=None):
        Client(InProcTransport(self.server), "driver").create(
            name, deps=deps, meta=meta)

    def steal_n_for(self, n_workers: int) -> int:
        return pick_batch_size("dwork", max(n_workers, 1), self.per_task_s,
                               model=self.metg)

    def start_worker(self, worker_id: str,
                     execute: Callable[[str, dict], bool], *,
                     fail_after: Optional[int] = None):
        """fail_after: simulate a node crash after N tasks (tests/drills)."""
        cl = Client(InProcTransport(self.server), worker_id)

        def loop():
            done = 0
            steal_n = self.steal_n_for(len(self.workers))
            while True:
                resp = cl.steal(n=steal_n)
                if isinstance(resp, ExitResp):
                    return
                if isinstance(resp, NotFound):
                    time.sleep(0.001)
                    if self.server._all_done():
                        return
                    continue
                assert isinstance(resp, TaskMsg)
                for name, meta in resp.tasks:
                    if fail_after is not None and done >= fail_after:
                        cl.exit()        # crash: hand tasks back
                        return
                    ok = execute(name, meta)
                    cl.complete(name, ok=ok)
                    with self._lock:
                        self.completed.append((worker_id, name))
                    done += 1

        th = threading.Thread(target=loop, daemon=True)
        with self._lock:
            self.workers[worker_id] = th
        if self.remesh:
            self.remesh(len(self.workers))
        th.start()
        return th

    def lose_worker(self, worker_id: str):
        """Driver-side failure detection (paper: Exit may be called by the
        user to recover from a node failure)."""
        Client(InProcTransport(self.server), worker_id).exit()
        with self._lock:
            self.workers.pop(worker_id, None)
        if self.remesh:
            self.remesh(len(self.workers))

    def join(self, timeout: float = 60.0):
        t0 = time.time()
        for th in list(self.workers.values()):
            th.join(max(0.0, timeout - (time.time() - t0)))
        return self.server.stats()
