"""Elastic worker pool: the futures client's resident engine as the
fault-tolerance layer.

Training work-shards / inference request batches are engine tasks
submitted through `repro.client.Client` (each returns a `Future`); the
client's resident `Engine` dispatches them, and membership changes
(`start_worker` / `lose_worker`) invoke a `remesh` callback so the
runtime can re-lower the step for the new device count (elastic
scaling) and resume from the latest checkpoint.  A worker crash
(`fail_after` drills, or any `WorkerCrash` raised from the step
function) announces Exit so the in-flight tasks are requeued — never
lost, never marked failed; a silently wedged worker is reaped by the
engine's heartbeat lease.

METG-aware batching (paper §5, automated): `steal_n` is re-derived on
EVERY membership change so per-steal work tracks the live worker count —
the engine re-reads it each dispatch round, so the new batch size applies
without restarting anything.

This module is a thin client of the futures-era engine: the per-worker
steal/complete loops that used to live here are the engine's dispatch
loop, and task plumbing is the client's (`submit` hands back a `Future`
that resolves exactly once across crash requeues).
"""
from __future__ import annotations

import threading
from typing import Callable, Optional

from repro.client import Client, Future
from repro.core.engine import WorkerCrash
from repro.core.metg import METGModel, pick_batch_size


class ElasticPool:
    def __init__(self, *, lease_timeout: float = 30.0,
                 remesh: Optional[Callable[[int], None]] = None,
                 per_task_s: float = 1.0):
        self.client = Client(scheduler="dwork", workers=0, resident=True,
                             lease_timeout=lease_timeout,
                             executor=self._execute, pass_worker=True)
        self.engine = self.client.engine
        self.remesh = remesh
        self.per_task_s = per_task_s
        self.metg = METGModel.from_paper()
        self.workers: dict[str, Callable] = {}    # worker -> execute fn
        self._crash_after: dict[str, int] = {}
        self._done: dict[str, int] = {}
        self._lock = threading.Lock()
        self.completed: list = []
        self.client.start()

    # ------------------------------------------------------------------
    def submit(self, name: str, deps=(), meta=None) -> Future:
        """Queue a named work shard; the returned `Future` resolves when
        the shard reaches its terminal state (exactly once, across any
        crash requeues)."""
        return self.client.submit_task(name, deps=deps, meta=meta)

    def steal_n_for(self, n_workers: int) -> int:
        # shards divide dwork's dispatch bound, so a sharded hub (alone
        # or behind the forwarding tree) needs proportionally less
        # batching at the same worker count
        return pick_batch_size("dwork", max(n_workers, 1), self.per_task_s,
                               model=self.metg, shards=self.engine.shards)

    def _retune(self):
        """Membership changed: re-derive the METG batch size for the live
        worker count and tell the runtime to re-lower (remesh)."""
        n = len(self.workers)
        self.engine.steal_n = self.steal_n_for(n)
        if self.remesh:
            self.remesh(n)

    def _execute(self, name: str, meta: dict, worker: str):
        limit = self._crash_after.get(worker)
        if limit is not None and self._done.get(worker, 0) >= limit:
            # simulated node crash: the engine requeues everything this
            # worker still holds (including this task) — zero loss
            raise WorkerCrash(f"{worker} crashed after {limit} tasks")
        fn = self.workers.get(worker)
        if fn is None:
            # lose_worker() raced the dispatch loop: the executor was
            # deregistered while this task was already stolen — crash the
            # worker so the task is REQUEUED, never marked failed
            raise WorkerCrash(f"{worker} was lost mid-task")
        ok = fn(name, meta)
        with self._lock:
            self.completed.append((worker, name))
            self._done[worker] = self._done.get(worker, 0) + 1
        return ok

    def start_worker(self, worker_id: str,
                     execute: Callable[[str, dict], bool], *,
                     fail_after: Optional[int] = None) -> str:
        """fail_after: simulate a node crash after N tasks (tests/drills)."""
        self.workers[worker_id] = execute
        self._done[worker_id] = 0
        if fail_after is not None:
            self._crash_after[worker_id] = fail_after
        self._retune()
        self.client.add_worker(worker_id)
        return worker_id

    def lose_worker(self, worker_id: str):
        """Driver-side failure detection (paper: Exit may be called by the
        user to recover from a node failure)."""
        self.client.lose_worker(worker_id)
        self.workers.pop(worker_id, None)
        self._retune()

    def join(self, timeout: float = 60.0) -> dict:
        """Wait for every submitted task to reach a terminal state and
        return the server stats.  The pool stays up — more work can be
        submitted after a join (continuous service)."""
        self.client.drain(timeout)
        return self.client.stats()

    def shutdown(self):
        """Stop the resident loop for good; returns the EngineReport."""
        if self.engine.started:
            return self.client.close()
        return None

    # a pool abandoned without shutdown() must not keep a dispatch thread
    # busy-waking for the life of the process
    def __enter__(self) -> "ElasticPool":
        return self

    def __exit__(self, *exc):
        self.shutdown()

    def __del__(self):
        try:
            if self.engine.started:
                self.engine.shutdown(drain=False, timeout=2.0)
        except Exception:  # noqa: BLE001 — interpreter-teardown best effort
            pass
