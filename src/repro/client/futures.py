"""Futures for the client layer: one `Future` per submitted task.

A `Future` is resolved exactly once, from the engine's first-terminal
notification (`Engine.on_result`) — requeued re-executions after a
worker crash never re-resolve it.  Futures share their owning client's
condition variable instead of carrying a per-future `threading.Event`,
which keeps the per-submit allocation cost low enough for the
`BENCH_client.json` overhead gate (client path <= 2x the raw engine
path).

Failure taxonomy (what `result()` raises):

    TaskFailed        the task itself failed without raising a Python
                      exception the client could capture (executor
                      returned ok=False, injected fault, engine stall)
    DependencyFailed  the task never ran because an upstream dependency
                      failed or was cancelled (failure poisoning,
                      surfaced downstream)
    CancelledError    this future was cancelled via `Future.cancel()`
    <original exc>    the task's function raised: the exception object
                      is captured in-process and re-raised verbatim
"""
from __future__ import annotations

import queue
import time
from typing import Callable, Iterable, Optional

from repro.core.engine.comm.serialize import RemoteValue


class CancelledError(Exception):
    """The future was cancelled before its task was stolen."""


class TaskFailed(RuntimeError):
    """The task reached the failed terminal state without a captured
    Python exception (executor returned ok=False, injected fault, or the
    engine stalled before the task could run)."""


class DependencyFailed(TaskFailed):
    """The task was poisoned: an upstream dependency failed or was
    cancelled, so this task can never run (dwork terminal-state
    semantics surfaced on the downstream future)."""


_PENDING = "pending"
_DONE = "done"
_CANCELLED = "cancelled"


class Future:
    """Handle for one submitted task.  Created by `Client.submit` /
    `Client.map` / `Client.submit_task`; may be passed as an argument to
    a later `submit`, where it is lifted into an engine dependency and
    replaced by its value at execution time (dynamic DAG construction).
    """

    __slots__ = ("_client", "name", "_state", "_value", "_exception",
                 "_record", "_callbacks", "_pending_exc")

    def __init__(self, client, name: str):
        self._client = client
        self.name = name
        self._state = _PENDING
        self._value = None
        self._exception: Optional[BaseException] = None
        self._record = None             # TaskResult of the counted execution
        self._callbacks: list = []
        self._pending_exc: Optional[BaseException] = None

    # -------------------------------------------------------------- state
    def done(self) -> bool:
        """True once resolved (value, exception, or cancelled)."""
        return self._state is not _PENDING

    def cancelled(self) -> bool:
        return self._state is _CANCELLED

    @property
    def task_result(self):
        """The engine `TaskResult` of the execution that resolved this
        future (None while pending or when the task never executed —
        poisoned, cancelled, or failed at submit).  Carries the per-rank
        timings the mpi-list adapter feeds the Gumbel straggler law."""
        return self._record

    def result(self, timeout: Optional[float] = None):
        """The task's value.  Blocks until resolved; raises `TimeoutError`
        on expiry, `CancelledError` if cancelled, or the task's failure
        (the original exception when it raised in-process, `TaskFailed` /
        `DependencyFailed` otherwise)."""
        self._wait(timeout)
        if self._state is _CANCELLED:
            raise CancelledError(self.name)
        if self._exception is not None:
            raise self._exception
        if isinstance(self._value, RemoteValue):
            # peer-to-peer data plane: the payload stayed in its producing
            # worker's store — materialize (and cache) on first read
            self._value = self._value.get()
        return self._value

    def exception(self, timeout: Optional[float] = None
                  ) -> Optional[BaseException]:
        """The task's exception (None on success).  Blocks like
        `result()`; raises `CancelledError` if the future was cancelled
        (concurrent.futures semantics)."""
        self._wait(timeout)
        if self._state is _CANCELLED:
            raise CancelledError(self.name)
        return self._exception

    def cancel(self) -> bool:
        """Withdraw the task if no worker has stolen it yet.  True means
        the task will never run (dependents are poisoned and observe
        `DependencyFailed`); False means it is already running, done, or
        the scheduler won the race."""
        return self._client._cancel(self)

    def add_done_callback(self, fn: Callable[["Future"], None]):
        """Call `fn(future)` when the future resolves (immediately if it
        already has).  Callbacks run on the engine's dispatch thread —
        keep them short and never block on another future from the same
        client."""
        with self._client._cv:
            if self._state is _PENDING:
                self._callbacks.append(fn)
                return
        fn(self)

    def _remove_callback(self, fn):
        """Unregister a pending callback (gather's timeout path, so
        repeated polls don't accumulate dead barrier closures)."""
        with self._client._cv:
            try:
                self._callbacks.remove(fn)
            except ValueError:
                pass

    def __repr__(self):
        if self._state is _PENDING:
            state = "pending"
        elif self._state is _CANCELLED:
            state = "cancelled"
        elif self._exception is not None:
            state = f"error={self._exception!r}"
        else:
            state = "ok"
        return f"Future({self.name}, {state})"

    # ----------------------------------------------------------- plumbing
    def _wait(self, timeout: Optional[float]):
        if self._state is not _PENDING:
            return
        client = self._client
        client._ensure_running()
        cv = client._cv
        with cv:
            client._waiters += 1
            try:
                if not cv.wait_for(lambda: self._state is not _PENDING,
                                   timeout):
                    raise TimeoutError(
                        f"future {self.name} unresolved after {timeout}s")
            finally:
                client._waiters -= 1

    def _peek(self):
        """Dependency lift: the producer's value, called from a dependent
        task's execution.  The engine only runs a dependent after every
        dependency completed, so an unresolved producer here is an engine
        ordering bug, not a user error."""
        if self._state is _PENDING:
            raise RuntimeError(
                f"dependency {self.name} executed out of order")
        if self._state is _CANCELLED or self._exception is not None:
            raise DependencyFailed(f"dependency {self.name} failed")
        return self._value

    def _resolve(self, *, state: str, value=None,
                 exception: Optional[BaseException] = None, record=None):
        """Exactly-once resolution; late duplicates are dropped."""
        client = self._client
        cv = client._cv
        with cv:
            if self._state is not _PENDING:
                return
            self._value = value
            self._exception = exception
            self._record = record
            self._state = state
            callbacks, self._callbacks = self._callbacks, []
            # broadcast only when a result()/exception() caller is
            # actually blocked: resolutions outnumber waits by orders of
            # magnitude on a busy client, and every needless notify is a
            # cross-thread GIL bounce on the dispatch hot path (gather
            # rides a one-shot barrier callback instead)
            if client._waiters:
                cv.notify_all()
        for fn in callbacks:
            try:
                fn(self)
            except Exception:           # noqa: BLE001 — a user callback
                pass                    # must not kill the dispatch loop


def as_completed(futures: Iterable[Future],
                 timeout: Optional[float] = None):
    """Yield futures in completion order (like
    `concurrent.futures.as_completed`).  Raises `TimeoutError` if not
    every future resolves within `timeout` seconds."""
    futures = list(futures)
    done_q: queue.Queue = queue.Queue()
    for f in futures:
        f._client._ensure_running()
        f.add_done_callback(done_q.put)
    deadline = None if timeout is None else time.monotonic() + timeout
    try:
        for _ in range(len(futures)):
            remaining = (None if deadline is None
                         else deadline - time.monotonic())
            if remaining is not None and remaining <= 0:
                raise TimeoutError("as_completed timed out")
            try:
                yield done_q.get(timeout=remaining)
            except queue.Empty:
                raise TimeoutError("as_completed timed out") from None
    finally:
        # timeout or an abandoned generator must not leave dead
        # callbacks (pinning the queue) on still-pending futures
        for f in futures:
            if not f.done():
                f._remove_callback(done_q.put)
