"""`Client`: the futures front door over the unified engine.

One class drives all three schedulers and the serving layer.  The
default (resident) mode owns an `Engine(resident=True)` whose dispatch
loop runs in a background thread: `submit()` builds the task graph
dynamically (futures passed as arguments become engine dependencies —
no pre-declared universe), and every task's first terminal transition
resolves its `Future` through the engine's `on_result` plumbing, so a
`WorkerCrash` requeue re-executes the task but can never double-resolve
the future.

Batch mode (`resident=False`) serves the legacy front doors: the
dwork `run_pool`, `PMake.run`, and engine-backed `mpi_list.Context` are
thin shims that build a universe through the same `submit()` calls and
then `run()` it to a terminal state, returning the familiar
`EngineReport` — one construction path, two execution styles.
"""
from __future__ import annotations

import threading
import time
from typing import Callable, Iterable, Optional

from repro.client.futures import (_CANCELLED, _DONE, CancelledError,
                                  DependencyFailed, Future, TaskFailed)
from repro.core.engine.comm.serialize import RemoteValue, Ref, dumps_call
from repro.core.engine.executor import Engine, EngineReport
from repro.core.engine.model import CREATED, FAILED, WorkerCrash, next_seq
from repro.core.engine.tracing import OverheadReport, TraceRecorder

SCHEDULERS = ("dwork", "pmake", "mpi_list")

# per-scheduler defaults: dwork is the bag-of-tasks baseline; pmake needs
# a wide steal window so EFT priorities order globally (the engine's heap
# only ranks tasks it has stolen); mpi_list adapters size steal_n to
# ranks/workers themselves
_DEFAULT_STEAL_N = {"dwork": 4, "pmake": 64, "mpi_list": 4}
# core.metg spells the third scheduler with a dash
_METG_NAME = {"dwork": "dwork", "pmake": "pmake", "mpi_list": "mpi-list"}


class Client:
    """Futures-first front door for every scheduler and the serving layer.

        with Client(scheduler="dwork", workers=4) as c:
            fs = [c.submit(f, x) for x in xs]
            values = c.gather(fs)

    See the `repro.client` package docstring for the per-scheduler
    quickstarts and the bounded-state options
    (`max_trace_events` / `keep_results` / `prune_every`).
    """

    def __init__(self, scheduler: str = "dwork", *, workers: int = 4,
                 transport: str = "inproc", shards: int = 1,
                 steal_n: Optional[int] = None, resident: bool = True,
                 server=None, executor: Optional[Callable] = None,
                 pass_worker: bool = False, tracer=None, faults=None,
                 clock=None, poll: float = 0.001,
                 lease_timeout: Optional[float] = None,
                 tree_fanout: int = 4, tree_levels: int = 1,
                 keep_results: bool = True,
                 max_trace_events: Optional[int] = None,
                 prune_every: int = 0, retry=None,
                 journal_dir=None, **engine_kw):
        scheduler = scheduler.replace("-", "_")
        if scheduler not in SCHEDULERS:
            raise ValueError(f"unknown scheduler {scheduler!r}; "
                             f"pick one of {SCHEDULERS}")
        self.scheduler = scheduler
        self.resident = bool(resident)
        self._executor = executor
        self._executor_pass_worker = bool(pass_worker)
        if steal_n is None:
            steal_n = _DEFAULT_STEAL_N[scheduler]
        if max_trace_events is not None:
            if tracer is not None:
                raise ValueError(
                    "pass max_trace_events OR a pre-built tracer, not "
                    "both — a caller-supplied recorder would silently "
                    "ignore the bound (build it with "
                    "TraceRecorder(max_events=...) instead)")
            tracer = TraceRecorder(clock=clock, max_events=max_trace_events)
        # an existing task universe (run_pool shim): adapt the caller's
        # TaskServer / ShardedHub instead of letting the engine build one
        backend = None
        self._owns_backend = False
        if server is not None:
            backend, lease = self._adapt_server(
                server, transport=transport, workers=workers,
                tree_fanout=tree_fanout, tree_levels=tree_levels,
                tracer=tracer, clock=clock)
            if backend.tracer is not None:
                tracer = backend.tracer
            if lease_timeout is None:
                lease_timeout = lease
            self._owns_backend = transport == "tree"   # sockets to release
        self.engine = Engine(
            workers=workers, transport=transport, steal_n=steal_n,
            shards=shards, backend=backend, tracer=tracer, faults=faults,
            clock=clock, poll=poll, lease_timeout=lease_timeout,
            tree_fanout=tree_fanout, tree_levels=tree_levels,
            resident=self.resident, keep_results=keep_results,
            retry=retry, journal=journal_dir, **engine_kw)
        self._futures: dict[str, Future] = {}
        self._cv = threading.Condition(threading.Lock())  # every Future
        self._waiters = 0                    # result() callers blocked
        self._lifecycle = threading.Lock()
        self._frontends: list = []
        self._closed = False
        self._report: Optional[EngineReport] = None
        self._live_results_needed = False   # a wrapper will _peek mid-run
        self._pruned_any = False            # arms stub containment
        self._loop_failed: Optional[BaseException] = None
        self._prune_every = max(int(prune_every), 0)
        self._resolved = 0
        self._submitted = 0
        self._futures_resolved = 0          # futures only (not __batch etc.)
        self._metrics = None                # MetricsRegistry once attached
        self._stats_servers: list = []      # stopped by close()

    @staticmethod
    def _adapt_server(server, *, transport, workers, tree_fanout,
                      tree_levels, tracer, clock):
        # lazy imports: dwork submodules import engine pieces
        from repro.core.dwork.sharded import ShardedHub
        from repro.core.engine.backends import (ServerBackend,
                                                ShardedBackend, TreeBackend)

        if isinstance(server, ShardedHub):
            lease = (server.shards[0].lease_timeout if server.shards
                     else None)
            if transport == "tree":
                # sharded hub BEHIND the forwarding tree: the top-level
                # routers hash-route the Table-2 verbs per shard
                tracer = tracer or TraceRecorder(clock=clock)
                return TreeBackend(hub=server, workers=workers,
                                   fanout=tree_fanout, levels=tree_levels,
                                   tracer=tracer), lease
            return ShardedBackend(hub=server, tracer=tracer), lease
        if transport == "tree":
            # the Forwarders capture the tracer at construction, so it
            # must exist BEFORE the tree is built or hop events are lost
            tracer = tracer or TraceRecorder(clock=clock)
            return TreeBackend(server=server, workers=workers,
                               fanout=tree_fanout, levels=tree_levels,
                               tracer=tracer), server.lease_timeout
        return (ServerBackend(server=server, tracer=tracer),
                server.lease_timeout)

    # ------------------------------------------------------------- submit
    def submit(self, fn: Callable, *args, key: Optional[str] = None,
               priority: float = 0.0, slots: int = 1, deps=(),
               retry=None, tenant: Optional[str] = None,
               **kwargs) -> Future:
        """Schedule `fn(*args, **kwargs)` and return its `Future`.

        Any `Future` among the arguments is lifted into an engine
        dependency and replaced by its value when the task runs, so
        chains of submits build the DAG dynamically.  `deps` adds extra
        dependencies (futures or task names) that are ordering-only.
        `priority` is greedy-highest-first (pmake EFT); `slots` is the
        pool capacity the task occupies while running (pmake nodes).
        Task names are single-use — pass `key=` only for unique names.

        `retry` attaches a per-task `RetryPolicy` (overrides the
        client-wide `retry=` passed at construction); transient failures
        re-enqueue with backoff instead of failing the future.

        `tenant` labels the task for per-tenant observability: the label
        lands in the task's engine `meta` (the same slot the serving
        layer uses) so accounting tools can slice by tenant.  Purely
        observational — scheduling never looks at it.  (Serving-path
        requests take the label via `Frontend.submit(tenant=)`, which
        also threads it through REQ_* trace events, windowed
        `LatencyReport.by_tenant` slices, and the tenant-labelled
        request-latency histogram.)

        NOTE: `key`, `priority`, `slots`, `deps`, `retry`, and `tenant`
        are reserved by this signature (per the scheduler API) and are
        NOT forwarded to `fn` — to call a function with a same-named
        keyword, wrap it: `c.submit(functools.partial(fn, priority=3),
        x)`."""
        self._check_open()
        name = key if key is not None else \
            f"{getattr(fn, '__name__', 'task')}-{next_seq()}"
        fdeps = [a for a in args if isinstance(a, Future)]
        if kwargs:
            fdeps += [v for v in kwargs.values() if isinstance(v, Future)]
        extra = []
        for d in deps:
            (fdeps if isinstance(d, Future) else extra).append(d)
        dep_names = self._lift_deps(fdeps, extra)
        if dep_names is None:           # a dependency already failed
            return self._fail_fast(name, fdeps)
        fut = Future(self, name)
        engine_kw = {}
        if tenant is not None:
            engine_kw["meta"] = {"tenant": tenant}
        if self.engine.transport == "proc":
            # the task runs in another PROCESS: pack (fn, args, kwargs)
            # with cloudpickle NOW — an unpicklable callable raises
            # SerializationError here, naming the task, instead of
            # hanging a worker.  Done-future arguments inline their
            # value; pending ones ride as `Ref` placeholders the worker
            # resolves from its local cache or a Fetch round-trip.  The
            # `_make_call` wrapper (which captures the unpicklable
            # Future) never crosses the boundary.
            meta = dict(engine_kw.get("meta") or {})
            meta["__call__"] = _proc_call_payload(name, fn, args, kwargs)
            engine_kw["meta"] = meta
            return self._submit(fut, fn=None, deps=dep_names,
                                priority=priority,
                                slots=max(int(slots), 1), retry=retry,
                                **engine_kw)
        if not all(d.done() for d in fdeps):
            # the wrapper will _peek a producer mid-run, so futures must
            # resolve live (batch run() otherwise defers resolution to
            # the final report and keeps the raw dispatch hot path)
            self._live_results_needed = True
        return self._submit(fut, fn=_make_call(fut, fn, args, kwargs),
                            deps=dep_names, priority=priority,
                            slots=max(int(slots), 1), retry=retry,
                            **engine_kw)

    def submit_task(self, name: str, *, deps=(), meta: Optional[dict] = None,
                    priority: float = 0.0, slots: int = 1,
                    fn: Optional[Callable] = None, retry=None) -> Future:
        """Schedule a NAMED task executed by the client's `executor=`
        callback (or `fn`, a zero-arg callable) — the by-name execution
        style of the pmake and elastic adapters, with a `Future` attached.
        `deps` may mix task names and futures."""
        self._check_open()
        fdeps, extra = [], []
        for d in deps:
            (fdeps if isinstance(d, Future) else extra).append(d)
        dep_names = self._lift_deps(fdeps, extra)
        if dep_names is None:           # a dependency already failed
            return self._fail_fast(name, fdeps)
        return self._submit(Future(self, name), fn=fn, deps=dep_names,
                            meta=meta, priority=priority,
                            slots=max(int(slots), 1), retry=retry)

    def map(self, fn: Callable, *iterables, priority: float = 0.0,
            slots: int = 1) -> list:
        """One future per element (zipped across `iterables`), like
        `distributed.Client.map`."""
        return [self.submit(fn, *xs, priority=priority, slots=slots)
                for xs in zip(*iterables)]

    @staticmethod
    def _lift_deps(fdeps: list, extra: list) -> Optional[list]:
        """Future deps -> engine dep names.  Already-RESOLVED futures are
        satisfied dependencies and are dropped (their value is delivered
        via `_peek` at execution) — re-declaring a name that
        `prune_terminal()` already dropped server-side would resurrect it
        as a READY stub and wedge the dependent.  Returns None when a
        dependency already failed/cancelled: the task must never run
        (client-side fail-fast, since the pruned server may have
        forgotten the failure)."""
        for d in fdeps:
            if d.done() and (d.cancelled() or d._exception is not None):
                return None
        return [d.name for d in fdeps if not d.done()] + extra

    def _fail_fast(self, name: str, fdeps: list) -> Future:
        """Mirror of the engine's failed-dep fail-fast, applied at the
        client layer: resolve the future as DependencyFailed without
        submitting anything.  The name is still registered so the
        single-use contract holds (a later duplicate key raises like
        every other)."""
        bad = next(d for d in fdeps if d.done()
                   and (d.cancelled() or d._exception is not None))
        fut = Future(self, name)
        if self._futures.setdefault(name, fut) is not fut:
            raise ValueError(f"future key {name!r} already in use "
                             "(task names are single-use)")
        tracer = self.engine.tracer
        why = f"dependency {bad.name} failed"
        tracer.emit(CREATED, task=name)
        tracer.emit(FAILED, task=name, error=why)
        fut._resolve(state=_DONE,
                     exception=DependencyFailed(f"{name}: {why}"))
        return fut

    def _check_open(self):
        """Reject submissions that could only produce futures nothing
        will ever resolve: a closed client, a one-shot batch client that
        already ran, or a resident client whose dispatch loop died."""
        if self._closed:
            raise RuntimeError("client is closed")
        if not self.resident and self._report is not None:
            raise RuntimeError(
                "batch client already ran (run() is one-shot); "
                "create a new Client for more work")
        if self.engine._loop_error is not None:
            raise RuntimeError(
                "engine dispatch loop died: "
                f"{self.engine._loop_error!r}")

    def _submit(self, fut: Future, **engine_kw) -> Future:
        """Shared registration + engine submission: registration is an
        atomic setdefault (a concurrent duplicate key cannot displace the
        original future's entry) and MUST precede the engine submit — a
        resident loop may ingest and resolve the task before submit()
        returns.  The engine listeners are attached lazily so
        pure-executor sessions (run_pool shim, the serving frontend
        alone) keep the no-listener fast path."""
        name = fut.name
        if self._futures.setdefault(name, fut) is not fut:
            raise ValueError(f"future key {name!r} already in use "
                             "(task names are single-use)")
        if self.engine.on_result is None:
            self.engine.on_result = self._on_result
            self.engine.on_loop_error = self._on_loop_error
        try:
            self.engine.submit(name, **engine_kw)
        except BaseException:
            # collision with an engine-level (non-future) name; only
            # drop OUR registry entry, never a racing winner's
            if self._futures.get(name) is fut:
                self._futures.pop(name, None)
            raise
        self._submitted += 1
        if (self._loop_failed is not None or self._closed) \
                and not fut.done():
            # the dispatch loop died — or close() ran to completion —
            # while this submit was in flight (after _check_open, after
            # the respective registry drain): nothing will ever resolve
            # this future, so fail it here instead of leaving a
            # permanent waiter
            why = (f"engine dispatch loop died: {self._loop_failed!r}"
                   if self._loop_failed is not None
                   else "client closed during submit")
            self._futures.pop(name, None)
            fut._resolve(state=_DONE,
                         exception=TaskFailed(f"{name}: {why}"))
        return fut

    def _on_loop_error(self, exc: BaseException):
        """The resident dispatch loop died: fail every pending future so
        result()/gather() waiters surface the cause instead of hanging
        (shutdown() still re-raises the original).  `_loop_failed` is set
        FIRST so a submit racing the death either sees it after
        registering (and self-fails in `_submit`) or registers before
        this drain and is failed here."""
        self._loop_failed = exc
        for name in list(self._futures):
            fut = self._futures.pop(name, None)
            if fut is not None and not fut.done():
                fut._resolve(state=_DONE, exception=TaskFailed(
                    f"{name}: engine dispatch loop died: {exc!r}"))

    # ------------------------------------------------------------ results
    def _on_result(self, name: str, ok: bool, res, error: Optional[str]):
        """Engine result plumbing: fires exactly once per task name, on
        the dispatch thread, outside the engine lock.  (The auto-prune
        below marks `_pruned_any`, which arms `_execute`'s
        resurrected-stub containment.)"""
        fut = self._futures.pop(name, None)
        if fut is not None:
            self._futures_resolved += 1
            if ok:
                fut._resolve(state=_DONE, value=res.value, record=res)
            elif error == "cancelled" and res is None:
                fut._resolve(state=_CANCELLED)
            elif fut._pending_exc is not None:
                fut._resolve(state=_DONE, exception=fut._pending_exc,
                             record=res)
            elif res is None:
                # never executed: poisoned upstream / failed at submit
                fut._resolve(state=_DONE,
                             exception=DependencyFailed(f"{name}: {error}"))
            else:
                fut._resolve(state=_DONE,
                             exception=TaskFailed(f"{name}: {error}"))
        self._resolved += 1
        if self._prune_every and self._resolved % self._prune_every == 0:
            self._pruned_any = True
            self.engine.prune_terminal()

    def gather(self, futures: Iterable[Future], *,
               timeout: Optional[float] = None,
               return_exceptions: bool = False) -> list:
        """Wait for every future and return their values in order.  A
        failure raises its exception (after all futures resolved) unless
        `return_exceptions=True`, which returns exceptions in-place.  In
        batch mode the first gather runs the engine."""
        fs = list(futures)
        self._ensure_running()
        # one-shot barrier instead of per-future waits: callbacks run on
        # the dispatch thread, so the countdown needs no lock, and the
        # waiting thread is woken exactly once — per-future condition
        # broadcasts would bounce the GIL on every resolution
        pending = [f for f in fs if not f.done()]
        if pending:
            remaining = [len(pending)]
            lk = threading.Lock()     # immediate callbacks run on THIS
            done_evt = threading.Event()   # thread, late ones on dispatch

            def _one_done(_f):
                with lk:
                    remaining[0] -= 1
                    last = remaining[0] == 0
                if last:
                    done_evt.set()

            for f in pending:
                f.add_done_callback(_one_done)
            if not done_evt.wait(timeout):
                for f in pending:       # a re-polled gather must not
                    f._remove_callback(_one_done)   # accumulate barriers
                n_left = sum(1 for f in fs if not f.done())
                raise TimeoutError(
                    f"gather: {n_left}/{len(fs)} futures unresolved "
                    f"after {timeout}s")
        out, first = [], None
        for f in fs:
            exc = (CancelledError(f.name) if f.cancelled()
                   else f._exception)
            if exc is None:
                if isinstance(f._value, RemoteValue):
                    # data-plane handle: materialize (and cache) on read
                    f._value = f._value.get()
                out.append(f._value)
            elif return_exceptions:
                out.append(exc)
            elif first is None:
                first = exc
        if first is not None:
            raise first
        return out

    def _cancel(self, fut: Future) -> bool:
        if fut.done():
            return False
        return self.engine.cancel(fut.name)

    # ---------------------------------------------------------- lifecycle
    def start(self) -> "Client":
        """Start the resident dispatch loop (idempotent; `with Client(...)
        as c:` and the first blocking wait call this for you)."""
        if not self.resident:
            raise RuntimeError("start() is resident-mode; batch mode "
                               "(resident=False) executes via run()")
        if self._closed:
            raise RuntimeError("client is closed")
        self._start_engine()
        return self

    def _start_engine(self):
        with self._lifecycle:
            if not self.engine.started:
                if self._executor is None:
                    # futures-only session: the engine's own registered-fn
                    # dispatch is the leanest path (no worker plumbing)
                    self.engine.start()
                elif self.engine.transport == "proc":
                    # ship the RAW user executor to the worker processes:
                    # the `_execute` bound method drags the whole client
                    # (futures, locks) into the pickle and cannot cross.
                    # Futures-submitted tasks still run their packed
                    # `meta["__call__"]` worker-side, which takes
                    # precedence over the executor.
                    self.engine.start(self._executor,
                                      pass_worker=self._executor_pass_worker)
                else:
                    self.engine.start(self._execute, pass_worker=True)

    def _ensure_running(self):
        if self._closed:
            return
        if self.resident:
            if not self.engine.started:
                self.start()
        elif self._report is None:
            self.run()

    def run(self) -> EngineReport:
        """Batch mode: drain the submitted universe to a terminal state
        and resolve every future (the legacy front doors' execution
        path).  One-shot; returns the `EngineReport`."""
        if self.resident:
            raise RuntimeError("run() is batch-mode; resident clients "
                               "drain via gather()/drain()/close()")
        with self._lifecycle:
            # serialized: concurrent result()/gather() waiters must not
            # drive two dispatch loops over the same engine (each would
            # see only a partial result set)
            if self._report is not None:
                return self._report
            pass_worker = True
            if self._executor is None:
                execute = None
            elif self.engine.transport == "proc":
                # raw user executor across the process boundary (see
                # _start_engine); packed `__call__` payloads win per task
                execute = self._executor
                pass_worker = self._executor_pass_worker
            else:
                execute = self._execute
            if not self._live_results_needed:
                # no wrapper peeks a producer mid-run: drop the per-task
                # result listener so the dispatch loop keeps the raw
                # (run_pool-identical) hot path; every future resolves
                # from the report below — this keeps the legacy shims'
                # measured overhead at the engine baseline
                self.engine.on_result = None
                self.engine.on_loop_error = None
            try:
                report = self.engine.run(execute, pass_worker=pass_worker)
            finally:
                if self._owns_backend:
                    self.engine.backend.close()
                    self._owns_backend = False
            self._report = report
            self._resolve_leftovers(report)
            return report

    def drain(self, timeout: Optional[float] = None) -> bool:
        """Resident mode: block until every submitted task is terminal."""
        self._ensure_running()
        return self.engine.drain(timeout)

    def close(self, *, drain: bool = True,
              timeout: Optional[float] = None) -> Optional[EngineReport]:
        """Shut the client down: close any serving frontends, stop the
        resident loop (draining outstanding work by default), and fail
        any future the engine never resolved.  Idempotent; returns the
        final `EngineReport` (None for a never-started resident client)."""
        if self._closed:
            return self._report
        self._closed = True
        try:
            if not self.resident and self._report is None \
                    and self._futures:
                self.run()                # `with Client(resident=False)`
            if self.resident and not self.engine.started and drain \
                    and self._futures and self.engine._loop_error is None:
                self._start_engine()      # lazy start: run pending work
            for fe in self._frontends:
                fe.close(drain=drain, timeout=timeout)
            if self.resident:
                self._report = self.engine.shutdown(drain=drain,
                                                    timeout=timeout)
        finally:
            for srv in self._stats_servers:
                srv.stop()
            self._stats_servers = []
            for name in list(self._futures):
                fut = self._futures.pop(name, None)
                if fut is not None and not fut.done():
                    fut._resolve(state=_DONE, exception=TaskFailed(
                        f"{name}: client closed before completion"))
            if self._owns_backend:
                self.engine.backend.close()
                self._owns_backend = False
        return self._report

    def __enter__(self) -> "Client":
        # inline transports (inproc/tree) run tasks on the dispatch
        # thread itself, so starting the loop during graph construction
        # buys no parallelism — it only GIL-contends with the submitting
        # thread.  The loop starts lazily at the first blocking call
        # (gather / result / drain / serve / close).  transport="thread"
        # has real concurrency to gain (blocking task bodies overlap
        # with submission), so it starts eagerly.
        if self.resident and self.engine.transport == "thread":
            self.start()
        return self

    def __exit__(self, *exc):
        self.close()

    # ---------------------------------------------------------- execution
    def _execute(self, name: str, meta: dict, worker: str):
        """The engine's execute callback when an `executor=` is attached:
        futures-submitted tasks run their wrapped fn (value wrapped so
        the engine never tuple-interprets it); named tasks fall through
        to the executor (pmake scripts, elastic work shards), whose
        return keeps the engine convention (bool | (ok, value) | None)."""
        task = self.engine.tasks.get(name)
        if task is not None and task.fn is not None:
            return (True, task.fn())
        if task is None and self._pruned_any:
            # a name the engine does not know, on a client whose every
            # task IS registered (submit/submit_task): a pruned name
            # resurrected as a server stub by a dep that raced
            # prune_terminal.  Complete it as a no-op — the original
            # already ran; re-invoking the executor would duplicate its
            # side effects.  (run_pool-style pre-created universes never
            # prune, so their unregistered names still reach the
            # executor.)
            return True
        if self._executor_pass_worker:
            return self._executor(name, meta, worker)
        return self._executor(name, meta)

    def _resolve_leftovers(self, report: EngineReport):
        """Batch mode ends with server-side-only terminal states (tasks
        poisoned before the engine ever saw them) — resolve their futures
        from the report."""
        for name in list(self._futures):
            fut = self._futures.pop(name, None)
            if fut is None or fut.done():
                continue
            res = report.results.get(name)
            if res is not None:
                if res.ok:
                    fut._resolve(state=_DONE, value=res.value, record=res)
                elif fut._pending_exc is not None:
                    fut._resolve(state=_DONE, exception=fut._pending_exc,
                                 record=res)
                else:
                    fut._resolve(state=_DONE, exception=TaskFailed(
                        f"{name}: {res.error}"), record=res)
            elif name in report.errors:
                fut._resolve(state=_DONE, exception=DependencyFailed(
                    f"{name}: poisoned by an upstream failure"))
            else:
                why = ("engine stalled before the task ran"
                       if report.stalled else "never reached terminal state")
                fut._resolve(state=_DONE,
                             exception=TaskFailed(f"{name}: {why}"))

    # ------------------------------------------------------------ serving
    def serve(self, execute_batch: Callable, **frontend_kw):
        """Attach a continuous-serving `Frontend` (bounded admission +
        METG-aware dynamic batching) to this client's resident engine and
        start it.  Closed automatically by `close()`."""
        if not self.resident:
            raise RuntimeError("serve() requires resident mode")
        from repro.core.serving import Frontend

        frontend_kw.setdefault("scheduler", _METG_NAME[self.scheduler])
        self.start()
        fe = Frontend(self.engine, execute_batch, **frontend_kw)
        fe.start()
        self._frontends.append(fe)
        if self._metrics is not None:
            # a stats server is already up: fold the new frontend in so
            # its request latencies and admission counters appear live
            from repro.core.obs import instrument

            instrument(self._metrics, frontend=fe,
                       frontend_index=len(self._frontends) - 1)
        return fe

    # --------------------------------------------------------- membership
    def add_worker(self, name: Optional[str] = None) -> str:
        """Grow the live pool (resident elastic scaling)."""
        return self.engine.add_worker(name)

    def lose_worker(self, name: str):
        """Driver-side failure detection: drop a worker, requeue its work."""
        self.engine.lose_worker(name)

    def live_workers(self) -> int:
        return self.engine.live_workers()

    # ---------------------------------------------------------------- obs
    def stats_server(self, port: int = 0, *, host: str = "127.0.0.1"):
        """Start the live observability endpoint for this client: wires a
        `MetricsRegistry` over the engine, backend, frontends, and the
        futures counters (`repro.core.obs.instrument`), then serves
        `/stats`, `/health`, and `/metrics` from an `http.server` thread.
        `port=0` binds an ephemeral port — read it from the returned
        `StatsServer`'s `.url`.  Idempotent metrics wiring; the server is
        stopped automatically by `close()`.

            srv = client.stats_server()
            print(srv.url)        # point  python -m repro.core.obs.top  here
        """
        from repro.core.obs import StatsServer, instrument

        self._metrics = instrument(self._metrics, client=self)
        srv = StatsServer(self._metrics, client=self,
                          host=host, port=port).start()
        self._stats_servers.append(srv)
        return srv

    def report(self) -> OverheadReport:
        """METG accounting for the session so far (or the final report
        after close): the same empirical per-task overhead / tasks-per-s /
        rpc breakdown the engine front doors produce."""
        if self._report is not None:
            return self._report.overhead()
        if self.engine.transport == "thread":
            workers = min(self.engine.workers, self.engine.capacity)
        elif self.engine.transport == "proc":
            workers = self.engine.live_workers()   # real OS parallelism
        else:
            workers = 1      # serial inline transports (engine convention)
        return self.engine.tracer.report(workers=max(workers, 1))

    def prune(self) -> int:
        """Bounded-state maintenance: drop terminal history entries from
        the engine and server tables (see `Engine.prune_terminal`)."""
        self._pruned_any = True
        return self.engine.prune_terminal()

    def stats(self) -> dict:
        return self.engine.backend.stats()

    def __repr__(self):
        mode = "resident" if self.resident else "batch"
        state = "closed" if self._closed else (
            "running" if (self.resident and self.engine.started) else "idle")
        return (f"Client({self.scheduler}, {mode}, {state}, "
                f"workers={self.engine.workers}, "
                f"pending={len(self._futures)})")


def _proc_call_payload(name: str, fn: Callable, args: tuple,
                       kwargs: dict) -> str:
    """Pack a futures submission for a worker process: cloudpickle
    `(fn, args, kwargs)` with done-future arguments inlined to their
    values and pending ones replaced by `Ref(task)` placeholders (the
    worker materializes those from its local cache or a Fetch).  Raises
    `SerializationError` naming the task on an unpicklable callable or
    argument — the submit-time contract of `transport="proc"`."""
    def lift(x):
        if not isinstance(x, Future):
            return x
        if not x.done():
            return Ref(x.name)
        if isinstance(x._value, RemoteValue):
            # the value never left its producing worker: keep it remote
            # (the dependent peer-fetches it) instead of hauling it
            # through this process — but pin the name so auto-prune can't
            # evict the payload before the dependent runs (a done
            # future's dep edge is dropped by _lift_deps)
            x._client.engine.pin(x.name)
            return Ref(x.name)
        return x._peek()

    a = tuple(lift(x) for x in args)
    kw = {k: lift(v) for k, v in kwargs.items()} if kwargs else {}
    return dumps_call(fn, a, kw, task=name)


def _make_call(fut: Future, fn: Callable, args: tuple, kwargs: dict):
    """Wrap a submitted fn: lift Future arguments to their values at
    execution time, capture the real exception object for the future
    (the engine only keeps a repr), and let WorkerCrash propagate so the
    engine requeues instead of failing.  Returns the raw value — the
    registered-fn dispatch path (`_execute_registered` / the client's
    `_execute`) wraps it in (True, value), so user return values are
    never tuple-interpreted by the engine."""
    def call():
        try:
            a = tuple(x._peek() if isinstance(x, Future) else x
                      for x in args)
            if kwargs:
                kw = {k: (v._peek() if isinstance(v, Future) else v)
                      for k, v in kwargs.items()}
                return fn(*a, **kw)
            return fn(*a)
        except WorkerCrash:
            raise
        except Exception as e:          # noqa: BLE001 — delivered via the
            fut._pending_exc = e        # future, task marked failed
            raise
    return call
