"""Futures-first client API: one front door for every scheduler and the
serving layer.

The paper's three schedulers share one engine (PR 1-3); this package
gives them one *interface*: `Client.submit(fn, *args) -> Future`, with
futures-as-dependencies (the `distributed`/Balsam shape).  A `Client`
owns a resident `Engine` — submit while it runs, no pre-declared task
universe — and every future resolves exactly once from the engine's
first-terminal notification, across `WorkerCrash` requeues and
heartbeat-lease expiries.  An upstream failure (or cancel) poisons its
transitive dependents, which surface `DependencyFailed`.

Quickstart — dwork (bag of dynamic tasks, work-stealing pool):

    from repro.client import Client

    with Client(scheduler="dwork", workers=4, steal_n=4) as c:
        squares = [c.submit(lambda x=x: x * x, key=f"sq{x}") for x in range(100)]
        total = c.submit(sum, c.submit(lambda: [1, 2, 3]))   # future-as-dep
        print(c.gather(squares), total.result())
        print(c.report().summary())          # METG accounting, unchanged

Quickstart — pmake (EFT priorities, node slots):

    with Client(scheduler="pmake", workers=8) as c:
        shards = [c.submit(train_shard, i, priority=10 - i, slots=2)
                  for i in range(4)]
        summary = c.submit(summarize, *shards)    # waits on all four
        summary.result()

Quickstart — mpi_list (bulk-synchronous rank blocks):

    with Client(scheduler="mpi_list", workers=8) as c:
        blocks = [list(range(p * 100, (p + 1) * 100)) for p in range(8)]
        done = c.map(lambda blk: [x * 2 for x in blk], blocks)
        flat = [y for blk in c.gather(done) for y in blk]

Serving rides the same client (`repro.core.serving` frontend):

    with Client(scheduler="dwork", workers=2, lease_timeout=30.0) as c:
        frontend = c.serve(execute_batch, max_wait_s=0.005)
        reply = frontend.submit(payload)
        reply.wait(); print(reply.value)

Long-lived sessions stay bounded with the opt-in knobs:
`Client(max_trace_events=100_000)` puts the trace on a ring buffer,
`keep_results=False` skips the engine's results history (futures hold
the values), and `prune_every=N` drops terminal entries from the
engine + server history tables every N resolved futures.

The legacy front doors — `dwork.pool.run_pool`, `pmake.PMake.run`,
`mpi_list.Context(engine_workers=...)` — are thin shims over the batch
mode of this client (`Client(resident=False)` + `run()`); their
signatures and `EngineReport` contract are unchanged.
"""
from repro.client.client import SCHEDULERS, Client
from repro.client.futures import (CancelledError, DependencyFailed, Future,
                                  TaskFailed, as_completed)

__all__ = ["Client", "Future", "as_completed", "CancelledError",
           "DependencyFailed", "TaskFailed", "SCHEDULERS"]
