"""repro — Three Practical Workflow Schedulers (Rogers 2021) as a multi-pod
JAX training/serving framework.

Layers:
  repro.core      — the paper's contribution: pmake, dwork, mpi_list, METG
  repro.models    — pure-JAX model zoo (10 assigned architectures)
  repro.kernels   — Pallas TPU kernels (tiled A^T B matmul = paper workload,
                    flash attention, rwkv6 scan, mamba2 SSD)
  repro.runtime   — sharded train/serve steps, KV cache, elastic pool
  repro.optim     — AdamW, ZeRO-1, gradient compression
  repro.launch    — mesh, multi-pod dry-run, train/serve/campaign drivers
"""

__version__ = "0.1.0"
