"""deepseek-v2-lite-16b [moe] — MLA (kv_lora=512) + MoE 64 routed top-6,
2 shared experts, first layer dense. [arXiv:2405.04434; hf]

Assignment note: the assignment line says "MoE 64e top-6" and also mentions
"160 routed" (which is full V2); we follow the explicit 64-expert spec of
V2-Lite. d_ff=1408 is the per-expert hidden size; the first dense layer uses
10944 (HF config) — recorded here for completeness.
"""
from repro.configs.base import MLAConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b",
    family="moe",
    n_layers=27,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,
    vocab_size=102400,
    act="silu",
    rope_theta=1e4,
    mla=MLAConfig(q_lora_rank=0, kv_lora_rank=512,
                  qk_nope_head_dim=128, qk_rope_head_dim=64, v_head_dim=128),
    moe=MoEConfig(n_experts=64, top_k=6, n_shared_experts=2, d_expert=1408,
                  dense_residual=False, first_dense_layers=1, dense_d_ff=10944),
)
