"""qwen2-vl-2b [vlm] — M-RoPE backbone; dynamic-resolution patch frontend is a
stub (`input_specs` supplies M-RoPE position streams; smoke tests splice
precomputed patch embeddings). [arXiv:2409.12191; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-2b",
    family="vlm",
    n_layers=28,
    d_model=1536,
    n_heads=12,
    n_kv_heads=2,
    d_ff=8960,
    vocab_size=151936,
    head_dim=128,
    qkv_bias=True,
    act="silu",
    rope_theta=1e6,
    mrope=True,
    mrope_sections=(16, 24, 24),   # temporal/height/width rotary half-dims
    tie_embeddings=True,
)
