"""gemma2-2b [dense] — local+global alternating attention, logit softcaps.
[arXiv:2408.00118; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-2b",
    family="dense",
    n_layers=26,
    d_model=2304,
    n_heads=8,
    n_kv_heads=4,
    d_ff=9216,
    vocab_size=256000,
    head_dim=256,
    act="gelu",
    rope_theta=1e4,
    attn_logit_softcap=50.0,
    final_logit_softcap=30.0,
    sliding_window=4096,
    local_global_every=2,        # every 2nd layer is global, others local
    query_pre_attn_scalar=256.0,
    post_norms=True,
    embed_scale=True,
    rms_plus_one=True,
    tie_embeddings=True,
)
