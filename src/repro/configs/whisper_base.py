"""whisper-base [audio] — encoder-decoder; conv frontend is a stub
(`input_specs` supplies precomputed 1500-frame embeddings).
[arXiv:2212.04356; unverified]

Positions: sinusoidal (computed on the fly) for both encoder and decoder —
the real model uses learned decoder positions; stubbed per DESIGN.md §6 so
that the assigned decode shapes (32k) remain lowerable.
"""
from repro.configs.base import EncoderConfig, ModelConfig

CONFIG = ModelConfig(
    name="whisper-base",
    family="audio",
    n_layers=6,
    d_model=512,
    n_heads=8,
    n_kv_heads=8,
    d_ff=2048,
    vocab_size=51865,
    head_dim=64,
    act="gelu",
    gated_mlp=False,
    encoder=EncoderConfig(n_layers=6, n_frames=1500, n_heads=8),
)
