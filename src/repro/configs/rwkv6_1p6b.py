"""rwkv6-1.6b [ssm] — Finch: attention-free, data-dependent decay.
[arXiv:2404.05892; unverified]"""
from repro.configs.base import ModelConfig, RWKVConfig

CONFIG = ModelConfig(
    name="rwkv6-1.6b",
    family="ssm",
    n_layers=24,
    d_model=2048,
    n_heads=32,                 # wkv heads = d_model / rwkv.head_dim
    n_kv_heads=32,
    d_ff=7168,
    vocab_size=65536,
    head_dim=64,
    act="relu",                 # rwkv channel-mix uses squared relu
    rwkv=RWKVConfig(head_dim=64, decay_lora=64, tokenshift_lora=32, chunk=64),
)
