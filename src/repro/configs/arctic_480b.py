"""arctic-480b [moe] — 128 experts top-2 in parallel with a dense FFN
residual (Arctic's dense-MoE hybrid). [hf:Snowflake/snowflake-arctic-base; hf]"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="arctic-480b",
    family="moe",
    n_layers=35,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_ff=4864,
    vocab_size=32000,
    head_dim=128,
    act="silu",
    rope_theta=1e4,
    moe=MoEConfig(n_experts=128, top_k=2, n_shared_experts=0, d_expert=4864,
                  dense_residual=True, first_dense_layers=0, dense_d_ff=4864),
)
