"""Architecture config registry: ``get_config("qwen2.5-32b")`` etc."""
from __future__ import annotations

from repro.configs.base import (
    SHAPES,
    EncoderConfig,
    MLAConfig,
    ModelConfig,
    MoEConfig,
    RunConfig,
    RWKVConfig,
    ShapeConfig,
    SSMConfig,
    applicable_shapes,
    input_specs,
)

from repro.configs.qwen2_5_32b import CONFIG as _qwen2_5_32b
from repro.configs.deepseek_67b import CONFIG as _deepseek_67b
from repro.configs.gemma2_2b import CONFIG as _gemma2_2b
from repro.configs.deepseek_7b import CONFIG as _deepseek_7b
from repro.configs.zamba2_2p7b import CONFIG as _zamba2_2p7b
from repro.configs.whisper_base import CONFIG as _whisper_base
from repro.configs.qwen2_vl_2b import CONFIG as _qwen2_vl_2b
from repro.configs.rwkv6_1p6b import CONFIG as _rwkv6_1p6b
from repro.configs.deepseek_v2_lite import CONFIG as _deepseek_v2_lite
from repro.configs.arctic_480b import CONFIG as _arctic_480b

REGISTRY: dict[str, ModelConfig] = {
    c.name: c
    for c in [
        _qwen2_5_32b,
        _deepseek_67b,
        _gemma2_2b,
        _deepseek_7b,
        _zamba2_2p7b,
        _whisper_base,
        _qwen2_vl_2b,
        _rwkv6_1p6b,
        _deepseek_v2_lite,
        _arctic_480b,
    ]
}

ARCH_IDS = list(REGISTRY)


def get_config(name: str) -> ModelConfig:
    key = name.replace("_", "-")
    if key in REGISTRY:
        return REGISTRY[key]
    # allow prefix match (e.g. "deepseek-v2-lite" for "deepseek-v2-lite-16b")
    hits = [k for k in REGISTRY if k.startswith(key)]
    if len(hits) == 1:
        return REGISTRY[hits[0]]
    raise KeyError(f"unknown arch {name!r}; available: {ARCH_IDS}")


__all__ = [
    "ARCH_IDS", "REGISTRY", "get_config", "input_specs", "applicable_shapes",
    "SHAPES", "ShapeConfig", "ModelConfig", "RunConfig", "MLAConfig",
    "MoEConfig", "SSMConfig", "RWKVConfig", "EncoderConfig",
]
