"""zamba2-2.7b [hybrid] — Mamba2 backbone + shared attention blocks.
[arXiv:2411.15242; hf]

54 Mamba2 layers; a weight-shared (attention + FFN) transformer block is
applied every 6 SSM layers (9 applications), following the Zamba2 design of
reusing one shared block throughout the depth.
"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b",
    family="hybrid",
    n_layers=54,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    d_ff=10240,
    vocab_size=32000,
    head_dim=80,
    act="gelu",
    rope_theta=1e4,
    attn_every=6,
    ssm=SSMConfig(state_dim=64, head_dim=64, expand=2, conv_dim=4, chunk=64),
)
