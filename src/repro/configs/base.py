"""Config system for the repro framework.

Every assigned architecture is a `ModelConfig` (exact numbers from the
assignment table) plus a set of input shapes (`SHAPES`).  Full configs are
only ever *lowered* (ShapeDtypeStruct, no allocation); smoke tests use
`reduced()` copies.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# Sub-configs for family-specific blocks
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class MLAConfig:
    """DeepSeek-V2 multi-head latent attention."""

    q_lora_rank: int = 0          # 0 => full-rank q projection (V2-Lite)
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int = 64
    top_k: int = 6
    n_shared_experts: int = 2     # shared experts run on every token
    d_expert: int = 1408          # per-expert FFN hidden size
    dense_residual: bool = False  # Arctic: dense FFN in parallel with MoE
    first_dense_layers: int = 1   # leading layers use a dense FFN instead
    dense_d_ff: int = 0           # hidden size of dense FFN (0 => d_ff)
    capacity_factor: float = 1.25
    router_aux_loss: float = 0.001


@dataclass(frozen=True)
class SSMConfig:
    """Mamba2 / SSD block parameters."""

    state_dim: int = 64
    head_dim: int = 64
    expand: int = 2
    conv_dim: int = 4
    chunk: int = 64               # chunked-scan block length
    n_groups: int = 1


@dataclass(frozen=True)
class RWKVConfig:
    head_dim: int = 64
    decay_lora: int = 64          # rank of data-dependent decay LoRA
    tokenshift_lora: int = 32
    chunk: int = 64


@dataclass(frozen=True)
class EncoderConfig:
    """Whisper-style encoder (conv frontend stubbed)."""

    n_layers: int = 6
    n_frames: int = 1500          # post-conv sequence length
    n_heads: int = 8


@dataclass(frozen=True)
class ModelConfig:
    name: str = "model"
    family: str = "dense"         # dense | hybrid | audio | vlm | ssm | moe
    n_layers: int = 2
    d_model: int = 256
    n_heads: int = 4
    n_kv_heads: int = 4
    d_ff: int = 1024
    vocab_size: int = 1024
    head_dim: int = 0             # 0 => d_model // n_heads
    qkv_bias: bool = False
    tie_embeddings: bool = False
    norm_eps: float = 1e-6
    act: str = "silu"             # silu | gelu
    rope_theta: float = 1e6
    # gemma2 features
    attn_logit_softcap: float = 0.0
    final_logit_softcap: float = 0.0
    sliding_window: int = 0       # >0: local-attention window
    local_global_every: int = 0   # >0: every Nth layer is global, rest local
    query_pre_attn_scalar: float = 0.0  # gemma2 uses d_model/n_heads
    post_norms: bool = False      # gemma2 post-attn/post-ffn norms
    embed_scale: bool = False     # gemma2 scales embeds by sqrt(d_model)
    rms_plus_one: bool = False    # gemma-style (1 + scale) RMSNorm
    gated_mlp: bool = True        # False => plain 2-layer MLP (whisper)
    # vlm
    mrope: bool = False           # Qwen2-VL multimodal RoPE (3 position streams)
    mrope_sections: tuple = (16, 24, 24)  # per-stream rotary sections (half-dims)
    # hybrid (zamba2): shared attention block applied every `attn_every` ssm layers
    attn_every: int = 0
    # sub-configs
    mla: Optional[MLAConfig] = None
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    rwkv: Optional[RWKVConfig] = None
    encoder: Optional[EncoderConfig] = None
    # vocab padding for sharding (physical embedding rows; logits masked)
    vocab_pad_multiple: int = 256

    # ------------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        m = self.vocab_pad_multiple
        return ((self.vocab_size + m - 1) // m) * m

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm" and self.attn_every == 0

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # ------------------------------------------------------------------
    def reduced(self) -> "ModelConfig":
        """A tiny same-family config for CPU smoke tests."""
        kw: dict = dict(
            n_layers=min(self.n_layers, 2 if self.attn_every == 0 else 2 * max(self.attn_every, 1)),
            d_model=128,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 4) if self.n_kv_heads < self.n_heads else 4,
            d_ff=256,
            vocab_size=512,
            head_dim=32,
            vocab_pad_multiple=16,
        )
        if self.mla is not None:
            kw["mla"] = MLAConfig(q_lora_rank=0, kv_lora_rank=64,
                                  qk_nope_head_dim=32, qk_rope_head_dim=16, v_head_dim=32)
        if self.moe is not None:
            kw["moe"] = dataclasses.replace(
                self.moe, n_experts=8, top_k=min(self.moe.top_k, 2),
                n_shared_experts=min(self.moe.n_shared_experts, 1),
                d_expert=64, dense_d_ff=256, first_dense_layers=min(self.moe.first_dense_layers, 1))
        if self.ssm is not None:
            kw["ssm"] = dataclasses.replace(self.ssm, state_dim=16, head_dim=16, chunk=16)
        if self.rwkv is not None:
            kw["rwkv"] = dataclasses.replace(self.rwkv, head_dim=32, decay_lora=16,
                                             tokenshift_lora=8, chunk=16)
        if self.encoder is not None:
            kw["encoder"] = dataclasses.replace(self.encoder, n_layers=2, n_frames=32, n_heads=4)
        if self.mrope:
            kw["mrope_sections"] = (4, 6, 6)   # sums to reduced head_dim//2
        if self.sliding_window:
            kw["sliding_window"] = 16
        if self.attn_every:
            kw["attn_every"] = self.attn_every
        return self.replace(**kw)


# ---------------------------------------------------------------------------
# Input shapes (assigned): every arch is paired with all four.
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    mode: str                     # "train" | "prefill" | "decode"


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def applicable_shapes(cfg: ModelConfig) -> dict[str, str]:
    """shape name -> "run" or "skip:<reason>" per the assignment rules."""
    out = {}
    for name, sh in SHAPES.items():
        if name == "long_500k":
            # sub-quadratic attention required: run for SSM / hybrid / linear-attn
            if cfg.family in ("ssm", "hybrid"):
                out[name] = "run"
            else:
                out[name] = "skip:full-attention arch; 500k decode out of family spec (DESIGN.md §6)"
        else:
            out[name] = "run"
    return out


# ---------------------------------------------------------------------------
# Run-time (training/serving) config
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class RunConfig:
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    remat: str = "none"           # none | dots | full
    microbatches: int = 1
    # optimizer
    lr: float = 3e-4
    weight_decay: float = 0.1
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 1000
    zero1: bool = True            # shard optimizer state over the data axis
    adam_state_dtype: str = "float32"   # float32 | bfloat16 (quantized adam)
    grad_compress: str = "none"   # none | bf16 | int8 (all-reduce compression)
    # serving
    seq_shard_kv: bool = False    # shard KV cache sequence over the data axis
    shard_params_2d: bool = False  # FSDP-style 2D weight sharding (serving)
    # misc
    seed: int = 0

    def replace(self, **kw) -> "RunConfig":
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
# input_specs: ShapeDtypeStruct stand-ins for every model input.
# ---------------------------------------------------------------------------


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    """Abstract (no-allocation) input pytree for a given (arch, shape) cell.

    train:   {tokens, labels, segment_ids?}   (B, S) int32
    prefill: {tokens}                         (B, S) int32
    decode:  {tokens}                         (B,)   int32 (one new token/seq)
    extras per family (mrope positions, encoder frames, ...).
    """
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    sds = jax.ShapeDtypeStruct
    specs: dict = {}
    if shape.mode == "train":
        specs["tokens"] = sds((B, S), i32)
        specs["labels"] = sds((B, S), i32)
    elif shape.mode == "prefill":
        specs["tokens"] = sds((B, S), i32)
    else:  # decode: one new token against a cache of length S
        specs["tokens"] = sds((B,), i32)
        specs["positions"] = sds((B,), i32)
    if cfg.mrope and shape.mode != "decode":
        specs["mrope_positions"] = sds((3, B, S), i32)
    if cfg.family == "audio":
        enc = cfg.encoder
        # conv frontend is a stub: precomputed frame embeddings
        specs["encoder_frames"] = sds((B, enc.n_frames, cfg.d_model), jnp.bfloat16)
    return specs
