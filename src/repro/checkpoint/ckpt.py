"""Checkpointing: sharded-save/restore with elastic resharding.

Layout per step:  <dir>/step_<N>/
    manifest.json       tree structure, shapes, dtypes, step, metadata
    arrays.npz          flattened path -> ndarray
Writes go to a tmp dir + atomic rename (crash-safe); `AsyncCheckpointer`
overlaps serialization with the next training steps (one in flight).
Restore accepts a different mesh than the save used — arrays are re-placed
with the target NamedShardings (elastic scaling).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from pathlib import Path

import jax
import numpy as np


def _flatten(tree) -> dict:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        flat[key] = np.asarray(jax.device_get(leaf))
    return flat


def save(directory: str, step: int, tree, metadata: dict = None) -> str:
    d = Path(directory)
    final = d / f"step_{step:08d}"
    tmp = d / f".tmp_step_{step:08d}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)
    flat = _flatten(tree)
    np.savez(tmp / "arrays.npz", **flat)
    treedef = jax.tree_util.tree_structure(tree)
    manifest = {
        "step": step,
        "treedef": str(treedef),
        "keys": sorted(flat),
        "shapes": {k: list(v.shape) for k, v in flat.items()},
        "dtypes": {k: str(v.dtype) for k, v in flat.items()},
        "metadata": metadata or {},
        "time": time.time(),
    }
    (tmp / "manifest.json").write_text(json.dumps(manifest))
    if final.exists():
        shutil.rmtree(final)
    os.replace(tmp, final)                      # atomic publish
    return str(final)


def latest_step(directory: str):
    d = Path(directory)
    if not d.exists():
        return None
    steps = sorted(int(p.name.split("_")[1]) for p in d.glob("step_*"))
    return steps[-1] if steps else None


def restore(directory: str, step: int, abstract_tree, *, mesh=None,
            spec_tree=None):
    """Rebuild the pytree; if mesh+specs given, place arrays sharded
    (elastic: the mesh need not match the one used at save time)."""
    d = Path(directory) / f"step_{step:08d}"
    data = np.load(d / "arrays.npz")
    leaves_with_path = jax.tree_util.tree_flatten_with_path(abstract_tree)[0]
    treedef = jax.tree_util.tree_structure(abstract_tree)
    spec_leaves = (jax.tree_util.tree_leaves(
        spec_tree, is_leaf=lambda s: s is None or hasattr(s, "index"))
        if spec_tree is not None else None)
    out = []
    for i, (path, leaf) in enumerate(leaves_with_path):
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        arr = data[key]
        assert tuple(arr.shape) == tuple(leaf.shape), (key, arr.shape,
                                                       leaf.shape)
        x = arr.astype(leaf.dtype)
        if mesh is not None and spec_leaves is not None:
            x = jax.device_put(
                x, jax.sharding.NamedSharding(mesh, spec_leaves[i]))
        out.append(jax.numpy.asarray(x))
    return jax.tree_util.tree_unflatten(treedef, out)


def retain(directory: str, keep: int = 3):
    d = Path(directory)
    steps = sorted(d.glob("step_*"))
    for p in steps[:-keep]:
        shutil.rmtree(p)


class AsyncCheckpointer:
    """One save in flight; next save waits for the previous (bounded)."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._thread: threading.Thread = None
        self.saved: list = []

    def save(self, step: int, tree, metadata: dict = None):
        self.wait()
        host_tree = jax.tree_util.tree_map(jax.device_get, tree)

        def work():
            p = save(self.directory, step, host_tree, metadata)
            self.saved.append(p)
            retain(self.directory, self.keep)

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
