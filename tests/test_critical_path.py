"""Critical-path analyzer tests: exact-math verification on synthetic
hand-built traces (known path, known segment durations, attribution
telescoping to the makespan), requeue/retry episode accounting, live
end-to-end runs with a seeded straggler across transports, the explain
CLI, the chrome-trace critical-path overlay, and the /stats surface."""
import json
import time

import pytest

from repro.client import Client
from repro.core.engine import (COMPLETED, CREATED, READY, RETRIED, RPC,
                               RUN_END, RUN_START, STOLEN, Engine,
                               ManualClock, TraceRecorder)
from repro.core.obs import CriticalPathReport, StatsServer, instrument
from repro.core.obs import explain as obs_explain
from repro.core.obs import top as obs_top


def _at(tr, clock, t, event, task=None, worker=None, **extra):
    clock.now = t
    tr.emit(event, task=task, worker=worker, **extra)


def _chain_trace():
    """a -> b -> d on two workers, with side task s1 riding along.

    Known timeline (all stamps explicit):
      a:  created 0.0, ready 0.0, stolen 0.1, run [0.2, 1.2] w0, done 1.3
      s1: created 0.0, ready 0.0, stolen 0.1, run [0.2, 0.5] w1, done 0.55
      b:  created 0.0 (deps a),   ready 1.3, stolen 1.5,
          run [1.6, 3.6] w1, done 3.7
      d:  created 0.0 (deps b),   ready 3.7, stolen 3.8,
          run [3.9, 4.4] w0, done 4.5
    Critical path [a, b, d]; makespan 4.5; per-stage totals
    dep_wait 0.0, queue 0.4, dispatch 0.3, run 3.5, notify 0.3.
    """
    clock = ManualClock()
    tr = TraceRecorder(clock=clock)
    _at(tr, clock, 0.00, CREATED, task="a")
    _at(tr, clock, 0.00, READY, task="a")
    _at(tr, clock, 0.00, CREATED, task="s1")
    _at(tr, clock, 0.00, READY, task="s1")
    _at(tr, clock, 0.00, CREATED, task="b", deps=["a"])
    _at(tr, clock, 0.00, CREATED, task="d", deps=["b"])
    _at(tr, clock, 0.10, STOLEN, task="a", worker="w0")
    _at(tr, clock, 0.10, STOLEN, task="s1", worker="w1")
    _at(tr, clock, 0.20, RUN_START, task="a", worker="w0")
    _at(tr, clock, 0.20, RUN_START, task="s1", worker="w1")
    _at(tr, clock, 0.50, RUN_END, task="s1", worker="w1")
    _at(tr, clock, 0.55, COMPLETED, task="s1", worker="w1")
    _at(tr, clock, 1.20, RUN_END, task="a", worker="w0")
    _at(tr, clock, 1.30, COMPLETED, task="a", worker="w0")
    _at(tr, clock, 1.30, READY, task="b")
    _at(tr, clock, 1.50, STOLEN, task="b", worker="w1")
    _at(tr, clock, 1.60, RUN_START, task="b", worker="w1")
    _at(tr, clock, 3.60, RUN_END, task="b", worker="w1")
    _at(tr, clock, 3.70, COMPLETED, task="b", worker="w1")
    _at(tr, clock, 3.70, READY, task="d")
    _at(tr, clock, 3.80, STOLEN, task="d", worker="w0")
    _at(tr, clock, 3.90, RUN_START, task="d", worker="w0")
    _at(tr, clock, 4.40, RUN_END, task="d", worker="w0")
    _at(tr, clock, 4.50, COMPLETED, task="d", worker="w0")
    return tr


# ------------------------------------------------- synthetic exact math


def test_known_dag_recovers_exact_path_and_decomposition():
    rep = CriticalPathReport.from_trace(_chain_trace(), workers=2)
    assert rep.path == ["a", "b", "d"]
    assert rep.n_tasks == 4
    assert abs(rep.makespan_s - 4.5) < 1e-9
    # exact per-stage attribution, known by construction
    assert abs(rep.dep_wait_s - 0.0) < 1e-9
    assert abs(rep.queue_s - 0.4) < 1e-9
    assert abs(rep.dispatch_s - 0.3) < 1e-9
    assert abs(rep.run_s - 3.5) < 1e-9
    assert abs(rep.notify_s - 0.3) < 1e-9
    # the decomposition telescopes EXACTLY to the makespan (acceptance
    # tolerance is 5%; the construction guarantees equality)
    total = rep.sched_s + rep.run_s
    assert abs(total - rep.makespan_s) < 1e-9
    assert abs(rep.compute_s - 3.5) < 1e-9
    assert abs(rep.sched_frac - 1.0 / 4.5) < 1e-9


def test_known_dag_per_task_segments():
    rep = CriticalPathReport.from_trace(_chain_trace(), workers=2)
    by_task = {row["task"]: row for row in rep.segments}
    assert by_task["a"]["queue_s"] == 0.1
    assert by_task["a"]["dispatch_s"] == 0.1
    assert by_task["a"]["run_s"] == 1.0
    assert by_task["a"]["notify_s"] == 0.1
    # b's span starts where a finished (1.3): its READY at the same
    # stamp means zero dep-wait, then 0.2 queue / 0.1 dispatch
    assert by_task["b"]["t_s"] == 1.3
    assert by_task["b"]["dep_wait_s"] == 0.0
    assert by_task["b"]["queue_s"] == 0.2
    assert by_task["b"]["run_s"] == 2.0
    assert by_task["d"]["run_s"] == 0.5
    assert all(row["n_runs"] == 1 and row["retries"] == 0
               for row in rep.segments)


def test_known_dag_concurrency_and_idle_gaps():
    rep = CriticalPathReport.from_trace(_chain_trace(), workers=2)
    # run episodes: a [0.2,1.2], s1 [0.2,0.5], b [1.6,3.6], d [3.9,4.4]
    assert rep.concurrency_peak == 2                  # a and s1 overlap
    assert abs(rep.concurrency_mean - 3.8 / 4.5) < 1e-9
    # nothing ran in [0,0.2), [1.2,1.6), [3.6,3.9), and the final
    # notify tail [4.4,4.5) after d's RUN_END
    assert abs(rep.idle_s - 1.0) < 1e-9
    gaps = dict(rep.idle_gaps)
    assert gaps[1.2] == 0.4 and gaps[3.6] == 0.3
    assert gaps[0.0] == 0.2 and gaps[4.4] == 0.1
    # profile changepoints are (t, level) and end back at level 0
    assert rep.profile[0] == (0.2, 2)
    assert rep.profile[-1][1] == 0


def test_known_dag_straggler_detection_honors_factor():
    tr = _chain_trace()
    rep = CriticalPathReport.from_trace(tr, workers=2)
    # final run durations 0.3/0.5/1.0/2.0: median 1.0, nothing >= 4x
    assert rep.run_median_s == 1.0 and rep.stragglers == []
    rep2 = CriticalPathReport.from_trace(tr, workers=2,
                                         straggler_factor=2.0)
    assert [s["task"] for s in rep2.stragglers] == ["b"]
    assert rep2.stragglers[0]["on_path"] is True
    assert rep2.stragglers[0]["ratio"] == 2.0


def test_explicit_dep_table_overrides_created_stamps():
    # strip the CREATED deps stamps: with no dep table the path collapses
    # to the final task; the engine's dep_table() restores the chain
    tr = _chain_trace()
    events = [e for e in tr.events]
    for e in events:
        if e.event == CREATED:
            e.extra.pop("deps", None)
    bare = CriticalPathReport.from_events(events, workers=2)
    assert bare.path == ["d"]
    table = {"b": ("a",), "d": ("b",)}
    rep = CriticalPathReport.from_events(events, deps=table, workers=2)
    assert rep.path == ["a", "b", "d"]
    assert abs((rep.sched_s + rep.run_s) - rep.makespan_s) < 1e-9


def test_retry_episodes_count_as_wasted_subspans():
    clock = ManualClock()
    tr = TraceRecorder(clock=clock)
    _at(tr, clock, 0.00, CREATED, task="r")
    _at(tr, clock, 0.00, READY, task="r")
    _at(tr, clock, 0.10, STOLEN, task="r", worker="w0")
    _at(tr, clock, 0.20, RUN_START, task="r", worker="w0")
    _at(tr, clock, 0.60, RUN_END, task="r", worker="w0")
    _at(tr, clock, 0.65, RETRIED, task="r", attempt=1)
    _at(tr, clock, 0.70, STOLEN, task="r", worker="w1")
    _at(tr, clock, 0.80, RUN_START, task="r", worker="w1")
    _at(tr, clock, 1.00, RUN_END, task="r", worker="w1")
    _at(tr, clock, 1.05, COMPLETED, task="r", worker="w1")
    rep = CriticalPathReport.from_trace(tr)
    assert rep.path == ["r"]
    row = rep.segments[0]
    assert row["n_runs"] == 2 and row["retries"] == 1
    # the FINAL episode is the attributed one; the first 0.4s is wasted
    assert row["wasted_s"] == 0.4
    assert row["episodes"] == [{"t_s": 0.2, "run_s": 0.4, "worker": "w0"}]
    assert abs(rep.queue_s - 0.7) < 1e-9       # ready 0.0 -> last steal 0.7
    assert abs(rep.run_s - 0.2) < 1e-9
    assert abs(rep.wasted_s - 0.4) < 1e-9
    assert abs((rep.sched_s + rep.run_s) - rep.makespan_s) < 1e-9


def test_rpc_fold_excludes_hops_from_totals():
    clock = ManualClock()
    tr = TraceRecorder(clock=clock)
    _at(tr, clock, 0.0, CREATED, task="t")
    _at(tr, clock, 0.1, COMPLETED, task="t", worker="w0")
    tr.emit(RPC, op="complete_steal", dt=2e-3)
    tr.emit(RPC, op="hop:L1", dt=1e-3)
    rep = CriticalPathReport.from_trace(tr)
    assert rep.n_rpc == 1 and abs(rep.rpc_s - 2e-3) < 1e-12
    assert rep.rpc_by_op["hop:L1"] == (1, 1e-3)


def test_summary_shape_and_truncation():
    rep = CriticalPathReport.from_trace(_chain_trace(), workers=2)
    s = rep.summary()
    assert s["path"] == ["a", "b", "d"]
    assert s["breakdown_s"]["run"] == 3.5
    assert s["sched_s"] + s["compute_s"] == s["makespan_s"]
    assert "path_truncated" not in s
    s2 = rep.summary(max_tasks=2)
    assert s2["path"] == ["b", "d"] and s2["path_truncated"] is True
    assert len(s2["segments"]) == 2
    assert s2["n_tasks_on_path"] == 3          # the true path length
    json.dumps(s)                              # /stats-able


def test_empty_and_eventless_traces_degrade():
    tr = TraceRecorder(clock=ManualClock())
    rep = CriticalPathReport.from_trace(tr)
    assert rep.path == [] and rep.makespan_s == 0.0
    assert rep.summary()["n_tasks"] == 0
    assert obs_explain.render(rep)             # renders, not crashes


# ------------------------------------------------------- live end-to-end


@pytest.mark.parametrize("transport", ["inproc", "thread"])
def test_live_seeded_straggler_lands_on_path(transport):
    with Client(scheduler="dwork", workers=3, transport=transport) as c:
        fast = [c.submit(time.sleep, 0.002, key=f"fast{i}")
                for i in range(8)]
        slow = c.submit(time.sleep, 0.12, key="slowpoke")
        tail = c.submit(lambda _x=None: 0, slow, key="tail")
        c.gather(fast + [slow, tail])
        rep = c.report().explain()
    assert "slowpoke" in rep.path              # the straggler gates the run
    assert rep.makespan_s > 0.1
    # attribution sums to makespan within the 5% acceptance tolerance
    assert abs((rep.sched_s + rep.run_s) - rep.makespan_s) \
        <= 0.05 * rep.makespan_s
    strag = {s["task"]: s for s in rep.stragglers}
    assert "slowpoke" in strag and strag["slowpoke"]["on_path"] is True
    assert strag["slowpoke"]["run_s"] >= 0.1


def test_from_engine_joins_dep_table_and_pool_shape():
    eng = Engine(workers=2, transport="thread", resident=True)
    eng.start()
    try:
        eng.submit("up", fn=lambda: time.sleep(0.01))
        eng.submit("down", fn=lambda: None, deps=("up",))
        assert eng.drain(timeout=30)
        assert eng.dep_table() == {"down": ("up",)}
        rep = CriticalPathReport.from_engine(eng)
        assert rep.path[-2:] == ["up", "down"]
        assert rep.workers == 2
    finally:
        eng.shutdown()


def test_overhead_report_explain_requires_a_trace():
    from repro.core.engine import OverheadReport
    with pytest.raises(ValueError):
        OverheadReport().explain()
    tr = _chain_trace()
    cp = tr.report(workers=2).explain()
    assert cp.path == ["a", "b", "d"]


# ------------------------------------------- save/load + the explain CLI


def test_trace_save_load_roundtrip(tmp_path):
    tr = _chain_trace()
    p = tmp_path / "run.trace.jsonl"
    n = tr.save(str(p))
    assert n == len(tr.events)
    tr2 = TraceRecorder.load(str(p))
    assert len(tr2.events) == n
    assert tr2.n_emitted == tr.n_emitted and tr2.dropped == tr.dropped
    old, new = tr.events[0], tr2.events[0]
    assert (old.t, old.event, old.task, old.worker, old.extra) == \
        (new.t, new.event, new.task, new.worker, new.extra)
    rep = CriticalPathReport.from_trace(tr2, workers=2)
    assert rep.path == ["a", "b", "d"]
    assert abs(rep.makespan_s - 4.5) < 1e-9


def test_trace_load_rejects_foreign_files(tmp_path):
    p = tmp_path / "other.jsonl"
    p.write_text('{"format": "something-else"}\n')
    with pytest.raises(ValueError):
        TraceRecorder.load(str(p))


def test_explain_cli_text_json_and_chrome(tmp_path, capsys):
    tr = _chain_trace()
    p = tmp_path / "run.trace.jsonl"
    tr.save(str(p))
    assert obs_explain.main([str(p), "--workers", "2"]) == 0
    out = capsys.readouterr().out
    assert "critical path" in out and "a" in out and "slowest" not in out
    assert "scheduler" in out and "compute" in out
    chrome = tmp_path / "run.trace.json"
    assert obs_explain.main([str(p), "--json", "--chrome",
                             str(chrome)]) == 0
    digest = json.loads(capsys.readouterr().out)
    assert digest["path"] == ["a", "b", "d"]
    doc = json.loads(chrome.read_text())
    lanes = {e["args"]["name"] for e in doc["traceEvents"]
             if e["ph"] == "M" and e["name"] == "thread_name"}
    assert "critical path" in lanes


# -------------------------------------------------- chrome-trace overlay


def test_chrome_trace_critical_path_lane_and_flow_arrows():
    tr = _chain_trace()
    rep = CriticalPathReport.from_trace(tr, workers=2)
    doc = tr.to_chrome_trace(critical_path=rep.path)
    evs = doc["traceEvents"]
    lanes = {e["args"]["name"]: e["tid"] for e in evs
             if e["ph"] == "M" and e["name"] == "thread_name"}
    assert "critical path" in lanes
    # the critical lane sorts above the worker lanes
    assert lanes["critical path"] < lanes["w0"] < lanes["w1"]
    lane = [e for e in evs if e.get("cat") == "critical_path"
            and e["ph"] == "X"]
    assert [e["name"] for e in lane] == ["a", "b", "d"]
    assert [e["args"]["order"] for e in lane] == [0, 1, 2]
    assert all(e["tid"] == lanes["critical path"] for e in lane)
    # flow arrows stitch consecutive path runs across the worker lanes:
    # a(w0) -> b(w1) and b(w1) -> d(w0)
    starts = [e for e in evs if e.get("ph") == "s"]
    ends = [e for e in evs if e.get("ph") == "f"]
    assert len(starts) == 2 and len(ends) == 2
    assert [e["tid"] for e in starts] == [lanes["w0"], lanes["w1"]]
    assert [e["tid"] for e in ends] == [lanes["w1"], lanes["w0"]]
    for s, f in zip(starts, ends):
        assert s["id"] == f["id"] and f["bp"] == "e"
        assert f["ts"] >= s["ts"]              # arrows never point backward
    # without the overlay the document is unchanged in shape
    plain = tr.to_chrome_trace()
    assert not any(e.get("cat") == "critical_path"
                   for e in plain["traceEvents"])


# ------------------------------------------------------- /stats surface


def test_stats_endpoint_and_top_render_carry_critical_path():
    import urllib.request

    eng = Engine(workers=2, transport="thread", resident=True)
    eng.start()
    try:
        reg = instrument(engine=eng)
        with StatsServer(reg, engine=eng) as srv:
            eng.submit("root", fn=lambda: time.sleep(0.01))
            eng.submit("leaf", fn=lambda: None, deps=("root",))
            assert eng.drain(timeout=30)
            with urllib.request.urlopen(srv.url + "/stats",
                                        timeout=10) as resp:
                stats = json.loads(resp.read().decode())
            cp = stats["critical_path"]
            assert cp["path"][-1] == "leaf"
            assert cp["makespan_s"] > 0
            text = obs_top.render(stats)
            assert "critical path:" in text and "concurrency" in text
    finally:
        eng.shutdown()


def test_stats_endpoint_skips_oversized_traces():
    eng = Engine(workers=1, transport="thread", resident=True)
    eng.start()
    try:
        reg = instrument(engine=eng)
        with StatsServer(reg, engine=eng, explain_max_events=3) as srv:
            for i in range(5):
                eng.submit(f"t{i}", fn=lambda: None)
            assert eng.drain(timeout=30)
            stats = srv.stats()
            assert "skipped" in stats["critical_path"]
            text = obs_top.render(stats)
            assert "critical path:" in text    # the skip reason surfaces
    finally:
        eng.shutdown()
