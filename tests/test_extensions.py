"""Paper §5/§6 extensions: sharded hub, overlapping client, gradient
compression with error feedback."""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.dwork import Client, InProcTransport, TaskServer
from repro.core.dwork.overlap import OverlapClient
from repro.core.dwork.sharded import ShardedHub
from repro.optim.compress import (compress_roundtrip, compressed_grads,
                                  dequantize_int8, quantize_int8)


def test_sharded_hub_no_deps():
    hub = ShardedHub(n_shards=3)
    for i in range(30):
        hub.create(f"t{i}")
    seen = []
    n = hub.run_to_completion(lambda name, meta: seen.append(name) or True,
                              workers=3)
    assert n == 30 and sorted(set(seen)) == sorted(seen)


def test_sharded_hub_cross_shard_deps():
    """Dependencies whose tasks hash to different shards must still be
    honored (proxy/notify delegation)."""
    hub = ShardedHub(n_shards=2)
    order = []
    # chain a -> b -> c -> d: names hash across both shards
    names = ["alpha", "bravo", "charlie", "delta"]
    for i, n in enumerate(names):
        hub.create(n, deps=[names[i - 1]] if i else [])
    done = hub.run_to_completion(lambda name, meta: order.append(name) or True,
                                 workers=2)
    assert order == names, order


def test_sharded_hub_metg_model():
    from repro.core.metg import METGModel
    m = METGModel.from_paper()
    assert m.dwork_metg(864, shards=4) * 4 == m.dwork_metg(864)


def test_overlap_client_completes_and_prefetches():
    srv = TaskServer()
    driver = Client(InProcTransport(srv), "driver")
    for i in range(20):
        driver.create(f"t{i}")
    cl = OverlapClient(InProcTransport(srv), "w0")
    done = cl.run_loop(lambda n, m: True, steal_n=2)
    assert done == 20
    assert srv.stats()["completed"] == 20


def test_quantize_roundtrip_error_bounded():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(1000,)).astype(np.float32))
    q, s = quantize_int8(x)
    back = dequantize_int8(q, s, x.shape)
    # per-block max error <= scale/2
    err = np.abs(np.asarray(back - x))
    assert err.max() <= float(s.max()) * 0.51


def test_error_feedback_converges():
    """With error feedback, the SUM of compressed grads tracks the true sum
    (residuals don't accumulate) — the property that preserves SGD."""
    rng = np.random.default_rng(1)
    true_sum = np.zeros(512, np.float32)
    fed_sum = np.zeros(512, np.float32)
    e = None
    for _ in range(50):
        g = {"w": jnp.asarray(rng.normal(size=512).astype(np.float32))}
        out, e = compressed_grads(g, e)
        true_sum += np.asarray(g["w"])
        fed_sum += np.asarray(out["w"])
    resid = float(np.abs(np.asarray(e["w"])).max())
    drift = np.abs(fed_sum - true_sum).max()
    # drift is bounded by the current residual, not growing with steps
    assert drift <= resid + 1e-4, (drift, resid)
