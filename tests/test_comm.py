"""Comm subsystem tests: the connector/listener registry, the proc
transport end to end (worker processes over the Table-2 frame protocol),
crash recovery with zero task loss, submit-time serialization errors,
multi-host joins, and orphan reaping.

Every task callable here is a lambda: cloudpickle serializes lambdas BY
VALUE, so they cross the process boundary without the worker needing to
import this test module (module-level test functions pickle by
reference and would fail to resolve in the worker)."""
import os
import signal
import subprocess
import sys
import threading
import time

import pytest

from repro.client import Client
from repro.client.futures import TaskFailed
from repro.core.dwork.api import Create
from repro.core.dwork.pool import run_pool
from repro.core.dwork.server import TaskServer
from repro.core.engine import Engine, TraceRecorder
from repro.core.engine.comm import (Ref, SerializationError, connect,
                                    dumps_call, listen, loads_call,
                                    transport_names)
from repro.core.engine.model import REQUEUED, WORKER_DEAD, WorkerCrash

HB = 0.1          # fast heartbeat so liveness tests stay quick


def _pid_alive(pid: int) -> bool:
    try:
        os.kill(pid, 0)
    except ProcessLookupError:
        return False
    except PermissionError:
        return True
    return True


def _wait_gone(pids, timeout=10.0) -> bool:
    deadline = time.monotonic() + timeout
    while time.monotonic() < deadline:
        if not any(_pid_alive(p) for p in pids):
            return True
        time.sleep(0.05)
    return False


# ------------------------------------------------------------- registry


def test_transport_registry_names():
    names = transport_names()
    assert set(names) >= {"inproc", "thread", "tree", "proc"}
    with pytest.raises(ValueError, match="unknown transport"):
        Engine(transport="carrier-pigeon")


def test_connect_listen_roundtrip_tcp_and_inproc():
    class Echo:
        def handle(self, msg):
            return msg

    for addr in ("tcp://127.0.0.1:0", "inproc://test-echo"):
        lst = listen(addr, Echo())
        try:
            comm = connect(lst.address)
            out = comm.request(Create(task="ping"))
            assert isinstance(out, Create) and out.task == "ping"
            comm.close()
        finally:
            lst.stop()
    with pytest.raises(ValueError, match="no scheme"):
        connect("localhost:1234")


def test_serialize_call_roundtrip_and_error_naming():
    payload = dumps_call((lambda x, y=1: x + y), (4,), {"y": 2}, task="t")
    fn, args, kwargs = loads_call(payload)
    assert fn(*args, **kwargs) == 6
    lock = threading.Lock()
    with pytest.raises(SerializationError) as ei:
        dumps_call((lambda: lock.acquire()), task="locked-up")
    assert "locked-up" in str(ei.value)
    assert Ref("a").name == "a" and "a" in repr(Ref("a"))


# ---------------------------------------------------------- proc: basics


def test_proc_batch_roundtrip_values():
    eng = Engine(transport="proc", workers=2, heartbeat_s=HB)
    for i in range(20):
        eng.submit(f"t{i}", (lambda i=i: i * i))
    rep = eng.run()
    assert not rep.stalled
    assert sorted(r.value for r in rep.results.values()) == \
        [i * i for i in range(20)]
    assert rep.workers == 2       # real parallelism, unlike inline


def test_proc_dependencies_and_failure_poisoning():
    eng = Engine(transport="proc", workers=2, heartbeat_s=HB)
    eng.submit("ok", (lambda: 3))
    eng.submit("boom", (lambda: 1 / 0))
    eng.submit("doomed", (lambda: 99), deps=("boom",))
    rep = eng.run()
    assert rep.results["ok"].value == 3
    r = rep.results["boom"]
    assert not r.ok and "ZeroDivisionError" in r.error
    assert "doomed" not in rep.results        # poisoned, never ran
    assert "doomed" in rep.errors or "boom" in rep.errors


def test_proc_submit_time_serialization_error_names_task():
    eng = Engine(transport="proc", workers=1, heartbeat_s=HB)
    lock = threading.Lock()
    try:
        with pytest.raises(SerializationError) as ei:
            eng.submit("unpicklable-task", (lambda: lock.acquire()))
        assert "unpicklable-task" in str(ei.value)
    finally:
        eng.backend.close()


def test_proc_shards_compose():
    eng = Engine(transport="proc", workers=3, shards=2, heartbeat_s=HB)
    assert eng.shards == 2
    for i in range(30):
        eng.submit(f"t{i}", (lambda i=i: i))
    rep = eng.run()
    assert sorted(r.value for r in rep.results.values()) == list(range(30))


def test_proc_run_pool_shim():
    srv = TaskServer()
    for i in range(10):
        srv.handle(Create(task=f"job{i}"))
    rep = run_pool(srv, (lambda name, meta: (True, name)), workers=2,
                   transport="proc", heartbeat_s=HB)
    assert len(rep.results) == 10
    assert all(r.value == r.task for r in rep.results.values())


# ----------------------------------------------------- crash + liveness


def test_proc_sigkill_mid_task_requeues_exactly_once():
    """A SIGKILLed worker process surfaces as a crash; its in-flight
    tasks requeue and the run finishes with zero loss and no duplicate
    terminal accounting."""
    eng = Engine(transport="proc", workers=2, resident=True,
                 heartbeat_s=HB)
    eng.start()
    assert eng.wait_workers(2, timeout=20)
    for i in range(8):
        eng.submit(f"s{i}", (lambda i=i: (time.sleep(0.2), i)[1]))
    time.sleep(0.3)                       # mid-flight
    victim = next(iter(eng.worker_pids().values()))
    os.kill(victim, signal.SIGKILL)
    assert eng.drain(timeout=60)
    rep = eng.shutdown()
    assert not rep.stalled and eng.worker_deaths == 1
    assert sorted(r.value for r in rep.results.values() if r.ok) == \
        list(range(8))
    dead = rep.trace.of(WORKER_DEAD)
    assert len(dead) == 1 and dead[0].extra.get("reason") in ("crash",
                                                              "stale")


def test_proc_worker_crash_exception_kills_real_process():
    """WorkerCrash raised in a task body hard-exits the worker process;
    with every worker dead the batch run reports a stall, not a hang."""
    eng = Engine(transport="proc", workers=2, heartbeat_s=HB)
    eng.submit("die", (lambda: (_ for _ in ()).throw(WorkerCrash("x"))))
    rep = eng.run()
    assert rep.stalled and eng.worker_deaths == 2
    assert "die" not in rep.results


def test_proc_lease_expiry_requeues_via_wire():
    """With an explicit lease_timeout shorter than a task, an idle
    worker's steal reaps the expired lease: the task re-runs and the
    wire-observed requeue is traced via='lease' — but the engine still
    counts the task exactly once."""
    eng = Engine(transport="proc", workers=2, heartbeat_s=HB,
                 lease_timeout=0.3)
    eng.submit("long", (lambda: (time.sleep(0.9), "v")[1]))
    for i in range(3):
        eng.submit(f"pad{i}", (lambda: None))
    rep = eng.run()
    assert rep.results["long"].ok and rep.results["long"].value == "v"
    rq = [e for e in rep.trace.of(REQUEUED)
          if e.extra.get("via") == "lease"]
    assert rq and sum(e.extra.get("n", 0) for e in rq) >= 1
    # exactly-once: one terminal record despite the duplicate execution
    assert len([n for n in rep.results if n == "long"]) == 1


def test_proc_futures_chain_across_kill():
    """A pending-future argument crosses as a Ref; after the producer's
    worker is killed, the dependent lands on a fresh worker and fetches
    the value from the front door."""
    # steal_n=1: the worker reports a's completion BEFORE stealing hold,
    # so a.result() returns while the worker is wedged inside hold and
    # b is still pending when the kill lands
    with Client(workers=1, transport="proc", steal_n=1,
                heartbeat_s=HB) as c:
        a = c.submit(lambda: (time.sleep(0.3), 7)[1])
        # wedge the single worker so b cannot run before the kill lands
        hold = c.submit(lambda: (time.sleep(2.0), "held")[1])
        b = c.submit((lambda x: x + 1), a)   # a pending -> Ref in payload
        assert a.result(timeout=30) == 7     # worker is now inside `hold`
        eng = c.engine
        assert eng.wait_workers(1, timeout=20)
        victim = next(iter(eng.worker_pids().values()))
        os.kill(victim, signal.SIGKILL)
        eng.add_worker()
        # b lands on the fresh worker, whose empty cache forces a Fetch
        # of a's value from the front door
        assert b.result(timeout=60) == 8
        assert hold.result(timeout=60) == "held"   # requeued, re-run
        assert eng.worker_deaths >= 1


def test_proc_announced_exit_lose_worker():
    eng = Engine(transport="proc", workers=2, resident=True,
                 heartbeat_s=HB)
    eng.start()
    assert eng.wait_workers(2, timeout=20)
    eng.lose_worker("w0")
    for i in range(6):
        eng.submit(f"t{i}", (lambda i=i: i))
    assert eng.drain(timeout=30)
    rep = eng.shutdown()
    assert sorted(r.value for r in rep.results.values()) == list(range(6))
    assert all(r.worker != "w0" for r in rep.results.values()
               if r.t_start > 0)
    assert any(e.extra.get("reason") == "lose"
               for e in rep.trace.of(WORKER_DEAD))


# -------------------------------------------------------------- client


def test_proc_client_futures_map_gather():
    with Client(workers=2, transport="proc", heartbeat_s=HB) as c:
        fs = c.map((lambda x: x + 1), range(12))
        assert c.gather(fs) == list(range(1, 13))
        a = c.submit(lambda: 10)
        b = c.submit(lambda: 32)
        s = c.submit((lambda x, y: x + y), a, b)
        assert s.result(timeout=30) == 42


def test_proc_client_failure_and_submit_time_error():
    with Client(workers=2, transport="proc", heartbeat_s=HB) as c:
        f = c.submit(lambda: [].pop())
        with pytest.raises(TaskFailed, match="IndexError"):
            f.result(timeout=30)
        lock = threading.Lock()
        with pytest.raises(SerializationError) as ei:
            c.submit((lambda: lock.acquire()), key="cant-pickle")
        assert "cant-pickle" in str(ei.value)
        # the failed submit must not leak a permanently-pending future
        ok = c.submit(lambda: "fine")
        assert ok.result(timeout=30) == "fine"


# ----------------------------------------------------- pool lifecycle


def test_proc_orphans_reaped_on_shutdown():
    eng = Engine(transport="proc", workers=2, resident=True,
                 heartbeat_s=HB)
    eng.start()
    assert eng.wait_workers(2, timeout=20)
    pids = list(eng.worker_pids().values())
    assert all(_pid_alive(p) for p in pids)
    eng.submit("t", (lambda: 1))
    assert eng.drain(timeout=30)
    eng.shutdown()
    assert _wait_gone(pids), f"worker processes survived shutdown: {pids}"


def test_proc_orphans_reaped_on_interpreter_exit():
    """A session that never reaches shutdown() must not leave worker
    processes behind: the atexit net (and the workers' own
    connection-loss self-reaping) clean up on interpreter exit."""
    code = (
        "import sys, time\n"
        "from repro.core.engine import Engine\n"
        "eng = Engine(transport='proc', workers=2, resident=True,\n"
        "             heartbeat_s=0.1)\n"
        "eng.start()\n"
        "assert eng.wait_workers(2, timeout=20)\n"
        "print(' '.join(str(p) for p in eng.worker_pids().values()))\n"
        "sys.stdout.flush()\n"
        # exit with the pool still running: no shutdown(), no close()
    )
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(os.path.dirname(os.path.dirname(
            os.path.abspath(__file__))), "src")]
        + ([env["PYTHONPATH"]] if env.get("PYTHONPATH") else []))
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=60)
    assert out.returncode == 0, out.stderr
    pids = [int(p) for p in out.stdout.split()]
    assert len(pids) == 2
    assert _wait_gone(pids), f"workers outlived the interpreter: {pids}"


# ----------------------------------------------------------- multi-host


def test_proc_multi_host_join_via_cli_worker():
    """An engine with zero local workers; a worker launched by hand (the
    multi-host path) dials the front door, joins on Hello, and drains
    the universe."""
    eng = Engine(transport="proc", workers=0, resident=True,
                 heartbeat_s=HB)
    eng.start()
    deadline = time.monotonic() + 10
    while eng.comm_address is None and time.monotonic() < deadline:
        time.sleep(0.01)
    addr = eng.comm_address
    assert addr and addr.startswith("tcp://")
    for i in range(5):
        eng.submit(f"m{i}", (lambda i=i: i * 10))
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(os.path.dirname(os.path.dirname(
            os.path.abspath(__file__))), "src")]
        + ([env["PYTHONPATH"]] if env.get("PYTHONPATH") else []))
    proc = subprocess.Popen(
        [sys.executable, "-m", "repro.core.engine.comm.worker",
         "--connect", addr], env=env,
        stdout=subprocess.DEVNULL, stderr=subprocess.DEVNULL)
    try:
        assert eng.drain(timeout=60)
        rep = eng.shutdown()
        assert sorted(r.value for r in rep.results.values()) == \
            [0, 10, 20, 30, 40]
        # engine-assigned id for an anonymous join
        assert all(r.worker.startswith("r") for r in rep.results.values())
        proc.wait(timeout=15)
        assert proc.returncode == 0       # clean protocol goodbye
    finally:
        if proc.poll() is None:
            proc.kill()


# ------------------------------------------------------------------ obs


def test_proc_rss_gauge_and_stats_pids():
    from repro.core.obs import StatsServer, instrument

    eng = Engine(transport="proc", workers=2, resident=True,
                 heartbeat_s=HB)
    eng.start()
    assert eng.wait_workers(2, timeout=20)
    for i in range(4):
        eng.submit(f"t{i}", (lambda i=i: i))
    assert eng.drain(timeout=30)
    reg = instrument(engine=eng)
    srv = StatsServer(reg, engine=eng).start()
    try:
        stats = srv.stats()
        rows = stats["workers"]
        assert all(row.get("pid") and row.get("rss_bytes", 0) > 1 << 20
                   for row in rows.values())
        rss = {k: v for k, v in stats["metrics"]["gauges"].items()
               if k.startswith("repro_worker_rss_bytes")}
        assert len(rss) == 2 and all(v > 1 << 20 for v in rss.values())
        from repro.core.obs.top import render
        view = render(stats)
        assert "PID" in view and "RSS_MB" in view
    finally:
        srv.stop()
        eng.shutdown()


def test_proc_tracer_spans_reconstructed():
    """Worker-side durations reconstruct RUN_START/RUN_END spans that
    the overhead report can pair (no negative dispatch)."""
    from repro.core.engine.model import RUN_END, RUN_START, STOLEN

    tracer = TraceRecorder()
    eng = Engine(transport="proc", workers=2, tracer=tracer,
                 heartbeat_s=HB)
    for i in range(6):
        eng.submit(f"t{i}", (lambda: time.sleep(0.02)))
    rep = eng.run()
    starts = {e.task: e.t for e in rep.trace.of(RUN_START)}
    ends = {e.task: e.t for e in rep.trace.of(RUN_END)}
    stolen = {e.task: e.t for e in rep.trace.of(STOLEN)}
    assert set(starts) == {f"t{i}" for i in range(6)}
    for t in starts:
        assert stolen[t] <= starts[t] <= ends[t]
        assert ends[t] - starts[t] >= 0.015       # worker-measured dur
    ov = rep.overhead()
    assert ov.n_tasks == 6
