"""pmake unit + property tests: template matching, graph construction, EFT
priority, file-based restart, failure poisoning (paper §2.1)."""
import tempfile
from pathlib import Path

import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.pmake import PMake, build_graph, parse_rules, parse_targets
from repro.core.pmake.rules import match_output, template_regex

RULES = """
simulate:
  resources: {time: 120, nrs: 10, cpu: 42, gpu: 6}
  inp:
    param: "{n}.param"
  out:
    trj: "{n}.trj"
  setup: echo setup-sim
  script: |
    {mpirun} echo simulate {inp[param]} > {out[trj]}
analyze:
  resources: {time: 10, nrs: 1, cpu: 1}
  inp:
    trj: "{n}.trj"
  out:
    npy: "an_{n}.npy"
  script: |
    cat {inp[trj]} > {out[npy]}
"""

TARGETS = """
sim1:
  dirname: System1
  loop:
    n: "range(1,4)"
  tgt:
    npy: "an_{n}.npy"
"""


def test_template_matching():
    r = parse_rules(RULES)["analyze"]
    assert match_output(r, "an_7.npy") == {"n": "7"}
    assert match_output(r, "an_x12.npy") == {"n": "x12"}
    assert match_output(r, "foo.trj") is None


@given(st.text(alphabet="abc_.", min_size=0, max_size=10),
       st.text(alphabet="0123456789x", min_size=1, max_size=6))
def test_template_roundtrip(prefix, var):
    t = prefix + "{n}" + ".out"
    m = template_regex(t).match(prefix + var + ".out")
    assert m is not None and m.group("n") == var


def test_graph_and_eft_priority(tmp_path):
    for n in range(1, 4):
        (tmp_path / "System1").mkdir(exist_ok=True)
        (tmp_path / "System1" / f"{n}.param").write_text("p")
    rules = parse_rules(RULES)
    targets = parse_targets(TARGETS)
    tasks = build_graph(rules, targets, root=str(tmp_path))
    assert len(tasks) == 6
    sims = [t for t in tasks.values() if t.rule.name == "simulate"]
    anas = [t for t in tasks.values() if t.rule.name == "analyze"]
    # EFT: node-hours closure — simulate = 120/60*10 + successor 10/60*1
    assert abs(sims[0].priority - (20.0 + 1 / 6)) < 1e-9
    assert abs(anas[0].priority - 1 / 6) < 1e-9
    assert all(s.priority > a.priority for s in sims for a in anas)


def test_full_run_and_restart(tmp_path):
    (tmp_path / "System1").mkdir()
    for n in range(1, 4):
        (tmp_path / "System1" / f"{n}.param").write_text(f"param{n}")
    pm = PMake(RULES, TARGETS, root=str(tmp_path), total_nodes=4)
    stats = pm.run()
    assert stats["done"] == 6 and stats["errors"] == 0
    out = (tmp_path / "System1" / "an_2.npy").read_text()
    assert "simulate 2.param" in out
    # scripts + logs materialized with the paper's naming
    assert (tmp_path / "System1" / "simulate.2.sh").exists()
    assert (tmp_path / "System1" / "simulate.2.log").exists()
    # restart: nothing to rebuild
    pm2 = PMake(RULES, TARGETS, root=str(tmp_path), total_nodes=4)
    stats2 = pm2.run()
    assert stats2["done"] == len(pm2.tasks)
    starts = [e for e in pm2.log if e["event"] == "start"]
    assert starts == []                     # file-sync: no re-execution


def test_failure_poisons_successors(tmp_path):
    rules = """
bad:
  resources: {time: 1, nrs: 1}
  out: {o: "bad.txt"}
  script: "exit 3"
after:
  resources: {time: 1, nrs: 1}
  inp: {o: "bad.txt"}
  out: {p: "after.txt"}
  script: "echo hi > after.txt"
"""
    targets = """
t:
  dirname: .
  out: {p: "after.txt"}
"""
    pm = PMake(rules, targets, root=str(tmp_path), total_nodes=1)
    stats = pm.run()
    assert stats["errors"] == 2 and stats["done"] == 0


def test_missing_rule_is_reported(tmp_path):
    targets = 'u:\n  dirname: .\n  out: {x: "nope.out"}\n'
    try:
        PMake("", targets, root=str(tmp_path))
        assert False, "expected FileNotFoundError"
    except FileNotFoundError as e:
        assert "nope.out" in str(e)


def test_node_limited_parallelism(tmp_path):
    """With 1 node, 10-node simulate still runs (clamped) but serially."""
    (tmp_path / "System1").mkdir()
    for n in range(1, 4):
        (tmp_path / "System1" / f"{n}.param").write_text("p")
    pm = PMake(RULES, TARGETS, root=str(tmp_path), total_nodes=1)
    stats = pm.run()
    assert stats["done"] == 6
