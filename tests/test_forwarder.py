"""Forwarding-tree tests (paper §4-§5): pipelined relay correctness under
concurrent clients, 2-level chaining, upstream-failure surfacing, and the
engine lifecycle suite (requeue, announced/silent death, straggler
jitter) running unchanged over `transport="tree"`."""
import threading
import time

import pytest

from repro.core.dwork import (Client, Forwarder, InProcTransport, TaskServer,
                              run_pool)
from repro.core.dwork.client import TCPServer, TCPTransport
from repro.core.engine import (COMPLETED, RPC, STOLEN, Engine, FaultPlan,
                               ManualClock)


def hub_with_tasks(n, prefix="t", lease_timeout=None, clock=None):
    srv = TaskServer(lease_timeout=lease_timeout, clock=clock)
    boss = Client(InProcTransport(srv), "boss")
    for i in range(n):
        boss.create(f"{prefix}{i}", meta={"x": i})
    return srv


def serve(srv):
    tcp = TCPServer(("127.0.0.1", 0), srv)
    tcp.serve_background()
    return tcp


# ------------------------------------------------------------- forwarder


def test_relay_correctness_single_client():
    srv = hub_with_tasks(20)
    tcp = serve(srv)
    fwd = Forwarder(("127.0.0.1", 0), tcp.server_address)
    fwd.serve_background()
    try:
        cl = Client(TCPTransport(*fwd.server_address), "w0")
        done = cl.run_loop(lambda name, meta: True, steal_n=4)
        assert done == 20
        assert srv.counters["completed"] == 20
        assert fwd.relayed > 0 and fwd.upstream_error is None
    finally:
        fwd.close()
        tcp.shutdown()


def test_relay_correctness_concurrent_clients():
    """8 workers through ONE forwarder (one shared pipelined upstream
    link): every task completes exactly once, none lost or duplicated."""
    srv = hub_with_tasks(200)
    tcp = serve(srv)
    fwd = Forwarder(("127.0.0.1", 0), tcp.server_address)
    fwd.serve_background()
    counts = {}
    lock = threading.Lock()

    def work(w):
        cl = Client(TCPTransport(*fwd.server_address), w)
        cl.run_loop(lambda name, meta: counts.__setitem__(
            name, counts.get(name, 0) + 1) or True, steal_n=2)

    try:
        threads = [threading.Thread(target=work, args=(f"w{i}",))
                   for i in range(8)]
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=30)
        assert srv.counters["completed"] == 200
        assert len(counts) == 200
        assert all(v == 1 for v in counts.values())    # exactly once
        assert fwd.upstream_error is None
    finally:
        fwd.close()
        tcp.shutdown()


def test_two_level_chaining():
    """worker -> leaf forwarder -> mid forwarder -> hub."""
    srv = hub_with_tasks(30)
    tcp = serve(srv)
    mid = Forwarder(("127.0.0.1", 0), tcp.server_address)
    mid.serve_background()
    leaf = Forwarder(("127.0.0.1", 0), mid.server_address)
    leaf.serve_background()
    try:
        cl = Client(TCPTransport(*leaf.server_address), "w0")
        done = cl.run_loop(lambda name, meta: True, steal_n=4)
        assert done == 30 and srv.counters["completed"] == 30
        assert leaf.relayed > 0 and mid.relayed > 0
    finally:
        leaf.close()
        mid.close()
        tcp.shutdown()


def test_upstream_failure_surfaced_not_swallowed():
    """A hub that dies mid-conversation must close the downstream side
    and record the error on the forwarder — not hang or pass silently."""
    import socket

    lst = socket.socket()
    lst.bind(("127.0.0.1", 0))
    lst.listen(1)

    def doomed_hub():
        conn, _ = lst.accept()
        conn.recv(4)                                 # read part of a frame
        conn.close()                                 # ... then die on it

    th = threading.Thread(target=doomed_hub, daemon=True)
    th.start()
    fwd = Forwarder(("127.0.0.1", 0), lst.getsockname())
    fwd.serve_background()
    try:
        cl = Client(TCPTransport(*fwd.server_address), "w0")
        with pytest.raises((ConnectionError, OSError)):
            cl.steal(n=1)
        deadline = time.time() + 5
        while fwd.upstream_error is None and time.time() < deadline:
            time.sleep(0.01)
        assert fwd.upstream_error is not None        # surfaced
    finally:
        fwd.close()
        lst.close()


def test_abrupt_downstream_disconnect_keeps_serving():
    """A client vanishing mid-stream must not wedge the shared upstream
    link for the other clients."""
    srv = hub_with_tasks(40)
    tcp = serve(srv)
    fwd = Forwarder(("127.0.0.1", 0), tcp.server_address)
    fwd.serve_background()
    try:
        rude = TCPTransport(*fwd.server_address)
        Client(rude, "rude").steal(n=1)
        rude.sock.close()                            # abrupt, no goodbye
        cl = Client(TCPTransport(*fwd.server_address), "w0")
        done = cl.run_loop(lambda name, meta: True, steal_n=4)
        assert done >= 39                            # rude's steal is lost
        assert fwd.upstream_error is None            # link still healthy
    finally:
        fwd.close()
        tcp.shutdown()


# ------------------------------------------- engine over transport="tree"


def test_tree_transport_dag_execution():
    eng = Engine(workers=2, transport="tree", steal_n=2)
    eng.submit("a", fn=lambda: 1)
    eng.submit("b", fn=lambda: 2, deps=["a"])
    eng.submit("c", fn=lambda: 3, deps=["a", "b"])
    rep = eng.run()
    assert rep.completed == {"a", "b", "c"} and not rep.stalled
    assert rep.results["c"].value == 3


def test_tree_hop_events_attributed_not_double_counted():
    eng = Engine(workers=4, transport="tree", steal_n=4, tree_fanout=2,
                 tree_levels=2)
    for i in range(60):
        eng.submit(f"t{i}", fn=lambda: None)
    rep = eng.run()
    assert len(rep.completed) == 60
    assert rep.backend_stats["tree"]["forwarders"] == [1, 2]
    ov = rep.overhead()
    assert "hop:L1" in ov.rpc_by_op and "hop:L2" in ov.rpc_by_op
    # hops are attribution-only: excluded from the end-to-end rpc totals
    hop_n = sum(c for op, (c, _t) in ov.rpc_by_op.items()
                if op.startswith("hop:"))
    total_n = sum(c for c, _t in ov.rpc_by_op.values())
    assert ov.n_rpc == total_n - hop_n
    # every worker round-trip crossed both levels
    lvl = rep.backend_stats["tree"]["relayed"]
    assert lvl[0] == lvl[1] > 0


def test_tree_announced_death_zero_lost_tasks():
    """Worker death behind a forwarder: Exit recycles its assignment at
    the hub and the survivors finish everything (zero lost tasks)."""
    faults = FaultPlan(seed=7).kill_worker("w1", after_steals=4)
    eng = Engine(workers=3, transport="tree", steal_n=4, faults=faults)
    for i in range(120):
        eng.submit(f"t{i}", fn=lambda: None)
    rep = eng.run()
    assert not rep.stalled
    assert len(rep.completed) == 120                 # zero lost tasks
    assert rep.overhead().n_requeued >= 1
    assert rep.backend_stats["completed"] == 120
    assert rep.backend_stats["assigned"] == 0


def test_tree_silent_death_recovered_by_lease():
    clk = ManualClock(tick=1e-3)
    faults = FaultPlan(seed=3).kill_worker("w1", after_steals=2, silent=True)
    eng = Engine(workers=2, transport="tree", steal_n=2, clock=clk,
                 lease_timeout=0.05, faults=faults)
    for i in range(20):
        eng.submit(f"x{i}", fn=lambda: None)
    rep = eng.run()
    assert len(rep.completed) == 20 and not rep.stalled
    assert rep.overhead().n_requeued >= 1


def test_tree_straggler_jitter_recorded():
    faults = FaultPlan(seed=11).stragglers(1e-3)
    eng = Engine(workers=2, transport="tree", steal_n=2, faults=faults)
    for i in range(16):
        eng.submit(f"j{i}", fn=lambda: None)
    rep = eng.run()
    assert len(rep.completed) == 16
    assert rep.overhead().virtual_s != 0.0           # jitter traced


def test_run_pool_tree_matches_inproc_results():
    srv = hub_with_tasks(50)
    rep = run_pool(srv, lambda name, meta: (True, meta["x"] * 2),
                   workers=4, steal_n=4, transport="tree", tree_fanout=2)
    assert len(rep.completed) == 50 and not rep.stalled
    assert all(rep.results[f"t{i}"].value == 2 * i for i in range(50))
    assert rep.backend_stats["tree"]["relayed"][0] > 0
    # regression: the default-tracer path must still attribute hops
    # (the Forwarders capture the tracer at construction time)
    assert any(op.startswith("hop:")
               for op in rep.overhead().rpc_by_op), rep.overhead().rpc_by_op


def test_tree_backend_built_without_tracer_still_attributes_hops():
    """A TreeBackend constructed bare and handed to Engine gets the
    engine's tracer patched in AFTER the forwarders were built — the
    assignment must propagate down or hop events silently vanish."""
    from repro.core.engine import TreeBackend
    backend = TreeBackend(workers=2, fanout=2)
    eng = Engine(workers=2, transport="tree", steal_n=2, backend=backend)
    for i in range(20):
        eng.submit(f"t{i}", fn=lambda: None)
    try:
        rep = eng.run()
    finally:
        backend.close()                       # engine doesn't own it
    assert len(rep.completed) == 20
    assert any(op.startswith("hop:") for op in rep.overhead().rpc_by_op)


def test_tree_trace_counts_conserved():
    eng = Engine(workers=2, transport="tree", steal_n=2)
    for i in range(40):
        eng.submit(f"t{i}", fn=lambda: None)
    rep = eng.run()
    tr = rep.trace
    assert tr.count(COMPLETED) == 40
    assert tr.count(STOLEN) >= 40
    assert tr.count(RPC) > 0
