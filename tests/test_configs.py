import jax
import pytest

from repro.configs import (ARCH_IDS, REGISTRY, SHAPES, applicable_shapes,
                           get_config, input_specs)

ASSIGNED = {
    "qwen2.5-32b": dict(n_layers=64, d_model=5120, n_heads=40, n_kv_heads=8,
                        d_ff=27648, vocab_size=152064),
    "deepseek-67b": dict(n_layers=95, d_model=8192, n_heads=64, n_kv_heads=8,
                         d_ff=22016, vocab_size=102400),
    "gemma2-2b": dict(n_layers=26, d_model=2304, n_heads=8, n_kv_heads=4,
                      d_ff=9216, vocab_size=256000),
    "deepseek-7b": dict(n_layers=30, d_model=4096, n_heads=32, n_kv_heads=32,
                        d_ff=11008, vocab_size=102400),
    "zamba2-2.7b": dict(n_layers=54, d_model=2560, n_heads=32, n_kv_heads=32,
                        d_ff=10240, vocab_size=32000),
    "whisper-base": dict(n_layers=6, d_model=512, n_heads=8, n_kv_heads=8,
                         d_ff=2048, vocab_size=51865),
    "qwen2-vl-2b": dict(n_layers=28, d_model=1536, n_heads=12, n_kv_heads=2,
                        d_ff=8960, vocab_size=151936),
    "rwkv6-1.6b": dict(n_layers=24, d_model=2048, d_ff=7168,
                       vocab_size=65536),
    "deepseek-v2-lite-16b": dict(n_layers=27, d_model=2048, n_heads=16,
                                 d_ff=1408, vocab_size=102400),
    "arctic-480b": dict(n_layers=35, d_model=7168, n_heads=56, n_kv_heads=8,
                        d_ff=4864, vocab_size=32000),
}


def test_all_archs_registered():
    assert set(ARCH_IDS) == set(ASSIGNED)


@pytest.mark.parametrize("name", list(ASSIGNED))
def test_exact_assigned_numbers(name):
    cfg = get_config(name)
    for field, value in ASSIGNED[name].items():
        assert getattr(cfg, field) == value, (name, field)


def test_family_specifics():
    assert get_config("qwen2.5-32b").qkv_bias
    g = get_config("gemma2-2b")
    assert g.attn_logit_softcap == 50.0 and g.final_logit_softcap == 30.0
    assert g.sliding_window == 4096 and g.local_global_every == 2
    z = get_config("zamba2-2.7b")
    assert z.ssm.state_dim == 64 and z.attn_every == 6
    m = get_config("deepseek-v2-lite-16b")
    assert m.mla.kv_lora_rank == 512 and m.moe.top_k == 6
    assert m.moe.n_shared_experts == 2
    a = get_config("arctic-480b")
    assert a.moe.n_experts == 128 and a.moe.top_k == 2 and a.moe.dense_residual
    assert get_config("qwen2-vl-2b").mrope
    assert get_config("rwkv6-1.6b").rwkv is not None
    assert get_config("whisper-base").encoder.n_frames == 1500


def test_shapes_table():
    assert SHAPES["train_4k"].seq_len == 4096
    assert SHAPES["train_4k"].global_batch == 256
    assert SHAPES["prefill_32k"].global_batch == 32
    assert SHAPES["decode_32k"].global_batch == 128
    assert SHAPES["long_500k"].seq_len == 524288


def test_long_500k_applicability():
    runs = {n for n in ARCH_IDS
            if applicable_shapes(get_config(n))["long_500k"] == "run"}
    assert runs == {"zamba2-2.7b", "rwkv6-1.6b"}


@pytest.mark.parametrize("name", list(ASSIGNED))
@pytest.mark.parametrize("shape", list(SHAPES))
def test_input_specs_are_abstract(name, shape):
    cfg = get_config(name)
    specs = input_specs(cfg, SHAPES[shape])
    assert all(isinstance(v, jax.ShapeDtypeStruct) for v in specs.values())
    if SHAPES[shape].mode == "decode":
        assert specs["tokens"].shape == (SHAPES[shape].global_batch,)
    else:
        assert specs["tokens"].shape == (SHAPES[shape].global_batch,
                                         SHAPES[shape].seq_len)


@pytest.mark.parametrize("name", list(ASSIGNED))
def test_reduced_is_small_same_family(name):
    cfg = get_config(name)
    r = cfg.reduced()
    assert r.family == cfg.family
    assert r.d_model <= 256 and r.vocab_size <= 1024
    assert (r.moe is None) == (cfg.moe is None)
    assert (r.mla is None) == (cfg.mla is None)
