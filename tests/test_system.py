"""End-to-end behaviour tests for the paper's system: the three schedulers
driving real framework work (training steps, campaign files), and the
checkpoint/restart path."""
import json
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest


def test_dwork_drives_training_steps(tmp_path):
    """dwork as the work-distribution layer: training steps are tasks; a
    crashing worker's steps are re-executed by the survivor; the final
    model state matches an uninterrupted run (determinism via per-step
    data/seed in task metadata)."""
    from repro.configs import RunConfig, get_config
    from repro.core.dwork import Client, InProcTransport, TaskServer
    from repro.core.dwork.api import ExitResp, NotFound, TaskMsg
    from repro.models.common import Options
    from repro.models.model import build_model
    from repro.optim.adamw import init_opt
    from repro.runtime.train_step import make_train_step

    cfg = get_config("deepseek-7b").reduced()
    model = build_model(cfg, Options(q_block=32, kv_block=32))
    rc = RunConfig(total_steps=8, warmup_steps=1)
    step_fn = jax.jit(make_train_step(model, rc))

    def run_with_dwork(crash: bool):
        params = model.init(jax.random.PRNGKey(0))
        opt = init_opt(params, rc)
        srv = TaskServer()
        driver = Client(InProcTransport(srv), "driver")
        # sequential chain: step i depends on step i-1
        for i in range(6):
            driver.create(f"step{i}", deps=[f"step{i-1}"] if i else [])
        state = {"params": params, "opt": opt}

        def execute(worker, fail_at=None):
            cl = Client(InProcTransport(srv), worker)
            n = 0
            while True:
                r = cl.steal()
                if isinstance(r, ExitResp):
                    return
                if isinstance(r, NotFound):
                    return
                for name, _ in r.tasks:
                    if fail_at is not None and n >= fail_at:
                        cl.exit()          # crash before completing
                        return
                    i = int(name[4:])
                    key = jax.random.PRNGKey(100 + i)
                    batch = {"tokens": jax.random.randint(
                        key, (2, 32), 0, cfg.vocab_size)}
                    batch["labels"] = jnp.roll(batch["tokens"], -1, 1)
                    state["params"], state["opt"], _ = step_fn(
                        state["params"], state["opt"], batch)
                    cl.complete(name)
                    n += 1

        if crash:
            execute("w0", fail_at=2)       # dies holding step2
            execute("w1")                  # survivor finishes
        else:
            execute("w0")
        assert srv.stats()["completed"] == 6
        return state["params"]

    p_clean = run_with_dwork(crash=False)
    p_crash = run_with_dwork(crash=True)
    for a, b in zip(jax.tree_util.tree_leaves(p_clean),
                    jax.tree_util.tree_leaves(p_crash)):
        assert float(jnp.max(jnp.abs(a - b))) < 1e-6


def test_pmake_campaign_files(tmp_path):
    """pmake end-to-end with the paper's script/log file conventions."""
    rules = """
gen:
  resources: {time: 1, nrs: 1}
  out: {d: "data_{n}.txt"}
  script: "echo payload-{n} > data_{n}.txt"
sum:
  resources: {time: 1, nrs: 1}
  inp: {a: "data_1.txt", b: "data_2.txt"}
  out: {s: "summary.txt"}
  script: "cat data_1.txt data_2.txt > summary.txt"
"""
    targets = 't:\n  dirname: .\n  out: {s: "summary.txt"}\n'
    from repro.core.pmake import PMake
    pm = PMake(rules, targets, root=str(tmp_path), total_nodes=2)
    stats = pm.run()
    assert stats["done"] == 3 and stats["errors"] == 0
    assert (tmp_path / "summary.txt").read_text() == \
        "payload-1\npayload-2\n"


def test_mpilist_is_the_data_pipeline():
    """The training pipeline is an mpi-list program: verify its batches
    flow through a real train step without NaNs."""
    from repro.configs import RunConfig, get_config
    from repro.data.pipeline import Pipeline
    from repro.models.common import Options
    from repro.models.model import build_model
    from repro.optim.adamw import init_opt
    from repro.runtime.train_step import make_train_step

    cfg = get_config("rwkv6-1.6b").reduced()
    model = build_model(cfg, Options(q_block=32, kv_block=32))
    rc = RunConfig(total_steps=3, warmup_steps=1)
    params = model.init(jax.random.PRNGKey(0))
    opt = init_opt(params, rc)
    step = jax.jit(make_train_step(model, rc))
    pipe = Pipeline(cfg.vocab_size, 32, 2, seed=1, n_ranks=3)
    for batch in pipe.batches(3):
        params, opt, m = step(params, opt,
                              {k: jnp.asarray(v) for k, v in batch.items()})
        assert np.isfinite(float(m["loss"]))


def test_train_checkpoint_restart_bitexact(tmp_path):
    """Crash/restart via the checkpoint layer reproduces the uninterrupted
    optimizer trajectory (same data => identical params)."""
    from repro.checkpoint import ckpt
    from repro.configs import RunConfig, get_config
    from repro.models.common import Options
    from repro.models.model import build_model
    from repro.optim.adamw import init_opt
    from repro.runtime.train_step import make_train_step

    cfg = get_config("gemma2-2b").reduced()
    model = build_model(cfg, Options(q_block=32, kv_block=32))
    rc = RunConfig(total_steps=6, warmup_steps=1)
    step = jax.jit(make_train_step(model, rc))

    def batch_for(i):
        key = jax.random.PRNGKey(500 + i)
        b = {"tokens": jax.random.randint(key, (2, 32), 0, cfg.vocab_size)}
        b["labels"] = jnp.roll(b["tokens"], -1, 1)
        return b

    params = model.init(jax.random.PRNGKey(0))
    opt = init_opt(params, rc)
    for i in range(4):
        params, opt, _ = step(params, opt, batch_for(i))
    ref = params

    params = model.init(jax.random.PRNGKey(0))
    opt = init_opt(params, rc)
    for i in range(2):
        params, opt, _ = step(params, opt, batch_for(i))
    ckpt.save(str(tmp_path), 2, {"p": params, "o": opt})
    # "crash"; restart from disk
    abs_tree = jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
        {"p": params, "o": opt})
    tree = ckpt.restore(str(tmp_path), 2, abs_tree)
    params, opt = tree["p"], tree["o"]
    for i in range(2, 4):
        params, opt, _ = step(params, opt, batch_for(i))
    for a, b in zip(jax.tree_util.tree_leaves(ref),
                    jax.tree_util.tree_leaves(params)):
        assert float(jnp.max(jnp.abs(a - b))) == 0.0
