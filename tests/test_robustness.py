"""Durable control plane tests: write-ahead journal round-trips, crash
recovery via `Engine.recover` (across transports and sharding), retry
policies (transient recovery, exhaustion poisoning, backoff
determinism), and the serving frontend's per-request queue deadline."""
import json
import os

import pytest

from repro.client import Client
from repro.core.engine import (COMPLETED, FAILED, REQ_TIMEOUT, RETRIED,
                               Engine, FaultPlan, Journal, RetryPolicy)
from repro.core.serving import Frontend

# ---------------------------------------------------------------- journal


def test_journal_round_trip(tmp_path):
    j = Journal(tmp_path, sync_every=1)
    j.append_create("a", (), {"k": 1})
    j.append_create("b", ("a",), {})
    j.append_create("c", ("b",), {})
    j.append_terminal("a", True)
    j.append_terminal("b", False, "boom")
    j.append_cancel("c")
    j.append_requeue(2, "exit")
    j.close()
    st = Journal.replay(tmp_path)
    assert st.created["a"] == ((), {"k": 1})
    assert st.created["b"] == (("a",), {})
    assert st.completed == {"a"}
    assert st.failed == {"b": "boom"}
    assert st.cancelled == {"c"}
    assert st.requeues == 2
    assert st.terminal() == {"a", "b", "c"}
    assert st.pending() == []


def test_journal_appends_are_name_deduplicated(tmp_path):
    j = Journal(tmp_path, sync_every=1)
    j.append_create("a", (), {})
    j.append_terminal("a", True)
    before = j.bytes_written
    # duplicate create, a second terminal, and a cancel-after-terminal
    # must all write nothing (exactly-once terminal, idempotent replay)
    j.append_create("a", ("x",), {"other": 1})
    j.append_terminal("a", False, "late duplicate")
    j.append_cancel("a")
    assert j.bytes_written == before
    j.close()
    st = Journal.replay(tmp_path)
    assert st.completed == {"a"} and not st.failed and not st.cancelled


def test_journal_tolerates_torn_tail(tmp_path):
    j = Journal(tmp_path, sync_every=1)
    j.append_create("a", (), {})
    j.append_create("b", (), {})
    j.append_terminal("a", True)
    j.close()
    seg = sorted(tmp_path.glob("wal-*.jsonl"))[-1]
    with open(seg, "a", encoding="utf-8") as fh:
        fh.write('["ok","b"')          # mid-write crash: no newline, torn
    st = Journal.replay(tmp_path)
    assert st.torn_lines == 1
    assert st.completed == {"a"}       # the torn record never happened
    assert [n for n, _, _ in st.pending()] == ["b"]


def test_journal_checkpoint_compacts_and_rotates(tmp_path):
    j = Journal(tmp_path, sync_every=1)
    for i in range(10):
        j.append_create(f"t{i}", (), {})
    for i in range(8):
        j.append_terminal(f"t{i}", True)
    old_segs = set(tmp_path.glob("wal-*.jsonl"))
    j.checkpoint()
    assert (tmp_path / "checkpoint.json").exists()
    live_segs = set(tmp_path.glob("wal-*.jsonl"))
    assert not (old_segs & live_segs)          # superseded segments gone
    doc = json.loads((tmp_path / "checkpoint.json").read_text())
    # compaction: only non-terminal creates survive in the checkpoint
    assert sorted(n for n, _, _ in doc["created"]) == ["t8", "t9"]
    j.append_terminal("t8", True)              # appends continue post-rotate
    j.close()
    st = Journal.replay(tmp_path)
    assert len(st.completed) == 9
    assert [n for n, _, _ in st.pending()] == ["t9"]


def test_journal_auto_checkpoint_threshold(tmp_path):
    j = Journal(tmp_path, sync_every=1, checkpoint_every=5)
    for i in range(6):
        j.append_create(f"t{i}", (), {})
    assert j.n_checkpoints >= 1
    j.close()
    st = Journal.replay(tmp_path)
    assert len(st.created) == 6


# ------------------------------------------------------- engine journaling


def test_engine_journals_a_batch_run(tmp_path):
    jdir = tmp_path / "j"
    eng = Engine(workers=2, transport="inproc", journal=str(jdir))
    eng.submit("a", fn=lambda: 1)
    eng.submit("b", fn=lambda: 2, deps=["a"])
    rep = eng.run()
    assert rep.completed == {"a", "b"}
    st = Journal.replay(jdir)
    assert st.completed == {"a", "b"} and not st.pending()


def test_engine_journals_failure_and_poison(tmp_path):
    def execute(name, meta):
        if name == "bad":
            raise ValueError("boom")
        return True

    eng = Engine(workers=1, transport="inproc", journal=str(tmp_path))
    eng.submit("bad")
    eng.submit("child", deps=["bad"])
    eng.run(execute)
    st = Journal.replay(tmp_path)
    assert set(st.failed) == {"bad", "child"}
    assert "boom" in st.failed["bad"]
    assert "bad" in st.failed["child"]   # poison records name the culprit


def test_resident_drain_makes_journal_durable(tmp_path):
    eng = Engine(workers=2, transport="thread", resident=True,
                 journal=str(tmp_path), on_result=lambda *a: None)
    eng.start()
    for i in range(20):
        eng.submit(f"t{i}", fn=lambda i=i: i)
    assert eng.drain(10.0)
    # drained => durable: replay BEFORE shutdown already sees everything
    st = Journal.replay(tmp_path)
    assert len(st.completed) == 20
    eng.shutdown()


# --------------------------------------------------------------- recovery

RECOVERY_MATRIX = [("inproc", 1), ("thread", 1), ("tree", 1), ("tree", 2)]


@pytest.mark.parametrize("transport,shards", RECOVERY_MATRIX)
def test_recover_completes_a_crashed_run(tmp_path, transport, shards):
    """Phase 1 crashes mid-DAG (every worker dies -> stall); recovery
    re-runs exactly the unfinished tasks and completes the workload with
    zero loss and zero double-completions."""
    n = 24
    jdir = str(tmp_path / "j")
    phase1: list = []
    phase2: list = []

    def make_execute(sink):
        def execute(name, meta):
            sink.append(name)
            return True
        return execute

    faults = (FaultPlan(seed=2).kill_worker("w0", after_steals=3)
              .kill_worker("w1", after_steals=3))
    eng = Engine(workers=2, transport=transport, shards=shards,
                 journal=jdir, faults=faults, max_idle_rounds=50)
    for i in range(n):
        deps = [f"t{i-1}"] if i % 4 else []      # chains of 4, 6 roots
        eng.submit(f"t{i}", deps=deps, meta={"i": i})
    rep1 = eng.run(make_execute(phase1))
    assert rep1.stalled                          # the simulated crash
    done1 = set(rep1.completed)
    assert 0 < len(done1) < n                    # genuinely mid-DAG

    st = Journal.replay(jdir)
    assert st.completed == done1                 # journal saw every terminal
    assert len(st.pending()) == n - len(done1)

    eng2 = Engine.recover(jdir, workers=2, transport=transport,
                          shards=shards)
    rep2 = eng2.run(make_execute(phase2))
    assert not rep2.stalled
    # zero loss, zero double-completion
    assert set(phase2) == {f"t{i}" for i in range(n)} - done1
    assert not (set(phase2) & set(phase1))
    st2 = Journal.replay(jdir)
    assert len(st2.completed) == n and not st2.pending()


def test_recover_preserves_exactly_once_on_result(tmp_path):
    """A recovered resident session: `on_result` fires once per pending
    task and NEVER for tasks that completed before the crash."""
    jdir = str(tmp_path)
    j = Journal(jdir, sync_every=1)
    j.append_create("a", (), {})
    j.append_create("b", ("a",), {})
    j.append_create("c", ("b",), {})
    j.append_terminal("a", True)
    j.close()
    fired: list = []
    eng = Engine.recover(jdir, workers=2, transport="thread", resident=True,
                         on_result=lambda name, ok, res, err:
                         fired.append((name, ok)))
    eng.start(lambda name, meta: True)
    assert eng.drain(10.0)
    eng.shutdown()
    assert sorted(fired) == [("b", True), ("c", True)]


def test_recover_poisons_pending_task_with_failed_dep(tmp_path):
    jdir = str(tmp_path)
    j = Journal(jdir, sync_every=1)
    j.append_create("bad", (), {})
    j.append_create("child", ("bad",), {})
    j.append_create("ok", (), {})
    j.append_terminal("bad", False, "died before the crash")
    j.close()
    ran: list = []
    eng = Engine.recover(jdir, workers=1, transport="inproc")
    rep = eng.run(lambda name, meta: ran.append(name) or True)
    assert ran == ["ok"]                 # the poisoned child never runs
    assert rep.completed == {"ok"}
    st = Journal.replay(jdir)
    assert "child" in st.failed and "dependency bad failed" in \
        st.failed["child"]


def test_recovered_engine_is_itself_recoverable(tmp_path):
    """Appends continue in the same directory: crash the recovery run and
    recover again."""
    jdir = str(tmp_path)
    j = Journal(jdir, sync_every=1)
    for i in range(8):
        j.append_create(f"t{i}", (), {})
    j.append_terminal("t0", True)
    j.close()
    faults = FaultPlan(seed=1).kill_worker("w0", after_steals=2)
    eng = Engine.recover(jdir, workers=1, transport="inproc", faults=faults,
                         max_idle_rounds=50)
    rep = eng.run(lambda name, meta: True)
    assert rep.stalled
    eng2 = Engine.recover(jdir, workers=2, transport="inproc")
    rep2 = eng2.run(lambda name, meta: True)
    assert not rep2.stalled
    st = Journal.replay(jdir)
    assert len(st.completed) == 8 and not st.pending()


# ----------------------------------------------------------------- retry


@pytest.mark.parametrize("transport", ["inproc", "thread"])
def test_transient_failures_recover_within_budget(tmp_path, transport):
    faults = FaultPlan(seed=3).fail_first_k(2)
    eng = Engine(workers=2, transport=transport, faults=faults,
                 retry=RetryPolicy(max_attempts=3, backoff=0.0))
    for i in range(8):
        eng.submit(f"t{i}", fn=lambda i=i: i)
    rep = eng.run()
    assert len(rep.completed) == 8 and not rep.stalled
    assert eng.retries_total == 16               # 2 transient fails per task
    retried = [e for e in rep.trace.of(RETRIED)]
    assert len(retried) == 16
    assert {e.extra["attempt"] for e in retried} == {1, 2}
    assert rep.overhead().n_retried == 16


def test_retry_exhaustion_poisons_dependents():
    faults = FaultPlan().fail_first_k(5)         # outlives the budget
    eng = Engine(workers=1, transport="inproc", faults=faults,
                 retry=RetryPolicy(max_attempts=2))
    eng.submit("x", fn=lambda: 1)
    eng.submit("child", fn=lambda: 2, deps=["x"])
    rep = eng.run()
    assert not rep.results["x"].ok
    assert "child" in rep.errors                 # poisoned, never ran
    assert eng.retries_total == 1                # attempts 1->2, then fail
    assert rep.trace.count(FAILED) == 1          # x; child poisons serverside


def test_per_task_retry_overrides_engine_default():
    faults = FaultPlan().fail_first_k(1, tasks=["flaky", "doomed"])
    eng = Engine(workers=1, transport="inproc", faults=faults)  # no default
    eng.submit("flaky", fn=lambda: "v",
               retry=RetryPolicy(max_attempts=3))
    eng.submit("doomed", fn=lambda: "w")         # no policy: fails at once
    rep = eng.run()
    assert rep.results["flaky"].ok
    assert not rep.results["doomed"].ok
    assert eng.retries_total == 1


def test_retry_on_filters_error_classes():
    def execute(name, meta):
        raise ValueError("permanent config error")

    eng = Engine(workers=1, transport="inproc",
                 retry=RetryPolicy(max_attempts=5,
                                   retry_on=("TimeoutError", "ConnectionError")))
    eng.submit("t")
    rep = eng.run(execute)
    assert not rep.results["t"].ok
    assert eng.retries_total == 0                # non-matching: no retry


def test_backoff_is_a_seeded_pure_function():
    pol = RetryPolicy(max_attempts=4, backoff=0.1, jitter=0.5, seed=7)
    d1 = [pol.delay_s("task-a", k) for k in (1, 2, 3)]
    d2 = [pol.delay_s("task-a", k) for k in (1, 2, 3)]
    assert d1 == d2                              # deterministic
    assert d1[0] < d1[1] < d1[2]                 # exponential growth
    assert all(0.1 * 2 ** (k - 1) <= d <= 0.1 * 2 ** (k - 1) * 1.5
               for k, d in zip((1, 2, 3), d1))
    assert pol.delay_s("task-b", 1) != d1[0]     # keyed per task


def test_backoff_delay_is_honoured_without_stalling():
    faults = FaultPlan(seed=9).fail_first_k(1)
    eng = Engine(workers=2, transport="thread", faults=faults,
                 retry=RetryPolicy(max_attempts=2, backoff=0.02,
                                   jitter=0.0, seed=1))
    for i in range(4):
        eng.submit(f"t{i}", fn=lambda i=i: i)
    rep = eng.run()
    assert len(rep.completed) == 4 and not rep.stalled
    assert rep.wall_s >= 0.02                    # the backoff really waited


def test_worker_crash_is_never_retried():
    """WorkerCrash requeues via Exit (n_requeued), not via RetryPolicy."""
    from repro.core.engine import WorkerCrash

    hits: dict = {}

    def execute(name, meta):
        if name == "t0" and not hits.get("t0"):
            hits["t0"] = 1
            raise WorkerCrash("die")
        return True

    eng = Engine(workers=2, transport="inproc",
                 retry=RetryPolicy(max_attempts=5))
    for i in range(6):
        eng.submit(f"t{i}")
    rep = eng.run(execute)
    assert len(rep.completed) == 6
    assert eng.retries_total == 0
    assert rep.overhead().n_requeued >= 1


# ------------------------------------------------------------ client layer


def test_client_retry_and_journal_dir(tmp_path):
    jdir = str(tmp_path / "wal")
    attempts: dict = {}

    def flaky(x):
        attempts[x] = attempts.get(x, 0) + 1
        if attempts[x] == 1:
            raise ConnectionError("transient")
        return x * 10

    with Client(workers=2, transport="thread", journal_dir=jdir,
                retry=RetryPolicy(max_attempts=3, backoff=0.0)) as c:
        futs = [c.submit(flaky, i) for i in range(5)]
        assert c.gather(futs) == [0, 10, 20, 30, 40]
        assert c.engine.retries_total == 5
    st = Journal.replay(jdir)
    assert len(st.completed) == 5 and not st.pending()


def test_client_per_submit_retry_exhaustion_raises():
    def always(x):
        raise ConnectionError("still down")

    with Client(workers=1, transport="inproc") as c:
        f = c.submit(always, 1, retry=RetryPolicy(max_attempts=2,
                                                  backoff=0.0))
        # the original in-process exception is delivered, post-exhaustion
        with pytest.raises(ConnectionError):
            f.result(timeout=10.0)
        assert c.engine.retries_total == 1


# -------------------------------------------------------- frontend deadline


def test_frontend_queue_deadline_times_out():
    eng = Engine(workers=2, transport="thread", resident=True)
    eng.start()
    # huge batch target + long max_wait: queued requests sit until flushed
    fe = Frontend(eng, lambda ps: [p * 2 for p in ps], max_batch=64,
                  max_wait_s=5.0, per_request_s0=1e-6)
    fe.start()
    try:
        doomed = fe.submit(1, timeout=0.05)
        kept = fe.submit(2)                       # no deadline
        assert doomed.wait(5.0)
        assert doomed.timed_out and not doomed.ok
        assert "TimeoutError" in doomed.error
        assert not kept.done
        fe.flush()
        assert kept.wait(5.0) and kept.ok and kept.value == 4
        assert fe.stats()["timeouts"] == 1
        assert fe.engine.tracer.count(REQ_TIMEOUT) == 1
        # the timed-out request never reached a batch
        assert fe.accepted == 2
    finally:
        fe.close()
        eng.shutdown()


def test_frontend_dispatched_requests_ignore_deadline():
    eng = Engine(workers=2, transport="thread", resident=True)
    eng.start()
    fe = Frontend(eng, lambda ps: [p + 1 for p in ps],
                  max_batch=1, max_wait_s=0.001)  # dispatch immediately
    fe.start()
    try:
        r = fe.submit(41, timeout=30.0)
        assert r.wait(5.0) and r.ok and r.value == 42
        assert not r.timed_out and fe.stats()["timeouts"] == 0
    finally:
        fe.close()
        eng.shutdown()
