"""dwork semantics + property tests: dependency safety, exactly-once,
failure poisoning, crash recovery, deque order, persistence (paper §2.2)."""
import tempfile

import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.dwork import Client, InProcTransport, TaskServer
from repro.core.dwork.api import ExitResp, NotFound, TaskMsg


def mkclient(srv=None, worker="w0"):
    srv = srv or TaskServer()
    return srv, Client(InProcTransport(srv), worker)


def drain(cl, execute=lambda n, m: True, steal_n=1):
    order = []
    while True:
        r = cl.steal(n=steal_n)
        if isinstance(r, ExitResp):
            return order
        if isinstance(r, NotFound):
            return order
        for name, meta in r.tasks:
            order.append(name)
            cl.complete(name, ok=execute(name, meta))


# ---------------------------------------------------------------- unit


def test_fifo_order_without_deps():
    srv, cl = mkclient()
    for i in range(5):
        cl.create(f"t{i}")
    assert drain(cl) == [f"t{i}" for i in range(5)]


def test_transfer_goes_to_front():
    srv, cl = mkclient()
    cl.create("a")
    cl.create("b")
    r = cl.steal()
    assert r.tasks[0][0] == "a"
    cl.transfer("a", new_deps=[])          # re-insert at the FRONT
    assert cl.steal().tasks[0][0] == "a"


def test_transfer_with_new_deps():
    srv, cl = mkclient()
    cl.create("a")
    assert cl.steal().tasks[0][0] == "a"
    cl.transfer("a", new_deps=["pre"])     # forward-declares "pre"
    r = cl.steal()
    assert r.tasks[0][0] == "pre"
    cl.complete("pre")
    assert cl.steal().tasks[0][0] == "a"


def test_failure_poisons_transitive_successors():
    srv, cl = mkclient()
    cl.create("a")
    cl.create("b", deps=["a"])
    cl.create("c", deps=["b"])
    cl.create("x")
    cl.steal()
    cl.complete("a", ok=False)
    assert drain(cl) == ["x"]
    assert srv.errors == {"a", "b", "c"}


def test_transfer_cycle_deadlocks_not_crashes():
    """Paper: a Transfer adding a dependency on one's own successor is a
    user-error that deadlocks (never ready) — the server must not crash."""
    srv, cl = mkclient()
    cl.create("a")
    cl.create("b", deps=["a"])
    assert cl.steal().tasks[0][0] == "a"
    cl.transfer("a", new_deps=["b"])       # cycle a->b->a
    assert isinstance(cl.steal(), NotFound)
    assert not srv._all_done()


def test_steal_n_batching():
    srv, cl = mkclient()
    for i in range(10):
        cl.create(f"t{i}")
    r = cl.steal(n=4)
    assert len(r.tasks) == 4


def test_lease_timeout_requeues_stragglers():
    srv = TaskServer(lease_timeout=0.0)    # immediate expiry
    cl = Client(InProcTransport(srv), "slow")
    cl.create("a")
    assert cl.steal().tasks[0][0] == "a"
    cl2 = Client(InProcTransport(srv), "fast")
    r = cl2.steal()                        # straggler's task re-stolen
    assert isinstance(r, TaskMsg) and r.tasks[0][0] == "a"


def test_lease_requeue_front_once_no_double_complete():
    """Regression: an expired lease re-queues the task to the FRONT of the
    deque and bumps counters["requeued"] exactly once; when the straggling
    worker later Completes, the stale ready entry must NOT be served (and
    so never double-executed)."""
    clock = {"now": 0.0}
    srv = TaskServer(lease_timeout=1.0, clock=lambda: clock["now"])
    slow = Client(InProcTransport(srv), "slow")
    slow.create("a")
    slow.create("b")
    assert slow.steal().tasks[0][0] == "a"
    clock["now"] = 2.0                     # lease on "a" expires
    fast = Client(InProcTransport(srv), "fast")
    r = fast.steal()                       # reap requeues "a" to the FRONT
    assert r.tasks[0][0] == "a"            # ahead of "b" (LIFO re-insert)
    assert srv.counters["requeued"] == 1
    # straggler finally reports Complete — must be idempotent
    slow.complete("a")
    assert srv.counters["requeued"] == 1   # no double-requeue
    assert srv.counters["completed"] == 1  # completed exactly once
    assert fast.steal().tasks[0][0] == "b"
    fast.complete("b")
    assert isinstance(fast.steal(), ExitResp)


def test_lease_requeue_stale_entry_never_served():
    """Regression for the double-execution variant: lease expires, task is
    requeued, the straggler Completes BEFORE anyone re-steals — the stale
    ready entry must be skipped, not served again."""
    clock = {"now": 0.0}
    srv = TaskServer(lease_timeout=1.0, clock=lambda: clock["now"])
    slow = Client(InProcTransport(srv), "slow")
    slow.create("a")
    assert slow.steal().tasks[0][0] == "a"
    clock["now"] = 2.0
    fast = Client(InProcTransport(srv), "fast")
    srv._reap_leases()                     # "a" back on the ready deque
    assert srv.counters["requeued"] == 1
    slow.complete("a")                     # late completion wins
    r = fast.steal()                       # stale "a" must be skipped
    assert isinstance(r, ExitResp)         # all done; "a" not re-served
    assert srv.counters["completed"] == 1
    assert srv.counters["stolen"] == 1     # stolen once, ever


def test_persistence_reconstructs_ready():
    srv, cl = mkclient()
    cl.create("a")
    cl.create("b", deps=["a"])
    cl.steal()
    cl.complete("a")
    path = tempfile.mktemp()
    srv.save(path)
    srv2 = TaskServer.load(path)
    cl2 = Client(InProcTransport(srv2), "w1")
    assert cl2.steal().tasks[0][0] == "b"
    cl2.complete("b")
    assert isinstance(cl2.steal(), ExitResp)


# ------------------------------------------------------------ property

dag_strategy = st.lists(
    st.tuples(st.integers(0, 19), st.lists(st.integers(0, 19), max_size=3)),
    min_size=1, max_size=20)


@given(dag_strategy)
@settings(max_examples=60, deadline=None)
def test_deps_always_served_first(edges):
    """Fundamental safety: no task is ever served before all its (earlier-
    indexed => acyclic) dependencies completed."""
    srv, cl = mkclient()
    names = []
    for i, (node, deps) in enumerate(edges):
        name = f"n{i}"
        dep_names = [f"n{d}" for d in deps if d < i]
        cl.create(name, deps=dep_names)
        names.append((name, set(dep_names)))
    completed = set()
    order = []
    while True:
        r = cl.steal()
        if not isinstance(r, TaskMsg):
            break
        for name, _ in r.tasks:
            dep = dict(names).get(name, set())
            assert dep <= completed, (name, dep, completed)
            cl.complete(name)
            completed.add(name)
            order.append(name)
    assert len(order) == len({n for n, _ in names})


@given(dag_strategy, st.integers(1, 4), st.integers(0, 10))
@settings(max_examples=40, deadline=None)
def test_exactly_once_under_crashes(edges, n_workers, crash_after):
    """Tasks complete exactly once even when a worker crashes mid-run and
    its assignment is recycled."""
    srv = TaskServer()
    clients = [Client(InProcTransport(srv), f"w{i}") for i in range(n_workers)]
    for i, (node, deps) in enumerate(edges):
        clients[0].create(f"n{i}", deps=[f"n{d}" for d in deps if d < i])
    done = []
    crashed = False
    rounds = 0
    while rounds < 1000:
        rounds += 1
        progress = False
        for w, cl in enumerate(clients):
            r = cl.steal()
            if isinstance(r, TaskMsg):
                progress = True
                for name, _ in r.tasks:
                    if not crashed and w == 0 and len(done) >= crash_after:
                        cl.exit()          # crash before completing
                        crashed = True
                        break
                    cl.complete(name)
                    done.append(name)
        if not progress and srv._all_done():
            break
    n_tasks = len({f"n{i}" for i in range(len(edges))})
    assert sorted(set(done)) == sorted(done), "task completed twice"
    assert len(done) == n_tasks


@given(st.integers(1, 30), st.integers(1, 6))
@settings(max_examples=30, deadline=None)
def test_counts_conserved(n_tasks, steal_n):
    srv, cl = mkclient()
    for i in range(n_tasks):
        cl.create(f"t{i}")
    order = drain(cl, steal_n=steal_n)
    st_ = srv.stats()
    assert st_["completed"] == n_tasks == len(order)
    assert st_["ready"] == 0 and st_["assigned"] == 0
