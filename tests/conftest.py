"""Shared test fixtures.  NOTE: no XLA_FLAGS here — smoke tests and benches
must see the real single CPU device; only launch/dryrun.py forces 512."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


def tiny_batch(cfg, B=2, S=64, key=None):
    key = key if key is not None else jax.random.PRNGKey(7)
    batch = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size)}
    if cfg.mrope:
        batch["mrope_positions"] = jnp.broadcast_to(
            jnp.arange(S)[None, None], (3, B, S))
    if cfg.family == "audio":
        batch["encoder_frames"] = jax.random.normal(
            key, (B, cfg.encoder.n_frames, cfg.d_model)).astype(jnp.bfloat16)
    return batch
