"""mpi-list unit + property tests: the partition law and the monoid/functor
laws the DFM must satisfy (paper §2.3)."""
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.mpi_list import Context, partition_bounds


@given(st.integers(0, 500), st.integers(1, 32))
def test_partition_law(N, P):
    """Exactly the paper's rule: start = p*(N//P) + min(p, N%P); blocks are
    contiguous, ascending, and cover [0, N)."""
    spans = [partition_bounds(N, P, p) for p in range(P)]
    assert spans[0][0] == 0
    for (s0, e0), (s1, e1) in zip(spans, spans[1:]):
        assert e0 == s1
    assert spans[-1][1] == N
    sizes = [e - s for s, e in spans]
    assert max(sizes) - min(sizes) <= 1          # balanced


@given(st.integers(0, 200), st.integers(1, 16))
def test_iterates_collect_roundtrip(N, P):
    dfm = Context(P).iterates(N)
    dfm.check_partition_law()
    assert dfm.collect() == list(range(N))


@given(st.lists(st.integers(-100, 100), max_size=100), st.integers(1, 8))
def test_map_functor_law(xs, P):
    C = Context(P)
    f, g = (lambda x: x + 1), (lambda x: x * 2)
    a = C.scatter(xs).map(f).map(g).collect()
    b = C.scatter(xs).map(lambda x: g(f(x))).collect()
    assert a == b == [g(f(x)) for x in xs]


@given(st.lists(st.integers(-50, 50), max_size=80), st.integers(1, 8))
def test_reduce_and_scan(xs, P):
    C = Context(P)
    dfm = C.scatter(xs)
    assert dfm.reduce(lambda a, b: a + b, 0) == sum(xs)
    prefix = dfm.scan(lambda a, b: a + b, 0).collect()
    assert prefix == list(np.cumsum(xs)) if xs else prefix == []


@given(st.lists(st.integers(0, 1000), max_size=80), st.integers(1, 8),
       st.integers(1, 5))
def test_group_conserves_elements(xs, P, K):
    C = Context(P)
    g = C.scatter(xs).group(lambda x: {x % K: [x]},
                            lambda p, recs: sorted(recs))
    regrouped = sorted(sum(g.collect(), []))
    assert regrouped == sorted(xs)


@given(st.lists(st.lists(st.integers(), max_size=20), max_size=10),
       st.integers(1, 6))
def test_repartition_balances(chunks, P):
    C = Context(P)
    dfm = C.scatter(chunks)
    out = dfm.repartition(len, lambda x, n: [[e] for e in x],
                          lambda cs: [e for c in cs for e in c])
    flat = [e for blk in out.parts for x in blk for e in x]
    assert flat == [e for c in chunks for e in c]
    # per-rank record counts follow the partition law
    N = sum(len(c) for c in chunks)
    for p, blk in enumerate(out.parts):
        s, e = partition_bounds(N, P, p)
        got = sum(len(x) for x in blk)
        assert got == e - s


def test_flatmap_and_filter():
    C = Context(3)
    out = (C.iterates(10)
           .flatMap(lambda x: [x, x])
           .filter(lambda x: x % 2 == 0)
           .collect())
    assert out == [x for i in range(10) for x in (i, i) if x % 2 == 0]


def test_straggler_accounting():
    """BSP sync time = slowest minus fastest rank (the mpi-list METG)."""
    C = Context(4, jitter=lambda p: 0.01 * p)
    C.iterates(16).map(lambda x: x)
    assert C.sync_time >= 0.029


def test_mesh_bridge_single_device():
    import jax
    from repro.core.mpi_list import mesh_ops
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    dfm = mesh_ops.iterates(mesh, 32)
    out = mesh_ops.dfm_map(mesh, lambda x: x * x, dfm)
    assert int(mesh_ops.dfm_sum(mesh, out)) == sum(i * i for i in range(32))
    sc = mesh_ops.dfm_scan(mesh, lambda a, b: a + b, dfm)
    assert int(sc[-1]) == sum(range(32))
    import jax.numpy as jnp
    dest = jnp.asarray([i % 3 for i in range(32)])
    grouped = mesh_ops.group(mesh, dest, dfm)
    assert sorted(np.asarray(grouped).tolist()) == list(range(32))
