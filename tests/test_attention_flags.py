"""Perf-lever correctness: every §Perf optimization flag must be exact (or
within bf16 tolerance) vs the plain path."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config
from repro.models.attention import (decode_attention, expand_kv,
                                    flash_attention, head_mask, head_padding)
from repro.models.mla import init_mla, mla_decode, mla_forward
from repro.models.rope import rope_angles

KEY = jax.random.PRNGKey(0)


def _qkv(B=2, S=256, H=4, hd=64, dtype=jnp.float32):
    ks = jax.random.split(KEY, 3)
    return (jax.random.normal(ks[0], (B, S, H, hd), dtype),
            jax.random.normal(ks[1], (B, S, H, hd), dtype),
            jax.random.normal(ks[2], (B, S, H, hd), dtype))


def test_static_skip_exact():
    q, k, v = _qkv()
    a = flash_attention(q, k, v, causal=True, scale=0.125, q_block=64,
                        kv_block=64)
    b = flash_attention(q, k, v, causal=True, scale=0.125, q_block=64,
                        kv_block=64, skip_masked_blocks=True)
    assert float(jnp.max(jnp.abs(a - b))) == 0.0


def test_cond_skip_with_window():
    q, k, v = _qkv()
    a = flash_attention(q, k, v, causal=True, window=96, scale=0.125,
                        q_block=64, kv_block=64)
    b = flash_attention(q, k, v, causal=True, window=96, scale=0.125,
                        q_block=64, kv_block=64, skip_masked_blocks=True)
    assert float(jnp.max(jnp.abs(a - b))) < 1e-6


def test_probs_bf16_close():
    q, k, v = _qkv(dtype=jnp.bfloat16)
    a = flash_attention(q, k, v, causal=True, scale=0.125, q_block=64,
                        kv_block=64)
    b = flash_attention(q, k, v, causal=True, scale=0.125, q_block=64,
                        kv_block=64, probs_bf16=True)
    err = float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                - b.astype(jnp.float32))))
    assert err < 0.05, err


def test_head_padding_math():
    cfg = get_config("qwen2.5-32b")
    hq_pad, m_pad = head_padding(cfg)
    assert hq_pad % 16 == 0 and hq_pad == cfg.n_kv_heads * m_pad
    assert hq_pad >= cfg.n_heads
    mask = head_mask(cfg)
    assert int(mask.sum()) == cfg.n_heads
    for name in ("deepseek-67b", "gemma2-2b", "whisper-base", "qwen2-vl-2b",
                 "arctic-480b", "deepseek-7b", "zamba2-2.7b"):
        c = get_config(name)
        hp, mp = head_padding(c)
        assert hp % 16 == 0 and hp == c.n_kv_heads * mp and hp >= c.n_heads


def test_expand_kv_group_major():
    k = jnp.arange(2 * 3 * 4 * 2, dtype=jnp.float32).reshape(2, 3, 4, 2)
    e = expand_kv(k, 8)                      # M_pad = 2
    assert e.shape == (2, 3, 8, 2)
    assert bool(jnp.all(e[:, :, 0] == e[:, :, 1]))   # same group
    assert bool(jnp.all(e[:, :, 0] == k[:, :, 0]))


def test_decode_grouped_einsum_vs_expanded_ref():
    """decode_attention (grouped, cache never expanded) == expanded one-shot."""
    from repro.models.attention import attend_once
    B, T, G, hd, Hq = 2, 64, 2, 32, 8
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, 1, Hq, hd))
    kc = jax.random.normal(ks[1], (B, T, G, hd))
    vc = jax.random.normal(ks[2], (B, T, G, hd))
    pos = jnp.asarray([T - 1, T // 2])
    out = decode_attention(q, kc, vc, pos, scale=hd ** -0.5)
    allow = jnp.arange(T)[None, :] <= pos[:, None]
    ref = attend_once(q, expand_kv(kc, Hq), expand_kv(vc, Hq),
                      mask=allow[:, None, None, :], scale=hd ** -0.5)
    assert float(jnp.max(jnp.abs(out - ref))) < 1e-4


def test_mla_absorb_equivalence():
    """Absorbed MLA decode (the §Perf serving path) == naive decompression."""
    cfg = get_config("deepseek-v2-lite-16b").reduced()
    p = init_mla(KEY, cfg, 0)
    B, T = 2, 32
    x = jax.random.normal(jax.random.PRNGKey(3), (B, 1, cfg.d_model),
                          jnp.float32)
    m = cfg.mla
    cache = (jax.random.normal(jax.random.PRNGKey(4), (B, T, m.kv_lora_rank)),
             jax.random.normal(jax.random.PRNGKey(5),
                               (B, T, m.qk_rope_head_dim)))
    pos = jnp.asarray([10, 20])
    sin, cos = rope_angles(pos[:, None], m.qk_rope_head_dim, cfg.rope_theta)
    o1, c1 = mla_decode(p, x, cfg, sin, cos, cache, pos, absorb=False)
    o2, c2 = mla_decode(p, x, cfg, sin, cos, cache, pos, absorb=True)
    assert float(jnp.max(jnp.abs(o1 - o2))) < 1e-3
    for a, b in zip(c1, c2):
        assert float(jnp.max(jnp.abs(a - b))) == 0.0


def test_grad_cast_guards_cotangent_dtype():
    from repro.models.common import grad_cast

    def f(x):
        y = grad_cast(x)                      # x bf16
        return jnp.sum(y.astype(jnp.float32) ** 2)

    x = jnp.ones((4,), jnp.bfloat16)
    g = jax.grad(f)(x)
    assert g.dtype == jnp.bfloat16
