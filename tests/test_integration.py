"""Integration tests: training loss decreases, microbatch equivalence,
elastic pool crash recovery, data pipeline determinism, serving."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import RunConfig, get_config
from repro.data.pipeline import Pipeline
from repro.models.common import Options
from repro.models.model import build_model
from repro.optim.adamw import init_opt
from repro.runtime.elastic import ElasticPool
from repro.runtime.train_step import make_train_step


def test_training_loss_decreases():
    cfg = get_config("deepseek-7b").reduced()
    model = build_model(cfg, Options(q_block=32, kv_block=32))
    rc = RunConfig(lr=1e-3, total_steps=15, warmup_steps=2)
    params = model.init(jax.random.PRNGKey(0))
    opt = init_opt(params, rc)
    pipe = Pipeline(cfg.vocab_size, 64, 4, seed=0)
    step = jax.jit(make_train_step(model, rc), donate_argnums=(0, 1))
    losses = []
    for batch in pipe.batches(15):
        jb = {k: jnp.asarray(v) for k, v in batch.items()}
        params, opt, m = step(params, opt, jb)
        losses.append(float(m["loss"]))
    assert np.isfinite(losses).all()
    assert np.mean(losses[-3:]) < np.mean(losses[:3])


def test_microbatch_grad_equivalence():
    """mb=1 and mb=2 produce (nearly) the same update."""
    cfg = get_config("deepseek-7b").reduced()
    model = build_model(cfg, Options(q_block=32, kv_block=32))
    params = model.init(jax.random.PRNGKey(0))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (4, 64),
                                          0, cfg.vocab_size)}
    batch["labels"] = jnp.roll(batch["tokens"], -1, 1)
    outs = {}
    for mb in (1, 2):
        rc = RunConfig(microbatches=mb, total_steps=10, warmup_steps=0)
        opt = init_opt(params, rc)
        p2, _, m = jax.jit(make_train_step(model, rc))(params, opt, batch)
        outs[mb] = (p2, float(m["loss"]))
    l1 = jax.tree_util.tree_leaves(outs[1][0])
    l2 = jax.tree_util.tree_leaves(outs[2][0])
    max_d = max(float(jnp.max(jnp.abs(a - b))) for a, b in zip(l1, l2))
    assert max_d < 5e-2, max_d
    assert abs(outs[1][1] - outs[2][1]) < 0.1


def test_pipeline_deterministic():
    p1 = Pipeline(512, 32, 4, seed=3)
    p2 = Pipeline(512, 32, 4, seed=3)
    b1 = next(iter(p1.batches(1)))
    b2 = next(iter(p2.batches(1)))
    assert np.array_equal(b1["tokens"], b2["tokens"])
    # labels are next-token shifted
    assert np.array_equal(b1["labels"][:, :-1], b1["tokens"][:, 1:])


def test_elastic_pool_crash_recovery():
    with ElasticPool(lease_timeout=5.0, per_task_s=0.001) as pool:
        for i in range(30):
            pool.submit(f"step{i}")
        seen = []
        pool.start_worker("w_bad", lambda n, m: seen.append(n) or True,
                          fail_after=3)
        pool.start_worker("w_ok", lambda n, m: seen.append(n) or True)
        stats = pool.join(timeout=30)
        assert stats["completed"] == 30
        assert stats["requeued"] >= 1      # the crashed worker's stolen tasks


def test_elastic_remesh_called():
    calls = []
    with ElasticPool(remesh=lambda n: calls.append(n)) as pool:
        pool.submit("a")
        pool.start_worker("w0", lambda n, m: True)
        pool.join(timeout=10)
        pool.lose_worker("w0")
        assert calls == [1, 0]


def test_greedy_generate_prefill_decode_consistency():
    from repro.runtime.serve_step import greedy_generate
    cfg = get_config("deepseek-7b").reduced()
    model = build_model(cfg, Options(q_block=32, kv_block=32))
    params = model.init(jax.random.PRNGKey(0))
    B, S = 2, 32
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(2), (B, S),
                                          2, cfg.vocab_size)}
    out = greedy_generate(model, params, batch, max_new=4, cache_len=S + 8)
    assert out.shape == (B, 4)
    # pure-forward re-derivation of the first generated token
    logits, _ = jax.jit(lambda p, b: model.forward(p, b))(params, batch)
    tok0 = jnp.argmax(logits[:, -1, :cfg.vocab_size], -1)
    assert bool(jnp.all(out[:, 0] == tok0))
