"""The docs-examples CI check: every ```python code block in README.md
and docs/*.md must execute green, so the documentation cannot rot —
a snippet that stops matching the code fails the build, not the reader.

Convention: fenced blocks tagged `python` are executable and
self-contained (each runs in a fresh namespace); illustrative material
(shell commands, diagrams, layouts) uses `bash`/`text` fences and is
not executed."""
import re
from pathlib import Path

import pytest

ROOT = Path(__file__).resolve().parents[1]
DOC_FILES = [ROOT / "README.md", *sorted((ROOT / "docs").glob("*.md"))]

FENCE = re.compile(r"```python\n(.*?)```", re.S)


def _blocks():
    for path in DOC_FILES:
        if not path.exists():
            continue
        for i, m in enumerate(FENCE.finditer(path.read_text())):
            yield pytest.param(path.name, m.group(1),
                               id=f"{path.name}:{i}")


PARAMS = list(_blocks())


def test_docs_exist_with_snippets():
    """README.md and docs/ are part of the repo contract — and they must
    contain executable quickstarts, not just prose."""
    assert (ROOT / "README.md").exists()
    assert (ROOT / "docs" / "architecture.md").exists()
    assert (ROOT / "docs" / "tuning.md").exists()
    docs_with_code = {doc for doc, _code in
                      (p.values for p in PARAMS)}
    assert {"README.md", "architecture.md", "tuning.md"} <= docs_with_code


@pytest.mark.parametrize("doc,code", PARAMS)
def test_doc_snippet_executes(doc, code):
    exec(compile(code, f"<{doc}>", "exec"),
         {"__name__": "__doc_snippet__"})
