"""Optimizer + checkpoint tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import ckpt
from repro.configs import RunConfig
from repro.optim.adamw import (adamw_update, clip_by_global_norm, init_opt,
                               lr_schedule)


def test_adamw_converges_quadratic():
    rc = RunConfig(lr=0.1, weight_decay=0.0, warmup_steps=0, total_steps=200,
                   grad_clip=0.0)
    params = {"w": jnp.asarray([3.0, -2.0]), "b": jnp.asarray(5.0)}
    opt = init_opt(params, rc)

    def loss(p):
        return jnp.sum(p["w"] ** 2) + p["b"] ** 2

    for _ in range(150):
        g = jax.grad(loss)(params)
        params, opt, _ = adamw_update(g, opt, params, rc)
    assert float(loss(params)) < 1e-2


def test_grad_clip():
    g = {"a": jnp.ones((10,)) * 100.0}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert abs(float(jnp.linalg.norm(clipped["a"])) - 1.0) < 1e-5
    assert float(norm) > 100.0


def test_lr_schedule_warmup_cosine():
    rc = RunConfig(lr=1e-3, warmup_steps=10, total_steps=100)
    lrs = [float(lr_schedule(jnp.asarray(s), rc)) for s in range(100)]
    assert lrs[0] < lrs[9] <= rc.lr * 1.001
    assert lrs[99] < lrs[50] < lrs[12]


def test_quantized_adam_state_dtype():
    rc = RunConfig(adam_state_dtype="bfloat16")
    params = {"w": jnp.zeros((4, 4))}
    opt = init_opt(params, rc)
    assert opt.m["w"].dtype == jnp.bfloat16


def test_zero1_spec():
    from jax.sharding import PartitionSpec as P
    from repro.runtime.sharding import zero1_spec
    sp = zero1_spec(P(None, None, "model"), (64, 512, 1024), 16)
    assert sp == P("data", None, "model")
    # no dim divisible -> unchanged
    sp2 = zero1_spec(P("model"), (100,), 16)
    assert sp2 == P("model")


def test_checkpoint_roundtrip(tmp_path):
    tree = {"params": {"w": jnp.arange(12.0).reshape(3, 4)},
            "step": jnp.asarray(7)}
    ckpt.save(str(tmp_path), 7, tree, {"note": "x"})
    assert ckpt.latest_step(str(tmp_path)) == 7
    abs_tree = jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)
    back = ckpt.restore(str(tmp_path), 7, abs_tree)
    assert np.allclose(back["params"]["w"], tree["params"]["w"])
    assert int(back["step"]) == 7


def test_checkpoint_async_and_retention(tmp_path):
    c = ckpt.AsyncCheckpointer(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        c.save(s, {"x": jnp.asarray(float(s))})
    c.wait()
    steps = sorted(int(p.name.split("_")[1])
                   for p in tmp_path.glob("step_*"))
    assert steps == [3, 4]


def test_checkpoint_elastic_reshard(tmp_path):
    """Restore onto a mesh (sharded placement) from a plain host save."""
    from jax.sharding import PartitionSpec as P
    tree = {"w": jnp.arange(16.0).reshape(4, 4)}
    ckpt.save(str(tmp_path), 1, tree)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    abs_tree = {"w": jax.ShapeDtypeStruct((4, 4), jnp.float32)}
    back = ckpt.restore(str(tmp_path), 1, abs_tree, mesh=mesh,
                        spec_tree={"w": P(None, "model")})
    assert np.allclose(back["w"], tree["w"])
    assert back["w"].sharding.spec == P(None, "model")
