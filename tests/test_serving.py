"""Continuous-serving subsystem tests: resident engine lifecycle
(submit-while-running, drain-to-empty, dynamic membership, WorkerCrash
requeue), frontend admission/backpressure, METG-aware dynamic batching,
and per-request latency accounting from the trace."""
import threading
import time

import pytest

from repro.core.engine import (REQ_DONE, REQ_ENQUEUED, REQUEUED, Engine,
                               FaultPlan, LatencyReport, ManualClock,
                               TraceRecorder, WorkerCrash, percentile)
from repro.core.metg import METGModel
from repro.core.serving import AdmissionFull, Frontend


# ------------------------------------------------------- resident engine


def test_resident_submit_while_running_and_drain_to_empty():
    eng = Engine(workers=2, resident=True)
    eng.start()
    try:
        seen = []
        for i in range(50):
            eng.submit(f"a{i}", fn=lambda i=i: seen.append(i))
        assert eng.drain(timeout=30)
        assert len(seen) == 50
        # the pool is still live: a second wave after a full drain
        for i in range(50):
            eng.submit(f"b{i}", fn=lambda i=i: seen.append(i))
        assert eng.drain(timeout=30)
        assert len(seen) == 100
    finally:
        rep = eng.shutdown()
    assert len(rep.completed) == 100 and not rep.stalled


def test_resident_submit_with_deps_while_running():
    eng = Engine(workers=2, resident=True)
    eng.start()
    try:
        order = []
        eng.submit("root", fn=lambda: order.append("root"))
        eng.submit("mid", fn=lambda: order.append("mid"), deps=["root"])
        eng.submit("leaf", fn=lambda: order.append("leaf"), deps=["mid"])
        assert eng.drain(timeout=30)
        assert order == ["root", "mid", "leaf"]
    finally:
        eng.shutdown()


def test_resident_shutdown_without_work_is_clean():
    eng = Engine(workers=2, resident=True)
    eng.start()
    rep = eng.shutdown()
    assert not rep.stalled and rep.results == {}


def test_resident_failure_poisons_dependents_and_drain_completes():
    eng = Engine(workers=1, resident=True)
    eng.start()
    try:
        eng.submit("bad", fn=lambda: 1 / 0)
        eng.submit("child", fn=lambda: None, deps=["bad"])
        assert eng.drain(timeout=30)     # poisoned tasks count as terminal
        # a dependent submitted AFTER the failure fails engine-side too
        eng.submit("late", fn=lambda: None, deps=["bad"])
        assert eng.drain(timeout=30)
    finally:
        rep = eng.shutdown()
    assert not rep.results["bad"].ok
    assert "child" in rep.errors
    assert "child" not in rep.completed and "late" not in rep.completed


def test_resident_worker_crash_requeues_in_flight_zero_loss():
    eng = Engine(workers=0, resident=True, steal_n=4)
    done = {}

    def execute(name, meta, worker):
        if worker == "bad" and done.get("bad", 0) >= 2:
            raise WorkerCrash("drill")
        done[worker] = done.get(worker, 0) + 1
        return True

    eng.start(execute, pass_worker=True)
    try:
        eng.add_worker("bad")
        eng.add_worker("ok")
        for i in range(40):
            eng.submit(f"t{i}")
        assert eng.drain(timeout=30)
    finally:
        rep = eng.shutdown()
    assert len(rep.completed) == 40          # zero loss
    assert done["bad"] == 2
    assert rep.trace.count("worker_dead") == 1
    requeued = sum(e.extra.get("n", 1) for e in rep.trace.of(REQUEUED))
    assert requeued >= 1                     # the in-flight steal came back
    assert eng.live_workers() == 1


def test_resident_fault_plan_kill_mid_stream():
    faults = FaultPlan(0).kill_worker("w1", after_steals=3)
    eng = Engine(workers=4, resident=True, steal_n=2, faults=faults)
    eng.start()
    try:
        for i in range(100):
            eng.submit(f"t{i}", fn=lambda: None)
        assert eng.drain(timeout=30)
    finally:
        rep = eng.shutdown()
    assert len(rep.completed) == 100
    assert rep.trace.count("worker_dead") == 1
    assert eng.live_workers() == 3


def test_resident_lose_worker_recycles_and_membership_shrinks():
    eng = Engine(workers=3, resident=True)
    eng.start()
    try:
        eng.lose_worker("w0")
        for i in range(30):
            eng.submit(f"t{i}", fn=lambda: None)
        assert eng.drain(timeout=30)
    finally:
        rep = eng.shutdown()
    assert len(rep.completed) == 30
    assert eng.live_workers() == 2
    assert all(r.worker != "w0" for r in rep.results.values())


def test_resident_dynamic_steal_n_applies_mid_run():
    """The loop re-reads self.steal_n every round (elastic retunes it on
    membership change): larger batches -> strictly fewer round-trips."""

    def rpcs(steal_n):
        eng = Engine(workers=1, resident=True)
        eng.steal_n = steal_n            # mutated after construction
        eng.start()
        for i in range(200):
            eng.submit(f"t{i}", fn=lambda: None)
        assert eng.drain(timeout=30)
        return eng.shutdown().overhead().n_rpc

    assert rpcs(8) < rpcs(1)


def test_resident_duplicate_task_name_rejected_not_wedged():
    """A duplicate Create is a server-side no-op, so silently accepting
    it would leak an _inflight slot and hang drain() forever."""
    eng = Engine(workers=1, resident=True)
    eng.start()
    try:
        eng.submit("t", fn=lambda: None)
        with pytest.raises(ValueError):
            eng.submit("t", fn=lambda: None)
        assert eng.drain(timeout=30)         # the leak would hang this
    finally:
        rep = eng.shutdown()
    assert len(rep.completed) == 1


def test_resident_worker_rejoins_under_old_id_after_loss():
    eng = Engine(workers=0, resident=True)
    eng.start()
    try:
        eng.add_worker("w_a")
        eng.lose_worker("w_a")
        eng.add_worker("w_a")                # recovered node, same id
        for i in range(20):
            eng.submit(f"t{i}", fn=lambda: None)
        assert eng.drain(timeout=30)
        assert eng.live_workers() == 1
    finally:
        rep = eng.shutdown()
    assert len(rep.completed) == 20


def test_batch_mode_rejects_resident_api():
    eng = Engine(workers=1)
    with pytest.raises(RuntimeError):
        eng.start()
    with pytest.raises(RuntimeError):
        eng.shutdown()


# ------------------------------------------------------------- frontend


def _echo_frontend(eng, **kw):
    return Frontend(eng, lambda ps: [p * 2 for p in ps], **kw)


def test_frontend_serves_and_traces_latency():
    eng = Engine(workers=4, resident=True, steal_n=4)
    fe = _echo_frontend(eng, max_wait_s=0.002, max_batch=16,
                        per_request_s0=2e-6, max_queue=512)
    fe.start()
    reqs = [fe.submit(i) for i in range(300)]
    for r in reqs:
        assert r.wait(30), f"{r} never completed"
    assert all(r.ok for r in reqs)
    assert [r.value for r in reqs] == [2 * i for i in range(300)]
    fe.close()
    rep = eng.shutdown()
    lat = rep.overhead().requests
    assert lat is not None and lat.n_requests == 300
    assert lat.n_batches >= 1 and lat.mean_batch > 1.0   # real coalescing
    assert 0.0 < lat.p50_s <= lat.p95_s <= lat.p99_s <= lat.max_s
    assert all(r.latency_s > 0 for r in reqs)


def test_frontend_zero_loss_across_worker_kill():
    faults = FaultPlan(0).kill_worker("w1", after_steals=4)
    eng = Engine(workers=4, resident=True, steal_n=2, faults=faults)
    fe = _echo_frontend(eng, max_wait_s=0.001, max_batch=8,
                        per_request_s0=2e-6, max_queue=512)
    fe.start()
    reqs = [fe.submit(i) for i in range(200)]
    for r in reqs:
        assert r.wait(30), "request lost across worker death"
    assert all(r.ok and r.value == 2 * i for i, r in enumerate(reqs))
    fe.close()
    rep = eng.shutdown()
    assert rep.trace.count("worker_dead") == 1
    assert sum(e.extra.get("n", 1) for e in rep.trace.of(REQUEUED)) >= 1
    assert rep.overhead().requests.n_requests == 200


def test_frontend_reject_backpressure_when_queue_full():
    eng = Engine(workers=1, resident=True)
    fe = _echo_frontend(eng, max_queue=4, policy="reject")
    # coalescer not started: the queue only fills
    for i in range(4):
        fe.submit(i)
    with pytest.raises(AdmissionFull):
        fe.submit(99)
    assert fe.rejected == 1
    assert eng.tracer.count("req_rejected") == 1


def test_frontend_block_backpressure_times_out_then_recovers():
    eng = Engine(workers=1, resident=True)
    fe = _echo_frontend(eng, max_queue=2, policy="block")
    fe.submit(0)
    fe.submit(1)
    with pytest.raises(AdmissionFull):
        fe.submit(2, timeout=0.05)
    # start serving: space frees and a blocked submit goes through
    fe.start()
    r = fe.submit(3, timeout=10.0)
    assert r.wait(10) and r.ok
    fe.close()
    eng.shutdown()


def test_frontend_max_wait_deadline_ships_partial_batch():
    eng = Engine(workers=1, resident=True)
    fe = _echo_frontend(eng, max_wait_s=0.01, max_batch=64,
                        per_request_s0=1e-7)  # target >> 1: deadline rules
    fe.start()
    t0 = time.perf_counter()
    r = fe.submit(21)
    assert r.wait(10) and r.value == 42
    assert time.perf_counter() - t0 < 5.0
    fe.close()
    eng.shutdown()


def test_frontend_batch_target_adapts_to_workers_and_observed_time():
    eng = Engine(workers=4, resident=True)
    fe = _echo_frontend(eng, max_batch=4096, per_request_s0=1e-6)
    # dwork METG(P) = rtt*P: more live workers -> bigger batches needed
    four = fe.target_batch()
    eng._live = 16
    sixteen = fe.target_batch()
    assert sixteen == pytest.approx(4 * four, rel=0.01)
    # slower observed per-request time -> smaller batches suffice
    fe._per_req_s = 1e-3
    assert fe.target_batch() < sixteen


def test_frontend_execute_error_fails_requests_not_hangs():
    eng = Engine(workers=1, resident=True)
    fe = Frontend(eng, lambda ps: 1 / 0, max_wait_s=0.001)
    fe.start()
    r = fe.submit(1)
    assert r.wait(10)
    assert not r.ok and "ZeroDivisionError" in r.error
    fe.close()
    rep = eng.shutdown()
    lat = rep.overhead().requests
    assert lat.n_requests == 1 and lat.n_failed == 1


def test_frontend_flush_dispatches_below_target():
    eng = Engine(workers=1, resident=True)
    fe = _echo_frontend(eng, max_wait_s=60.0, max_batch=64,
                        per_request_s0=1e-7)  # huge target + deadline
    fe.start()
    r = fe.submit(5)
    fe.flush()
    assert r.wait(10) and r.value == 10
    fe.close()
    eng.shutdown()


# ----------------------------------------------------- latency accounting


def test_percentile_interpolation():
    xs = sorted([10.0, 20.0, 30.0, 40.0])
    assert percentile(xs, 0.0) == 10.0
    assert percentile(xs, 1.0) == 40.0
    assert percentile(xs, 0.5) == 25.0
    assert percentile([], 0.5) == 0.0
    assert percentile([7.0], 0.99) == 7.0


def test_latency_report_from_synthetic_trace_deterministic():
    clk = ManualClock(tick=0.0)
    tr = TraceRecorder(clock=clk)
    for i, lat in enumerate([0.001, 0.002, 0.003, 0.004]):
        tr.emit(REQ_ENQUEUED, task=f"r{i}", depth=i + 1)
        tr.emit(REQ_DONE, task=f"r{i}", latency_s=lat, ok=(i != 3))
    tr.emit("batch_formed", task="b1", size=4, wait_s=0.002, depth=0)
    lat = LatencyReport.from_trace(tr)
    assert lat.n_requests == 4 and lat.n_failed == 1 and lat.n_batches == 1
    assert lat.mean_batch == 4.0
    assert lat.p50_s == pytest.approx(0.0025)
    assert lat.max_s == pytest.approx(0.004)
    assert lat.queue_depth_max == 4
    assert lat.batch_wait_mean_s == pytest.approx(0.002)
    s = lat.summary()
    assert s["latency_ms"]["p50"] == pytest.approx(2.5)


def test_elastic_pool_retunes_steal_n_on_membership_change():
    """Satellite regression: batch size must track the LIVE worker count,
    not the count at startup (the module docstring's promise)."""
    from repro.runtime.elastic import ElasticPool
    pool = ElasticPool(per_task_s=1e-6)     # tiny tasks -> visible batching
    pool.start_worker("w_a", lambda n, m: True)
    n1 = pool.engine.steal_n
    pool.start_worker("w_b", lambda n, m: True)
    n2 = pool.engine.steal_n
    assert n2 > n1                          # dwork METG(P) grows with P
    pool.lose_worker("w_b")
    assert pool.engine.steal_n == n1        # shrinks back
    for i in range(20):
        pool.submit(f"t{i}")
    stats = pool.join(timeout=30)
    assert stats["completed"] == 20
    pool.shutdown()


def test_elastic_pool_serves_second_wave_after_join():
    from repro.runtime.elastic import ElasticPool
    pool = ElasticPool(per_task_s=0.001)
    pool.start_worker("w0", lambda n, m: True)
    for i in range(10):
        pool.submit(f"a{i}")
    assert pool.join(timeout=30)["completed"] == 10
    for i in range(10):
        pool.submit(f"b{i}")
    assert pool.join(timeout=30)["completed"] == 20
    pool.shutdown()


def test_frontend_requires_resident_engine():
    with pytest.raises(ValueError):
        Frontend(Engine(workers=1), lambda ps: ps)


def test_concurrent_submitters_thread_safe():
    eng = Engine(workers=4, resident=True, steal_n=4)
    fe = _echo_frontend(eng, max_queue=1024, max_wait_s=0.002,
                        max_batch=32, per_request_s0=2e-6)
    fe.start()
    out = {}

    # payload * 2 on a tuple concatenates: (c, i) -> (c, i, c, i)
    def client_simple(cid):
        rs = [fe.submit((cid, i)) for i in range(50)]
        ok = True
        for i, r in enumerate(rs):
            if not r.wait(30) or not r.ok or r.value != (cid, i, cid, i):
                ok = False
        out[cid] = ok

    threads = [threading.Thread(target=client_simple, args=(c,))
               for c in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    fe.close()
    rep = eng.shutdown()
    assert all(out.values())
    assert rep.overhead().requests.n_requests == 200


# -------------------------------------------------- monitoring snapshots


def test_frontend_snapshot_windows_are_disjoint_and_complete():
    """snapshot() covers exactly the requests resolved since the previous
    snapshot: windows never overlap, never drop, and reset to empty."""
    eng = Engine(workers=2, resident=True, steal_n=2)
    fe = _echo_frontend(eng, max_wait_s=0.002, per_request_s0=2e-6)
    fe.start()
    assert fe.snapshot().n_requests == 0         # priming call arms
    reqs = [fe.submit(i) for i in range(40)]
    fe.flush()
    for r in reqs:
        assert r.wait(30)
    s1 = fe.snapshot()
    assert s1.n_requests == 40
    assert 0.0 < s1.p50_s <= s1.p99_s <= s1.max_s
    assert s1.n_batches >= 1 and s1.window_s > 0.0
    more = [fe.submit(i) for i in range(10)]
    fe.flush()
    for r in more:
        assert r.wait(30)
    s2 = fe.snapshot()
    assert s2.n_requests == 10                   # only the new window
    assert s2.t_s >= s1.t_s
    assert fe.snapshot().n_requests == 0         # empty window is valid
    assert [s.n_requests for s in fe.snapshots] == [0, 40, 10, 0]
    assert "window_s" in s1.summary()
    fe.close()
    eng.shutdown()


def test_frontend_periodic_snapshots_bounded_and_callback():
    seen = []
    eng = Engine(workers=2, resident=True, steal_n=2)
    fe = _echo_frontend(eng, max_wait_s=0.001, per_request_s0=2e-6,
                        snapshot_interval_s=0.02, snapshot_keep=4,
                        on_snapshot=seen.append)
    fe.start()
    reqs = [fe.submit(i) for i in range(30)]
    fe.flush()
    for r in reqs:
        assert r.wait(30)
    deadline = time.time() + 10
    while len(seen) < 5 and time.time() < deadline:
        time.sleep(0.01)
    fe.close()                                   # stops the monitor too
    eng.shutdown()
    assert len(seen) >= 5                        # periodic firing
    assert len(fe.snapshots) <= 4                # bounded deque
    assert sum(s.n_requests for s in seen) == 30 # windows partition traffic
    assert all(s.window_s >= 0.0 for s in seen)


def test_frontend_snapshot_counts_rejections_in_window():
    eng = Engine(workers=1, resident=True)
    fe = _echo_frontend(eng, max_queue=2, policy="reject", max_wait_s=10.0)
    fe.start()
    fe.snapshot()                                # arm monitoring
    fe.submit(1)
    fe.submit(2)
    with pytest.raises(AdmissionFull):
        fe.submit(3)
    snap = fe.snapshot()
    assert snap.n_rejected == 1
    assert fe.snapshot().n_rejected == 0         # window reset
    fe.flush()
    fe.close()
    eng.shutdown()


def test_frontend_close_snapshot_covers_drain_tail():
    """Requests that only resolve during close()'s flush+drain must still
    reach the monitor: the final snapshot is taken AFTER the drain."""
    eng = Engine(workers=2, resident=True, steal_n=2)
    fe = _echo_frontend(eng, max_wait_s=5.0)     # nothing ships until close
    fe.start()
    fe.start_snapshots(60.0)                     # will never fire on its own
    reqs = [fe.submit(i) for i in range(12)]
    fe.close()                                   # flush + drain + snapshot
    eng.shutdown()
    assert all(r.done for r in reqs)
    assert sum(s.n_requests for s in fe.snapshots) == 12
