"""dquery CLI round-trip over a live TCP dhub."""
import threading

from repro.core.dwork.client import TCPServer
from repro.core.dwork.server import TaskServer
from repro.core.dwork import dquery


def test_dquery_roundtrip(capsys):
    srv = TaskServer()
    tcp = TCPServer(("127.0.0.1", 0), srv)
    tcp.serve_background()
    host, port = tcp.server_address
    base = ["--host", host, "--port", str(port)]
    assert dquery.main(base + ["create", "a"]) == 0
    assert dquery.main(base + ["create", "b", "-d", "a"]) == 0
    assert dquery.main(base + ["steal"]) == 0
    out = capsys.readouterr().out
    assert out.strip().splitlines()[-1] == "a"
    assert dquery.main(base + ["complete", "a"]) == 0
    assert dquery.main(base + ["steal"]) == 0
    assert capsys.readouterr().out.strip().splitlines()[-1] == "b"
    assert dquery.main(base + ["complete", "b"]) == 0
    assert dquery.main(base + ["stats"]) == 0
    assert '"completed": 2' in capsys.readouterr().out
    assert dquery.main(base + ["steal"]) == 4          # EXIT: all done
    tcp.shutdown()
