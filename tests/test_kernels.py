"""Per-kernel shape/dtype sweeps: Pallas (interpret=True on CPU) vs the
pure-jnp ref.py oracle (deliverable c)."""
import jax
import jax.numpy as jnp
import pytest

from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.kernels.mamba2_ssd.ops import ssd, ssd_ref, ssd_sequential_ref
from repro.kernels.rwkv6_scan.ops import (wkv6, wkv6_ref,
                                          wkv6_sequential_ref)
from repro.kernels.tiled_matmul.ops import tiled_matmul
from repro.kernels.tiled_matmul.ref import tiled_matmul_ref

KEY = jax.random.PRNGKey(0)


@pytest.mark.parametrize("K,M,N", [(256, 256, 256), (512, 256, 384),
                                   (384, 128, 512), (128, 128, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_tiled_matmul(K, M, N, dtype):
    a = jax.random.normal(KEY, (K, M), jnp.float32).astype(dtype)
    b = jax.random.normal(jax.random.PRNGKey(1), (K, N),
                          jnp.float32).astype(dtype)
    out = tiled_matmul(a, b, bm=128, bn=128, bk=128)
    ref = tiled_matmul_ref(a, b)
    tol = 1e-4 if dtype == jnp.float32 else 0.25
    assert float(jnp.max(jnp.abs(out.astype(jnp.float32)
                                 - ref.astype(jnp.float32)))) < tol


@pytest.mark.parametrize("B,H,S,hd", [(2, 4, 256, 64), (1, 2, 512, 128),
                                      (2, 1, 128, 32)])
@pytest.mark.parametrize("window,cap", [(0, 0.0), (128, 0.0), (0, 50.0)])
def test_flash_attention(B, H, S, hd, window, cap):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, H, S, hd), jnp.float32)
    k = jax.random.normal(ks[1], (B, H, S, hd), jnp.float32)
    v = jax.random.normal(ks[2], (B, H, S, hd), jnp.float32)
    out = flash_attention(q, k, v, window=window, logit_softcap=cap,
                          bq=128, bk=128)
    ref = flash_attention_ref(q, k, v, window=(window or None),
                              logit_softcap=cap)
    assert float(jnp.max(jnp.abs(out - ref))) < 2e-5


@pytest.mark.parametrize("B,S,H,hd", [(2, 128, 4, 32), (1, 64, 2, 64),
                                      (1, 96, 1, 32)])
@pytest.mark.parametrize("chunk", [16, 32])
def test_wkv6(B, S, H, hd, chunk):
    ks = jax.random.split(KEY, 5)
    r = jax.random.normal(ks[0], (B, S, H, hd)) * 0.5
    k = jax.random.normal(ks[1], (B, S, H, hd)) * 0.5
    v = jax.random.normal(ks[2], (B, S, H, hd))
    logw = -jnp.exp(jax.random.normal(ks[3], (B, S, H, hd)) * 0.5 - 1.0)
    u = jax.random.normal(ks[4], (H, hd)) * 0.1
    y_seq, _ = wkv6_sequential_ref(r, k, v, logw, u)
    y_chk, _ = wkv6_ref(r, k, v, logw, u, chunk=chunk)
    y_pal = wkv6(r, k, v, logw, u, chunk=chunk)
    assert float(jnp.max(jnp.abs(y_seq - y_chk))) < 1e-3
    assert float(jnp.max(jnp.abs(y_seq - y_pal))) < 1e-3


@pytest.mark.parametrize("B,S,H,hd,N", [(2, 128, 8, 16, 16),
                                        (1, 64, 4, 32, 8)])
@pytest.mark.parametrize("chunk", [16, 32])
def test_mamba2_ssd(B, S, H, hd, N, chunk):
    ks = jax.random.split(KEY, 4)
    xdt = jax.random.normal(ks[0], (B, S, H, hd)) * 0.5
    dA = -jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    Bc = jax.random.normal(ks[2], (B, S, 1, N)) * 0.5
    Cc = jax.random.normal(ks[3], (B, S, 1, N)) * 0.5
    y_seq, _ = ssd_sequential_ref(xdt, dA, Bc, Cc)
    y_chk, _ = ssd_ref(xdt, dA, Bc, Cc, chunk=chunk)
    y_pal = ssd(xdt, dA, Bc, Cc, chunk=chunk)
    assert float(jnp.max(jnp.abs(y_seq - y_chk))) < 1e-3
    assert float(jnp.max(jnp.abs(y_seq - y_pal))) < 1e-3


def test_flash_kernel_matches_model_blockwise():
    """Kernel, oracle, and the model's blockwise path agree."""
    from repro.models.attention import flash_attention as model_blockwise
    B, H, S, hd = 2, 2, 256, 64
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, S, H, hd), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, H, hd), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, H, hd), jnp.float32)
    blockwise = model_blockwise(q, k, v, causal=True, scale=hd ** -0.5,
                                q_block=64, kv_block=64)
    kern = flash_attention(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                           v.transpose(0, 2, 1, 3), bq=64, bk=64)
    assert float(jnp.max(jnp.abs(blockwise.transpose(0, 2, 1, 3)
                                 - kern))) < 2e-5
